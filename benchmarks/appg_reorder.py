"""App. G: hot–cold reordering vs Ripple-style co-activation reordering.

Paper finding: the two give comparable contiguity gains; hot–cold is the
lightweight winner. We measure the CDF-style contiguity (mean chunk size of
a top-k selection) and chunked-selection latency under both orderings.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChunkConfig,
    ChunkSelector,
    chunk_stats_np,
    coactivation_reordering,
    hot_cold_reordering,
    topk_mask_np,
)

from .common import ImportanceModel, Rows

N, COLS = 2048, 2048  # small matrix: coactivation is O(N^2) calibration-time
SP = 0.4


def run(rows: Rows) -> None:
    rng = np.random.default_rng(13)
    imp = ImportanceModel(rng, N, sigma=1.0, jitter=0.8)
    cal = imp.calibration(20)
    hot = hot_cold_reordering(cal)
    coa = coactivation_reordering(cal)
    sel = ChunkSelector.build(N, COLS * 2, device="nano",
                              cfg=ChunkConfig.for_shape(N, COLS, "nano"))
    v = imp.sample()
    budget = int((1 - SP) * N)

    results = {}
    for name, perm in (("original", np.arange(N)), ("hot_cold", hot.perm),
                       ("coactivation", coa.perm)):
        m = topk_mask_np(v[perm], budget)
        avg, _ = chunk_stats_np(m)
        lat = float(sel.table.mask_latency(jnp.asarray(m)))
        results[name] = (avg, lat)
        rows.add(f"appg/{name}", lat * 1e6, f"avg_chunk={avg:.2f}")
    hc, co = results["hot_cold"], results["coactivation"]
    rows.add("appg/comparable", 0.0,
             f"hotcold_vs_coact_latency={co[1]/max(hc[1],1e-12):.2f}"
             f"(paper: minor difference)")
