"""Fig. 4b: latency vs sparsity for scattered vs contiguous access.

Reproduces the paper's counterintuitive crossover: scattered sparse reads of
a 128 MB matrix (Qwen2-7B MLP scale) can take LONGER than loading everything
contiguously, while block-aligned sparse reads scale with volume.
"""
from __future__ import annotations

import numpy as np

from repro.core import FlashOffloadSimulator

from .common import Rows

N_ROWS = 18944  # Qwen2-7B down-proj rows
ROW_BYTES = 3584 * 2  # ≈ 7 KB → full matrix ≈ 130 MB


def run(rows: Rows) -> None:
    rng = np.random.default_rng(0)
    for device in ("nano", "agx"):
        sim = FlashOffloadSimulator(device, seed=1)
        full = sim.estimate(np.ones(N_ROWS, bool), ROW_BYTES)
        rows.add(f"fig4/{device}/full_load", full * 1e6, "sparsity=0.0")
        crossover = None
        for sp in (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7):
            keep = int((1 - sp) * N_ROWS)
            scattered = np.zeros(N_ROWS, bool)
            scattered[rng.permutation(N_ROWS)[:keep]] = True
            contig = np.zeros(N_ROWS, bool)
            block = 64  # ≈448 KB chunks: saturating
            idx = rng.permutation(N_ROWS // block)[: keep // block]
            for i in idx:
                contig[i * block : (i + 1) * block] = True
            lat_s = sim.estimate(scattered, ROW_BYTES)
            lat_c = sim.estimate(contig, ROW_BYTES)
            rows.add(
                f"fig4/{device}/scattered_sp{sp}",
                lat_s * 1e6,
                f"vs_full={lat_s/full:.2f}x",
            )
            rows.add(
                f"fig4/{device}/contiguous_sp{sp}",
                lat_c * 1e6,
                f"vs_full={lat_c/full:.2f}x",
            )
            if crossover is None and lat_s > full:
                crossover = sp
        rows.add(
            f"fig4/{device}/scattered_slower_than_full",
            0.0,
            f"first_sparsity={crossover}",
        )
