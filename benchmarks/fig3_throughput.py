"""Fig. 3 / Fig. 4a: read throughput vs block size per device profile."""
from __future__ import annotations


from repro.core import JETSON_AGX, JETSON_NANO

from .common import Rows

KB = 1024.0
MB = KB * KB


def run(rows: Rows) -> None:
    for prof in (JETSON_NANO, JETSON_AGX):
        for size_kb in (4, 16, 64, 128, 236, 348, 1024):
            thr = float(prof.throughput_bytes(size_kb * KB)) / MB
            lat = float(prof.latency_bytes(size_kb * KB))
            rows.add(
                f"fig3/{prof.name}/block_{size_kb}KB",
                lat * 1e6,
                f"throughput_MBps={thr:.0f}",
            )
        rows.add(
            f"fig3/{prof.name}/saturation",
            float(prof.latency_bytes(prof.saturation_bytes())) * 1e6,
            f"sat99_KB={prof.saturation_bytes()/KB:.0f}",
        )
