"""Roofline analysis (deliverable g): derive compute/memory/collective terms
per (arch × shape × mesh) from the dry-run's compiled artifacts.

  compute_term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory_term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective_term = collective_bytes_per_device / ICI_bw_per_chip

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (×4 usable links ≈ 2e11 B/s aggregate; we use per-link
conservative 5e10 — documented convention in EXPERIMENTS.md).

MODEL_FLOPS = 6·N·D for training (fwd+bwd), 2·N_active·D for inference,
where D = processed tokens; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs import get_config
from repro.configs.shapes import get_shape

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (conservative single-link convention)


def count_params(cfg) -> Dict[str, float]:
    """Total and active parameter counts (analytic)."""
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd = cfg.resolved_head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.has_moe:
        mlp_total = cfg.n_experts * 3 * d * f
        mlp_active = cfg.moe_top_k * 3 * d * f
        if cfg.moe_shared_expert:
            mlp_total += 3 * d * f
            mlp_active += 3 * d * f
    elif cfg.arch_type == "ssm":
        # xlstm block params approx: up(2di) + qkv(3di^2) + down
        di = 2 * d
        mlp_total = mlp_active = d * 2 * di + 3 * di * di + di * d
        attn = 0
    else:
        n_mats = 3 if cfg.mlp == "swiglu" else 2
        mlp_total = mlp_active = n_mats * d * f
    if cfg.arch_type == "hybrid":
        m_cfg_inner = cfg.ssm_expand * d
        mamba = d * (2 * m_cfg_inner + 2 * cfg.ssm_state + m_cfg_inner // cfg.ssm_head_dim) \
            + m_cfg_inner * d
        shared = attn + 3 * d * f
        total = L * mamba + shared + v * d * 2
        return {"total": total, "active": total}
    layers = L * (attn + mlp_total)
    layers_active = L * (attn + mlp_active)
    if cfg.is_encdec:
        layers += cfg.encoder_layers * (attn + mlp_total) + L * attn  # cross attn
        layers_active = layers
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return {"total": layers + emb, "active": layers_active + emb}


def model_flops(cfg, shape) -> float:
    """Paper-convention useful FLOPs for the whole step (all devices)."""
    p = count_params(cfg)
    n_active = p["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analytic_memory_bytes(cfg, shape, mesh_axes: Dict[str, int]) -> float:
    """First-order per-device HBM traffic for the TPU target (bf16 weights,
    flash-style attention internals VMEM-resident — see EXPERIMENTS.md
    §Roofline conventions)."""
    tp = mesh_axes.get("model", 1)
    dp = mesh_axes.get("data", 1) * mesh_axes.get("pod", 1)
    p = count_params(cfg)
    w_dev = p["total"] * 2.0 / tp  # bf16 TP shard streamed through HBM
    b_dev = max(shape.global_batch // dp, 1)
    d, L = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        s_sp = max(shape.seq_len // tp, 1)  # sequence-parallel residual
        opt_dev = p["total"] / (tp * (dp if cfg.fsdp else 1))
        acts = 6.0 * L * b_dev * s_sp * d * 2.0  # store+read+recompute (remat)
        return 3.0 * w_dev + 24.0 * opt_dev + acts
    if shape.kind == "prefill":
        cache = L * b_dev * shape.seq_len * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * 2.0 / max(tp, 1)
        acts = 3.0 * L * b_dev * max(shape.seq_len // tp, 1) * d * 2.0
        return w_dev + cache + acts
    # decode: weights + cache read once per token
    from repro.models.model import effective_window

    window = effective_window(cfg, shape.seq_len)
    phys = min(shape.seq_len, window) if window else shape.seq_len
    cache = L * b_dev * phys * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * 2.0 / max(tp, 1)
    if cfg.arch_type in ("ssm", "hybrid"):
        cache = 1e6 * b_dev  # O(1) recurrent states (order of MBs)
    return w_dev + cache


def analyze(report: Dict, n_chips: int) -> Dict:
    arch, shape_name = report["arch"], report["shape"]
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_axes = report.get("mesh", {}).get("axes", {"data": 16, "model": 16})
    flops_dev = report.get("corrected_flops_per_device") or report.get(
        "flops_per_device") or 0.0
    coll_dev = report.get("corrected_collective_bytes_per_device")
    if coll_dev is None:
        coll_dev = report.get("collectives", {}).get("total_bytes", 0) or 0
    bytes_dev = analytic_memory_bytes(cfg, shape, mesh_axes)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * n_chips
    return {
        "arch": arch,
        "shape": shape_name,
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "hlo_bytes_dev": report.get("corrected_bytes_per_device"),
        "peak_bytes_per_dev": (report.get("memory") or {}).get("peak_bytes"),
        "fits_16GB": ((report.get("memory") or {}).get("peak_bytes") or 0) < 16e9,
    }


def load_reports(dirpath: str) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            rep = json.load(f)
        if "error" not in rep:
            out.append(rep)
    return out


def run(rows) -> None:
    dirpath = os.environ.get("DRYRUN_DIR", "results/dryrun_pod1")
    if not os.path.isdir(dirpath):
        rows.add("roofline/SKIP", 0.0, f"no dry-run dumps in {dirpath}")
        return
    for rep in load_reports(dirpath):
        n_chips = 512 if rep.get("multi_pod") else 256
        a = analyze(rep, n_chips)
        step_us = max(a["compute_s"], a["memory_s"], a["collective_s"]) * 1e6
        rows.add(
            f"roofline/{a['arch']}/{a['shape']}",
            step_us,
            f"dom={a['dominant']};c={a['compute_s']*1e6:.0f}us;"
            f"m={a['memory_s']*1e6:.0f}us;x={a['collective_s']*1e6:.0f}us;"
            f"useful={a['useful_ratio']:.2f};fits={a['fits_16GB']}",
        )
