"""App. N: generalization to plain-LLM decode (LLaMA3-8B / Qwen2-7B
geometries, single-token gated-activation importance). Paper reports 1.22×
and 2.09× average importance–latency speedups."""
from __future__ import annotations

import numpy as np

from .common import Rows, llm_importance
from .fig6_tradeoff import matched_speedups

MODELS = {
    "llama3-8b": (4096, 14336),
    "qwen2-7b": (3584, 18944),
}


def run(rows: Rows) -> None:
    # reuse the tradeoff machinery but with spikier single-token importance
    import jax.numpy as jnp

    from repro.core import ChunkConfig, ChunkSelector, retention, topk_mask_np

    rng = np.random.default_rng(11)
    for name, (d, f) in MODELS.items():
        speedups = []
        for n, cols, seed in ((d, f, 1), (f, d, 2)):
            v = llm_importance(rng, n)
            vj = jnp.asarray(v)
            sel = ChunkSelector.build(n, cols * 2, device="nano",
                                      cfg=ChunkConfig.for_shape(n, cols, "nano"))
            curves = {"topk": [], "chunk": []}
            for sp in (0.2, 0.3, 0.4, 0.5, 0.6):
                budget = int((1 - sp) * n)
                m_t = topk_mask_np(v, budget)
                curves["topk"].append(
                    (float(retention(vj, jnp.asarray(m_t))),
                     float(sel.table.mask_latency(jnp.asarray(m_t))))
                )
                m_c, _, lat_c = sel.select(vj, jnp.int32(budget))
                curves["chunk"].append((float(retention(vj, m_c)), float(lat_c)))
            speedups.extend(matched_speedups(curves))
        rows.add(f"appn/{name}", 0.0,
                 f"mean_speedup={np.mean(speedups):.2f}x(paper 1.22-2.09x)")
