"""Fig. 10 / App. J: contiguity-distribution shift — baseline, +reorder,
+reorder+chunk. Paper: average chunk size goes from ~1–2 to ~50."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChunkConfig,
    ChunkSelector,
    chunk_stats_np,
    hot_cold_reordering,
    topk_mask_np,
)

from .common import ImportanceModel, Rows

SHAPES = {"q_3584": (3584, 3584), "down_18944": (18944, 3584)}
SP = 0.4


def run(rows: Rows) -> None:
    rng = np.random.default_rng(3)
    for name, (n, cols) in SHAPES.items():
        imp = ImportanceModel(rng, n, jitter=1.0)
        reo = hot_cold_reordering(imp.calibration(20))
        sel = ChunkSelector.build(n, cols * 2, device="nano",
                                  cfg=ChunkConfig.for_shape(n, cols, "nano"))
        v = imp.sample()
        budget = int((1 - SP) * n)

        m0 = topk_mask_np(v, budget)
        m1 = topk_mask_np(v[reo.perm], budget)
        m2, _, _ = sel.select(jnp.asarray(v[reo.perm]), jnp.int32(budget))
        for tag, m in (("baseline", m0), ("+reorder", m1),
                       ("+reorder+chunk", np.asarray(m2))):
            avg, mode = chunk_stats_np(m)
            rows.add(f"fig10/{name}/{tag}", 0.0,
                     f"avg_chunk={avg:.1f};mode={mode}")
