"""Table 3 / App. L: LLM-in-a-Flash row-column bundling vs NEURON CHUNKING,
at matched retention. Bundling interleaves q/k/v rows so one selected neuron
is one contiguous 3-row read — but the selection stays layout-oblivious.
Paper: ours beats the baseline 1.5–3.4× and bundling 1.7–4.0×."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChunkConfig,
    ChunkSelector,
    bundled_latency,
    retention,
    topk_mask_np,
    unbundled_latency,
)

from .common import ImportanceModel, Rows

MODELS = {
    "llava-7b": 3584,
    "llava-0.5b": 896,
    "vila-8b": 4096,
    "nvila-2b": 1536,
}
SPARSITIES = [0.2, 0.3, 0.4, 0.5, 0.6]


def run(rows: Rows) -> None:
    rng = np.random.default_rng(7)
    for name, d in MODELS.items():
        imp = ImportanceModel(rng, d)
        v = imp.sample()
        vj = jnp.asarray(v)
        row_bytes = d * 2
        sel = ChunkSelector.build(d, row_bytes, device="nano",
                                  cfg=ChunkConfig.for_shape(d, d, "nano"))
        base, bund, chunk_curve = [], [], []
        for sp in SPARSITIES:
            budget = int((1 - sp) * d)
            m_t = topk_mask_np(v, budget)
            ret = float(retention(vj, jnp.asarray(m_t)))
            base.append((ret, unbundled_latency(m_t, row_bytes, 3, "nano")))
            bund.append((ret, bundled_latency(m_t, row_bytes, 3, "nano")))
            m_c, _, lat_c = sel.select(vj, jnp.int32(budget))
            chunk_curve.append((float(retention(vj, m_c)), float(lat_c) * 3))
        ch = sorted(chunk_curve)
        ret_c = np.asarray([r for r, _ in ch])
        lat_c = np.asarray([lat for _, lat in ch])

        def ours_at(r):
            return max(float(np.interp(r, ret_c, lat_c)), 1e-12)

        sp_base = np.mean([lat / ours_at(r) for r, lat in base])
        sp_bund = np.mean([lat / ours_at(r) for r, lat in bund])
        rows.add(
            f"table3/{name}",
            ours_at(base[2][0]) * 1e6,
            f"vs_baseline={sp_base:.2f}x(paper 1.5-3.4);"
            f"vs_bundling={sp_bund:.2f}x(paper 1.7-4.0)",
        )
