"""Discussion §5: hot-neuron caching is complementary to chunk selection.

The paper: cached neurons get zero importance; "once hot weights are cached,
the remaining uncached accesses become more scattered (even after
reordering), making our chunk-based selection more critical". We cache the
top-f% hottest neurons (by calibration frequency) and measure the
top-k-vs-chunk I/O ratio for the REMAINING loads as f grows.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChunkConfig,
    ChunkSelector,
    activation_frequency,
    topk_mask_np,
)

from .common import ImportanceModel, Rows

N, COLS = 18944, 3584
SP = 0.4


def run(rows: Rows) -> None:
    rng = np.random.default_rng(19)
    imp = ImportanceModel(rng, N, sigma=1.0, jitter=0.6)
    freq = activation_frequency(imp.calibration(20))
    sel = ChunkSelector.build(N, COLS * 2, device="nano",
                              cfg=ChunkConfig.for_shape(N, COLS, "nano"))
    v = imp.sample()

    for cache_frac in (0.0, 0.25, 0.5):
        n_cached = int(cache_frac * N)
        cached = np.zeros(N, bool)
        cached[np.argsort(-freq)[:n_cached]] = True
        v_eff = np.where(cached, 0.0, v).astype(np.float32)
        budget = max(int((1 - SP) * N) - n_cached, 64)  # remaining I/O budget
        m_t = topk_mask_np(v_eff, budget)
        lat_t = float(sel.table.mask_latency(jnp.asarray(m_t)))
        m_c, _, lat_c = sel.select(jnp.asarray(v_eff), jnp.int32(budget))
        ratio = lat_t / max(float(lat_c), 1e-12)
        rows.add(
            f"disc5/cache_{int(cache_frac*100)}pct",
            float(lat_c) * 1e6,
            f"topk_vs_chunk={ratio:.2f}x",
        )
