"""Fig. 8: latency breakdown — I/O vs compute vs selection overhead per
decode step (LLaVA-7B geometry, 28 layers), baseline vs ours at sparsity 0.4.
Selection overhead is REAL wall-clock of the jit-compiled selector on this
host (the paper's ≈2 ms/matrix budget is GPU-sorted; we report CPU numbers).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ChunkConfig, ChunkSelector, ComputeModel, topk_mask_np

from .common import ImportanceModel, Rows, time_call

D, F, LAYERS = 3584, 18944, 28
SP = 0.4


def run(rows: Rows) -> None:
    rng = np.random.default_rng(0)
    comp = ComputeModel()
    device = "nano"
    per_layer = {}
    select_ms = 0.0
    for site, (n, cols, n_mats) in {
        "qkv": (D, D, 3),
        "o": (D, D, 1),
        "gateup": (D, F, 2),
        "down": (F, D, 1),
    }.items():
        imp = ImportanceModel(rng, n)
        v = jnp.asarray(imp.sample())
        sel = ChunkSelector.build(n, cols * 2, device=device,
                                  cfg=ChunkConfig.for_shape(n, cols, device))
        budget = jnp.int32(int((1 - SP) * n))
        wall = time_call(lambda: sel.select(v, budget))
        select_ms += wall * 1e3
        m_c, n_sel, lat_c = sel.select(v, budget)
        m_t = topk_mask_np(np.asarray(v), int(budget))
        lat_t = float(sel.table.mask_latency(jnp.asarray(m_t)))
        per_layer[site] = {
            "io_chunk": float(lat_c) * n_mats,
            "io_topk": lat_t * n_mats,
            "compute_chunk": comp.matmul_seconds(int(n_sel), cols) * n_mats,
            "compute_topk": comp.matmul_seconds(int(budget), cols) * n_mats,
        }
    tot = {k: sum(p[k] for p in per_layer.values()) * LAYERS for k in
           ("io_chunk", "io_topk", "compute_chunk", "compute_topk")}
    rows.add("fig8/topk/io", tot["io_topk"] * 1e6, "per_decode_step")
    rows.add("fig8/topk/compute", tot["compute_topk"] * 1e6, "")
    rows.add("fig8/chunk/io", tot["io_chunk"] * 1e6,
             f"io_reduction={tot['io_topk']/tot['io_chunk']:.2f}x")
    rows.add("fig8/chunk/compute", tot["compute_chunk"] * 1e6,
             "slight_increase_expected")
    rows.add("fig8/chunk/selection_overhead", select_ms * LAYERS * 1e3,
             f"host_cpu_ms_per_model={select_ms*LAYERS:.1f}")
