"""Fig. 9: component ablation — baseline top-k, +hot-cold reordering, and
+reordering+chunk selection, compared at MATCHED retention (the paper's
"comparable accuracy" protocol). Paper (LLaVA-7B): reordering alone up to
1.23×, with chunking up to 2.55×."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChunkConfig,
    ChunkSelector,
    hot_cold_reordering,
    retention,
    topk_mask_np,
)

from .common import ImportanceModel, Rows

D, COLS = 18944, 3584  # LLaVA-7B down projection (the paper's showcase)
SPARSITIES = [0.2, 0.3, 0.4, 0.5, 0.6]


def run(rows: Rows) -> None:
    rng = np.random.default_rng(1)
    imp = ImportanceModel(rng, D, sigma=1.0, jitter=1.0)
    reo = hot_cold_reordering(imp.calibration(20))
    sel = ChunkSelector.build(D, COLS * 2, device="nano",
                              cfg=ChunkConfig.for_shape(D, COLS, "nano"))
    v = imp.sample()
    vj = jnp.asarray(v)
    v_r = v[reo.perm]

    base, plus_reorder, chunk_curve = [], [], []
    for sp in SPARSITIES:
        budget = int((1 - sp) * D)
        m = topk_mask_np(v, budget)
        ret = float(retention(vj, jnp.asarray(m)))
        base.append((ret, float(sel.table.mask_latency(jnp.asarray(m)))))
        # reordering keeps the same selected SET → identical retention
        m_r = topk_mask_np(v_r, budget)
        plus_reorder.append((ret, float(sel.table.mask_latency(jnp.asarray(m_r)))))
        m_c, _, lat_c = sel.select(jnp.asarray(v_r), jnp.int32(budget))
        chunk_curve.append((float(retention(jnp.asarray(v_r), m_c)), float(lat_c)))

    sp_reorder = [b[1] / r[1] for b, r in zip(base, plus_reorder)]
    ch = sorted(chunk_curve)
    ret_c = np.asarray([r for r, _ in ch])
    lat_c = np.asarray([l for _, l in ch])
    sp_chunk = [
        b_lat / max(float(np.interp(b_ret, ret_c, lat_c)), 1e-12)
        for b_ret, b_lat in base
    ]
    rows.add("fig9/baseline_topk", base[2][1] * 1e6, "speedup=1.00x")
    rows.add("fig9/+reorder", plus_reorder[2][1] * 1e6,
             f"matched_speedup_max={max(sp_reorder):.2f}x(paper up to 1.23x)")
    rows.add("fig9/+reorder+chunk", float(np.interp(base[2][0], ret_c, lat_c)) * 1e6,
             f"matched_speedup_max={max(sp_chunk):.2f}x(paper up to 2.55x)")
