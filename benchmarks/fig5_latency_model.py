"""Fig. 5: chunk-based latency model validation — estimated vs "measured"
(simulator) latency across realistic selection patterns. The paper finds a
near-linear relation (proportional bias); we report the fitted slope and R².
"""
from __future__ import annotations

import numpy as np

from repro.core import ChunkConfig, ChunkSelector, FlashOffloadSimulator

from .common import Rows, vlm_importance


def run(rows: Rows) -> None:
    rng = np.random.default_rng(2)
    n, row_bytes = 8192, 4096
    for device in ("nano", "agx"):
        sel = ChunkSelector.build(n, row_bytes, device=device,
                                  cfg=ChunkConfig(8, 236, 8, 8))
        sim = FlashOffloadSimulator(device, seed=3)
        est, meas = [], []
        for i in range(24):
            v = vlm_importance(rng, n)
            import jax.numpy as jnp

            budget = int((0.3 + 0.5 * rng.random()) * n)
            mask, _, lat = sel.select(jnp.asarray(v), jnp.int32(budget))
            est.append(float(lat))
            meas.append(sim.measure(np.asarray(mask), row_bytes))
        est, meas = np.asarray(est), np.asarray(meas)
        slope = float((est * meas).sum() / (est * est).sum())
        resid = meas - slope * est
        r2 = 1.0 - float((resid**2).sum() / ((meas - meas.mean()) ** 2).sum())
        rows.add(
            f"fig5/{device}/latency_model",
            float(est.mean() * 1e6),
            f"prop_bias={slope:.2f};R2={r2:.3f}",
        )
