"""Fig. 16 / App. K: effect of visual-token density per frame.

Fewer tokens per frame → less smoothing of the importance average → slightly
spikier distributions; the paper finds the chunking advantage robust across
densities. We sweep tokens/frame ∈ {196, 49, 16} and report the matched-
retention speedup at each density.
"""
from __future__ import annotations

import numpy as np

from .common import ImportanceModel, Rows
from .fig6_tradeoff import matched_speedups

D, F = 3584, 18944  # LLaVA-7B geometry


def run(rows: Rows) -> None:
    import jax.numpy as jnp

    from repro.core import ChunkConfig, ChunkSelector, retention, topk_mask_np

    rng = np.random.default_rng(17)
    for tokens in (196, 49, 16):
        speedups = []
        for n, cols, seed in ((D, F, 1), (F, D, 2)):
            imp = ImportanceModel(rng, n)
            v = imp.sample(tokens=tokens)
            vj = jnp.asarray(v)
            sel = ChunkSelector.build(n, cols * 2, device="nano",
                                      cfg=ChunkConfig.for_shape(n, cols, "nano"))
            curves = {"topk": [], "chunk": []}
            for sp in (0.2, 0.3, 0.4, 0.5, 0.6):
                budget = int((1 - sp) * n)
                m_t = topk_mask_np(v, budget)
                curves["topk"].append(
                    (float(retention(vj, jnp.asarray(m_t))),
                     float(sel.table.mask_latency(jnp.asarray(m_t))))
                )
                m_c, _, lat_c = sel.select(vj, jnp.int32(budget))
                curves["chunk"].append((float(retention(vj, m_c)), float(lat_c)))
            speedups.extend(matched_speedups(curves))
        rows.add(f"appk/tokens_{tokens}", 0.0,
                 f"mean_speedup={np.mean(speedups):.2f}x")
