"""Table 1 / App. C: coefficient of variation of neuron importance —
validates our importance generator sits in the published bands
(VLMs 1.07–4.55, ReLU LLM 8.63–11.65)."""
from __future__ import annotations

import numpy as np

from .common import Rows, cv, relu_llm_importance, vlm_importance


def run(rows: Rows) -> None:
    rng = np.random.default_rng(5)
    n = 18944
    vlm_cvs = [cv(vlm_importance(rng, n)) for _ in range(5)]
    relu_cvs = [cv(relu_llm_importance(rng, n)) for _ in range(5)]
    rows.add("table1/vlm_cv", 0.0,
             f"mean={np.mean(vlm_cvs):.2f};paper_band=1.07-4.55;"
             f"in_band={1.07 <= np.mean(vlm_cvs) <= 4.55}")
    rows.add("table1/relu_llm_cv", 0.0,
             f"mean={np.mean(relu_cvs):.2f};paper_band=8.63-11.65;"
             f"ratio_vs_vlm={np.mean(relu_cvs)/np.mean(vlm_cvs):.1f}x")
