"""Serving throughput: fused-scan decode vs the legacy per-token loop, the
chunk-plan reuse knob, and continuous-batching request latency per policy.

Three sections (reduced InternVL2 under the Nano flash simulator):

  * serve/fused_vs_loop — equal batch, equal policy: wall tokens/s of the
    one-jit ``lax.scan`` decode vs the seed's one-jit-call-per-token loop,
    asserting byte-identical greedy tokens (the acceptance criterion);
  * serve/plan_reuse — I/O per token as ``plan_refresh_interval`` grows
    (selection reruns every k steps, resident chunks are free in between);
  * serve/batch_<method> — chunk vs topk vs dense vs dense_free under
    concurrent Poisson-arriving streams: simulated tokens/s and p50/p95
    request latency from the continuous-batching scheduler.

Standalone:  PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import PoissonArrivalDriver, Request, Scheduler, ServeEngine

from .common import Rows

ARCH = "internvl2-76b"
BATCH = 2
DECODE_TOKENS = 32
PROMPT_LEN = 32
MAX_SEQ = 128


def _setup():
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, InputShape("bench", PROMPT_LEN, BATCH, "train"))
    return cfg, model, params, batch


def _engine(model, params, method="chunk", refresh=1, seed=5):
    return ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                       device="nano", sparsity=0.4, method=method, seed=seed,
                       plan_refresh_interval=refresh)


def _timed_decode(eng, decode_fn, tok0, n, repeats=3):
    """Median wall seconds; the first run's tokens are returned for the
    identity check (later repeats mutate the cache, which doesn't change
    the per-step cost being measured)."""
    out = None
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        o = decode_fn(tok0, n)
        jax.block_until_ready(o)
        walls.append(time.perf_counter() - t0)
        out = o if out is None else out
    return out, float(np.median(walls))


def bench_fused_vs_loop(rows: Rows, model, params, batch) -> None:
    eng_f = _engine(model, params)
    eng_l = _engine(model, params)
    tok0 = jnp.argmax(eng_f.prefill(batch), -1)[:, None].astype(jnp.int32)
    eng_l.prefill(batch)
    # warm up both compiled paths, then measure from identical cache state
    eng_f.decode(tok0, DECODE_TOKENS)
    eng_l.decode_per_token(tok0, DECODE_TOKENS)
    eng_f.prefill(batch)
    eng_l.prefill(batch)
    out_f, wall_f = _timed_decode(eng_f, eng_f.decode, tok0, DECODE_TOKENS)
    eng_l.prefill(batch)
    out_l, wall_l = _timed_decode(eng_l, eng_l.decode_per_token, tok0, DECODE_TOKENS)
    identical = bool(jnp.all(out_f == out_l))
    tps_f = DECODE_TOKENS * BATCH / wall_f
    tps_l = DECODE_TOKENS * BATCH / wall_l
    assert identical, "fused scan and per-token loop diverged"
    assert tps_f > tps_l, (
        f"fused decode must beat the per-token loop: {tps_f:.1f} vs {tps_l:.1f} tok/s"
    )
    rows.add("serve/fused_scan", wall_f / DECODE_TOKENS * 1e6,
             f"tokens_per_s={tps_f:.1f}")
    rows.add("serve/per_token_loop", wall_l / DECODE_TOKENS * 1e6,
             f"tokens_per_s={tps_l:.1f}")
    rows.add("serve/fused_vs_loop", 0.0,
             f"speedup={tps_f / tps_l:.2f}x identical_tokens={identical}")


def bench_plan_reuse(rows: Rows, model, params, batch) -> None:
    for k in (1, 2, 4, 8):
        eng = _engine(model, params, refresh=k)
        tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
        eng.decode(tok0, DECODE_TOKENS)
        steps = [s for s in eng.stats if s.kind == "decode"]
        io_tok = float(np.mean([s.io_est_s for s in steps]))
        refreshes = sum(1 for s in steps if s.io_est_s > 0)
        rows.add(f"serve/plan_reuse_k{k}", io_tok * 1e6,
                 f"refresh_steps={refreshes}/{DECODE_TOKENS}")


def bench_continuous_batching(rows: Rows, cfg, model, params,
                              n_requests: int = 8, rate_rps: float = 500.0) -> None:
    rng = np.random.default_rng(11)
    prompts = []
    for _ in range(n_requests):
        p = dict(make_dummy_batch(cfg, InputShape("req", PROMPT_LEN, 1, "train")))
        p["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, p["tokens"].shape), jnp.int32
        )
        prompts.append(p)

    # first-order GEMV compute floor per token so the zero-I/O dense_free
    # policy has a finite (compute-bound) latency on the simulated clock
    compute_s = 1e-4
    for method in ("chunk", "topk", "dense", "dense_free"):
        eng = _engine(model, params, method=method, refresh=2)
        sched = Scheduler(eng, round_tokens=4, compute_s_per_token=compute_s)
        driver = PoissonArrivalDriver(
            rate_rps,
            lambda rid: Request(rid=rid, prompt=prompts[rid % n_requests],
                                max_new_tokens=8),
            seed=3,
        )
        sched.submit(driver.generate(n_requests))
        st = sched.run()
        rows.add(
            f"serve/batch_{method}",
            st.latency_p50_s * 1e6,
            f"tokens_per_s={st.tokens_per_s:.1f} "
            f"p95_ms={st.latency_p95_s*1e3:.2f} finished={st.finished}",
        )


def run(rows: Rows) -> None:
    cfg, model, params, batch = _setup()
    bench_fused_vs_loop(rows, model, params, batch)
    bench_plan_reuse(rows, model, params, batch)
    bench_continuous_batching(rows, cfg, model, params)


if __name__ == "__main__":
    rows = Rows()
    print("name,us_per_call,derived")
    run(rows)
    rows.emit()
