"""Serving throughput: fused-scan decode vs the legacy per-token loop, the
overlapped I/O–compute pipeline vs the serial charge, the chunk-plan reuse
knob, the residency-cache budget sweep, and continuous-batching request
latency per policy.

Eight sections (reduced InternVL2 under the flash simulator):

  * serve/fused_vs_loop — equal batch, equal policy: wall tokens/s of the
    one-jit ``lax.scan`` decode vs the seed's one-jit-call-per-token loop,
    asserting byte-identical greedy tokens (the acceptance criterion);
  * serve/backend_* — the kernel-backed decode execution path
    (``--backend kernel``: the Pallas DMA gather kernels consume the decode
    plan's chunk tables inside the scan) vs the reference schedule twin,
    asserting byte-identical greedy tokens across backends at wbits 16 AND
    8 (in-kernel dequantization vs the twin's identical per-block multiply)
    and emitting both wall tokens/s (interpret-mode kernels on CPU CI);
  * serve/sharded_<d>x<m>_* — multi-chip sharded serving (``--mesh``, a
    (data, model) host-device mesh simulated via
    XLA_FLAGS=--xla_force_host_platform_device_count=8): per mesh shape
    and wbits 16/8, asserts greedy tokens byte-identical to the 1×1
    engine, total modeled I/O bytes equal, and the per-shard byte lanes
    summing to the unsharded total; emits wall tokens/s per shape (rows
    degrade to an explicit skipped marker below data×model devices);
  * serve/quantized_* — int8 chunk storage (``--wbits 8``) vs fp16 on BOTH
    the nano and agx profiles at equal settings (deterministic sim):
    asserts total modeled I/O bytes at 8 bits strictly below fp16 and the
    ratio at or under QUANTIZED_BYTES_RATIO_MAX (payload halves;
    per-block scales add 4/8 bytes per row) — the PR-6 byte-trajectory
    floor CI gates on;
  * serve/overlap_<device> — the two-stage prefetch pipeline on BOTH the
    nano and agx profiles, swept over prefetch depth: asserts overlapped
    per-step decode latency strictly below the serial charge for
    method=chunk, byte-identical tokens across --overlap/--no-overlap AND
    prefetch_depth 0/1/2, efficiency(depth 2) ≥ efficiency(depth 1) ≥ the
    floor, and that the chunk-vs-topk latency advantage survives in both
    charging modes; emits serial and overlapped simulated tokens/s +
    overlap_efficiency per depth;
  * serve/admission_* — bubble-aware scheduler admission: a request backlog
    admitted against banked decode-stall credit vs the admission-at-cost
    baseline; asserts the feature fires (admitted_during_stall ≥ 1,
    positive bubble utilization — the smoke floor) and never slows the
    simulated clock;
  * serve/paged_kv_* — the paged KV cache (``--kv-page-tokens``): slot-mode
    greedy tokens byte-identical to the dense per-slot cache across
    backend × wbits and on the 2×2 mesh, strictly more concurrent
    shared-prefix streams than the dense slot cap at equal KV memory, and
    the shared-prefix resident-byte reduction at or above
    PAGED_KV_SHARING_FLOOR — the PR-10 acceptance rows CI gates on;
  * serve/plan_reuse — I/O per token as ``plan_refresh_interval`` grows
    (selection reruns every k steps, resident chunks are free in between);
  * serve/cache_sweep — steady-state decode I/O vs DRAM residency budget
    (``cache_mb``) for chunk AND topk at fixed sparsity: the serve-stack
    reproduction of the paper's §5 claim — more cache → strictly less
    flash I/O, and the chunk-vs-topk advantage persists (indeed grows) at
    every swept budget because the remaining misses are more scattered;
  * serve/batch_<method> — chunk vs topk vs dense vs dense_free under
    concurrent Poisson-arriving streams: simulated tokens/s and p50/p95
    request latency from the continuous-batching scheduler;
  * serve/fault_* — storage-fault robustness (docs/robustness.md):
    fault-off byte-identity (tokens + io_summary), then sustained thermal
    throttle with per-request deadlines, DegradationController off vs on —
    asserts controller-on attainment strictly higher, p99 strictly lower,
    the degraded baseline preempting a deadline-blown request, and the
    degraded tokens/s above FAULT_DEGRADED_TPS_FLOOR; fully deterministic
    under the fixed fault seed.

Standalone:  PYTHONPATH=src python -m benchmarks.serve_throughput
CI artifact: PYTHONPATH=src python -m benchmarks.serve_throughput \
                 --smoke --out BENCH_serve.json
(--smoke runs the first four sections shrunk to a minute or two on CPU —
continuous batching is covered by tier-1 tests — and skips the
wall-clock speedup assertion, which is noise-prone on shared CI runners;
the byte-identity, I/O-ordering and overlap assertions always run, and the
smoke FAILS if overlap_efficiency drops below OVERLAP_EFFICIENCY_FLOOR —
the perf-trajectory guard for the prefetch pipeline.)
"""
from __future__ import annotations

import os

# Must land before jax initializes: the sharded-mesh section needs >= 4
# devices, simulated as host CPU devices on CI runners and laptops alike.
# setdefault keeps a caller's own XLA_FLAGS intact, and when the module is
# imported after jax already initialized (e.g. from a test) the section
# simply skips below 4 devices.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import (
    PoissonArrivalDriver,
    Request,
    Scheduler,
    ServeEngine,
    SparseExecution,
)
from repro.sharding.serve import ServeMesh

from .common import Rows, decode_backend_pair

ARCH = "internvl2-76b"
BATCH = 2
DECODE_TOKENS = 32
PROMPT_LEN = 32
MAX_SEQ = 128
# conservative floor for the prefetch pipeline's overlap efficiency (the
# fraction of hideable time actually hidden; ~0.92+ at current settings) —
# the CI smoke fails below it to guard the perf trajectory
OVERLAP_EFFICIENCY_FLOOR = 0.5
# ceiling for int8-vs-fp16 total modeled I/O bytes at matched settings
# (~0.49 at current geometry: payload exactly halves, the per-block scale
# lane adds 4 bytes per 8 rows) — the CI smoke fails above it so quantized
# storage can never silently stop paying for itself
QUANTIZED_BYTES_RATIO_MAX = 0.55
# the fault-robustness scenario (sustained thermal throttle + deadlines):
# per-request SLO and arrival spacing picked so the throttled baseline
# blows deadlines (and preempts) while the degradation controller keeps
# the same workload inside SLO; the tokens/s floor is ~half the current
# controller-on throughput so the CI smoke fails if adaptive degradation
# regresses badly
FAULT_DEADLINE_S = 0.03
FAULT_ARRIVAL_GAP_S = 0.002
FAULT_DEGRADED_TPS_FLOOR = 200.0
# floor for the shared-prefix KV-byte reduction (resident pages, 4 streams
# sharing a 4-page prefix vs 4 unique same-length prompts: 20/8 = 2.5x at
# current geometry) — the CI smoke fails below 2x, the PR-10 acceptance
# criterion for prefix sharing
PAGED_KV_SHARING_FLOOR = 2.0


def _setup():
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, InputShape("bench", PROMPT_LEN, BATCH, "train"))
    return cfg, model, params, batch


def _engine(model, params, method="chunk", refresh=1, seed=5, cache_mb=0.0,
            device="nano", overlap=True, prefetch_depth=1, backend="reference",
            wbits=16, mesh=None):
    return ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                       device=device, sparsity=0.4, method=method, seed=seed,
                       plan_refresh_interval=refresh, cache_mb=cache_mb,
                       overlap=overlap, prefetch_depth=prefetch_depth,
                       backend=backend, wbits=wbits, mesh=mesh)


def _timed_decode(eng, decode_fn, tok0, n, repeats=3):
    """Median wall seconds; the first run's tokens are returned for the
    identity check (later repeats mutate the cache, which doesn't change
    the per-step cost being measured)."""
    out = None
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        o = decode_fn(tok0, n)
        jax.block_until_ready(o)
        walls.append(time.perf_counter() - t0)
        out = o if out is None else out
    return out, float(np.median(walls))


def bench_fused_vs_loop(rows: Rows, model, params, batch,
                        decode_tokens=DECODE_TOKENS, repeats=3,
                        assert_speedup=True) -> None:
    eng_f = _engine(model, params)
    eng_l = _engine(model, params)
    tok0 = jnp.argmax(eng_f.prefill(batch), -1)[:, None].astype(jnp.int32)
    eng_l.prefill(batch)
    # warm up both compiled paths, then measure from identical cache state
    eng_f.decode(tok0, decode_tokens)
    eng_l.decode_per_token(tok0, decode_tokens)
    eng_f.prefill(batch)
    eng_l.prefill(batch)
    out_f, wall_f = _timed_decode(eng_f, eng_f.decode, tok0, decode_tokens,
                                  repeats=repeats)
    eng_l.prefill(batch)
    out_l, wall_l = _timed_decode(eng_l, eng_l.decode_per_token, tok0,
                                  decode_tokens, repeats=repeats)
    identical = bool(jnp.all(out_f == out_l))
    tps_f = decode_tokens * BATCH / wall_f
    tps_l = decode_tokens * BATCH / wall_l
    assert identical, "fused scan and per-token loop diverged"
    if assert_speedup:
        assert tps_f > tps_l, (
            f"fused decode must beat the per-token loop: {tps_f:.1f} vs {tps_l:.1f} tok/s"
        )
    rows.add("serve/fused_scan", wall_f / decode_tokens * 1e6,
             f"tokens_per_s={tps_f:.1f}")
    rows.add("serve/per_token_loop", wall_l / decode_tokens * 1e6,
             f"tokens_per_s={tps_l:.1f}")
    rows.add("serve/fused_vs_loop", 0.0,
             f"speedup={tps_f / tps_l:.2f}x identical_tokens={identical}")


def bench_backend_parity(rows: Rows, model, params, batch,
                         decode_tokens=DECODE_TOKENS, repeats=1) -> None:
    """The kernel-backed decode execution path vs the reference backend:
    equal settings, byte-identical greedy tokens (the PR-5 acceptance
    invariant — the backend switch changes how the masked arithmetic is
    realized, never which neurons participate), wall tokens/s for both —
    at wbits=16 AND wbits=8 (PR 6: the kernels dequantize int8 chunk
    payloads in VMEM; the reference twin performs the elementwise-identical
    per-block multiply, so the parity invariant extends to the quantized
    path unchanged). The kernel backend runs the Pallas DMA gather kernels
    in interpret mode here (CPU CI), so its wall number measures the
    schedule's emulation, not MXU throughput — the rows that matter for
    the perf trajectory are the parity bits plus the reference-backend
    tokens/s."""
    for wbits in (16, 8):
        results = decode_backend_pair(model, params, batch, max_seq=MAX_SEQ,
                                      batch_size=BATCH, n_tokens=decode_tokens,
                                      seed=5, repeats=repeats, wbits=wbits)
        suffix = "" if wbits == 16 else "_w8"
        for backend, (_eng, _out, wall) in results.items():
            tps = decode_tokens * BATCH / wall
            rows.add(f"serve/backend_{backend}{suffix}",
                     wall / decode_tokens * 1e6,
                     f"tokens_per_s={tps:.1f} identical_tokens=True "
                     f"wbits={wbits}")


def bench_sharded_mesh(rows: Rows, model, params, batch,
                       decode_tokens=DECODE_TOKENS,
                       shapes=((2, 2),)) -> None:
    """Multi-chip sharded serving vs the single-device engine: per mesh
    shape (data, model) and wbits 16/8, prefill + fused-scan decode at
    equal settings, asserting (1) byte-identical greedy tokens — the
    sharded-serving acceptance invariant: storage and I/O shard over the
    model axis but every fold's operands are gathered and summed in
    single-device block order (kernels/backend.py), so the mesh can never
    change a token; (2) equal total modeled I/O bytes; (3) the per-shard
    byte lanes (``shard_summary``) summing to that total. Emits wall
    tokens/s per shape. Below data×model devices (the CI smoke sets
    XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax init,
    as does this module when imported first) the shape degrades to an
    explicit skipped row rather than silently vanishing."""
    ndev = len(jax.devices())
    for wbits in (16, 8):
        suffix = f"w{wbits}"
        eng0 = _engine(model, params, refresh=2, cache_mb=2.0, wbits=wbits)
        tok0 = jnp.argmax(eng0.prefill(batch), -1)[:, None].astype(jnp.int32)
        out0, _ = _timed_decode(eng0, eng0.decode, tok0, decode_tokens,
                                repeats=1)
        bytes0 = eng0.io_summary()["io_bytes"]
        for d, m in shapes:
            name = f"serve/sharded_{d}x{m}_{suffix}"
            if ndev < d * m:
                rows.add(name, 0.0,
                         f"skipped=True devices={ndev} needed={d * m}")
                continue
            eng = _engine(model, params, refresh=2, cache_mb=2.0,
                          wbits=wbits, mesh=ServeMesh.create(d, m))
            tok = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
            out, wall = _timed_decode(eng, eng.decode, tok, decode_tokens,
                                      repeats=1)
            identical = bool(jnp.array_equal(out0, out))
            assert identical, (
                f"{name}: sharded greedy tokens diverged from the 1x1 mesh "
                f"at wbits={wbits} — the operand-gather constraint in "
                "kernels/backend.py is the byte-identity mechanism"
            )
            total = eng.io_summary()["io_bytes"]
            assert abs(total - bytes0) <= 1e-6 * max(bytes0, 1.0), (
                f"{name}: sharded total I/O bytes {total} != unsharded "
                f"{bytes0} — per-shard accounting must repartition, never "
                "rescale, the modeled traffic"
            )
            ss = eng.shard_summary()
            per = ss["io_bytes_per_shard"]
            assert abs(sum(per) - total) <= 1e-6 * max(total, 1.0), (
                f"{name}: per-shard byte lanes {per} do not sum to the "
                f"engine total {total}"
            )
            tps = decode_tokens * BATCH / wall
            rows.add(name, wall / decode_tokens * 1e6,
                     f"tokens_per_s={tps:.1f} identical_tokens={identical} "
                     f"io_bytes_eq=True shards={ss['n_shards']} "
                     f"wbits={wbits}")


def bench_overlap_pipeline(rows: Rows, model, params, batch,
                           devices=("nano", "agx"),
                           decode_tokens=DECODE_TOKENS,
                           depth_engines=True) -> None:
    """The overlapped I/O–compute prefetch pipeline vs the serial charge,
    swept over prefetch depth.

    Per device profile: (1) --overlap / --no-overlap chunk engines AND
    engines at prefetch_depth 0/1/2 at identical settings must all emit
    byte-identical tokens (the pipeline only re-times the same masks);
    (2) the overlapped per-step decode latency must be STRICTLY below the
    serial Σio+Σcompute charge (deterministic sim); (3) a deeper pipeline
    never hides less: efficiency(depth=2) ≥ efficiency(depth=1) ≥ the
    OVERLAP_EFFICIENCY_FLOOR; (4) the chunk-vs-topk latency advantage must
    survive under BOTH charging modes. Emits serial/overlapped simulated
    tokens/s per depth.

    ``depth_engines=False`` (the smoke mode) gets the depth sweep from
    ``ServeEngine.reprice_timeline`` — the pipeline is a host-side timeline
    over recorded per-layer I/O, so repricing the depth-1 engine's decode at
    other depths yields exactly what identically-seeded engines would charge
    — skipping two full engine compiles on CI; the engine-level byte
    identity across real depth-0/1/2 engines stays pinned by the full run
    and by tests/test_dma_kernels.py."""
    for device in devices:
        eng_o = _engine(model, params, device=device, overlap=True)
        eng_2 = (
            _engine(model, params, device=device, overlap=True, prefetch_depth=2)
            if depth_engines else None
        )
        eng_0 = (
            _engine(model, params, device=device, overlap=True, prefetch_depth=0)
            if depth_engines else None
        )
        eng_s = _engine(model, params, device=device, overlap=False)
        eng_t = _engine(model, params, device=device, method="topk")
        identity_engines = [e for e in (eng_o, eng_2, eng_0, eng_s) if e is not None]
        for eng in identity_engines + [eng_t]:
            eng.simulator.noise = 0.0  # deterministic for the assertions
        tok0 = jnp.argmax(eng_o.prefill(batch), -1)[:, None].astype(jnp.int32)
        for eng in identity_engines[1:] + [eng_t]:
            eng.prefill(batch)
        outs = [eng.decode(tok0, decode_tokens) for eng in identity_engines]
        for out in outs[1:]:
            assert bool(jnp.all(outs[0] == out)), (
                f"[{device}] tokens must be byte-identical across "
                "--overlap modes and prefetch depths 0/1/2"
            )
        eng_t.decode(tok0, decode_tokens)

        so = eng_o.io_summary()
        st = eng_t.io_summary()
        if eng_2 is not None:
            s2 = eng_2.io_summary()
            overlap2, eff2 = s2["decode_overlap_s"], s2["overlap_efficiency"]
        else:
            tl2 = eng_o.reprice_timeline(2)
            overlap2, eff2 = tl2.overlap_total_s, tl2.overlap_efficiency()
        serial, overlapped = so["decode_serial_s"], so["decode_overlap_s"]
        assert overlapped < serial, (
            f"[{device}] overlapped decode must be strictly below serial: "
            f"{overlapped:.3e} vs {serial:.3e}"
        )
        # per-step too, not just in aggregate
        steps = [s for s in eng_o.stats if s.kind == "decode"]
        assert all(s.overlap_s <= s.serial_s + 1e-15 for s in steps)
        # depth 0 degenerates to the serial schedule exactly; a deeper
        # pipeline is monotone: depth 2 hides at least as much as depth 1
        if eng_0 is not None:
            s0 = eng_0.io_summary()
            assert abs(s0["decode_overlap_s"] - s0["decode_serial_s"]) < 1e-12
        assert overlap2 <= overlapped + 1e-15, (
            f"[{device}] depth-2 pipeline must not be slower than depth-1"
        )
        # the chunk-vs-topk advantage survives both charging modes
        assert st["decode_overlap_s"] > overlapped, (
            f"[{device}] chunk must beat topk under the overlapped charge"
        )
        assert st["decode_serial_s"] > serial, (
            f"[{device}] chunk must beat topk under the serial charge"
        )
        eff = so["overlap_efficiency"]
        assert eff2 >= eff >= OVERLAP_EFFICIENCY_FLOOR, (
            f"[{device}] overlap_efficiency must satisfy depth2 {eff2:.3f} "
            f">= depth1 {eff:.3f} >= {OVERLAP_EFFICIENCY_FLOOR}"
        )
        n_tok = decode_tokens * BATCH
        rows.add(f"serve/overlap_{device}",
                 overlapped / decode_tokens * 1e6,
                 f"sim_tokens_per_s={n_tok / overlapped:.1f} "
                 f"overlap_efficiency={eff:.3f} "
                 f"stall_ms={so['decode_stall_s']*1e3:.2f}")
        rows.add(f"serve/overlap_depth2_{device}",
                 overlap2 / decode_tokens * 1e6,
                 f"sim_tokens_per_s={n_tok / overlap2:.1f} "
                 f"overlap_efficiency={eff2:.3f}")
        rows.add(f"serve/serial_{device}",
                 serial / decode_tokens * 1e6,
                 f"sim_tokens_per_s={n_tok / serial:.1f} "
                 f"speedup={serial / overlapped:.3f}x")


def bench_quantized_io(rows: Rows, model, params, batch,
                       devices=("nano", "agx"),
                       decode_tokens=DECODE_TOKENS) -> None:
    """int8 chunk storage vs fp16 on both device profiles (PR 6): identical
    settings and seed, deterministic sim, the same quality proxy (selection
    budget = (1-sparsity)·N rows per site either way) — total modeled I/O
    bytes at wbits=8 must come in strictly below fp16 AND at or under the
    QUANTIZED_BYTES_RATIO_MAX ceiling (the payload halves; the per-block
    scale lane costs 4 bytes per 8 rows). Emits per-width bytes plus the
    ratio row the CI artifact tracks."""
    for device in devices:
        total_bytes = {}
        for wbits in (16, 8):
            eng = _engine(model, params, device=device, wbits=wbits)
            eng.simulator.noise = 0.0  # deterministic sim for the assertions
            tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
            eng.decode(tok0, decode_tokens)
            s = eng.io_summary()
            total_bytes[wbits] = float(s["io_bytes"])
            rows.add(f"serve/quantized_w{wbits}_{device}",
                     s["io_sim_s"] / decode_tokens * 1e6,
                     f"io_bytes={s['io_bytes']:.0f} wbits={wbits}")
        ratio = total_bytes[8] / total_bytes[16]
        assert total_bytes[8] < total_bytes[16], (
            f"[{device}] wbits=8 total I/O bytes must be strictly below "
            f"fp16: {total_bytes[8]:.0f} vs {total_bytes[16]:.0f}"
        )
        assert ratio <= QUANTIZED_BYTES_RATIO_MAX, (
            f"[{device}] quantized_bytes_ratio {ratio:.3f} exceeds the "
            f"{QUANTIZED_BYTES_RATIO_MAX} ceiling — int8 chunk storage "
            "stopped paying for itself"
        )
        rows.add(f"serve/quantized_bytes_ratio_{device}", 0.0,
                 f"ratio={ratio:.3f} ceiling={QUANTIZED_BYTES_RATIO_MAX}")


def bench_plan_reuse(rows: Rows, model, params, batch,
                     intervals=(1, 2, 4, 8), decode_tokens=DECODE_TOKENS) -> None:
    for k in intervals:
        eng = _engine(model, params, refresh=k)
        tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
        eng.decode(tok0, decode_tokens)
        steps = [s for s in eng.stats if s.kind == "decode"]
        io_tok = float(np.mean([s.io_est_s for s in steps]))
        refreshes = sum(1 for s in steps if s.io_est_s > 0)
        rows.add(f"serve/plan_reuse_k{k}", io_tok * 1e6,
                 f"refresh_steps={refreshes}/{decode_tokens}")


def bench_cache_sweep(rows: Rows, model, params, batch, cfg,
                      fractions=(0.0, 0.15, 0.35, 0.7),
                      decode_tokens=DECODE_TOKENS) -> None:
    """§5 end-to-end: sweep the residency-cache byte budget at fixed 0.4
    sparsity and record steady-state decode I/O + hit rate for chunk and
    topk. Asserts the acceptance criteria: chunk I/O is monotone
    non-increasing in budget (strictly below cache-0 whenever the budget is
    > 0) and the chunk-vs-topk advantage persists at every point."""
    sizing = SparseExecution(cfg, device="nano", sparsity=0.4)  # sizes the sweep
    total_mb = sizing.sparsifiable_bytes(cfg.n_layers) / (1024.0 * 1024.0)
    budgets = [round(f * total_mb, 3) for f in fractions]
    steady = {}
    for method in ("chunk", "topk"):
        for mb in budgets:
            eng = _engine(model, params, method=method, refresh=2, cache_mb=mb)
            eng.simulator.noise = 0.0  # deterministic sim for the assertions
            tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
            eng.decode(tok0, decode_tokens)
            steps = [s for s in eng.stats if s.kind == "decode"]
            # steady state: drop the warm-up half where the tier is filling
            tail = steps[len(steps) // 2:]
            io_tok = float(np.mean([s.io_sim_s for s in tail]))
            hit = sum(s.hit_rows for s in tail)
            miss = sum(s.miss_rows for s in tail)
            rate = hit / (hit + miss) if (hit + miss) > 0 else 0.0
            steady[(method, mb)] = io_tok
            rows.add(f"serve/cache_sweep_{method}_mb{mb:g}", io_tok * 1e6,
                     f"hit_rate={rate:.3f} cache_frac_of_weights="
                     f"{mb / total_mb if total_mb else 0:.2f}")
    chunk_ios = [steady[("chunk", mb)] for mb in budgets]
    for prev, cur, mb in zip(chunk_ios, chunk_ios[1:], budgets[1:]):
        assert cur <= prev * (1 + 1e-9), (
            f"chunk I/O must be monotone non-increasing in cache budget; "
            f"rose to {cur:.3e} at {mb} MB"
        )
        assert cur < chunk_ios[0], (
            f"cache_mb={mb} > 0 must beat the cache-0 run strictly "
            f"({cur:.3e} vs {chunk_ios[0]:.3e})"
        )
    for mb in budgets:
        ratio = steady[("topk", mb)] / max(steady[("chunk", mb)], 1e-30)
        assert ratio > 1.0, (
            f"chunk-vs-topk I/O advantage must persist at cache_mb={mb} "
            f"(ratio {ratio:.2f})"
        )
        rows.add(f"serve/cache_topk_vs_chunk_mb{mb:g}", 0.0, f"ratio={ratio:.2f}x")


def bench_scheduler_admission(rows: Rows, cfg, model, params,
                              n_requests: int = 6, smoke: bool = False) -> None:
    """Bubble-aware scheduler admission: with more requests than slots, the
    backlog is admitted at round boundaries AFTER decode rounds have banked
    measured stall seconds — so their prefill charge rides the pipeline's
    I/O bubbles instead of extending the clock. Asserts (deterministic sim)
    that at least one admission was hidden and that realized bubble
    utilization is positive — the smoke-mode floor guarding the feature —
    and that the bubble-aware clock never exceeds the admission-at-cost
    baseline. Emits both schedulers' tokens/s plus the admission stats."""
    rng = np.random.default_rng(13)
    prompts = []
    for _ in range(n_requests):
        p = dict(make_dummy_batch(cfg, InputShape("req", PROMPT_LEN, 1, "train")))
        p["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, p["tokens"].shape), jnp.int32
        )
        prompts.append(p)

    results = {}
    # smoke keeps only the bubble-aware engine (the floor below is what CI
    # gates on); the full run also prices the admission-at-cost baseline
    for mode in ("bubble",) if smoke else ("bubble", "boundary"):
        eng = _engine(model, params, refresh=2)
        eng.simulator.noise = 0.0
        sched = Scheduler(eng, round_tokens=2,
                          admit_in_bubbles=(mode == "bubble"))
        # all requests arrive at t=0: slots fill, the rest wait through
        # decode rounds and are admitted against the banked stall credit
        sched.submit([
            Request(rid=i, prompt=prompts[i], max_new_tokens=4, arrival_s=0.0)
            for i in range(n_requests)
        ])
        st = sched.run()
        s = eng.io_summary()
        results[mode] = (st, s)
        rows.add(
            f"serve/admission_{mode}",
            st.latency_p50_s * 1e6,
            f"tokens_per_s={st.tokens_per_s:.1f} "
            f"admitted_during_stall={s['admitted_during_stall']} "
            f"bubble_utilization={s['bubble_utilization']:.3f} "
            f"stall_hidden_ms={s['stall_hidden_s']*1e3:.2f}",
        )

    st_b, s_b = results["bubble"]
    # the smoke-mode floor: the feature must demonstrably fire
    assert s_b["admitted_during_stall"] >= 1, (
        "bubble-aware admission never fired despite a request backlog"
    )
    assert s_b["bubble_utilization"] > 0.0
    if "boundary" in results:
        st_0, s_0 = results["boundary"]
        assert s_0["admitted_during_stall"] == 0  # baseline: no hiding
        assert st_b.sim_time_s <= st_0.sim_time_s + 1e-12, (
            "hiding admissions in stall bubbles must not slow the clock: "
            f"{st_b.sim_time_s:.4f} vs {st_0.sim_time_s:.4f}"
        )
        rows.add("serve/admission_speedup", 0.0,
                 f"sim_time_ratio="
                 f"{st_0.sim_time_s / max(st_b.sim_time_s, 1e-12):.3f}x "
                 f"finished={st_b.finished}/{n_requests}")


def bench_continuous_batching(rows: Rows, cfg, model, params,
                              n_requests: int = 8, rate_rps: float = 500.0) -> None:
    rng = np.random.default_rng(11)
    prompts = []
    for _ in range(n_requests):
        p = dict(make_dummy_batch(cfg, InputShape("req", PROMPT_LEN, 1, "train")))
        p["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, p["tokens"].shape), jnp.int32
        )
        prompts.append(p)

    # extra per-token host/dispatch constant on top of the engine's modeled
    # compute lane (the pipeline already gives dense_free a finite
    # compute-bound latency) — kept equal across policies
    compute_s = 1e-4
    for method in ("chunk", "topk", "dense", "dense_free"):
        eng = _engine(model, params, method=method, refresh=2)
        sched = Scheduler(eng, round_tokens=4, compute_s_per_token=compute_s)
        driver = PoissonArrivalDriver(
            rate_rps,
            lambda rid: Request(rid=rid, prompt=prompts[rid % n_requests],
                                max_new_tokens=8),
            seed=3,
        )
        sched.submit(driver.generate(n_requests))
        st = sched.run()
        rows.add(
            f"serve/batch_{method}",
            st.latency_p50_s * 1e6,
            f"tokens_per_s={st.tokens_per_s:.1f} "
            f"p95_ms={st.latency_p95_s*1e3:.2f} finished={st.finished}",
        )


def bench_fault_robustness(rows: Rows, cfg, model, params,
                           n_requests: int = 8) -> None:
    """Storage-fault robustness (ISSUE 8 acceptance rows, deterministic —
    fixed fault seed, simulator noise 0):

      * serve/fault_identity — fault machinery attached but disabled must
        be FREE: greedy tokens AND io_summary() byte-identical to an
        engine without it (select_overhead_s excluded: wall-clock timed);
      * serve/fault_off|on — sustained thermal throttle + per-request
        deadlines, DegradationController off vs on: asserts attainment_on
        strictly above attainment_off, p99_on strictly below p99_off, the
        degraded baseline preempting >= 1 deadline-blown request, and the
        controller-on degraded tokens/s above FAULT_DEGRADED_TPS_FLOOR —
        the floor CI gates on so adaptive degradation can never silently
        stop paying for itself."""
    tok0 = jnp.ones((BATCH, 1), jnp.int32)
    base = _engine(model, params)
    t_base = base.decode(tok0, 6)
    eng_none = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                           device="nano", sparsity=0.4, method="chunk",
                           seed=5, plan_refresh_interval=1, cache_mb=0.0,
                           fault_profile="none", fault_seed=123)
    t_none = eng_none.decode(tok0, 6)
    assert bool(jnp.all(t_base == t_none)), (
        "fault-off engine changed greedy tokens — injection must be free "
        "when disabled"
    )
    s_base, s_none = base.io_summary(), eng_none.io_summary()
    s_base.pop("select_overhead_s"), s_none.pop("select_overhead_s")
    assert s_base == s_none, (
        f"fault-off engine perturbed io_summary: "
        f"{ {k: (s_base[k], s_none[k]) for k in s_base if s_base[k] != s_none[k]} }"
    )
    rows.add("serve/fault_identity", 0.0,
             f"tokens_and_io_identical=True events="
             f"{eng_none.fault_summary()['fault_events']}")

    rng = np.random.default_rng(17)
    prompts = []
    for _ in range(n_requests):
        p = dict(make_dummy_batch(cfg, InputShape("req", PROMPT_LEN, 1, "train")))
        p["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, p["tokens"].shape), jnp.int32
        )
        prompts.append(p)

    results = {}
    for mode in ("off", "on"):
        eng = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                          device="nano", sparsity=0.4, method="chunk",
                          seed=5, plan_refresh_interval=1, cache_mb=0.0,
                          fault_profile="thermal_throttle", fault_seed=0,
                          degrade=(mode == "on"))
        eng.simulator.noise = 0.0
        sched = Scheduler(eng, round_tokens=2)
        sched.submit([
            Request(rid=i, prompt=prompts[i], max_new_tokens=6,
                    arrival_s=FAULT_ARRIVAL_GAP_S * i,
                    deadline_s=FAULT_DEADLINE_S)
            for i in range(n_requests)
        ])
        st = sched.run()
        fs = eng.fault_summary()
        results[mode] = st
        rows.add(
            f"serve/fault_{mode}",
            st.latency_p50_s * 1e6,
            f"tokens_per_s={st.tokens_per_s:.1f} "
            f"p99_ms={st.latency_p99_s*1e3:.2f} "
            f"slo_attainment={st.slo_attainment:.3f} "
            f"preempted={st.preempted} "
            f"degrade_scale={fs['degrade_scale']:.2f} "
            f"min_throttle_scale={fs['min_throttle_scale']:.2f}",
        )

    st_off, st_on = results["off"], results["on"]
    assert st_off.finished == st_on.finished == n_requests
    assert st_on.slo_attainment > st_off.slo_attainment, (
        f"degradation controller must lift SLO attainment under throttle: "
        f"on={st_on.slo_attainment:.3f} off={st_off.slo_attainment:.3f}"
    )
    assert st_on.latency_p99_s < st_off.latency_p99_s, (
        f"controller-on p99 must drop: on={st_on.latency_p99_s:.4f} "
        f"off={st_off.latency_p99_s:.4f}"
    )
    assert st_off.preempted >= 1, (
        "the degraded baseline must preempt >= 1 deadline-blown request"
    )
    assert st_on.tokens_per_s > st_off.tokens_per_s
    assert st_on.tokens_per_s >= FAULT_DEGRADED_TPS_FLOOR, (
        f"degraded throughput {st_on.tokens_per_s:.1f} tok/s under the "
        f"{FAULT_DEGRADED_TPS_FLOOR} floor — adaptive degradation stopped "
        "paying for itself"
    )


def bench_integrity(rows: Rows, cfg, model, params,
                    decode_tokens: int = 6) -> None:
    """End-to-end chunk integrity (PR 9 acceptance rows, deterministic —
    seeded corruption, simulator noise irrelevant to tokens):

      * serve/integrity_recovered_w{16,8} — bit_rot (every corruption
        transient, hence recoverable) + the recovery ladder: greedy tokens
        must be BYTE-IDENTICAL to the corruption-off engine and the
        detection rate must be exactly 1.0 (detected == recovered, nothing
        substituted or dropped) — the CI smoke fails if a corrupted block
        ever slips past the checksum or a recovery rung leaks into compute;
      * serve/integrity_norecover — same seed with recovery off must
        CHANGE the tokens (the injection is real, not a counter);
      * serve/integrity_ladder — degraded_nand retention errors exhaust
        the re-read budget and walk the substitute/drop rungs; the counters
        land in the artifact so the ladder's mix is tracked over time."""
    tok0 = jnp.ones((BATCH, 1), jnp.int32)
    for wbits, backend in ((16, "reference"), (8, "kernel")):
        base = _engine(model, params, backend=backend, wbits=wbits)
        t_base = np.asarray(base.decode(tok0, decode_tokens))
        eng = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                          device="nano", sparsity=0.4, method="chunk",
                          seed=5, plan_refresh_interval=1, cache_mb=0.0,
                          backend=backend, wbits=wbits,
                          corruption_profile="bit_rot", corruption_seed=7)
        t = np.asarray(eng.decode(tok0, decode_tokens))
        s = eng.io_summary()
        det, rec = s["corruptions_detected"], s["corruptions_recovered"]
        assert det > 0, (
            f"wbits={wbits}: bit_rot drew no corruption — the integrity "
            "rows are vacuous; raise decode_tokens or change the seed"
        )
        assert det == rec and not s["corruptions_substituted"] \
            and not s["corruptions_dropped"], (
            f"wbits={wbits}: bit_rot recovery rate must be exactly 1.0 "
            f"(detected={det} recovered={rec})"
        )
        assert np.array_equal(t_base, t), (
            f"wbits={wbits}: recovered corruption changed greedy tokens — "
            "a damaged block reached compute"
        )
        rows.add(f"serve/integrity_recovered_w{wbits}",
                 s["integrity_reread_s"] * 1e6,
                 f"backend={backend} detected={det:.0f} recovered={rec:.0f} "
                 f"detection_rate=1.0 tokens_identical=True")
    # recovery off: the same seed must measurably corrupt the output
    eng_off = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                          device="nano", sparsity=0.4, method="chunk",
                          seed=5, plan_refresh_interval=1, cache_mb=0.0,
                          corruption_profile="bit_rot", corruption_seed=7,
                          recover=False)
    t_off = np.asarray(eng_off.decode(tok0, decode_tokens))
    base16 = _engine(model, params)
    assert not np.array_equal(
        np.asarray(base16.decode(tok0, decode_tokens)), t_off
    ), "recovery-off corruption left tokens untouched — injection inert?"
    s_off = eng_off.io_summary()
    rows.add("serve/integrity_norecover", 0.0,
             f"detected={s_off['corruptions_detected']:.0f} "
             f"tokens_corrupted=True")
    # the full ladder: persistent retention errors → substitute/drop rungs
    eng_nand = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                           device="nano", sparsity=0.4, method="chunk",
                           seed=5, plan_refresh_interval=1, cache_mb=0.0,
                           corruption_profile="degraded_nand",
                           corruption_seed=3, max_reread=1)
    eng_nand.decode(tok0, decode_tokens)
    s_n = eng_nand.io_summary()
    assert s_n["corruptions_substituted"] > 0, (
        "degraded_nand never reached the substitution rung — the ladder "
        "below re-read is untested"
    )
    rows.add("serve/integrity_ladder", s_n["integrity_reread_s"] * 1e6,
             f"detected={s_n['corruptions_detected']:.0f} "
             f"recovered={s_n['corruptions_recovered']:.0f} "
             f"substituted={s_n['corruptions_substituted']:.0f} "
             f"dropped={s_n['corruptions_dropped']:.0f}")


def bench_paged_kv(rows: Rows, cfg, model, params, decode_tokens: int = 6,
                   combos=(("reference", 16), ("kernel", 8))) -> None:
    """Paged KV cache (PR 10 acceptance rows, fully deterministic):

      * serve/paged_kv_identity_<backend>_w<wbits> — slot-mode decode with
        the paged pool vs the dense per-slot cache at equal settings must
        produce BYTE-IDENTICAL greedy tokens (every slot admitted, no
        eviction — the workload class the identity criterion covers);
      * serve/paged_kv_2x2 — the same identity on a 2×2 (data, model)
        mesh, with the per-shard page lanes summing to the global count
        (skipped-row idiom below 4 devices, like serve/sharded_*);
      * serve/paged_kv_concurrency — at EQUAL KV memory (16 pages of 8
        tokens, max_seq 64), the dense layout caps at 16//8 = 2 resident
        slots while the paged engine serves 4 shared-prefix streams
        concurrently — the smoke fails unless strictly more streams than
        the dense slot cap fit;
      * serve/paged_kv_sharing — 4 shared-prefix streams vs 4 unique
        same-length prompts: resident KV pages (= bytes) must shrink by
        at least PAGED_KV_SHARING_FLOOR×.
    """
    rng = np.random.default_rng(11)
    # per-slot VLM prompts (frontend rows + tokens fuse to PROMPT_LEN
    # positions); distinct seeds -> fully distinct streams
    prompts = [
        make_dummy_batch(cfg, InputShape("req", PROMPT_LEN, 1, "train"),
                         seed=100 + i)
        for i in range(BATCH)
    ]

    def _slot_decode(eng):
        eng.enable_slots()
        lasts = []
        for slot, p in enumerate(prompts):
            last, _ = eng.admit_slot(slot, p)
            lasts.append(jnp.argmax(last, -1)[:, None])
        tok0 = jnp.concatenate(lasts).astype(jnp.int32)
        t0 = time.perf_counter()
        out, _ = eng.decode_slots(tok0, decode_tokens)
        jax.block_until_ready(out)
        return np.asarray(out), time.perf_counter() - t0

    for backend, wbits in combos:
        dense = _engine(model, params, backend=backend, wbits=wbits)
        paged = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                            device="nano", sparsity=0.4, method="chunk",
                            seed=5, plan_refresh_interval=1, cache_mb=0.0,
                            backend=backend, wbits=wbits, kv_page_tokens=8)
        out_d, _ = _slot_decode(dense)
        out_p, wall = _slot_decode(paged)
        name = f"serve/paged_kv_identity_{backend}_w{wbits}"
        assert np.array_equal(out_d, out_p), (
            f"{name}: paged greedy tokens diverged from the dense KV cache "
            "— the gathered page view must reproduce the dense reduction "
            "tree exactly (models/attention.py gather_paged_kv)"
        )
        paged.kv_pool.check()
        tps = decode_tokens * BATCH / wall
        rows.add(name, wall / decode_tokens * 1e6,
                 f"tokens_per_s={tps:.1f} identical_tokens=True "
                 f"pages_in_use={paged.kv_pool.pages_in_use} wbits={wbits}")

    # 2x2 mesh identity (skipped-row idiom below 4 devices)
    if len(jax.devices()) < 4:
        rows.add("serve/paged_kv_2x2", 0.0,
                 f"skipped=True devices={len(jax.devices())} needed=4")
    else:
        dense = _engine(model, params, mesh=ServeMesh.create(2, 2))
        paged = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                            device="nano", sparsity=0.4, method="chunk",
                            seed=5, plan_refresh_interval=1, cache_mb=0.0,
                            kv_page_tokens=8, mesh=ServeMesh.create(2, 2))
        out_d, _ = _slot_decode(dense)
        out_p, wall = _slot_decode(paged)
        assert np.array_equal(out_d, out_p), (
            "serve/paged_kv_2x2: paged tokens diverged on the 2x2 mesh"
        )
        per = paged.shard_summary()["kv_pages_per_shard"]
        assert sum(per) == paged.kv_pool.pages_in_use, (
            f"serve/paged_kv_2x2: per-shard page lanes {per} do not sum to "
            f"the global count {paged.kv_pool.pages_in_use}"
        )
        rows.add("serve/paged_kv_2x2", wall / decode_tokens * 1e6,
                 f"identical_tokens=True pages_per_shard={per}")

    # concurrency at equal KV memory: 16 usable pages of 8 tokens. The
    # dense layout must reserve max_seq (64 positions = 8 pages) per slot
    # up front -> 2 slots. Paged: 4 streams share a 4-page prefix (the
    # frontend rows + the first token span) and add a private tail page +
    # one decode-grown page each.
    pt, kv_pages, max_seq = 8, 17, 64
    dense_slot_cap = (kv_pages - 1) * pt // max_seq
    base = dict(make_dummy_batch(cfg, InputShape("req", 5 * pt, 1, "train"),
                                 seed=7))
    n_tok = base["tokens"].shape[1]
    streams = []
    for _ in range(4):
        p = dict(base)  # same frontend + leading tokens = shared prefix
        toks = np.asarray(p["tokens"]).copy()
        toks[0, n_tok - pt:] = rng.integers(0, cfg.vocab_size, pt)
        p["tokens"] = jnp.asarray(toks, jnp.int32)
        streams.append(p)
    eng = ServeEngine(model, params, max_seq=max_seq, batch_size=4,
                      device="nano", sparsity=0.4, method="chunk", seed=5,
                      plan_refresh_interval=1, cache_mb=0.0,
                      kv_page_tokens=pt, kv_pages=kv_pages)
    eng.enable_slots()
    lasts = []
    for slot, p in enumerate(streams):
        assert eng.kv_can_admit(p), (
            f"serve/paged_kv_concurrency: stream {slot} did not fit — "
            "prefix sharing must stretch the fixed page budget"
        )
        last, _ = eng.admit_slot(slot, p)
        lasts.append(jnp.argmax(last, -1)[:, None])
    tok0 = jnp.concatenate(lasts).astype(jnp.int32)
    out, _ = eng.decode_slots(tok0, decode_tokens)
    assert out.shape == (4, decode_tokens)
    eng.kv_pool.check()
    concurrent = sum(1 for s in range(4) if eng.kv_pool.slot_pages(s))
    assert concurrent > dense_slot_cap, (
        f"serve/paged_kv_concurrency: {concurrent} paged streams vs dense "
        f"slot cap {dense_slot_cap} at equal KV memory — the acceptance "
        "criterion requires strictly more"
    )
    rows.add("serve/paged_kv_concurrency", 0.0,
             f"streams={concurrent} dense_slot_cap={dense_slot_cap} "
             f"pages={eng.kv_pool.pages_in_use}/{kv_pages - 1} "
             f"shared_hits={eng.kv_pool.shared_pages_hit}")

    # sharing: resident KV bytes, shared-prefix vs unique same-length
    def _admit_all(prompt_list):
        e = ServeEngine(model, params, max_seq=max_seq, batch_size=4,
                        device="nano", sparsity=0.4, method="chunk", seed=5,
                        plan_refresh_interval=1, cache_mb=0.0,
                        kv_page_tokens=pt, kv_pages=41)
        e.enable_slots()
        for slot, p in enumerate(prompt_list):
            e.admit_slot(slot, p)
        return e.kv_pool.pages_in_use

    # distinct seeds: distinct frontend rows too, so nothing can share
    unique = [
        make_dummy_batch(cfg, InputShape("req", 5 * pt, 1, "train"),
                         seed=200 + i)
        for i in range(4)
    ]
    shared_pages = _admit_all(streams)
    unique_pages = _admit_all(unique)
    ratio = unique_pages / shared_pages
    assert ratio >= PAGED_KV_SHARING_FLOOR, (
        f"serve/paged_kv_sharing: page reduction {ratio:.2f}x below the "
        f"{PAGED_KV_SHARING_FLOOR}x floor ({unique_pages} unique vs "
        f"{shared_pages} shared)"
    )
    rows.add("serve/paged_kv_sharing", 0.0,
             f"kv_byte_reduction={ratio:.2f}x shared_pages={shared_pages} "
             f"unique_pages={unique_pages}")


def run(rows: Rows, smoke: bool = False) -> None:
    cfg, model, params, batch = _setup()
    if smoke:
        # tiny everything: identity + I/O-ordering + overlap assertions
        # (incl. the efficiency floor and the bubble-admission floor) still
        # run, wall-clock speedup (noisy on shared CI runners) does not;
        # the continuous-batching policy comparison is exercised by tier-1
        # tests instead
        bench_fused_vs_loop(rows, model, params, batch, decode_tokens=8,
                            repeats=1, assert_speedup=False)
        bench_backend_parity(rows, model, params, batch, decode_tokens=8)
        bench_sharded_mesh(rows, model, params, batch, decode_tokens=8)
        bench_overlap_pipeline(rows, model, params, batch, devices=("nano",),
                               decode_tokens=8, depth_engines=False)
        # both device profiles even in smoke: the int8-below-fp16 byte
        # ordering is a per-profile acceptance criterion
        bench_quantized_io(rows, model, params, batch, decode_tokens=8)
        bench_plan_reuse(rows, model, params, batch, intervals=(1, 4),
                         decode_tokens=8)
        bench_cache_sweep(rows, model, params, batch, cfg,
                          fractions=(0.0, 0.35), decode_tokens=8)
        bench_scheduler_admission(rows, cfg, model, params, n_requests=4,
                                  smoke=True)
        bench_fault_robustness(rows, cfg, model, params)
        bench_integrity(rows, cfg, model, params)
        bench_paged_kv(rows, cfg, model, params)
        return
    bench_fused_vs_loop(rows, model, params, batch)
    bench_backend_parity(rows, model, params, batch, repeats=3)
    bench_sharded_mesh(rows, model, params, batch)
    bench_overlap_pipeline(rows, model, params, batch)
    bench_quantized_io(rows, model, params, batch)
    bench_plan_reuse(rows, model, params, batch)
    bench_cache_sweep(rows, model, params, batch, cfg)
    bench_scheduler_admission(rows, cfg, model, params)
    bench_continuous_batching(rows, cfg, model, params)
    bench_fault_robustness(rows, cfg, model, params)
    bench_integrity(rows, cfg, model, params, decode_tokens=8)
    bench_paged_kv(rows, cfg, model, params, decode_tokens=8,
                   combos=(("reference", 16), ("reference", 8),
                           ("kernel", 16), ("kernel", 8)))


def _emit_json(rows: Rows, path: str, smoke: bool) -> None:
    payload = {
        "bench": "serve_throughput",
        "arch": ARCH,
        "smoke": smoke,
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows.rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def build_parser() -> argparse.ArgumentParser:
    """Exposed for tests/test_docs.py's docs-vs-CLI drift check."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI: every section, a minute or two on CPU")
    ap.add_argument("--out", default=None,
                    help="also write the rows as JSON (the CI perf artifact, "
                         "e.g. BENCH_serve.json)")
    return ap


if __name__ == "__main__":
    args = build_parser().parse_args()
    rows = Rows()
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    run(rows, smoke=args.smoke)
    rows.emit()
    print(f"# total {time.perf_counter() - t0:.1f}s")
    if args.out:
        _emit_json(rows, args.out, args.smoke)
