"""Chunk-gather kernel microbench: fused multi-site DMA dispatch vs the
per-site kernel inventory.

Interpret-mode on CPU (this container and CI have no TPU), so wall numbers
measure the *schedule's Python emulation*, not MXU throughput — the rows
that matter for the perf trajectory are structural and deterministic:

  * ``kernel/dispatches_*`` — pallas_call dispatches one decode layer's
    refresh step costs per path. Per-site: one ``sparse_matmul`` per matrix
    that doesn't share a fetch (q, k, v, o, down) plus one
    ``sparse_swiglu`` (gate/up fused); fused: the MLP collapses to ONE
    ``chunk_gather_mlp_dma`` call (gate/up/down off the batched
    ``(n_sites, K)`` plan lanes, SwiGLU intermediate never leaves VMEM).
  * ``kernel/bytes_*`` — modeled HBM traffic of the two paths from the SAME
    batched chunk plan: weight bytes are identical by construction (the fused
    kernel fetches the same chunk tables); the saving is the SwiGLU
    intermediate h (B × d_ff f32) that the per-site path writes then re-reads
    between the swiglu and down dispatches.
  * parity assertions — the fused kernel and the per-site kernels reproduce
    the ``chunk_gather_mlp_ref`` oracle on the plan actually produced by
    ``SparseExecution``'s batched refresh (tables routed straight from the
    plan carry, no host re-splitting).
  * ``kernel/tile_d*`` — the single-site DMA matmul swept over the output
    tile width (grid-step count vs VMEM slot budget; the ROADMAP's first
    real-TPU perf knob), parity asserted at every width.
  * ``kernel/quant_*`` — the quantized chunk format (PR 6): int8 payloads
    + per-block scale lanes fetched through the same DMA slot rotation and
    dequantized in VMEM, parity-checked against the dequantized-weights
    oracle, plus the same chunk plan's modeled row bytes priced at
    wbits=16 vs wbits=8 per site (ratio asserted under the serve smoke's
    ceiling).
  * ``kernel/decode_backend_*`` — end-to-end serve-engine decode through
    ``backend='kernel'`` vs ``backend='reference'``: byte-identical tokens
    asserted, wall tokens/s recorded for both.

Standalone:  PYTHONPATH=src python -m benchmarks.kernel_gather
CI artifact: PYTHONPATH=src python -m benchmarks.kernel_gather \
                 --smoke --out BENCH_kernel.json
(uploaded as the ``BENCH_kernel`` perf-trajectory artifact next to
``BENCH_serve.json`` by .github/workflows/ci.yml)
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import (
    chunk_gather_matmul_dma,
    chunk_gather_matmul_ref,
    chunk_gather_mlp_dma,
    chunk_gather_mlp_ref,
    dequantize_rows,
    masks_to_block_tables,
    quantize_rows,
    sparse_matmul_dma,
    sparse_mlp_fused,
    sparse_swiglu,
)
from repro.serving import SparseExecution
from repro.serving.sparse_exec import KERNEL_BLOCK_ROWS, KERNEL_MAX_CHUNK_ROWS

from .common import Rows, decode_backend_pair, llm_importance

ARCH = "internvl2-76b"
H_BYTES = 4  # the per-site path's SwiGLU intermediate round-trips as f32


def _layer_plan(sparse: SparseExecution, rng: np.random.Generator):
    """One layer's batched selection + kernel chunk tables, exactly the way
    a refresh step produces them: importance per site → ONE vmapped greedy →
    ONE vmapped mask→table conversion."""
    vs = np.zeros((sparse.batched.n_sites, sparse.batched.n_max), np.float32)
    for i, kind in enumerate(sparse.site_order):
        n = sparse.sites[kind].n
        vs[i, :n] = llm_importance(rng, n)
    masks, _ = sparse.batched.select(jnp.asarray(vs), sparse._budgets)
    kstarts, ksizes = masks_to_block_tables(
        masks, KERNEL_BLOCK_ROWS, KERNEL_MAX_CHUNK_ROWS
    )
    return masks, kstarts, ksizes


def _dispatch_and_bytes(sparse: SparseExecution, ksizes, batch: int):
    """(dispatches, modeled bytes) per layer refresh for both paths."""
    per_site_dispatch = 0
    weight_bytes = 0.0
    sizes = np.asarray(ksizes)
    for i, kind in enumerate(sparse.site_order):
        site = sparse.sites[kind]
        rows = float(sizes[i].sum())
        weight_bytes += rows * sparse.site_row_bytes(kind)
        if kind == "hidden_mlp":
            per_site_dispatch += 1  # gate/up already fuse (sparse_swiglu)
        else:
            per_site_dispatch += len(site.tables)  # one matmul per matrix
    d_ff = sparse.sites["ffn"].n if "ffn" in sparse.sites else 0
    h_round_trip = 2.0 * batch * d_ff * H_BYTES  # write + read between calls
    fused_dispatch = per_site_dispatch - 1  # swiglu + down matmul → one call
    return (
        per_site_dispatch,
        fused_dispatch,
        weight_bytes + h_round_trip,
        weight_bytes,
    )


def run(rows: Rows, smoke: bool = False) -> None:
    cfg = get_config(ARCH).reduced()
    rng = np.random.default_rng(7)
    sparse = SparseExecution(cfg, device="nano", sparsity=0.4, method="chunk")
    _masks, kstarts, ksizes = _layer_plan(sparse, rng)
    batch = 2

    per_site, fused, bytes_per_site, bytes_fused = _dispatch_and_bytes(
        sparse, ksizes, batch
    )
    assert fused < per_site, "fused path must issue fewer dispatches"
    assert bytes_fused < bytes_per_site, (
        "fused path must move fewer modeled bytes (no h round-trip)"
    )
    rows.add("kernel/dispatches_per_site", 0.0, f"count={per_site}")
    rows.add("kernel/dispatches_fused", 0.0,
             f"count={fused} saving={per_site - fused}")
    rows.add("kernel/bytes_per_site", 0.0, f"bytes={bytes_per_site:.0f}")
    rows.add("kernel/bytes_fused", 0.0,
             f"bytes={bytes_fused:.0f} "
             f"h_round_trip_saved={bytes_per_site - bytes_fused:.0f}")

    # -- interpret-mode execution: the fused kernel on the REAL plan lanes --
    order = list(sparse.site_order)
    ih, i_f = order.index("hidden_mlp"), order.index("ffn")
    n, f = sparse.sites["hidden_mlp"].n, sparse.sites["ffn"].n
    d = cfg.d_model
    wg = jnp.asarray(rng.normal(0, 0.05, (n, f)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.05, (n, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.05, (f, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (batch, n)), jnp.float32)
    lanes_s = jnp.stack([kstarts[ih], kstarts[i_f]])
    lanes_z = jnp.stack([ksizes[ih], ksizes[i_f]])

    depths = (1,) if smoke else (0, 1, 2)
    yref = chunk_gather_mlp_ref(wg, wu, wd, x, lanes_s, lanes_z)
    scale = float(jnp.max(jnp.abs(yref))) + 1.0
    for depth in depths:
        t0 = time.perf_counter()
        y = sparse_mlp_fused(wg, wu, wd, x, lanes_s, lanes_z,
                             max_chunk_rows=KERNEL_MAX_CHUNK_ROWS,
                             prefetch_depth=depth)
        y.block_until_ready()
        wall = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(y - yref))) / scale
        assert err < 1e-5, f"fused kernel diverged from oracle at depth {depth}: {err}"
        rows.add(f"kernel/fused_mlp_depth{depth}", wall * 1e6,
                 f"rel_err={err:.2e} interpret=cpu")

    # per-site path on the same plan: swiglu + down matmul, against the
    # same oracle (the two paths must agree bit-for-policy)
    h = sparse_swiglu(wg, wu, x, lanes_s[0], lanes_z[0],
                      max_chunk_rows=KERNEL_MAX_CHUNK_ROWS)
    y_ps = sparse_matmul_dma(wd, h, lanes_s[1], lanes_z[1],
                             max_chunk_rows=KERNEL_MAX_CHUNK_ROWS)
    err_ps = float(jnp.max(jnp.abs(y_ps - yref))) / scale
    assert err_ps < 1e-5, f"per-site path diverged from oracle: {err_ps}"
    rows.add("kernel/per_site_mlp_parity", 0.0, f"rel_err={err_ps:.2e}")

    if not smoke:
        # single-site DMA matmul parity across depths on the attn_out lane
        io_ = order.index("attn_out")
        n_o = sparse.sites["attn_out"].n
        w_o = jnp.asarray(rng.normal(0, 0.05, (n_o, d)), jnp.float32)
        x_o = jnp.asarray(rng.normal(0, 1, (batch, n_o)), jnp.float32)
        y0 = chunk_gather_matmul_ref(w_o, x_o, kstarts[io_], ksizes[io_])
        for depth in (0, 1, 2):
            y = sparse_matmul_dma(w_o, x_o, kstarts[io_], ksizes[io_],
                                  max_chunk_rows=KERNEL_MAX_CHUNK_ROWS,
                                  prefetch_depth=depth)
            err = float(jnp.max(jnp.abs(y - y0))) / (float(jnp.max(jnp.abs(y0))) + 1.0)
            assert err < 1e-5
            rows.add(f"kernel/matmul_dma_depth{depth}", 0.0, f"rel_err={err:.2e}")

    bench_tile_sweep(rows, sparse, kstarts, ksizes, rng, batch, smoke=smoke)
    bench_quantized_gather(rows, sparse, kstarts, ksizes, rng, batch,
                           smoke=smoke)
    bench_decode_backends(rows, smoke=smoke)


def bench_tile_sweep(rows: Rows, sparse, kstarts, ksizes, rng, batch: int,
                     smoke: bool = False) -> None:
    """``tile_d`` sweep of the single-site DMA matmul on the attn_out lane.

    tile_d is the kernel's output-column block: each grid step DMA-gathers
    one (block_rows × tile_d) weight tile, so a wider tile means fewer
    grid steps and larger contiguous transfers but a bigger VMEM slot
    budget ((prefetch_depth + 1) × block_rows × tile_d × dtype bytes per
    streamed operand). On real TPU this is the first knob of the ROADMAP's
    hardware perf pass; recorded here (interpret-mode wall, compiled &
    warmed) so the trajectory has a baseline shape, with parity asserted at
    every tile width (the schedule only re-tiles the same arithmetic)."""
    order = list(sparse.site_order)
    io_ = order.index("attn_out")
    n_o = sparse.sites["attn_out"].n
    d = sparse.cfg.d_model
    w_o = jnp.asarray(rng.normal(0, 0.05, (n_o, d)), jnp.float32)
    x_o = jnp.asarray(rng.normal(0, 1, (batch, n_o)), jnp.float32)
    y0 = chunk_gather_matmul_ref(w_o, x_o, kstarts[io_], ksizes[io_])
    scale = float(jnp.max(jnp.abs(y0))) + 1.0
    tiles = [t for t in (32, 64, 128) if d % t == 0]
    reps = 1 if smoke else 5
    for tile in tiles:
        y = sparse_matmul_dma(w_o, x_o, kstarts[io_], ksizes[io_],
                              tile_d=tile, max_chunk_rows=KERNEL_MAX_CHUNK_ROWS)
        y.block_until_ready()  # compile + warm
        err = float(jnp.max(jnp.abs(y - y0))) / scale
        assert err < 1e-5, f"tile_d={tile} diverged from oracle: {err}"
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            sparse_matmul_dma(w_o, x_o, kstarts[io_], ksizes[io_],
                              tile_d=tile,
                              max_chunk_rows=KERNEL_MAX_CHUNK_ROWS,
                              ).block_until_ready()
            walls.append(time.perf_counter() - t0)
        rows.add(f"kernel/tile_d{tile}", float(np.median(walls)) * 1e6,
                 f"rel_err={err:.2e} grid_steps={d // tile} interpret=cpu")


def bench_quantized_gather(rows: Rows, sparse, kstarts, ksizes, rng,
                           batch: int, smoke: bool = False) -> None:
    """The quantized chunk format through the DMA gather kernels (PR 6):
    int8 payloads + per-block f32 scale lanes ride the same async-copy slot
    rotation and are dequantized in VMEM before the f32 accumulation.
    Parity is asserted against the dequantized-weights reference oracle at
    every swept prefetch depth for BOTH kernels (single-site matmul on the
    attn_out lane, fused MLP on the hidden_mlp/ffn lanes); the bytes sweep
    prices the SAME chunk plan at wbits=16 vs 8 via two SparseExecution
    instances and asserts the per-site ratio stays under the serve smoke's
    QUANTIZED_BYTES_RATIO_MAX ceiling."""
    from .serve_throughput import QUANTIZED_BYTES_RATIO_MAX

    order = list(sparse.site_order)
    d = sparse.cfg.d_model

    # -- single-site quantized matmul on the attn_out lane -------------------
    io_ = order.index("attn_out")
    n_o = sparse.sites["attn_out"].n
    w_o = jnp.asarray(rng.normal(0, 0.05, (n_o, d)), jnp.float32)
    x_o = jnp.asarray(rng.normal(0, 1, (batch, n_o)), jnp.float32)
    q_o, s_o = quantize_rows(w_o, KERNEL_BLOCK_ROWS)
    yref = chunk_gather_matmul_ref(
        dequantize_rows(q_o, s_o, KERNEL_BLOCK_ROWS), x_o,
        kstarts[io_], ksizes[io_],
    )
    scale = float(jnp.max(jnp.abs(yref))) + 1.0
    depths = (1,) if smoke else (0, 1, 2)
    for depth in depths:
        t0 = time.perf_counter()
        y = chunk_gather_matmul_dma(q_o, x_o, kstarts[io_], ksizes[io_], s_o,
                                    block_rows=KERNEL_BLOCK_ROWS,
                                    max_chunk_rows=KERNEL_MAX_CHUNK_ROWS,
                                    prefetch_depth=depth, interpret=True)
        y.block_until_ready()
        wall = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(y - yref))) / scale
        assert err < 1e-5, (
            f"quantized matmul diverged from dequantized oracle at depth "
            f"{depth}: {err}"
        )
        rows.add(f"kernel/quant_matmul_depth{depth}", wall * 1e6,
                 f"rel_err={err:.2e} interpret=cpu")

    # -- fused quantized MLP on the real hidden_mlp/ffn plan lanes -----------
    ih, i_f = order.index("hidden_mlp"), order.index("ffn")
    n, f = sparse.sites["hidden_mlp"].n, sparse.sites["ffn"].n
    wg = jnp.asarray(rng.normal(0, 0.05, (n, f)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.05, (n, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.05, (f, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (batch, n)), jnp.float32)
    qg, sg = quantize_rows(wg, KERNEL_BLOCK_ROWS)
    qu, su = quantize_rows(wu, KERNEL_BLOCK_ROWS)
    qd, sd = quantize_rows(wd, KERNEL_BLOCK_ROWS)
    lanes_s = jnp.stack([kstarts[ih], kstarts[i_f]])
    lanes_z = jnp.stack([ksizes[ih], ksizes[i_f]])
    yref_m = chunk_gather_mlp_ref(
        dequantize_rows(qg, sg, KERNEL_BLOCK_ROWS),
        dequantize_rows(qu, su, KERNEL_BLOCK_ROWS),
        dequantize_rows(qd, sd, KERNEL_BLOCK_ROWS),
        x, lanes_s, lanes_z,
    )
    scale_m = float(jnp.max(jnp.abs(yref_m))) + 1.0
    for depth in depths:
        t0 = time.perf_counter()
        y = chunk_gather_mlp_dma(qg, qu, qd, x, lanes_s, lanes_z,
                                 scales=(sg, su, sd),
                                 block_rows=KERNEL_BLOCK_ROWS,
                                 max_chunk_rows=KERNEL_MAX_CHUNK_ROWS,
                                 prefetch_depth=depth, interpret=True)
        y.block_until_ready()
        wall = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(y - yref_m))) / scale_m
        assert err < 1e-5, (
            f"quantized fused MLP diverged from dequantized oracle at depth "
            f"{depth}: {err}"
        )
        rows.add(f"kernel/quant_mlp_depth{depth}", wall * 1e6,
                 f"rel_err={err:.2e} interpret=cpu")

    # -- bytes sweep: the same chunk plan priced at 16 vs 8 bits -------------
    sparse8 = SparseExecution(sparse.cfg, device="nano", sparsity=0.4,
                              method="chunk", wbits=8)
    sizes = np.asarray(ksizes)
    total16 = total8 = 0.0
    for i, kind in enumerate(order):
        rows_sel = float(sizes[i].sum())
        b16 = rows_sel * sparse.site_row_bytes(kind)
        b8 = rows_sel * sparse8.site_row_bytes(kind)
        total16 += b16
        total8 += b8
        ratio = b8 / b16
        assert ratio <= QUANTIZED_BYTES_RATIO_MAX, (
            f"site {kind}: quantized row bytes ratio {ratio:.3f} exceeds "
            f"{QUANTIZED_BYTES_RATIO_MAX}"
        )
        rows.add(f"kernel/quant_bytes_{kind}", 0.0,
                 f"bytes_w16={b16:.0f} bytes_w8={b8:.0f} ratio={ratio:.3f}")
    rows.add("kernel/quant_bytes_total", 0.0,
             f"bytes_w16={total16:.0f} bytes_w8={total8:.0f} "
             f"ratio={total8 / total16:.3f} "
             f"ceiling={QUANTIZED_BYTES_RATIO_MAX}")


def bench_decode_backends(rows: Rows, smoke: bool = False) -> None:
    """End-to-end decode through the execution backends: the serve engine's
    fused scan with ``backend='kernel'`` (the DMA kernels consuming the
    decode plan inside the scan) vs ``backend='reference'`` (the pure-jnp
    schedule twin), byte-identical tokens asserted
    (``common.decode_backend_pair`` — the same helper the serve smoke
    pins), wall tokens/s for both recorded into BENCH_kernel.json.
    Interpret-mode kernels on CPU — the kernel row tracks emulation
    overhead, the parity bit is the invariant."""
    import jax

    from repro.configs.base import InputShape
    from repro.models import build_model
    from repro.models.inputs import make_dummy_batch

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, InputShape("kb", 16, 2, "train"))
    n_tokens = 4 if smoke else 16
    results = decode_backend_pair(model, params, batch, max_seq=64,
                                  batch_size=2, n_tokens=n_tokens, seed=7)
    for backend, (_eng, _out, wall) in results.items():
        rows.add(f"kernel/decode_backend_{backend}",
                 wall / n_tokens * 1e6,
                 f"tokens_per_s={n_tokens * 2 / wall:.1f} "
                 "identical_tokens=True")


def _emit_json(rows: Rows, path: str, smoke: bool) -> None:
    payload = {
        "bench": "kernel_gather",
        "arch": ARCH,
        "smoke": smoke,
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows.rows
        ],
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


def build_parser() -> argparse.ArgumentParser:
    """Exposed for tests/test_docs.py's docs-vs-CLI drift check."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI mode: one depth, still asserts parity")
    ap.add_argument("--out", default=None,
                    help="also write rows as JSON (e.g. BENCH_kernel.json)")
    return ap


if __name__ == "__main__":
    args = build_parser().parse_args()
    rows = Rows()
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    run(rows, smoke=args.smoke)
    rows.emit()
    print(f"# total {time.perf_counter() - t0:.1f}s")
    if args.out:
        _emit_json(rows, args.out, args.smoke)
