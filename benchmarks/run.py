"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Latency values come from the
paper-calibrated flash simulator (DESIGN.md §6) except fig13/fig8 selection
overhead, which is real host wall-clock of the jit-compiled selector.

Usage:
  PYTHONPATH=src python -m benchmarks.run           # everything
  PYTHONPATH=src python -m benchmarks.run fig6 fig9 # subset
"""
from __future__ import annotations

import sys
import time

from .common import Rows


def main() -> None:
    from . import (
        appg_reorder,
        appk_token_density,
        appn_llm,
        disc5_caching,
        fig3_throughput,
        fig4_sparsity_latency,
        fig5_latency_model,
        fig6_tradeoff,
        fig8_breakdown,
        fig9_ablation,
        fig10_contiguity,
        fig13_overhead,
        roofline,
        serve_throughput,
        table1_cv,
        table3_bundling,
    )

    modules = {
        "fig3": fig3_throughput,
        "fig4": fig4_sparsity_latency,
        "fig5": fig5_latency_model,
        "fig6": fig6_tradeoff,
        "fig8": fig8_breakdown,
        "fig9": fig9_ablation,
        "fig10": fig10_contiguity,
        "fig13": fig13_overhead,
        "table1": table1_cv,
        "table3": table3_bundling,
        "appg": appg_reorder,
        "appk": appk_token_density,
        "appn": appn_llm,
        "disc5": disc5_caching,
        "roofline": roofline,
        "serve": serve_throughput,
    }
    selected = sys.argv[1:] or list(modules)
    rows = Rows()
    print("name,us_per_call,derived")
    for name in selected:
        mod = modules[name]
        t0 = time.time()
        mod.run(rows)
        rows.add(f"_meta/{name}/bench_wall_s", (time.time() - t0) * 1e6, "")
    rows.emit()


if __name__ == "__main__":
    main()
