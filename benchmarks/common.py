"""Shared benchmark utilities.

Importance-vector generator calibrated to the paper's Table 1/App. C: VLM
(gated-activation, multi-token-averaged) profiles have CV ≈ 1.1–3.3; ReLU
LLM decode profiles have CV ≈ 8–12. ``table1_cv`` validates the generator
against those bands. Latency numbers are produced by the FlashOffload
simulator (DESIGN.md §6) — they reproduce the paper's published device
behaviour, not new hardware measurements.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import numpy as np


def vlm_importance(rng: np.random.Generator, n: int, tokens: int = 196) -> np.ndarray:
    """Smooth multi-token-averaged importance (VLM frame append).

    Per-neuron scale ~ lognormal (hot/cold structure) × per-token |N(0,1)|
    averaged over ``tokens`` → CV in the 1.07–4.55 band of Table 1
    (σ=1.05 ⇒ CV ≈ 1.4; validated by benchmarks/table1_cv.py)."""
    scale = rng.lognormal(0.0, 1.05, n)
    acts = np.abs(rng.normal(0, 1, (tokens, n))) * scale
    return acts.mean(0).astype(np.float32)


def relu_llm_importance(rng: np.random.Generator, n: int) -> np.ndarray:
    """Spiky single-token ReLU-LLM decode importance (CV ≈ 8–12)."""
    active = rng.random(n) < 0.04
    mags = rng.lognormal(1.5, 1.0, n)
    return np.where(active, mags, rng.random(n) * 1e-2).astype(np.float32)


def llm_importance(rng: np.random.Generator, n: int) -> np.ndarray:
    """Plain gated-LLM single-token decode: smoother than ReLU, spikier
    than multi-token VLM (App. N)."""
    scale = rng.lognormal(0.0, 0.8, n)
    return (np.abs(rng.normal(0, 1, n)) * scale).astype(np.float32)


class ImportanceModel:
    """Stateful generator: per-neuron hot/cold scale is FIXED (as in a real
    network) while per-sample structure varies — so calibration-based
    reordering has real but IMPERFECT structure to exploit (App. F: "many
    neurons are neither always-on nor always-off").

    ``jitter``: stddev of per-sample lognormal modulation of each neuron's
    scale — controls how input-dependent the importance ordering is. The
    paper's ≤1.23× reordering-only gain implies substantial per-input
    variation; fig9/fig10 use jitter≈1.0."""

    def __init__(self, rng: np.random.Generator, n: int, sigma: float = 0.8,
                 jitter: float = 0.0):
        self.rng = rng
        self.n = n
        self.sigma = sigma
        self.jitter = jitter
        self.scale = rng.lognormal(0.0, sigma, n)

    def sample(self, tokens: int = 196) -> np.ndarray:
        scale = self.scale
        if self.jitter:
            scale = scale * self.rng.lognormal(0.0, self.jitter, self.n)
        acts = np.abs(self.rng.normal(0, 1, (tokens, self.n))) * scale
        return acts.mean(0).astype(np.float32)

    def calibration(self, n_samples: int, tokens: int = 196) -> np.ndarray:
        return np.stack([self.sample(tokens) for _ in range(n_samples)])


def cv(v: np.ndarray) -> float:
    return float(v.std() / max(v.mean(), 1e-12))


def time_call(fn: Callable, *args, repeats: int = 5) -> float:
    """Median wall seconds of a jitted callable (block_until_ready)."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def decode_backend_pair(model, params, batch, *, max_seq: int, batch_size: int,
                        n_tokens: int, seed: int, repeats: int = 1,
                        warm: bool = True, wbits: int = 16):
    """Run the SAME greedy decode through both execution backends
    (kernels/backend.py) and assert byte-identical tokens — the PR-5
    invariant both benchmark artifacts pin, extended in PR 6 to the
    quantized chunk format (``wbits=8``: in-kernel dequantization vs the
    reference twin's identical per-block multiply). Returns
    {backend: (engine, tokens, median_wall_s)}.

    Shared by ``serve_throughput.bench_backend_parity`` (BENCH_serve rows)
    and ``kernel_gather.bench_decode_backends`` (BENCH_kernel rows) so the
    two smokes cannot drift apart on what "parity" means."""
    import jax.numpy as jnp

    from repro.serving import ServeEngine

    results = {}
    outs = {}
    for backend in ("reference", "kernel"):
        eng = ServeEngine(model, params, max_seq=max_seq,
                          batch_size=batch_size, device="nano", sparsity=0.4,
                          method="chunk", seed=seed, backend=backend,
                          wbits=wbits)
        eng.simulator.noise = 0.0
        tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
        if warm:
            eng.decode(tok0, n_tokens)  # compile + warm
            eng.prefill(batch)
        out = None
        walls = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            o = eng.decode(tok0, n_tokens)
            jax.block_until_ready(o)
            walls.append(time.perf_counter() - t0)
            out = o if out is None else out
        outs[backend] = out
        results[backend] = (eng, out, float(np.median(walls)))
    assert bool(jax.numpy.all(outs["reference"] == outs["kernel"])), (
        f"backend='kernel' decode must produce byte-identical tokens to "
        f"backend='reference' (interpret mode, wbits={wbits})"
    )
    return results


class Rows:
    """Collects (name, us_per_call, derived) CSV rows."""

    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append((name, float(us_per_call), derived))

    def emit(self) -> None:
        for name, us, derived in self.rows:
            print(f"{name},{us:.3f},{derived}")


# paper-relevant matrix shapes (rows = input neurons, cols = outputs)
LLAVA7B_SHAPES = {
    "q": (3584, 3584),
    "o": (3584, 3584),
    "gate": (3584, 18944),
    "down": (18944, 3584),
}
