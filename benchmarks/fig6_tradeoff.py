"""Fig. 6/7: the headline accuracy–latency trade-off, top-k baseline vs
NEURON CHUNKING, on both devices across the paper's five model geometries.

Accuracy proxy: importance retention (the paper's own App. N proxy).
Speedup at matched retention is computed by linear interpolation along the
chunk curve, mirroring the paper's "at comparable accuracy" protocol
(mean 2.19× Nano / 2.89× AGX, max 4.65× / 5.76×).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import ChunkConfig, ChunkSelector, retention, topk_mask_np

from .common import ImportanceModel, Rows

# (d_model, d_ff) of the paper's five evaluation models
MODEL_SHAPES = {
    "llava-7b": (3584, 18944),
    "llava-0.5b": (896, 4864),
    "vila-8b": (4096, 14336),
    "nvila-2b": (1536, 8960),
    "longva-7b": (3584, 18944),
}
SPARSITIES = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]


def tradeoff_curves(
    n: int, cols: int, device: str, seed: int = 0
) -> Dict[str, List[Tuple[float, float]]]:
    """Returns {method: [(retention, latency_s)]} for one weight matrix."""
    rng = np.random.default_rng(seed)
    imp = ImportanceModel(rng, n)
    v = imp.sample()
    vj = jnp.asarray(v)
    row_bytes = cols * 2
    sel = ChunkSelector.build(
        n, row_bytes, device=device,
        cfg=ChunkConfig.for_shape(n, cols, device),
    )
    out = {"topk": [], "chunk": []}
    for sp in SPARSITIES:
        budget = int((1 - sp) * n)
        m_t = topk_mask_np(v, budget)
        lat_t = float(sel.table.mask_latency(jnp.asarray(m_t)))
        out["topk"].append((float(retention(vj, jnp.asarray(m_t))), lat_t))
        m_c, _, lat_c = sel.select(vj, jnp.int32(budget))
        out["chunk"].append((float(retention(vj, m_c)), float(lat_c)))
    return out


def matched_speedups(curves) -> List[float]:
    """For each top-k point, latency ratio vs the chunk curve interpolated
    at the same retention."""
    ch = sorted(curves["chunk"])
    ret_c = np.asarray([r for r, _ in ch])
    lat_c = np.asarray([l for _, l in ch])
    speedups = []
    for r_t, l_t in curves["topk"]:
        l_match = float(np.interp(r_t, ret_c, lat_c))
        speedups.append(l_t / max(l_match, 1e-12))
    return speedups


def run(rows: Rows) -> None:
    paper_avg = {"nano": 2.19, "agx": 2.89}
    paper_max = {"nano": 4.65, "agx": 5.76}
    for device in ("nano", "agx"):
        all_sp = []
        for name, (d, f) in MODEL_SHAPES.items():
            sp_q = matched_speedups(tradeoff_curves(d, d, device, seed=1))
            sp_down = matched_speedups(tradeoff_curves(f, d, device, seed=2))
            sp = sp_q + sp_down
            all_sp.extend(sp)
            rows.add(
                f"fig6/{device}/{name}",
                0.0,
                f"mean_speedup={np.mean(sp):.2f}x;max={np.max(sp):.2f}x",
            )
        rows.add(
            f"fig6/{device}/ALL",
            0.0,
            f"mean={np.mean(all_sp):.2f}x(paper {paper_avg[device]}x);"
            f"max={np.max(all_sp):.2f}x(paper {paper_max[device]}x)",
        )
