"""Nightly seeded fault sweep: every injection profile × seeds × backends.

Two planes, one artifact (``BENCH_faults.json``, uploaded by the nightly
CI job):

  * time plane (``FaultModel``, core/faults.py) — each fault profile runs
    the deadline-scheduled serving scenario with the adaptive
    ``DegradationController`` on, emitting SLO attainment, p99 latency and
    tokens/s per (profile, seed). Tokens never change on this plane, so
    every run also re-asserts byte-identity against the fault-off baseline.
  * data plane (``CorruptionModel``, PR 9) — each corruption profile runs
    the checksum-verified decode path per (seed, backend, wbits), emitting
    the detection/recovery/substitution/drop counters and the recovery
    rate. bit_rot (transient flips) must recover at exactly 1.0 with
    byte-identical tokens; the sticky profiles exercise the full ladder.

Everything is seeded and simulator noise is zeroed: the artifact's numbers
replay exactly, so a nightly diff is a real behavior change, never jitter.

Standalone:  PYTHONPATH=src python -m benchmarks.fault_sweep
CI artifact: PYTHONPATH=src python -m benchmarks.fault_sweep \
                 --out BENCH_faults.json
(--smoke shrinks the matrix to one seed and the two canonical
backend/wbits combos for a quick local pass.)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.faults import CORRUPTION_PROFILES, FAULT_PROFILES
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import Request, Scheduler, ServeEngine

from .common import Rows

ARCH = "internvl2-76b"
BATCH = 2
PROMPT_LEN = 32
MAX_SEQ = 128
DECODE_TOKENS = 6
DEADLINE_S = 0.03
ARRIVAL_GAP_S = 0.002
N_REQUESTS = 8


def _setup():
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, rng):
    out = []
    for rid in range(N_REQUESTS):
        p = dict(make_dummy_batch(cfg, InputShape("req", PROMPT_LEN, 1,
                                                  "train")))
        p["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, p["tokens"].shape), jnp.int32
        )
        out.append(Request(rid=rid, prompt=p, max_new_tokens=DECODE_TOKENS,
                           arrival_s=ARRIVAL_GAP_S * rid,
                           deadline_s=DEADLINE_S))
    return out


def sweep_time_plane(rows: Rows, cfg, model, params, seeds) -> None:
    """FaultModel profiles under the deadline scheduler, controller on."""
    tok0 = jnp.ones((BATCH, 1), jnp.int32)
    base = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                       device="nano", sparsity=0.4, method="chunk", seed=5)
    t_base = np.asarray(base.decode(tok0, DECODE_TOKENS))
    for profile in sorted(set(FAULT_PROFILES) - {"none"}):
        for seed in seeds:
            eng = ServeEngine(model, params, max_seq=MAX_SEQ,
                              batch_size=BATCH, device="nano", sparsity=0.4,
                              method="chunk", seed=5,
                              fault_profile=profile, fault_seed=seed,
                              degrade=True)
            eng.simulator.noise = 0.0
            t = np.asarray(eng.decode(tok0, DECODE_TOKENS))
            assert np.array_equal(t_base, t), (
                f"{profile}/seed={seed}: time-plane faults moved tokens"
            )
            sched = Scheduler(eng, round_tokens=2)
            sched.submit(_requests(cfg, np.random.default_rng(17)))
            st = sched.run()
            fs = eng.fault_summary()
            rows.add(
                f"faults/{profile}/seed{seed}",
                st.latency_p99_s * 1e6,
                f"slo_attainment={st.slo_attainment:.3f} "
                f"tokens_per_s={st.tokens_per_s:.1f} "
                f"p99_ms={st.latency_p99_s * 1e3:.2f} "
                f"events={fs['fault_events']} retries={fs['fault_retries']} "
                f"degrade_scale={fs['degrade_scale']:.2f}",
            )


def sweep_data_plane(rows: Rows, cfg, model, params, seeds, combos) -> None:
    """CorruptionModel profiles through the checksum-verified decode path."""
    tok0 = jnp.ones((BATCH, 1), jnp.int32)
    bases = {}
    for backend, wbits in combos:
        b = ServeEngine(model, params, max_seq=MAX_SEQ, batch_size=BATCH,
                        device="nano", sparsity=0.4, method="chunk", seed=5,
                        backend=backend, wbits=wbits)
        bases[(backend, wbits)] = np.asarray(b.decode(tok0, DECODE_TOKENS))
    for profile in sorted(set(CORRUPTION_PROFILES) - {"none"}):
        for seed in seeds:
            for backend, wbits in combos:
                eng = ServeEngine(model, params, max_seq=MAX_SEQ,
                                  batch_size=BATCH, device="nano",
                                  sparsity=0.4, method="chunk", seed=5,
                                  backend=backend, wbits=wbits,
                                  corruption_profile=profile,
                                  corruption_seed=seed)
                t = np.asarray(eng.decode(tok0, DECODE_TOKENS))
                s = eng.io_summary()
                det = s["corruptions_detected"]
                rec = s["corruptions_recovered"]
                identical = bool(np.array_equal(
                    bases[(backend, wbits)], t))
                if profile == "bit_rot" and det > 0:
                    # transient flips: the recovery floor CI gates on
                    assert det == rec and identical, (
                        f"bit_rot/seed={seed}/{backend}/w{wbits}: recovery "
                        f"rate {rec}/{det}, identical={identical}"
                    )
                rows.add(
                    f"corruption/{profile}/seed{seed}/{backend}_w{wbits}",
                    s["integrity_reread_s"] * 1e6,
                    f"detected={det:.0f} recovered={rec:.0f} "
                    f"substituted={s['corruptions_substituted']:.0f} "
                    f"dropped={s['corruptions_dropped']:.0f} "
                    f"recovery_rate={rec / det if det else 1.0:.3f} "
                    f"tokens_identical={identical}",
                )


def run(rows: Rows, smoke: bool = False) -> None:
    cfg, model, params = _setup()
    seeds = (0,) if smoke else (0, 1, 2)
    combos = ((("reference", 16), ("kernel", 8)) if smoke else
              (("reference", 16), ("kernel", 16),
               ("reference", 8), ("kernel", 8)))
    sweep_time_plane(rows, cfg, model, params, seeds)
    sweep_data_plane(rows, cfg, model, params, seeds, combos)


def _emit_json(rows: Rows, path: str, smoke: bool) -> None:
    payload = {
        "bench": "fault_sweep",
        "arch": ARCH,
        "smoke": smoke,
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows.rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one seed and two backend/wbits combos only")
    ap.add_argument("--out", default=None,
                    help="also write the rows as JSON (the nightly CI "
                         "artifact, e.g. BENCH_faults.json)")
    return ap


if __name__ == "__main__":
    args = build_parser().parse_args()
    rows = Rows()
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    run(rows, smoke=args.smoke)
    rows.emit()
    print(f"# total {time.perf_counter() - t0:.1f}s")
    if args.out:
        _emit_json(rows, args.out, args.smoke)
