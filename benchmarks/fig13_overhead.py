"""Fig. 13 / App. H: chunk-selection runtime overhead per weight-matrix
shape (paper budget: < 2 ms on Jetson GPU radix sort; we measure the
jit-compiled JAX selector on this host CPU — reported, not gated)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ChunkConfig, ChunkSelector

from .common import ImportanceModel, Rows, time_call

# representative shapes from the paper's Table 2
SHAPES = [(3584, 3584), (18944, 3584), (896, 4864), (4096, 14336), (1536, 8960)]


def run(rows: Rows) -> None:
    rng = np.random.default_rng(9)
    for (n, cols) in SHAPES:
        sel = ChunkSelector.build(n, cols * 2, device="nano",
                                  cfg=ChunkConfig.for_shape(n, cols, "nano"))
        v = jnp.asarray(ImportanceModel(rng, n).sample())
        budget = jnp.int32(int(0.6 * n))
        wall = time_call(lambda: sel.select(v, budget), repeats=5)
        rows.add(
            f"fig13/select_{n}x{cols}",
            wall * 1e6,
            f"candidates={sel.num_candidates};host_cpu_ms={wall*1e3:.2f}",
        )
