"""Storage fault injection, adaptive degradation, deadline-aware serving.

The headline invariants (ISSUE 8):

  * faults disabled (default) ⇒ greedy tokens AND ``io_summary()`` are
    byte/bit-identical to an engine without the fault machinery, across
    backends and wbits (``select_overhead_s`` is excluded everywhere — it
    is wall-clock measured and differs even between two identical runs);
  * faults enabled ⇒ tokens are UNCHANGED (time-only perturbation), and a
    given (profile, fault_seed) replays bit-identically;
  * under a sustained thermal throttle with per-request deadlines the
    DegradationController strictly improves SLO attainment and p99 over
    the controller-off baseline, and the degraded baseline exhibits the
    preempt-and-requeue path.

The nightly ``slow`` tier adds a seeded fault-trajectory sweep across
every profile × several seeds × both backends/wbits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.faults import (
    FAULT_PROFILES,
    FaultModel,
    FaultProfile,
    ThermalTrajectory,
    get_fault_profile,
)
from repro.core.offload import FlashOffloadSimulator
from repro.models import build_model
from repro.serving import (
    DegradationController,
    Request,
    Scheduler,
    ServeEngine,
    set_plan_budget_scale,
)

slow = pytest.mark.slow

# Aggressive deterministic profile for the perturbation tests: the throttle
# engages immediately (onset 0, ~instant ramp), so every event past the
# first microsecond of device time is charged at 2x regardless of how few
# events a short decode emits or which probabilistic draws land.
HAMMER = FaultProfile(
    "hammer", spike_prob=0.3, spike_scale=4.0, fail_prob=0.2, max_retries=3,
    throttle=ThermalTrajectory(onset_s=0.0, ramp_s=1e-6, floor=0.5),
)


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("method", "chunk")
    return ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                       sparsity=0.4, seed=1, **kw)


def _sim_summary(eng):
    """io_summary minus the wall-clock-measured selection-overhead lane
    (run-to-run noisy by construction; everything else is simulated and
    must be bit-identical where the tests say so)."""
    s = eng.io_summary()
    s.pop("select_overhead_s")
    return s


# -- ThermalTrajectory -------------------------------------------------------


def test_thermal_trajectory_sustained_shape():
    tr = ThermalTrajectory(onset_s=2e-3, ramp_s=10e-3, floor=0.25)
    assert tr.scale(0.0) == 1.0
    assert tr.scale(2e-3) == 1.0  # onset boundary still full speed
    mid = tr.scale(7e-3)  # halfway down the ramp
    assert 0.25 < mid < 1.0
    assert mid == pytest.approx(1.0 - 0.75 * 0.5)
    assert tr.scale(12e-3) == pytest.approx(0.25)
    assert tr.scale(1.0) == pytest.approx(0.25)  # sustained: never recovers


def test_thermal_trajectory_cycle_recovers():
    tr = ThermalTrajectory(onset_s=0.0, ramp_s=10e-3, floor=0.4, period_s=40e-3)
    low = tr.scale(15e-3)  # fully ramped within the first half
    assert low == pytest.approx(0.4)
    # second half recovers linearly back toward full speed
    assert tr.scale(30e-3) == pytest.approx(0.7)
    assert tr.scale(39.9e-3) > 0.99
    # and the pattern repeats next period
    assert tr.scale(55e-3) == pytest.approx(tr.scale(15e-3))


def test_thermal_trajectory_validation():
    with pytest.raises(ValueError, match="floor"):
        ThermalTrajectory(floor=0.0)
    with pytest.raises(ValueError, match="floor"):
        ThermalTrajectory(floor=1.5)
    with pytest.raises(ValueError):
        ThermalTrajectory(onset_s=-1.0)


# -- FaultProfile / FaultModel ----------------------------------------------


def test_fault_profile_validation():
    with pytest.raises(ValueError, match="spike_prob"):
        FaultProfile("bad", spike_prob=1.0)
    with pytest.raises(ValueError, match="spike_scale"):
        FaultProfile("bad", spike_scale=0.5)
    with pytest.raises(ValueError, match="fail_prob"):
        FaultProfile("bad", fail_prob=-0.1)
    with pytest.raises(ValueError, match="max_retries"):
        FaultProfile("bad", max_retries=-1)
    with pytest.raises(KeyError, match="unknown fault profile"):
        get_fault_profile("nope")


def test_fault_model_none_is_inert():
    fm = FaultModel("none", seed=0)
    assert not fm.enabled
    out = fm.perturb(1e-3, 0.0)
    assert out.charged_s == 1e-3 and out.retries == 0 and not out.spiked


def test_fault_model_deterministic_replay():
    a = FaultModel("degraded_nvme", seed=11)
    b = FaultModel("degraded_nvme", seed=11)
    busy_a = busy_b = 0.0
    for _ in range(200):
        oa = a.perturb(1e-4, busy_a)
        ob = b.perturb(1e-4, busy_b)
        assert oa.charged_s == ob.charged_s
        busy_a += oa.charged_s
        busy_b += ob.charged_s
    assert a.summary() == b.summary()
    # a different seed produces a different trajectory
    c = FaultModel("degraded_nvme", seed=12)
    for i in range(200):
        c.perturb(1e-4, i * 1e-4)
    assert c.summary() != a.summary()


def test_fault_model_retry_accounting_exact():
    """Transient failures: charged = (retries+1) × read + geometric
    backoff, exactly — the retry ledger must balance to the event charge."""
    p = FaultProfile("retry_only", fail_prob=0.5, max_retries=5,
                     backoff_base_s=1e-4, backoff_mult=2.0)
    fm = FaultModel(p, seed=3)
    saw_retry = False
    for _ in range(100):
        out = fm.perturb(1e-3, 0.0)
        assert out.charged_s == pytest.approx(
            1e-3 * (out.retries + 1) + out.backoff_s
        )
        if out.retries:
            saw_retry = True
            assert out.backoff_s == pytest.approx(
                1e-4 * (2.0 ** out.retries - 1)  # Σ base·mult^k, k<retries
            )
    assert saw_retry
    assert fm.summary()["retries"] > 0


def test_fault_model_spike_multiplies():
    p = FaultProfile("spiky", spike_prob=0.3, spike_scale=6.0)
    fm = FaultModel(p, seed=0)
    outs = [fm.perturb(1e-3, 0.0) for _ in range(100)]
    spiked = [o for o in outs if o.spiked]
    clean = [o for o in outs if not o.spiked]
    assert spiked and clean
    assert all(o.charged_s == pytest.approx(6e-3) for o in spiked)
    assert all(o.charged_s == pytest.approx(1e-3) for o in clean)


def test_fault_model_throttle_divides_latency():
    fm = FaultModel("thermal_throttle", seed=0)
    # before onset: clean; deep past the ramp: clean / floor
    assert fm.perturb(1e-4, 0.0).charged_s == pytest.approx(1e-4)
    assert fm.perturb(1e-4, 1.0).charged_s == pytest.approx(1e-4 / 0.25)
    assert fm.summary()["min_throttle_scale"] == pytest.approx(0.25)


def test_fault_profiles_registry():
    assert set(FAULT_PROFILES) >= {
        "none", "tail_spikes", "flaky_reads", "thermal_throttle",
        "thermal_cycle", "degraded_nvme",
    }
    assert not FAULT_PROFILES["none"].spike_prob


def test_fault_model_retries_reprice_at_advanced_clock():
    """Regression (PR 9 satellite): each retry's read must be priced at
    the throttle scale of the ADVANCED busy clock — first-attempt read +
    backoffs heat the device — not at the scale frozen from attempt 0.
    With a steep ramp the three reads land at three different scales; the
    frozen-scale bug would charge 3 × clean + backoff."""
    tr = ThermalTrajectory(onset_s=0.0, ramp_s=2e-3, floor=0.25)
    p = FaultProfile("steep", fail_prob=0.999999, max_retries=2,
                     backoff_base_s=1e-6, backoff_mult=1.0, throttle=tr)
    out = FaultModel(p, seed=0).perturb(1e-3, 0.0)
    assert out.retries == 2
    # hand-walk the clock: read0 at scale(0)=1.0, each retry re-reads at
    # the trajectory's scale of everything charged before it
    expect = 1e-3
    for _ in range(2):
        expect += 1e-6
        expect += 1e-3 / tr.scale(expect)
    assert out.charged_s == pytest.approx(expect)
    # strictly above what the frozen first-attempt scale would charge
    assert out.charged_s > 3e-3 + out.backoff_s + 1e-4
    # first attempt ran unthrottled; the outcome records attempt-0's scale
    assert out.throttle_scale == 1.0


# -- simulator measurement boundary ------------------------------------------


def test_simulator_fault_off_log_identical():
    """Attaching an inert FaultModel must not shift the simulator's RNG
    stream or event log in any way."""
    a = FlashOffloadSimulator("nano", seed=5)
    b = FlashOffloadSimulator("nano", seed=5, faults=FaultModel("none", seed=9))
    est = np.array([1e-4, 0.0, 3e-4, 2e-4])
    la = a.measure_from_estimate_batch(est, name="x")
    lb = b.measure_from_estimate_batch(est, name="x")
    np.testing.assert_array_equal(la, lb)
    assert a.log == b.log
    assert a.measure_from_estimate(1e-4) == b.measure_from_estimate(1e-4)


def test_simulator_faults_charge_time_only():
    """Faults only inflate latency; estimates, byte accounting and the
    zero-estimate steps are untouched."""
    clean = FlashOffloadSimulator("nano", seed=5, noise=0.0)
    faulty = FlashOffloadSimulator(
        "nano", seed=5, noise=0.0,
        faults=FaultModel("thermal_throttle", seed=0),
    )
    est = np.full(64, 1e-3)
    lc = clean.measure_from_estimate_batch(est, name="d", nbytes=est * 1e6)
    lf = faulty.measure_from_estimate_batch(est, name="d", nbytes=est * 1e6)
    assert lf.sum() > lc.sum()
    assert np.all(lf >= lc - 1e-15)
    assert faulty.total_bytes() == clean.total_bytes()
    # the event log records where the extra time came from
    assert sum(e.fault_s for e in faulty.log) == pytest.approx(
        float(lf.sum() - lc.sum())
    )
    assert all(e.fault_s == 0.0 for e in clean.log)


# -- DegradationController ---------------------------------------------------


def test_controller_clean_device_stays_at_full_budget():
    c = DegradationController()
    for _ in range(50):
        c.observe(np.full(8, 1.0))
    assert c.scale == 1.0 and not c.degraded
    assert c.summary()["tighten_steps"] == 0


def test_controller_tightens_then_recovers():
    c = DegradationController()
    c.observe(np.full(16, 4.0))  # sustained throttle
    assert c.scale < 1.0 and c.degraded
    tightened = c.scale
    c.observe(np.full(16, 4.0))
    assert c.scale <= tightened
    assert c.scale >= c.min_scale
    # device recovers → scale walks back to 1.0
    for _ in range(10):
        c.observe(np.full(16, 1.0))
    assert c.scale == 1.0 and not c.degraded
    s = c.summary()
    assert s["tighten_steps"] > 0 and s["relax_steps"] > 0


def test_controller_ignores_non_finite_and_validates():
    c = DegradationController()
    c.observe([np.nan, np.inf, 0.0, -1.0])
    assert c.observations == 0 and c.scale == 1.0
    with pytest.raises(ValueError, match="hysteresis"):
        DegradationController(degrade_ratio=1.0, recover_ratio=1.2)
    with pytest.raises(ValueError, match="alpha"):
        DegradationController(alpha=0.0)


def test_controller_hysteresis_never_oscillates_between_thresholds():
    """A ratio held anywhere in (recover_ratio, degrade_ratio) moves the
    scale in NEITHER direction — from healthy it never tightens, from
    degraded it never relaxes. The dead band is what keeps a borderline
    device from flapping budgets every call."""
    c = DegradationController()  # recover 1.25 < 1.4 < degrade 1.6
    for _ in range(100):
        c.observe([1.4])
    assert c.scale == 1.0
    assert c.summary()["tighten_steps"] == 0
    c.observe(np.full(32, 4.0))  # force a degrade
    assert c.scale < 1.0
    # let the EWMA decay into the dead band (it converges to 1.4 — while it
    # is still above degrade_ratio the controller keeps tightening, which
    # is correct: the dead band is a property of the FILTERED signal)
    while c.ewma >= c.degrade_ratio:
        c.observe([1.4])
    held = c.scale
    assert held < 1.0
    for _ in range(100):
        c.observe([1.4])
    assert c.scale == held  # parked: no relax, no further tighten
    assert c.summary()["relax_steps"] == 0


def test_controller_monotone_tightening_clamps_at_floor():
    """Under a sustained 4× ratio the scale walks DOWN monotonically in
    exact ``step`` decrements, clamps at min_scale, and tighten_steps
    counts only real moves (not the saturated observations)."""
    c = DegradationController()
    seen = [c.scale]
    for _ in range(20):
        c.observe([4.0])
        seen.append(c.scale)
    assert all(b <= a for a, b in zip(seen, seen[1:]))
    assert seen[-1] == c.min_scale
    moves = [a - b for a, b in zip(seen, seen[1:]) if a != b]
    assert all(m == pytest.approx(c.step) for m in moves[:-1])
    assert c.summary()["tighten_steps"] == len(moves)
    # parked at the floor: more bad observations change nothing
    c.observe(np.full(16, 4.0))
    assert c.scale == c.min_scale


def test_controller_recovery_lands_exactly_on_one():
    """Relaxation must terminate at exactly 1.0 even when min_scale is not
    step-aligned (0.5 with step 0.2 walks 0.7 → 0.9 → 1.0, the last move
    a truncated half-step) — a 0.9999… scale would silently shave every
    future budget."""
    c = DegradationController(min_scale=0.5)
    while c.scale > c.min_scale:
        c.observe([4.0])
    assert c.scale == 0.5
    seen = []
    for _ in range(20):
        c.observe([1.0])
        seen.append(c.scale)
    assert seen[-1] == 1.0  # exact, not approx
    lifts = [b - a for a, b in zip([0.5] + seen, seen) if b != a]
    assert lifts == pytest.approx([0.2, 0.2, 0.1])
    assert not c.degraded


def test_controller_random_streams_keep_invariants():
    """Deterministic sweep over seeded random ratio streams (NaN/inf/zero
    spiked in): the scale stays inside [min_scale, 1.0], only moves in
    ≤ step increments, and non-finite entries never count as
    observations."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        c = DegradationController()
        finite_seen = 0
        prev = c.scale
        for _ in range(60):
            r = rng.gamma(2.0, rng.choice([0.4, 1.2]), size=6)
            r[rng.integers(0, 6)] = rng.choice([np.nan, np.inf, 0.0, -2.0])
            finite_seen += int(np.sum(np.isfinite(r) & (r > 0)))
            c.observe(r)
            assert c.min_scale <= c.scale <= 1.0
            assert abs(c.scale - prev) <= c.step + 1e-12
            assert c.degraded == (c.scale < 1.0)
            prev = c.scale
        assert c.observations == finite_seen


def test_controller_observe_corruption_maps_rate_to_ratio():
    """The second degrade signal: rate 0 observes the healthy 1.0 (inert),
    a sustained rate above (degrade_ratio-1)/gain tightens, and non-finite
    or negative rates are ignored entirely."""
    c = DegradationController()  # gain 20: rate 0.05 → ratio 2.0 > 1.6
    for _ in range(50):
        c.observe_corruption(0.0)
    assert c.scale == 1.0 and c.observations == 50
    before = c.observations
    c.observe_corruption(np.nan)
    c.observe_corruption(-0.1)
    assert c.observations == before and c.scale == 1.0
    for _ in range(10):
        c.observe_corruption(0.05)
    assert c.scale < 1.0
    with pytest.raises(ValueError, match="corruption_ratio_gain"):
        DegradationController(corruption_ratio_gain=-1.0)
    # gain 0 turns the signal off no matter how corrupt the device is
    c0 = DegradationController(corruption_ratio_gain=0.0)
    for _ in range(20):
        c0.observe_corruption(0.5)
    assert c0.scale == 1.0


@given(st.integers(0, 2**31 - 1), st.floats(1.61, 64.0), st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_controller_bounds_property(seed, ratio, steps):
    """Property: any sustained ratio above the degrade threshold drives the
    scale monotonically toward (never past) min_scale; a subsequent healthy
    stream always returns it to exactly 1.0."""
    rng = np.random.default_rng(seed)
    c = DegradationController()
    prev = 1.0
    for _ in range(steps):
        c.observe(np.full(rng.integers(1, 8), ratio))
        assert c.min_scale <= c.scale <= prev
        prev = c.scale
    for _ in range(40):
        c.observe(np.full(4, 1.0))
    assert c.scale == 1.0


def test_set_plan_budget_scale_validates():
    plan = {"site": {"bscale": jnp.ones((3,), jnp.float32)}}
    out = set_plan_budget_scale(plan, 0.5)
    np.testing.assert_allclose(np.asarray(out["site"]["bscale"]), 0.5)
    with pytest.raises(ValueError, match="scale"):
        set_plan_budget_scale(plan, 0.0)
    # plans without the leaf pass through untouched
    p2 = {"site": {"mask": jnp.zeros((3,))}}
    assert set_plan_budget_scale(p2, 0.5) is p2


# -- engine: the headline byte-identity invariants ---------------------------


@pytest.mark.parametrize("backend,wbits", [("reference", 16), ("kernel", 8)])
def test_engine_fault_off_byte_identity(lm, backend, wbits):
    """Fault machinery off (default) ⇒ tokens AND io_summary bit-identical
    to an engine constructed without any fault/degrade arguments."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    base = _engine(model, params, backend=backend, wbits=wbits)
    t_base = base.decode(tok0, 5)
    off = _engine(model, params, backend=backend, wbits=wbits,
                  fault_profile="none", fault_seed=123)
    t_off = off.decode(tok0, 5)
    np.testing.assert_array_equal(np.asarray(t_base), np.asarray(t_off))
    assert _sim_summary(base) == _sim_summary(off)
    fs = off.fault_summary()
    assert not fs["fault_enabled"] and fs["fault_events"] == 0


@pytest.mark.parametrize("backend,wbits", [("reference", 16), ("kernel", 8)])
def test_engine_faults_perturb_time_never_tokens(lm, backend, wbits):
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    base = _engine(model, params, backend=backend, wbits=wbits)
    t_base = base.decode(tok0, 5)
    s_base = _sim_summary(base)
    faulty = _engine(model, params, backend=backend, wbits=wbits,
                     fault_profile=HAMMER, fault_seed=3)
    t_faulty = faulty.decode(tok0, 5)
    s_faulty = _sim_summary(faulty)
    np.testing.assert_array_equal(np.asarray(t_base), np.asarray(t_faulty))
    assert s_faulty["io_est_s"] == s_base["io_est_s"]  # planning unchanged
    assert s_faulty["io_bytes"] == s_base["io_bytes"]
    assert s_faulty["io_sim_s"] > s_base["io_sim_s"]  # only time moved
    assert faulty.fault_summary()["fault_events"] > 0


def test_engine_fault_seed_deterministic(lm):
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    runs = []
    for _ in range(2):
        e = _engine(model, params, fault_profile=HAMMER, fault_seed=3)
        e.decode(tok0, 5)
        runs.append((_sim_summary(e), e.fault_summary()))
    assert runs[0] == runs[1]
    other = _engine(model, params, fault_profile=HAMMER, fault_seed=4)
    other.decode(tok0, 5)
    assert _sim_summary(other)["io_sim_s"] != runs[0][0]["io_sim_s"]


def test_engine_degrade_clean_device_identity(lm):
    """Controller on + healthy device: the scale never leaves 1.0 and the
    whole run (tokens, io_summary) is bit-identical to degrade-off — the
    bscale plan leaf at 1.0 reproduces the static budgets exactly."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    base = _engine(model, params)
    t_base = base.decode(tok0, 6)
    on = _engine(model, params, degrade=True)
    t_on = on.decode(tok0, 6)
    np.testing.assert_array_equal(np.asarray(t_base), np.asarray(t_on))
    assert _sim_summary(base) == _sim_summary(on)
    assert on.fault_summary()["degrade_scale"] == 1.0


def test_engine_degrade_tightens_under_throttle_and_cuts_io(lm):
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)

    def run(degrade):
        e = _engine(model, params, fault_profile="thermal_throttle",
                    fault_seed=0, degrade=degrade)
        e.simulator.noise = 0.0
        for _ in range(6):
            e.decode(tok0, 4)
        return e

    off = run(False)
    on = run(True)
    fs = on.fault_summary()
    assert fs["degrade_scale"] < 1.0
    assert fs["degrade_tighten_steps"] >= 1
    # tightened budgets stream fewer bytes and charge less simulated I/O
    assert on.io_summary()["io_bytes"] < off.io_summary()["io_bytes"]
    assert on.io_summary()["io_sim_s"] < off.io_summary()["io_sim_s"]


def test_engine_degrade_needs_selecting_method(lm):
    cfg, model, params = lm
    with pytest.raises(ValueError, match="degrade"):
        _engine(model, params, method="dense", degrade=True)


def test_engine_degrade_per_token_path_applies_scale(lm):
    """The per-token loop shares the call-boundary contract: after enough
    degraded calls its controller tightens too, and the plan carries the
    scale into the next call."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    e = _engine(model, params, fault_profile="thermal_throttle",
                fault_seed=0, degrade=True)
    e.simulator.noise = 0.0
    for _ in range(6):
        e.decode_per_token(tok0, 4)
    assert e.fault_summary()["degrade_scale"] < 1.0


# -- end to end: deadlines + preemption under sustained throttle -------------


def _deadline_requests(cfg, n, max_new=6, deadline=0.03, gap=0.002):
    rng = np.random.default_rng(0)
    out = []
    for rid in range(n):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
        out.append(Request(rid=rid, prompt={"tokens": toks},
                           max_new_tokens=max_new, arrival_s=gap * rid,
                           deadline_s=deadline))
    return out


def _run_throttled(cfg, model, params, degrade):
    eng = _engine(model, params, fault_profile="thermal_throttle",
                  fault_seed=0, degrade=degrade)
    eng.simulator.noise = 0.0  # fully deterministic under --fault-seed
    sched = Scheduler(eng, round_tokens=2)
    sched.submit(_deadline_requests(cfg, 8))
    return sched.run(), eng


def test_controller_on_beats_off_on_slo(lm):
    """The acceptance scenario: sustained thermal throttle, per-request
    deadlines. Controller ON yields strictly higher attainment and
    strictly lower p99; the degraded baseline blows deadlines and
    exercises the preempt-and-requeue path (the preempted request is
    requeued, readmitted and still finishes — the run drains)."""
    cfg, model, params = lm
    off, _ = _run_throttled(cfg, model, params, degrade=False)
    on, eng_on = _run_throttled(cfg, model, params, degrade=True)
    assert off.finished == on.finished == 8  # both drained completely
    assert on.slo_attainment > off.slo_attainment
    assert on.latency_p99_s < off.latency_p99_s
    assert off.preempted >= 1  # the degraded baseline preempts + requeues
    assert eng_on.fault_summary()["degrade_scale"] < 1.0
    # deterministic: same seeds replay the exact same stats
    off2, _ = _run_throttled(cfg, model, params, degrade=False)
    assert off2 == off


def test_preempted_run_replays_token_identical(lm):
    """Evict-and-requeue restarts generation from the prompt; under a fixed
    fault seed the whole preempting run — including every restarted
    request's final tokens — replays bit-identically, and every preempted
    request still delivers its full output."""
    cfg, model, params = lm

    def run_once():
        eng = _engine(model, params, fault_profile="thermal_throttle",
                      fault_seed=0)
        eng.simulator.noise = 0.0
        sched = Scheduler(eng, round_tokens=2)
        reqs = _deadline_requests(cfg, 8)
        sched.submit(reqs)
        sched.run()
        return reqs

    a = run_once()
    pre = [r for r in a if r.preemptions > 0]
    assert pre, "scenario must exercise preemption"
    assert all(len(r.tokens_out) == r.max_new_tokens for r in a)
    b = run_once()
    for ra, rb in zip(a, b):
        assert ra.tokens_out == rb.tokens_out
        assert ra.preemptions == rb.preemptions


# -- nightly seeded fault-trajectory sweep -----------------------------------


@slow
@pytest.mark.parametrize("profile", sorted(set(FAULT_PROFILES) - {"none"}))
def test_fault_trajectory_sweep(lm, profile):
    """Nightly tier: every fault profile × several seeds × both backends
    and wbits — tokens never change, time never shrinks, replay is exact."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    for backend, wbits in (("reference", 16), ("kernel", 16),
                           ("reference", 8), ("kernel", 8)):
        # tokens are only identical at FIXED wbits (int8 storage changes
        # values by design) — baseline each (backend, wbits) combo
        base = _engine(model, params, backend=backend, wbits=wbits)
        t_base = np.asarray(base.decode(tok0, 6))
        base_sim = _sim_summary(base)["io_sim_s"]
        for seed in (0, 1, 2):
            e = _engine(model, params, backend=backend, wbits=wbits,
                        fault_profile=profile, fault_seed=seed)
            t = np.asarray(e.decode(tok0, 6))
            np.testing.assert_array_equal(t_base, t)
            # faults can only add charged time, never remove it
            assert _sim_summary(e)["io_sim_s"] >= base_sim - 1e-12
            # exact replay
            e2 = _engine(model, params, backend=backend, wbits=wbits,
                         fault_profile=profile, fault_seed=seed)
            e2.decode(tok0, 6)
            assert _sim_summary(e2) == _sim_summary(e)
            assert e2.fault_summary() == e.fault_summary()
