"""Dynamic chunk residency cache (paper §5 applied at serve time): byte
budget is never exceeded, more cache → never more simulated I/O, the fused
scan and the per-token loop stay byte-identical with the cache enabled, and
hit-rate accounting is consistent from plan counters up to io_summary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.chunking import ChunkConfig, ChunkSelector, select_chunks_np
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import ServeEngine
from repro.serving.sparse_exec import (
    PIN_SCORE,
    SparseExecution,
    plan_hit_miss,
    residency_from_score,
)

DECODE_TOKENS = 10
BUDGETS_MB = (0.0, 1.0, 4.0)


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, InputShape("res", 8, 2, "train"))
    return cfg, model, params, batch


def _decode_engine(lm, cache_mb, method="chunk", per_token=False, refresh=2):
    cfg, model, params, batch = lm
    eng = ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                      sparsity=0.4, method=method, seed=1,
                      plan_refresh_interval=refresh, cache_mb=cache_mb)
    eng.simulator.noise = 0.0  # deterministic simulated measurements
    tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
    fn = eng.decode_per_token if per_token else eng.decode
    out = fn(tok0, DECODE_TOKENS)
    return eng, out


@pytest.fixture(scope="module")
def swept(lm):
    """One decode per cache budget, shared across the assertions below."""
    return {mb: _decode_engine(lm, mb) for mb in BUDGETS_MB}


# -- byte budget -------------------------------------------------------------


def test_residency_rank_eviction_never_exceeds_cap():
    rng = np.random.default_rng(0)
    for cap in (0, 1, 7, 64, 200):
        score = jnp.asarray(rng.normal(0, 1, (200,)).astype(np.float32))
        res = residency_from_score(score, cap)
        assert int(res.sum()) <= cap
        # never-inserted rows (score <= 0) are never resident
        assert not bool(jnp.any(res & (score <= 0.0)))
    # ties cannot overflow the cap (stable rank, not threshold comparison)
    res = residency_from_score(jnp.ones((50,), jnp.float32), 10)
    assert int(res.sum()) == 10


def test_engine_residency_stays_under_byte_budget(swept):
    for mb, (eng, _) in swept.items():
        ctx = eng.sparse_ctx
        if mb == 0.0:
            assert not ctx.cache_enabled
            continue
        caps = ctx.cache_caps
        assert caps is not None
        budget_bytes = mb * 1024 * 1024
        used = 0.0
        n_layers = eng.model.cfg.n_layers
        for kind, state in eng._plan.items():
            cap = caps[kind]
            for layer in range(n_layers):
                res = residency_from_score(state["score"][layer], cap)
                assert int(res.sum()) <= cap
                used += float(res.sum()) * ctx.site_row_bytes(kind)
        assert used <= budget_bytes * (1 + 1e-6), (
            f"resident bytes {used} exceed budget {budget_bytes}"
        )


# -- I/O vs budget -----------------------------------------------------------


def _decode_io_est(eng):
    return sum(s.io_est_s for s in eng.stats if s.kind == "decode")


def test_io_monotone_non_increasing_in_cache_budget(swept):
    ios = [_decode_io_est(swept[mb][0]) for mb in BUDGETS_MB]
    assert all(b <= a + 1e-12 for a, b in zip(ios, ios[1:])), ios
    # acceptance: any positive budget is STRICTLY below the cache-0 run
    assert all(io < ios[0] for io in ios[1:]), ios


def test_positive_budget_reports_hits(swept):
    s = swept[1.0][0].io_summary()
    assert s["hit_rows"] > 0 and 0.0 < s["cache_hit_rate"] < 1.0
    s0 = swept[0.0][0].io_summary()
    assert s0["hit_rows"] == 0 and s0["cache_hit_rate"] == 0.0


# -- scan vs per-token equivalence ------------------------------------------


def test_scan_vs_per_token_identical_with_cache(lm):
    eng_s, out_s = _decode_engine(lm, 1.0)
    eng_p, out_p = _decode_engine(lm, 1.0, per_token=True)
    assert bool(jnp.all(out_s == out_p)), "tokens diverged with cache enabled"
    np.testing.assert_allclose(_decode_io_est(eng_s), _decode_io_est(eng_p),
                               rtol=1e-6)
    ss, sp = eng_s.io_summary(), eng_p.io_summary()
    assert ss["hit_rows"] == sp["hit_rows"]
    assert ss["miss_rows"] == sp["miss_rows"]


# -- hit-rate accounting -----------------------------------------------------


def test_hit_rate_accounting_sums_consistently(swept):
    eng, _ = swept[1.0]
    # plan counters (ground truth accumulated inside jit) == StepStats sums
    hit, miss = plan_hit_miss(eng._plan)
    s = eng.io_summary()
    np.testing.assert_allclose(float(hit), s["hit_rows"], rtol=1e-6)
    np.testing.assert_allclose(float(miss), s["miss_rows"], rtol=1e-6)
    # per-event hit rates agree with the per-step stats that produced them
    dec = [st for st in eng.stats if st.kind == "decode" and st.io_est_s > 0]
    events = [e for e in eng.simulator.log if e.name.startswith("decode")]
    assert len(events) == len(dec)
    for st, ev in zip(dec, events):
        rows = st.hit_rows + st.miss_rows
        want = st.hit_rows / rows if rows > 0 else 0.0
        np.testing.assert_allclose(ev.hit_rate, want, rtol=1e-6)
        assert 0.0 <= ev.hit_rate <= 1.0


# -- marginal-cost selection -------------------------------------------------


def test_selector_marginal_cost_free_when_fully_resident():
    n = 256
    sel = ChunkSelector.build(n, 64, device="nano",
                              cfg=ChunkConfig(8.0, 32.0, 8.0, 8.0))
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.random(n).astype(np.float32))
    resident = jnp.ones((n,), bool)
    mask, selected, est = sel.select(v, jnp.int32(128), resident)
    assert int(selected) > 0
    assert float(est) == 0.0  # everything selected is already in DRAM


def test_selector_matches_numpy_oracle_with_residency():
    n = 256
    cfg = ChunkConfig(8.0, 32.0, 8.0, 8.0)
    sel = ChunkSelector.build(n, 64, device="nano", cfg=cfg)
    rng = np.random.default_rng(7)
    v = rng.random(n).astype(np.float32)
    resident = np.zeros(n, bool)
    resident[32:96] = True
    m_np = select_chunks_np(v, 64, 64, sel.table, cfg, resident=resident)
    m_j, _, _ = sel.select(jnp.asarray(v), jnp.int32(64), jnp.asarray(resident))
    np.testing.assert_array_equal(np.asarray(m_j), m_np)


def test_static_cached_prewarm_is_pinned(lm):
    cfg, model, params, batch = lm
    n = cfg.d_model
    cached = jnp.zeros((n,), bool).at[jnp.arange(0, n, 8)].set(True)
    ctx = SparseExecution(cfg, device="nano", sparsity=0.4, method="chunk",
                          cached={"hidden_attn": cached}, cache_mb=1.0)
    plan = ctx.init_plan(cfg.n_layers)
    score = plan["hidden_attn"]["score"]
    assert bool(jnp.all(score[:, ::8] == PIN_SCORE))  # pre-warmed + pinned
    assert bool(jnp.all(score[:, 1::8] == 0.0))


# -- greedy kwarg bugfix -----------------------------------------------------


def test_greedy_false_raises(lm):
    cfg, model, params, _ = lm
    eng = ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                      sparsity=0.4, method="chunk", seed=1)
    tok = jnp.zeros((2, 1), jnp.int32)
    with pytest.raises(NotImplementedError, match="sampled decoding"):
        eng.decode(tok, 4, greedy=False)
    with pytest.raises(NotImplementedError, match="sampled decoding"):
        eng.decode_per_token(tok, 4, greedy=False)
