"""End-to-end chunk integrity (PR 9): checksum lane, data-plane corruption
injection, and the detection → re-read → recovery/degradation ladder.

The headline invariants:

  * every corruption RECOVERABLE (bit_rot: transient flips, p_stuck=0)
    ⇒ greedy tokens byte-identical to the corruption-off engine, across
    backends × wbits, with ``corruptions_detected == corruptions_recovered``
    and zero substitutions/drops — compute never sees a corrupt byte;
  * recovery OFF with the same (profile, seed) ⇒ the same injected damage
    reaches compute and measurably corrupts the tokens, yet the corrupted
    run itself replays bit-identically (and identically across backends:
    both apply the same ``corrupt_payload``);
  * corruptions that survive the re-read budget (degraded_nand) walk the
    deterministic ladder — resident-copy, substitute, drop — and every
    rung's counter in ``io_summary()`` replays exactly;
  * the checksum DMA lane itself is semantically inert: kernels with and
    without the third lane produce bit-identical outputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.faults import (
    CORRUPTION_PROFILES,
    CorruptionModel,
    CorruptionProfile,
    corruption_key,
    get_corruption_profile,
)
from repro.core.offload import pack_checksums
from repro.kernels.chunk_gather_dma import (
    chunk_gather_matmul_dma,
    chunk_gather_mlp_dma,
)
from repro.kernels.quantize import (
    QUANT_SUFFIX_CHECKSUM,
    block_checksums,
    quantize_params,
    quantize_rows,
)
from repro.models import build_model
from repro.serving import DegradationController, ServeEngine

slow = pytest.mark.slow

COUNTER_KEYS = (
    "corruptions_detected",
    "corruptions_recovered",
    "corruptions_substituted",
    "corruptions_dropped",
    "integrity_reread_s",
)


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("method", "chunk")
    return ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                       sparsity=0.4, seed=1, **kw)


def _counters(eng):
    s = eng.io_summary()
    return {k: s[k] for k in COUNTER_KEYS}


# ---------------------------------------------------------------------------
# block_checksums: the pack-time integrity lane
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.float16, jnp.float32])
def test_checksum_shape_dtype_and_single_bit_detection(rng, dtype):
    """One uint32 per 8-row block, and ANY single-bit flip of the stored
    payload moves exactly the containing block's checksum — the property
    the odd position weights guarantee."""
    w = jnp.asarray(rng.normal(0, 1, (32, 16)) * 10, dtype)
    ck = block_checksums(w)
    assert ck.shape == (4,) and ck.dtype == jnp.uint32
    # flip the lowest bit of one element in block 2 via bitcast
    itemsize = jnp.dtype(dtype).itemsize
    uint = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[itemsize]
    u = np.array(jax.lax.bitcast_convert_type(w, uint))
    u[17, 3] ^= 1
    w2 = jax.lax.bitcast_convert_type(jnp.asarray(u), dtype)
    ck2 = block_checksums(w2)
    changed = np.asarray(ck != ck2)
    assert changed.tolist() == [False, False, True, False]


def test_checksum_detects_reordering_within_block(rng):
    """Equal-weight sums would miss a row swap inside a block; the
    position weighting must not."""
    w = np.asarray(rng.normal(0, 1, (8, 8)), np.float32)
    swapped = w[[1, 0, 2, 3, 4, 5, 6, 7]]
    c0 = block_checksums(jnp.asarray(w))
    c1 = block_checksums(jnp.asarray(swapped))
    assert int(c0[0]) != int(c1[0])


def test_checksum_rows_must_divide_block():
    with pytest.raises(ValueError, match="multiple of block_rows"):
        block_checksums(jnp.ones((12, 4)))


def test_quantize_params_emits_checksum_leaf(rng):
    """wbits=8 pack path: the ``_ck`` leaf checksums the int8 payload —
    exactly the bytes the DMA lane streams at that width."""
    layers = {"wq": jnp.asarray(rng.normal(0, 1, (3, 16, 8)), jnp.bfloat16)}
    out = quantize_params(layers, ("wq",), checksums=True)
    ck = out["wq" + QUANT_SUFFIX_CHECKSUM]
    assert ck.shape == (3, 2) and ck.dtype == jnp.uint32
    q0, _ = quantize_rows(layers["wq"][0], 8)
    np.testing.assert_array_equal(np.asarray(ck[0]),
                                  np.asarray(block_checksums(q0)))
    # default stays checksum-free: no silent storage growth at wbits=8
    assert "wq" + QUANT_SUFFIX_CHECKSUM not in quantize_params(layers, ("wq",))


def test_pack_checksums_fp_twin(rng):
    """wbits=16 pack path: ``pack_checksums`` checksums the fp weight
    itself (the bytes streamed unquantized); missing names are skipped."""
    layers = {"wo": jnp.asarray(rng.normal(0, 1, (2, 24, 4)), jnp.float32)}
    out = pack_checksums(layers, ("wo", "absent"))
    assert sorted(out) == ["wo" + QUANT_SUFFIX_CHECKSUM]
    assert out["wo_ck"].shape == (2, 3) and out["wo_ck"].dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(out["wo_ck"][1]),
                                  np.asarray(block_checksums(layers["wo"][1])))


# ---------------------------------------------------------------------------
# CorruptionModel: seeded draw / damage / re-read semantics
# ---------------------------------------------------------------------------


def test_corruption_profile_validation():
    with pytest.raises(ValueError, match="p_block"):
        CorruptionProfile("bad", p_block=1.0)
    with pytest.raises(ValueError, match="mode"):
        CorruptionProfile("bad", p_block=0.1, mode="scramble")
    with pytest.raises(ValueError, match="p_stuck"):
        CorruptionProfile("bad", p_block=0.1, p_stuck=1.0)
    with pytest.raises(KeyError, match="unknown corruption profile"):
        get_corruption_profile("nope")
    with pytest.raises(ValueError, match="max_reread"):
        CorruptionModel("bit_rot", max_reread=-1)
    assert not CorruptionModel("none").enabled
    assert CorruptionModel("bit_rot").enabled
    assert set(CORRUPTION_PROFILES) == {
        "none", "bit_rot", "torn_read", "degraded_nand"}


def test_draw_blocks_masked_to_fetched_and_deterministic():
    cm = CorruptionModel("degraded_nand", seed=11)
    fetched = jnp.asarray([True] * 200 + [False] * 200)
    k = corruption_key(cm.base_key(), 3, 1, 2, 0)
    c1 = np.asarray(cm.draw_blocks(k, fetched))
    c2 = np.asarray(cm.draw_blocks(k, fetched))
    np.testing.assert_array_equal(c1, c2)
    # resident blocks (not fetched) never corrupt
    assert not c1[200:].any()
    assert c1[:200].any()  # p=0.05 over 200 draws: essentially certain
    # a different (layer, epoch, site, matrix) gives a different pattern
    c3 = np.asarray(cm.draw_blocks(corruption_key(cm.base_key(), 3, 2, 2, 0),
                                   fetched))
    assert not np.array_equal(c1, c3)


def test_draw_rereads_transient_profile_always_recovers():
    """p_stuck=0 (bit_rot): the first re-read is clean, so every corrupt
    block charges exactly one re-read and recovers."""
    cm = CorruptionModel("bit_rot", max_reread=2)
    corrupt = jnp.asarray([True, False, True])
    rr, rec = cm.draw_rereads(cm.base_key(), corrupt)
    assert np.asarray(rr).tolist() == [1, 0, 1]
    assert np.asarray(rec).tolist() == [True, False, True]


def test_draw_rereads_recovery_off_and_budget_zero():
    corrupt = jnp.ones(4, bool)
    for cm in (CorruptionModel("degraded_nand", recover=False),
               CorruptionModel("degraded_nand", max_reread=0)):
        rr, rec = cm.draw_rereads(cm.base_key(), corrupt)
        assert not np.asarray(rr).any() and not np.asarray(rec).any()


def test_draw_rereads_sticky_profile_sometimes_exhausts_budget():
    """degraded_nand (p_stuck=0.65): across many corrupt blocks some recover
    within budget and some exhaust it; charged re-reads never exceed
    max_reread and recovery ⇔ fails < budget."""
    cm = CorruptionModel("degraded_nand", seed=5, max_reread=2)
    corrupt = jnp.ones(512, bool)
    k = corruption_key(cm.base_key(), 0, 0, 0, 0)
    rr = np.asarray(cm.draw_rereads(k, corrupt)[0])
    rec = np.asarray(cm.draw_rereads(k, corrupt)[1])
    assert rr.min() >= 1 and rr.max() == 2
    assert rec.any() and not rec.all()
    # a block that recovered needed < budget failures → charged ≤ budget;
    # an unrecovered block charged exactly the full budget
    assert (rr[~rec] == 2).all()


def test_backoff_seconds_geometric_ladder():
    cm = CorruptionModel("bit_rot")  # base 5e-5, mult 2.0
    r = jnp.asarray([0, 1, 2, 3], jnp.int32)
    out = np.asarray(cm.backoff_seconds(r))
    # base * (m^r - 1) / (m - 1): 0, 1, 3, 7 units
    np.testing.assert_allclose(out, 5e-5 * np.asarray([0, 1, 3, 7]),
                               rtol=1e-6)
    flat = CorruptionModel(CorruptionProfile(
        "flat", p_block=0.01, backoff_base_s=1e-4, backoff_mult=1.0))
    np.testing.assert_allclose(np.asarray(flat.backoff_seconds(r)),
                               1e-4 * np.asarray([0, 1, 2, 3]), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.float16, jnp.float32])
def test_corrupt_payload_flip_touches_one_bit_per_block(rng, dtype):
    """mode="flip": exactly one element of one corrupted block differs, by
    exactly one bit; untouched blocks are bit-identical."""
    cm = CorruptionModel("bit_rot", seed=2)
    w = jnp.asarray(rng.normal(0, 1, (24, 8)) * 5, dtype)
    blocks = jnp.asarray([False, True, False])
    k = corruption_key(cm.base_key(), 0, 0, 0, 0)
    w2 = cm.corrupt_payload(w, blocks, k)
    itemsize = jnp.dtype(dtype).itemsize
    uint = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[itemsize]
    u0 = np.asarray(jax.lax.bitcast_convert_type(w, uint), np.uint32)
    u1 = np.asarray(jax.lax.bitcast_convert_type(w2, uint), np.uint32)
    diff = u0 ^ u1
    assert not diff[:8].any() and not diff[16:].any()
    nz = diff[8:16][diff[8:16] != 0]
    assert nz.size == 1  # one element
    assert bin(int(nz[0])).count("1") == 1  # one bit
    # the stored checksum flags exactly that block
    bad = np.asarray(block_checksums(w) != block_checksums(w2))
    assert bad.tolist() == [False, True, False]
    # deterministic in the key
    np.testing.assert_array_equal(
        np.asarray(w2), np.asarray(cm.corrupt_payload(w, blocks, k)))


def test_corrupt_payload_zero_mode_zeroes_whole_block(rng):
    cm = CorruptionModel("torn_read", seed=2)
    w = jnp.asarray(rng.normal(1, 0.1, (16, 4)), jnp.float32)
    blocks = jnp.asarray([True, False])
    w2 = np.asarray(cm.corrupt_payload(
        w, blocks, corruption_key(cm.base_key(), 0, 0, 0, 0)))
    assert (w2[:8] == 0.0).all()
    np.testing.assert_array_equal(w2[8:], np.asarray(w)[8:])


# ---------------------------------------------------------------------------
# the checksum DMA lane is semantically inert
# ---------------------------------------------------------------------------


def test_matmul_kernel_checksum_lane_bit_identical(rng):
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    starts = jnp.asarray([0, 24, 0, 0], jnp.int32)
    sizes = jnp.asarray([16, 32, 0, 0], jnp.int32)
    y0 = chunk_gather_matmul_dma(w, x, starts, sizes, tile_d=8,
                                 interpret=True)
    y1 = chunk_gather_matmul_dma(w, x, starts, sizes,
                                 checksums=block_checksums(w), tile_d=8,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    # quantized + checksummed: both extra lanes ride the slot rotation
    q, s = quantize_rows(w)
    yq0 = chunk_gather_matmul_dma(q, x, starts, sizes, s, tile_d=8,
                                  interpret=True)
    yq1 = chunk_gather_matmul_dma(q, x, starts, sizes, s, block_checksums(q),
                                  tile_d=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(yq0), np.asarray(yq1))


def test_mlp_kernel_checksum_lane_bit_identical(rng):
    wg = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    wu = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    wd = jnp.asarray(rng.normal(size=(48, 32)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    st = jnp.asarray([[0, 24, 0, 0], [8, 0, 0, 0]], jnp.int32)
    sz = jnp.asarray([[16, 32, 0, 0], [24, 0, 0, 0]], jnp.int32)
    z0 = chunk_gather_mlp_dma(wg, wu, wd, x, st, sz, tile_f=8, tile_d=8,
                              interpret=True)
    z1 = chunk_gather_mlp_dma(
        wg, wu, wd, x, st, sz,
        checksums=(block_checksums(wg), block_checksums(wu),
                   block_checksums(wd)),
        tile_f=8, tile_d=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(z0), np.asarray(z1))


def test_matmul_kernel_rejects_bad_checksum_shape(rng):
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    starts = jnp.asarray([0, 0, 0, 0], jnp.int32)
    sizes = jnp.asarray([8, 0, 0, 0], jnp.int32)
    with pytest.raises(ValueError, match="checksums"):
        chunk_gather_matmul_dma(w, x, starts, sizes,
                                checksums=jnp.zeros(7, jnp.uint32),
                                tile_d=8, interpret=True)


# ---------------------------------------------------------------------------
# engine: the headline byte-identity + ladder invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,wbits", [("reference", 16), ("kernel", 8)])
def test_engine_recovered_corruption_byte_identity(lm, backend, wbits):
    """bit_rot (every corruption recoverable) + recovery ⇒ tokens are
    byte-identical to the corruption-off engine; detected == recovered,
    nothing substituted or dropped, and the re-reads charged time."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    base = _engine(model, params, backend=backend, wbits=wbits)
    t_base = base.decode(tok0, 6)
    eng = _engine(model, params, backend=backend, wbits=wbits,
                  corruption_profile="bit_rot", corruption_seed=7)
    t = eng.decode(tok0, 6)
    np.testing.assert_array_equal(np.asarray(t_base), np.asarray(t))
    c = _counters(eng)
    assert c["corruptions_detected"] > 0
    assert c["corruptions_detected"] == c["corruptions_recovered"]
    assert c["corruptions_substituted"] == 0 == c["corruptions_dropped"]
    assert c["integrity_reread_s"] > 0.0
    # the re-read time reached the simulated I/O clock
    assert eng.io_summary()["io_sim_s"] > base.io_summary()["io_sim_s"]
    # the corruption-off engine's new counters are all exactly zero
    assert all(v == 0.0 for v in _counters(base).values())


@pytest.mark.parametrize("backend,wbits", [("reference", 16), ("kernel", 8)])
def test_engine_no_recover_corrupts_tokens_deterministically(lm, backend,
                                                             wbits):
    """Recovery off: the same (profile, seed) measurably corrupts the
    output — and the corrupted run replays bit-identically."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    t_base = _engine(model, params, backend=backend, wbits=wbits).decode(
        tok0, 6)

    def run():
        e = _engine(model, params, backend=backend, wbits=wbits,
                    corruption_profile="bit_rot", corruption_seed=7,
                    recover=False)
        return e, np.asarray(e.decode(tok0, 6))

    e1, t1 = run()
    e2, t2 = run()
    assert not np.array_equal(np.asarray(t_base), t1)
    np.testing.assert_array_equal(t1, t2)
    assert _counters(e1) == _counters(e2)
    c = _counters(e1)
    assert c["corruptions_detected"] > 0
    # nothing recovers, nothing is re-read, and the ladder never engages:
    # detection is observe-only when recovery is off
    assert c["corruptions_recovered"] == 0 == c["corruptions_substituted"]
    assert c["corruptions_dropped"] == 0 and c["integrity_reread_s"] == 0.0


def test_engine_corrupted_tokens_cross_backend_identical(lm):
    """Both backends apply the identical corrupt_payload damage, so even
    CORRUPTED tokens stay byte-identical across reference and kernel."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)

    def run(backend):
        e = _engine(model, params, backend=backend,
                    corruption_profile="bit_rot", corruption_seed=7,
                    recover=False)
        return np.asarray(e.decode(tok0, 5))

    np.testing.assert_array_equal(run("reference"), run("kernel"))


def test_engine_degraded_nand_ladder_replays_exactly(lm):
    """Corruptions that survive the re-read budget walk the ladder:
    substitutions and/or drops appear and every counter replays exactly.
    Units differ by rung — detected/recovered count block-EVENTS per
    matrix, substituted/dropped count ROWS (an unreadable block takes its
    KERNEL_BLOCK_ROWS site rows with it), so rows ≤ 8 × unrecovered
    events bounds the ladder's tail."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)

    def run():
        e = _engine(model, params, corruption_profile="degraded_nand",
                    corruption_seed=3, max_reread=1)
        t = np.asarray(e.decode(tok0, 6))
        return t, _counters(e)

    t1, c1 = run()
    t2, c2 = run()
    np.testing.assert_array_equal(t1, t2)
    assert c1 == c2
    assert c1["corruptions_detected"] > c1["corruptions_recovered"] > 0
    assert c1["corruptions_substituted"] > 0
    from repro.serving.sparse_exec import KERNEL_BLOCK_ROWS

    # only the FETCHED rows of an unreadable block are removed (resident
    # selected rows stay served from DRAM), so the bound is ≤, not ==
    assert (c1["corruptions_substituted"] + c1["corruptions_dropped"]
            <= KERNEL_BLOCK_ROWS
            * (c1["corruptions_detected"] - c1["corruptions_recovered"]))


def test_engine_corruption_requires_offloaded_plane(lm):
    cfg, model, params = lm
    with pytest.raises(ValueError, match="offloaded data plane"):
        _engine(model, params, method="dense_free",
                corruption_profile="bit_rot")
    with pytest.raises(ValueError, match="selecting method"):
        _engine(model, params, method="dense",
                corruption_profile="bit_rot")


def test_engine_per_token_path_matches_scan_counters(lm):
    """The per-token decode loop shares the plan-lane accounting: same
    seed, same number of steps ⇒ identical corruption counters and the
    identical recovered tokens as the scan path."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)

    def run(per_token):
        e = _engine(model, params, corruption_profile="bit_rot",
                    corruption_seed=7)
        fn = e.decode_per_token if per_token else e.decode
        return np.asarray(fn(tok0, 5)), _counters(e)

    t_scan, c_scan = run(False)
    t_tok, c_tok = run(True)
    np.testing.assert_array_equal(t_scan, t_tok)
    assert c_scan == c_tok


def test_engine_corruption_feeds_degradation_controller(lm):
    """Sustained corruption is the controller's second degrade signal: a
    high-rate profile with recovery tightens the budget scale even though
    latency alone would not."""
    cfg, model, params = lm
    tok0 = jnp.ones((2, 1), jnp.int32)
    e = _engine(model, params, corruption_profile="degraded_nand",
                corruption_seed=3, degrade=True)
    # crank the corruption gain so the signal dominates the healthy
    # latency observations within a short test decode
    e.degrade_controller = DegradationController(corruption_ratio_gain=200.0)
    e.simulator.noise = 0.0
    for _ in range(6):
        e.decode(tok0, 3)
    assert e.fault_summary()["degrade_scale"] < 1.0
