"""Importance, TEAL-style allocation, reordering, baselines, offload sim."""
import jax.numpy as jnp
import numpy as np
import pytest
from repro.core import (
    FlashOffloadSimulator,
    LayerProfile,
    activation_frequency,
    allocate_sparsity,
    budgets_from_sparsity,
    bundled_latency,
    calibrate_threshold,
    chunk_stats_np,
    coactivation_reordering,
    coefficient_of_variation,
    hot_cold_reordering,
    importance,
    importance_np,
    retention,
    threshold_mask,
    topk_mask,
    topk_mask_np,
    unbundled_latency,
)

# ---------------------------------------------------------------- importance


def test_importance_multi_token_average(rng):
    acts = rng.normal(0, 1, (4, 8, 16)).astype(np.float32)  # (b, s, n)
    v = np.asarray(importance(jnp.asarray(acts)))
    want = np.abs(acts).reshape(-1, 16).mean(0)
    np.testing.assert_allclose(v, want, rtol=1e-5)
    np.testing.assert_allclose(importance_np(acts), want, rtol=1e-5)


def test_cv_separates_relu_from_vlm(rng):
    """Table 1's phenomenon: ReLU-like (spiky) ≫ gated (smooth) CV."""
    smooth = rng.gamma(4.0, 1.0, 4096)  # SwiGLU-ish magnitude profile
    spiky = np.where(rng.random(4096) < 0.05, rng.gamma(4.0, 10.0, 4096), 1e-3)
    cv_s = float(coefficient_of_variation(jnp.asarray(smooth)))
    cv_p = float(coefficient_of_variation(jnp.asarray(spiky)))
    assert cv_p > 3 * cv_s


def test_retention_bounds(rng):
    v = jnp.asarray(rng.random(64).astype(np.float32))
    assert float(retention(v, jnp.ones(64, bool))) == pytest.approx(1.0)
    assert float(retention(v, jnp.zeros(64, bool))) == pytest.approx(0.0)


# ---------------------------------------------------------------- allocation


def test_teal_allocation_hits_target(rng):
    profiles = [
        LayerProfile(f"l{i}", rng.gamma(1.0 + i, 1.0, 256).astype(np.float32))
        for i in range(4)
    ]
    alloc = allocate_sparsity(profiles, target_sparsity=0.4, step=0.05)
    assert np.mean(list(alloc.values())) == pytest.approx(0.4, abs=0.011)
    budgets = budgets_from_sparsity(alloc, {f"l{i}": 256 for i in range(4)})
    assert all(0 < b <= 256 for b in budgets.values())


def test_teal_allocation_prefers_skewed_layers():
    """A layer whose mass concentrates in few neurons absorbs more sparsity."""
    n = 512
    skewed = np.zeros(n, np.float32)
    skewed[:16] = 100.0
    flat = np.ones(n, np.float32)
    alloc = allocate_sparsity(
        [LayerProfile("skewed", skewed), LayerProfile("flat", flat)],
        target_sparsity=0.3,
    )
    assert alloc["skewed"] > alloc["flat"]


# ---------------------------------------------------------------- reordering


def test_hot_cold_reordering_roundtrip(rng):
    cal = rng.random((32, 64)).astype(np.float32)
    r = hot_cold_reordering(cal)
    w = rng.normal(0, 1, (64, 16))
    a = rng.normal(0, 1, (64,)).astype(np.float32)
    y_orig = a @ w
    y_perm = np.asarray(r.apply_to_acts(jnp.asarray(a))) @ r.apply_to_rows(w)
    np.testing.assert_allclose(y_orig, y_perm, rtol=1e-5)
    assert (r.perm[r.inverse] == np.arange(64)).all()


def test_hot_cold_improves_contiguity():
    """§3.3: with stable hot/cold structure, reordering clusters the hot set."""
    rng = np.random.default_rng(1)
    n, s = 256, 64
    hot = rng.permutation(n)[: n // 2]  # scattered hot neurons
    cal = rng.random((s, n)).astype(np.float32) * 0.1
    cal[:, hot] += 1.0
    r = hot_cold_reordering(cal)
    v = cal.mean(0)
    mask_before = topk_mask_np(v, n // 2)
    mask_after = topk_mask_np(v[r.perm], n // 2)
    assert chunk_stats_np(mask_after)[0] > 5 * chunk_stats_np(mask_before)[0]


def test_coactivation_reordering_valid_permutation(rng):
    cal = rng.random((16, 48)).astype(np.float32)
    r = coactivation_reordering(cal)
    assert sorted(r.perm.tolist()) == list(range(48))


def test_activation_frequency_range(rng):
    freq = activation_frequency(rng.random((20, 30)).astype(np.float32))
    assert freq.shape == (30,)
    assert ((0 <= freq) & (freq <= 1)).all()
    assert freq.mean() == pytest.approx(0.5, abs=0.05)  # top-50% definition


# ---------------------------------------------------------------- baselines


def test_topk_np_jax_agree(rng):
    v = rng.random(128).astype(np.float32)
    m_np = topk_mask_np(v, 40)
    m_j = np.asarray(topk_mask(jnp.asarray(v), jnp.int32(40)))
    assert (m_np == m_j).all()
    assert m_np.sum() == 40


def test_threshold_calibration(rng):
    cal = rng.random((100, 64)).astype(np.float32)
    t = calibrate_threshold(cal, sparsity=0.7)
    m = np.asarray(threshold_mask(jnp.asarray(cal[0]), t))
    assert 0.1 < m.mean() < 0.5  # ~30% kept on average


def test_bundling_beats_separate_loads_for_same_mask(rng):
    """App. L: bundling q/k/v rows turns 3 scattered reads into 1."""
    mask = np.zeros(512, bool)
    mask[rng.permutation(512)[:128]] = True
    sep = unbundled_latency(mask, row_bytes=2048, n_matrices=3, device="nano")
    bun = bundled_latency(mask, row_bytes=2048, bundle=3, device="nano")
    assert bun < sep


# ---------------------------------------------------------------- offload sim


def test_simulator_proportional_lift(rng):
    sim = FlashOffloadSimulator("nano", seed=0, noise=0.02)
    mask = np.zeros(1024, bool)
    mask[:256] = True
    mask[512:768] = True
    est = sim.estimate(mask, 2048)
    meas = np.mean([sim.measure(mask, 2048) for _ in range(50)])
    lift = meas / est
    assert 1.0 < lift < 1.8  # Fig. 5: proportional, device-dependent bias
    assert sim.total_io_seconds() > 0
    sim.reset()
    assert sim.total_io_seconds() == 0


def test_simulator_fragmention_penalty(rng):
    """Fig. 4b: same bytes, scattered pattern much slower."""
    sim = FlashOffloadSimulator("agx", seed=1)
    n = 2048
    contig = np.zeros(n, bool)
    contig[:1024] = True
    scattered = np.zeros(n, bool)
    scattered[::2] = True  # same popcount, all size-1 chunks
    assert sim.estimate(scattered, 4096) > 5 * sim.estimate(contig, 4096)
