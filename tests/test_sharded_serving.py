"""Multi-chip sharded serving: byte-identity vs the single-device engine,
per-shard byte accounting, block-table partitioning, and ``--mesh``
validation.

Engine-compiling tests are marked ``slow`` AND skip below 4 devices: the
tier-1 run (single CPU device — conftest.py deliberately sets no XLA_FLAGS)
deselects or skips them, while the CI ``test-sharded`` job simulates 8 host
devices via XLA_FLAGS=--xla_force_host_platform_device_count=8 and runs this
file with ``-m ""``. The pure-logic tests (mesh validation, block-table
clipping, simulator shard lanes) run in every tier on one device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.offload import FlashOffloadSimulator
from repro.kernels.quantize import QUANT_BLOCK_ROWS
from repro.launch.serve import resolve_mesh
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import ServeEngine, SparseExecution, plan_transfer_bytes
from repro.sharding.serve import (
    ServeMesh,
    shard_block_tables,
    validate_serve_mesh,
)

slow = pytest.mark.slow
needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

SMOKE = InputShape(name="smoke", seq_len=16, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def vlm():
    cfg = get_config("internvl2-76b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _run(cfg, model, params, mesh, backend, wbits, n_tokens=6):
    eng = ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                      sparsity=0.5, method="chunk", seed=5,
                      plan_refresh_interval=2, cache_mb=2.0,
                      backend=backend, wbits=wbits, mesh=mesh)
    batch = make_dummy_batch(cfg, SMOKE)
    last = eng.prefill(batch)
    tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    out = eng.decode(tok0, n_tokens)
    return eng, np.asarray(out)


# ---------------------------------------------------------------------------
# engine-level invariants (8 simulated devices; the CI test-sharded tier)
# ---------------------------------------------------------------------------


@slow
@needs_mesh
@pytest.mark.parametrize("backend", ("reference", "kernel"))
@pytest.mark.parametrize("wbits", (16, 8))
def test_sharded_byte_identity(vlm, backend, wbits):
    """THE sharded-serving acceptance invariant: greedy tokens on a 2×2
    (data, model) host mesh byte-identical to the single-device engine at
    equal settings, for both execution backends and both storage widths —
    plus the accounting half: the mesh repartitions the modeled I/O, it
    never rescales it (totals equal, per-shard lanes sum to the total)."""
    cfg, model, params = vlm
    eng1, out1 = _run(cfg, model, params, ServeMesh.single(), backend, wbits)
    eng2, out2 = _run(cfg, model, params, ServeMesh.create(2, 2), backend,
                      wbits)
    assert np.array_equal(out1, out2), (
        f"greedy tokens diverged on the 2x2 mesh (backend={backend}, "
        f"wbits={wbits}):\n{out1}\n{out2}"
    )
    b1 = eng1.io_summary()["io_bytes"]
    b2 = eng2.io_summary()["io_bytes"]
    assert abs(b1 - b2) <= 1e-6 * max(b1, 1.0)
    ss = eng2.shard_summary()
    assert ss["mesh_data"] == 2 and ss["mesh_model"] == 2
    assert ss["n_shards"] == 2
    assert len(ss["io_bytes_per_shard"]) == 2
    assert abs(sum(ss["io_bytes_per_shard"]) - b2) <= 1e-6 * max(b2, 1.0)
    assert all(b > 0 for b in ss["io_bytes_per_shard"])
    assert ss["slots_per_data_shard"] == 1  # batch 2 over data=2
    # single-device engine keeps the unsharded surface: no shard lanes
    assert eng1.n_shards == 1
    assert all(e.shard_bytes is None for e in eng1.simulator.log)


@slow
@needs_mesh
def test_sharded_plan_lanes(vlm):
    """Per-shard plan accounting internals on the 2×2 mesh: only the
    row-sharded sites (attn_out streams wo's rows, ffn streams
    w_down/w_proj's) carry per-shard hit/miss lanes, shaped (layers,
    n_shards); ``plan_shard_bytes`` prices exactly those lanes plus an even
    split of the column-sharded sites."""
    cfg, model, params = vlm
    eng, _ = _run(cfg, model, params, ServeMesh.create(2, 2), "reference", 16)
    ctx = eng.sparse_ctx
    assert ctx.n_shards == 2
    for kind, site in ctx.sites.items():
        expect = 2 if kind in ("attn_out", "ffn") else 1
        assert ctx.row_shards[kind] == expect, kind
    plan = eng._plan
    for kind, ns in ctx.row_shards.items():
        state = plan[kind]
        if ns > 1:
            assert state["hit_shard"].shape[-1] == ns
            assert state["miss_shard"].shape[-1] == ns
        else:
            assert "hit_shard" not in state
    per = np.asarray(ctx.plan_shard_bytes(plan))
    assert per.shape == (2,)
    total = float(np.asarray(plan_transfer_bytes(plan)))
    assert abs(per.sum() - total) <= 1e-6 * max(total, 1.0)


@needs_mesh
def test_sharded_rejects_reorderings(vlm):
    """Per-shard block tables and byte counters assume selection row order
    equals storage row order — a reordering under a sharded mesh must fail
    loudly at construction, not corrupt the accounting."""
    cfg, _model, _params = vlm
    with pytest.raises(ValueError, match="reorderings"):
        SparseExecution(cfg, device="nano", sparsity=0.5, method="chunk",
                        reorderings={"ffn": object()},
                        mesh=ServeMesh.create(2, 2))


# ---------------------------------------------------------------------------
# pure-logic invariants (run on one device, every tier)
# ---------------------------------------------------------------------------


def test_shard_block_tables_partition():
    """Clipping a global chunk table to per-shard row ranges must exactly
    partition the gathered rows: per-shard sizes sum to the global sum,
    every surviving chunk lies inside its shard's range, and starts stay
    quant-block aligned."""
    n_rows, n_shards = 64, 2
    starts = jnp.asarray([0, 24, 32, 56], jnp.int32)
    sizes = jnp.asarray([16, 8, 16, 8], jnp.int32)
    cs, csz = shard_block_tables(starts, sizes, n_rows, n_shards)
    assert cs.shape == (n_shards, 4) and csz.shape == (n_shards, 4)
    assert int(csz.sum()) == int(sizes.sum())
    seg = n_rows // n_shards
    for s in range(n_shards):
        lo, hi = s * seg, (s + 1) * seg
        keep = np.asarray(csz[s]) > 0
        assert np.all(np.asarray(cs[s])[keep] >= lo)
        assert np.all((np.asarray(cs[s]) + np.asarray(csz[s]))[keep] <= hi)
        assert np.all(np.asarray(cs[s])[keep] % QUANT_BLOCK_ROWS == 0)


def test_shard_block_tables_straddling_chunk_splits():
    # one chunk spanning the shard boundary splits into two halves
    cs, csz = shard_block_tables(jnp.asarray([24]), jnp.asarray([16]), 64, 2)
    assert int(csz[0, 0]) == 8 and int(cs[0, 0]) == 24
    assert int(csz[1, 0]) == 8 and int(cs[1, 0]) == 32


def test_shard_block_tables_divisibility_error():
    with pytest.raises(ValueError, match="whole"):
        shard_block_tables(jnp.asarray([0]), jnp.asarray([8]), 24, 2)


def test_validate_serve_mesh_errors():
    validate_serve_mesh(1, 1)  # trivial mesh always fine
    with pytest.raises(ValueError, match=">= 1"):
        validate_serve_mesh(0, 2)
    with pytest.raises(ValueError, match="devices"):
        validate_serve_mesh(2, 2, n_devices=2)
    with pytest.raises(ValueError, match="batch"):
        validate_serve_mesh(2, 1, batch=3, n_devices=8)
    with pytest.raises(ValueError, match="streams"):
        validate_serve_mesh(2, 1, batch=2, streams=5, n_devices=8)
    with pytest.raises(ValueError, match="ffn|d_ff"):
        validate_serve_mesh(1, 3, d_ff=704, n_devices=8)


def test_resolve_mesh_cli_validation():
    """--mesh fails at parse time, before any model is built, with an
    actionable message (the launcher bugfix this PR pins)."""
    cfg = get_config("internvl2-76b").reduced()
    with pytest.raises(ValueError, match="data,model"):
        resolve_mesh("2", cfg, batch=2, streams=0)
    with pytest.raises(ValueError, match="integers"):
        resolve_mesh("a,b", cfg, batch=2, streams=0)
    # streams must divide the data axis (continuous-batching slots shard
    # over it); batch likewise
    if len(jax.devices()) >= 2:
        with pytest.raises(ValueError, match="streams"):
            resolve_mesh("2,1", cfg, batch=2, streams=3)
    else:
        with pytest.raises(ValueError, match="devices"):
            resolve_mesh("2,1", cfg, batch=2, streams=3)
    # the trivial mesh parses to the inert single-device context
    mesh = resolve_mesh("1,1", cfg, batch=2, streams=0)
    assert not mesh.is_sharded and mesh.size == 1


def test_single_mesh_is_inert():
    mesh = ServeMesh.single()
    assert not mesh.is_sharded
    x = jnp.ones((4, 4))
    assert mesh.replicate(x) is x
    assert mesh.put_batch(x) is x


def test_simulator_shard_lanes():
    """``total_bytes_by_shard`` splits recorded lanes exactly and legacy
    (lane-less) events evenly, always summing to ``total_bytes()``."""
    sim = FlashOffloadSimulator("nano", seed=0)
    sim.measure_from_estimate(1e-3, nbytes=10.0)  # legacy event: even split
    assert sim.total_bytes_by_shard(1) == (sim.total_bytes(),)
    sim.measure_from_estimate(1e-3, nbytes=100.0, shard_bytes=(60.0, 40.0))
    per = sim.total_bytes_by_shard(2)
    assert per == (65.0, 45.0)
    assert abs(sum(per) - sim.total_bytes()) < 1e-9
    with pytest.raises(ValueError, match="lanes"):
        sim.total_bytes_by_shard(3)
    with pytest.raises(ValueError, match=">= 1"):
        sim.total_bytes_by_shard(0)
