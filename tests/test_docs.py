"""Docs-drift guard (fast tier + its own CI step).

Two contracts keep README.md / docs/*.md honest:

  * every fenced ```python block EXECUTES — doc snippets are run, not
    trusted, so an API rename or contract change breaks the build until
    the docs catch up;
  * every ``--flag`` a doc mentions must exist in the argparse parser of
    the CLI(s) that doc describes — a renamed or removed flag fails here
    before a reader hits it.

Docs are written so the python blocks are self-contained and cheap (tiny
shapes, interpret-mode kernels); bash/console blocks are not executed.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:  # make `import benchmarks.*` resolvable
    sys.path.insert(0, str(REPO))

DOC_FILES = ["README.md", "docs/serving.md", "docs/kernels.md",
             "docs/benchmarks.md", "docs/sharding.md", "docs/robustness.md"]

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
# --flag tokens: double dash + lowercase word, dash-separated (excludes
# markdown rules/table borders, em dashes and single-dash pytest flags);
# the trailing lookahead rejects underscore continuations so XLA flags the
# docs quote (--xla_force_host_platform_device_count=8) are not mistaken
# for a CLI flag named --xla
_FLAG = re.compile(r"--[a-z][a-z0-9]*(?:-[a-z0-9]+)*(?![_a-z0-9-])")


def _doc_paths():
    paths = [REPO / f for f in DOC_FILES]
    missing = [str(p) for p in paths if not p.exists()]
    assert not missing, f"documented files missing: {missing}"
    # any new docs/*.md must be registered above so its snippets run
    extra = {p.name for p in (REPO / "docs").glob("*.md")} - {
        Path(f).name for f in DOC_FILES
    }
    assert not extra, (
        f"docs/*.md files not covered by test_docs.DOC_FILES: {extra}"
    )
    return paths


def _python_blocks():
    for path in _doc_paths():
        text = path.read_text()
        for i, m in enumerate(_FENCE.finditer(text)):
            rel = path.relative_to(REPO)
            yield pytest.param(str(rel), i, m.group(1), id=f"{rel}#block{i}")


@pytest.mark.parametrize("rel,idx,code", list(_python_blocks()))
def test_doc_python_block_executes(rel, idx, code):
    """Each fenced python block runs in a fresh namespace; its asserts are
    part of the doc's contract."""
    ns = {"__name__": f"docblock_{Path(rel).stem}_{idx}"}
    exec(compile(code, f"{rel}#block{idx}", "exec"), ns)


def _parsers():
    """The argparse parsers the docs describe, keyed by CLI."""
    from benchmarks.kernel_gather import build_parser as kernel_gather_parser
    from benchmarks.serve_throughput import build_parser as serve_tp_parser
    from repro.launch.serve import build_parser as serve_parser

    return {
        "repro.launch.serve": serve_parser(),
        "benchmarks.serve_throughput": serve_tp_parser(),
        "benchmarks.kernel_gather": kernel_gather_parser(),
    }


def _known_flags():
    flags = {}
    for name, parser in _parsers().items():
        for action in parser._actions:
            for opt in action.option_strings:
                flags.setdefault(opt, set()).add(name)
    return flags


@pytest.mark.parametrize("rel", DOC_FILES)
def test_documented_flags_exist(rel):
    """Every --flag in a doc resolves against the union of the parsers that
    doc covers (all docs here describe the serve CLI and/or the two
    benchmark CLIs)."""
    known = _known_flags()
    text = (REPO / rel).read_text()
    mentioned = sorted(set(_FLAG.findall(text)))
    assert mentioned, f"{rel} documents no CLI flags — regex or doc broken?"
    unknown = [f for f in mentioned if f not in known]
    assert not unknown, (
        f"{rel} mentions flags that exist in no argparse parser: {unknown} "
        f"(known parsers: {sorted(_parsers())})"
    )


def test_cli_flags_are_documented_somewhere():
    """The reverse direction for the user-facing serve CLI: every serve
    flag should be discoverable from the docs (README or docs/)."""
    text = "".join((REPO / f).read_text() for f in DOC_FILES)
    mentioned = set(_FLAG.findall(text))
    parser = _parsers()["repro.launch.serve"]
    undocumented = []
    for action in parser._actions:
        opts = [o for o in action.option_strings
                if o.startswith("--") and o != "--help"]
        if opts and not any(o in mentioned for o in opts):
            undocumented.append(opts[0])
    assert not undocumented, (
        f"serve CLI flags absent from README/docs: {undocumented}"
    )
