"""Serving-path correctness: prefill + decode must reproduce teacher-forced
forward logits (exact for attention archs in bf16; recurrent/hybrid archs
checked in f32 where chunked-vs-recurrent compute order differs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.model as M
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models import build_model
from repro.models.inputs import make_dummy_batch

SMOKE = InputShape(name="smoke", seq_len=12, global_batch=2, kind="train")


@pytest.fixture
def f32_dtype(monkeypatch):
    monkeypatch.setattr(M, "COMPUTE_DTYPE", jnp.float32)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-3-2b", "starcoder2-3b",
                                  "internvl2-76b", "olmoe-1b-7b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, SMOKE)
    last, cache = model.prefill(params, batch, 32)
    hidden, _ = model.forward(params, batch, remat=False)
    lg_fwd = model.logits(params, hidden)[:, -1].astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(last, np.float32), np.asarray(lg_fwd), atol=2e-2, rtol=1e-2
    )
    nxt = batch["tokens"][:, :1]
    lg_dec, cache, _ = model.decode_step(params, nxt, cache)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    h2, _ = model.forward(params, b2, remat=False)
    lg2 = model.logits(params, h2)[:, -1].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg2), atol=5e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", ["xlstm-125m", "zamba2-7b", "whisper-small"])
def test_sequential_decode_matches_forward_f32(arch, f32_dtype):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, SMOKE)
    toks = batch["tokens"]
    if cfg.is_encdec:
        b1 = dict(batch)
        b1["tokens"] = toks[:, :1]
        lg, cache = model.prefill(params, b1, 32)
        for i in range(1, toks.shape[1]):
            lg, cache, _ = model.decode_step(params, toks[:, i : i + 1], cache)
    else:
        cache = model.init_cache(2, 32)
        for i in range(toks.shape[1]):
            lg, cache, _ = model.decode_step(params, toks[:, i : i + 1], cache)
    hidden, _ = model.forward(params, batch, remat=False)
    lg_fwd = model.logits(params, hidden)[:, -1].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_fwd), atol=1e-3, rtol=1e-3)


def test_zamba_prefill_matches_forward_f32(f32_dtype):
    cfg = get_config("zamba2-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, SMOKE)
    toks = batch["tokens"]
    b1 = dict(batch)
    b1["tokens"] = toks[:, :-1]
    last, cache = model.prefill(params, b1, 32)
    lg_dec, _, _ = model.decode_step(params, toks[:, -1:], cache)
    hidden, _ = model.forward(params, batch, remat=False)
    lg_fwd = model.logits(params, hidden)[:, -1].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_fwd), atol=1e-3)


def test_frame_append_matches_prefill_f32(f32_dtype):
    """Appending visual tokens to a prefilled cache == one longer prefill."""
    cfg = get_config("internvl2-76b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    b = 2
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 8)), jnp.int32),
        "frontend": jnp.asarray(rng.normal(0, 1, (b, 4, cfg.d_frontend)), jnp.float32),
    }
    _, cache = model.prefill(params, batch, 64)
    frame = jnp.asarray(rng.normal(0, 1, (b, 4, cfg.d_frontend)), jnp.float32)
    hid_app, cache, _ = model.append_frame(params, frame, cache)
    # equivalent single prefill with both frames up front is not identical
    # (frame order differs); instead decode after append and compare against
    # a forward over the exact same token/frame layout is complex — assert
    # structural invariants + finiteness here:
    assert int(cache["length"]) == 8 + 4 + 4
    assert hid_app.shape == (b, 4, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hid_app.astype(jnp.float32))))
