"""Contiguity-distribution abstraction (paper §3)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Chunk,
    average_chunk_size_jax,
    chunk_stats_np,
    chunks_to_mask_np,
    contiguity_distribution_np,
    contiguity_histogram_jax,
    mask_to_chunks_np,
    mask_to_runs_jax,
)


def test_paper_example():
    """Selecting {1,2,4,6,7} yields chunks {1,2},{4},{6,7} (paper §3)."""
    mask = np.zeros(8, bool)
    mask[[1, 2, 4, 6, 7]] = True
    chunks = mask_to_chunks_np(mask)
    assert chunks == [Chunk(1, 2), Chunk(4, 1), Chunk(6, 2)]
    assert contiguity_distribution_np(mask) == {2: 2, 1: 1}


def test_empty_and_full():
    assert mask_to_chunks_np(np.zeros(5, bool)) == []
    assert mask_to_chunks_np(np.ones(5, bool)) == [Chunk(0, 5)]
    assert chunk_stats_np(np.zeros(4, bool)) == (0.0, 0)


@given(st.lists(st.booleans(), min_size=1, max_size=200))
@settings(max_examples=60, deadline=None)
def test_roundtrip_np(bits):
    mask = np.asarray(bits, bool)
    chunks = mask_to_chunks_np(mask)
    back = chunks_to_mask_np(chunks, len(mask))
    assert (back == mask).all()
    # chunks are maximal: no two adjacent
    for a, b in zip(chunks, chunks[1:]):
        assert a.stop < b.start


@given(st.lists(st.booleans(), min_size=1, max_size=128))
@settings(max_examples=60, deadline=None)
def test_jax_matches_np(bits):
    mask = np.asarray(bits, bool)
    starts, sizes, n = mask_to_runs_jax(jnp.asarray(mask))
    n = int(n)
    got = [Chunk(int(s), int(z)) for s, z in zip(starts[:n], sizes[:n])]
    assert got == mask_to_chunks_np(mask)
    # histogram count equals number of chunks; weighted sum = popcount
    hist = np.asarray(contiguity_histogram_jax(jnp.asarray(mask), len(mask)))
    assert hist.sum() == len(got)
    assert (hist * np.arange(len(hist))).sum() == mask.sum()


def test_average_chunk_size_jax():
    mask = np.zeros(10, bool)
    mask[[0, 1, 2, 5, 6, 9]] = True  # sizes 3, 2, 1
    assert float(average_chunk_size_jax(jnp.asarray(mask))) == pytest.approx(2.0)


def test_overlapping_chunks_rejected():
    with pytest.raises(ValueError):
        chunks_to_mask_np([Chunk(0, 3), Chunk(2, 2)], 8)
