"""The decode execution backend: kernel-vs-reference bitwise parity.

The PR-5 tentpole invariant — `backend="kernel"` routes the planned decode
path through the Pallas DMA gather kernels, `backend="reference"` through
their pure-jnp schedule twin, and the two must be BITWISE identical (same
multiply/add sequence), making byte-identical greedy tokens the system's
strongest correctness check. Array-level parity is fast-tier; the
engine-level token identity compiles two decode scans and is fast-tier too
(the acceptance criterion must gate every push).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.kernels import (
    ExecutionBackend,
    blocked_masked_matmul,
    dequantize_rows,
    masks_to_block_tables,
    pick_tile,
    quantize_rows,
    validate_backend,
)
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import ServeEngine, SparseExecution


def _backends():
    return (ExecutionBackend.create("reference"),
            ExecutionBackend.create("kernel", interpret=True))


# ---------------------------------------------------------------------------
# array-level parity: project / swiglu_mlp are bitwise twins
# ---------------------------------------------------------------------------


def test_project_bitwise_parity():
    rng = np.random.default_rng(0)
    n, d, b = 64, 48, 2  # d=48 -> pick_tile falls back to 16
    w = jnp.asarray(rng.normal(0, 0.1, (n, d)), jnp.bfloat16)
    x = jnp.asarray(rng.normal(0, 1.0, (b, n)), jnp.bfloat16)
    mask = jnp.asarray(rng.random(n) < 0.4)
    starts, sizes = masks_to_block_tables(mask[None, :])
    ref, ker = _backends()
    y_ref = ref.project(w, x, mask, starts[0], sizes[0])
    y_ker = ker.project(w, x, mask, starts[0], sizes[0])
    assert y_ref.dtype == y_ker.dtype == jnp.float32
    assert bool(jnp.all(y_ref == y_ker)), "backends must agree bitwise"
    # and both equal the exact masked matmul up to f32 accumulation noise
    dense = (x * mask.astype(x.dtype)).astype(jnp.float32) @ w.astype(jnp.float32)
    assert float(jnp.max(jnp.abs(y_ref - dense))) < 1e-5


def test_swiglu_mlp_bitwise_parity_and_h():
    rng = np.random.default_rng(1)
    n, f, d, b = 64, 96, 64, 2
    wg = jnp.asarray(rng.normal(0, 0.1, (n, f)), jnp.bfloat16)
    wu = jnp.asarray(rng.normal(0, 0.1, (n, f)), jnp.bfloat16)
    wd = jnp.asarray(rng.normal(0, 0.1, (f, d)), jnp.bfloat16)
    x = jnp.asarray(rng.normal(0, 1.0, (b, n)), jnp.bfloat16)
    hidden = jnp.asarray(rng.random(n) < 0.5)
    ffn = jnp.asarray(rng.random(f) < 0.3)
    # pad the two lanes into one (2, K) table like the batched refresh does
    n_max = max(n, f)
    masks = np.zeros((2, n_max), bool)
    masks[0, :n] = np.asarray(hidden)
    masks[1, :f] = np.asarray(ffn)
    starts, sizes = masks_to_block_tables(jnp.asarray(masks))
    ref, ker = _backends()
    y_ref, h_ref = ref.swiglu_mlp(wg, wu, wd, x, hidden, ffn, starts, sizes)
    y_ker, h_ker = ker.swiglu_mlp(wg, wu, wd, x, hidden, ffn, starts, sizes)
    assert bool(jnp.all(y_ref == y_ker))
    assert bool(jnp.all(h_ref == h_ker))
    # h is the UNMASKED intermediate: rows outside the ffn mask are nonzero
    # (importance recording must see them), while y charges only masked rows
    off = ~np.asarray(ffn)
    assert float(jnp.max(jnp.abs(np.asarray(h_ref)[:, off]))) > 0.0


def test_blocked_matmul_is_exact_masked_semantics():
    rng = np.random.default_rng(2)
    n, d = 32, 16
    w = jnp.asarray(rng.normal(0, 0.1, (n, d)), jnp.float32)
    xm = jnp.asarray(rng.normal(0, 1.0, (2, n)), jnp.float32)
    y = blocked_masked_matmul(xm, w)
    assert np.allclose(np.asarray(y), np.asarray(xm) @ np.asarray(w), atol=1e-5)


# ---------------------------------------------------------------------------
# quantized chunk storage (PR 6): the same bitwise-twin property at 8 bits
# ---------------------------------------------------------------------------


def test_project_quantized_bitwise_parity():
    """int8 payload + per-block scale lane through both backends: still
    bitwise twins (the kernel's in-VMEM dequant multiply is elementwise the
    reference twin's per-block multiply), and within half a quantization
    step of the dequantized dense matmul."""
    rng = np.random.default_rng(5)
    n, d, b = 64, 48, 2
    w = jnp.asarray(rng.normal(0, 0.1, (n, d)), jnp.bfloat16)
    q, s = quantize_rows(w)
    x = jnp.asarray(rng.normal(0, 1.0, (b, n)), jnp.bfloat16)
    mask = jnp.asarray(rng.random(n) < 0.4)
    starts, sizes = masks_to_block_tables(mask[None, :])
    ref, ker = _backends()
    y_ref = ref.project(q, x, mask, starts[0], sizes[0], s)
    y_ker = ker.project(q, x, mask, starts[0], sizes[0], s)
    assert y_ref.dtype == y_ker.dtype == jnp.float32
    assert bool(jnp.all(y_ref == y_ker)), "quantized backends must agree bitwise"
    dense = (x * mask.astype(x.dtype)).astype(jnp.float32) @ dequantize_rows(q, s)
    assert float(jnp.max(jnp.abs(y_ref - dense))) < 1e-4


def test_swiglu_mlp_quantized_bitwise_parity():
    rng = np.random.default_rng(6)
    n, f, d, b = 64, 96, 64, 2
    wg = jnp.asarray(rng.normal(0, 0.1, (n, f)), jnp.bfloat16)
    wu = jnp.asarray(rng.normal(0, 0.1, (n, f)), jnp.bfloat16)
    wd = jnp.asarray(rng.normal(0, 0.1, (f, d)), jnp.bfloat16)
    qg, sg = quantize_rows(wg)
    qu, su = quantize_rows(wu)
    qd, sd = quantize_rows(wd)
    x = jnp.asarray(rng.normal(0, 1.0, (b, n)), jnp.bfloat16)
    hidden = jnp.asarray(rng.random(n) < 0.5)
    ffn = jnp.asarray(rng.random(f) < 0.3)
    n_max = max(n, f)
    masks = np.zeros((2, n_max), bool)
    masks[0, :n] = np.asarray(hidden)
    masks[1, :f] = np.asarray(ffn)
    starts, sizes = masks_to_block_tables(jnp.asarray(masks))
    ref, ker = _backends()
    y_ref, h_ref = ref.swiglu_mlp(qg, qu, qd, x, hidden, ffn, starts, sizes,
                                  scales=(sg, su, sd))
    y_ker, h_ker = ker.swiglu_mlp(qg, qu, qd, x, hidden, ffn, starts, sizes,
                                  scales=(sg, su, sd))
    assert bool(jnp.all(y_ref == y_ker))
    assert bool(jnp.all(h_ref == h_ker))
    # h (the importance-recording intermediate) stays unmasked at 8 bits too
    off = ~np.asarray(ffn)
    assert float(jnp.max(jnp.abs(np.asarray(h_ref)[:, off]))) > 0.0


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_backend_validation():
    with pytest.raises(ValueError, match="unknown execution backend"):
        validate_backend("cuda")
    with pytest.raises(ValueError, match="unknown execution backend"):
        ExecutionBackend.create("triton")
    with pytest.raises(ValueError, match="prefetch_depth"):
        ExecutionBackend.create("kernel", prefetch_depth=-1)
    assert pick_tile(704) == 64 and pick_tile(896) == 128 and pick_tile(48) == 16
    with pytest.raises(ValueError, match="tile divisor"):
        pick_tile(12)


def test_kernel_backend_rejects_reorderings():
    from repro.core import hot_cold_reordering

    cfg = get_config("internvl2-76b").reduced()
    cal = np.random.default_rng(0).random((8, cfg.d_model)).astype(np.float32)
    reo = {"hidden_attn": hot_cold_reordering(cal)}
    SparseExecution(cfg, reorderings=reo)  # reference backend: fine
    with pytest.raises(ValueError, match="reorderings"):
        SparseExecution(cfg, reorderings=reo, backend="kernel")


def test_engine_validates_backend():
    cfg = get_config("internvl2-76b").reduced()
    model = build_model(cfg)
    with pytest.raises(ValueError, match="unknown execution backend"):
        ServeEngine(model, None, max_seq=32, batch_size=1, backend="nope")


def test_wbits_validation():
    cfg = get_config("internvl2-76b").reduced()
    with pytest.raises(ValueError, match="wbits"):
        SparseExecution(cfg, wbits=4)
    model = build_model(cfg)
    with pytest.raises(ValueError, match="wbits"):
        ServeEngine(model, None, max_seq=32, batch_size=1, wbits=4)


# ---------------------------------------------------------------------------
# engine-level: byte-identical greedy tokens (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def vlm():
    cfg = get_config("internvl2-76b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, InputShape("bk", 16, 2, "train"))
    return cfg, model, params, batch


def _decode(model, params, batch, backend, n=6, **kw):
    eng = ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                      sparsity=0.4, method="chunk", seed=3, backend=backend,
                      **kw)
    eng.simulator.noise = 0.0
    tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
    out = eng.decode(tok0, n)
    return eng, out


def test_decode_tokens_byte_identical_across_backends(vlm):
    cfg, model, params, batch = vlm
    eng_r, out_r = _decode(model, params, batch, "reference")
    eng_k, out_k = _decode(model, params, batch, "kernel")
    assert bool(jnp.all(out_r == out_k)), (
        "kernel-backend decode diverged from the reference backend"
    )
    # the backend changes HOW the arithmetic runs, never the selection —
    # so the I/O accounting must agree exactly too
    sr, sk = eng_r.io_summary(), eng_k.io_summary()
    assert sr["io_est_s"] == pytest.approx(sk["io_est_s"], rel=0, abs=0)
    assert sr["miss_rows"] == sk["miss_rows"]


def test_decode_tokens_byte_identical_at_wbits8(vlm):
    """The PR-6 acceptance criterion: greedy decode at --wbits 8 (int8
    chunk payloads dequantized in-kernel) stays byte-identical between the
    kernel backend and the reference twin, and the quantized run's total
    modeled I/O bytes land strictly below the fp16 run's."""
    cfg, model, params, batch = vlm
    eng_r, out_r = _decode(model, params, batch, "reference", wbits=8)
    eng_k, out_k = _decode(model, params, batch, "kernel", wbits=8)
    assert bool(jnp.all(out_r == out_k)), (
        "wbits=8 kernel-backend decode diverged from the reference backend"
    )
    sr, sk = eng_r.io_summary(), eng_k.io_summary()
    assert sr["io_bytes"] == sk["io_bytes"]  # selection unchanged by backend
    eng16, _ = _decode(model, params, batch, "reference")
    assert sr["io_bytes"] < eng16.io_summary()["io_bytes"], (
        "int8 chunk storage must move strictly fewer modeled bytes than fp16"
    )


@pytest.mark.slow
def test_decode_backend_parity_with_cache_and_reuse(vlm):
    """Residency cache + plan reuse ride the same plan carry the kernels
    consume — parity must survive both."""
    cfg, model, params, batch = vlm
    kw = dict(cache_mb=4.0, plan_refresh_interval=2)
    _, out_r = _decode(model, params, batch, "reference", **kw)
    _, out_k = _decode(model, params, batch, "kernel", **kw)
    assert bool(jnp.all(out_r == out_k))


@pytest.mark.slow
def test_decode_backend_parity_gelu_mlp():
    """The non-gated (c_fc/c_proj) MLP routes through two single-site
    backend projections — parity on a gelu-family arch."""
    cfg = get_config("starcoder2-3b").reduced()
    assert cfg.mlp == "gelu"
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = make_dummy_batch(cfg, InputShape("bg", 16, 2, "train"))
    _, out_r = _decode(model, params, batch, "reference", n=4)
    _, out_k = _decode(model, params, batch, "kernel", n=4)
    assert bool(jnp.all(out_r == out_k))


@pytest.mark.slow
def test_backend_is_depth_invariant(vlm):
    """prefetch_depth only re-times fetches; kernel-backend tokens are
    byte-identical across depths 0 and 2."""
    cfg, model, params, batch = vlm
    outs = [
        _decode(model, params, batch, "kernel", prefetch_depth=depth)[1]
        for depth in (0, 2)
    ]
    assert bool(jnp.all(outs[0] == outs[1]))
