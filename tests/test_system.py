"""End-to-end system behaviour: the paper's full serving pipeline with
sparsification policies, and a short training run — both on reduced models.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data import DataConfig, lm_batches
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import ServeEngine
from repro.training import AdamWConfig, Trainer


@pytest.mark.slow
def test_streaming_vlm_pipeline_chunk_beats_topk():
    """Full paper pipeline: prefill → 3 frames → decode, comparing policies.

    Asserts the paper's headline result (chunk ≥2× less I/O than top-k at
    equal sparsity) and that sparse decoding stays numerically sane.
    """
    cfg = get_config("internvl2-76b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    shape = InputShape(name="s", seq_len=32, global_batch=2, kind="train")
    rng = np.random.default_rng(0)

    results = {}
    for method in ("topk", "chunk"):
        eng = ServeEngine(model, params, max_seq=256, batch_size=2,
                          device="nano", sparsity=0.4, method=method, seed=9)
        last = eng.prefill(make_dummy_batch(cfg, shape))
        for _ in range(3):
            frame = jnp.asarray(rng.normal(0, 1, (2, 8, cfg.d_frontend)),
                                jnp.bfloat16)
            eng.append_frame(frame)
        tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
        out = eng.decode(tok0, 6)
        assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
        results[method] = sum(
            s.io_sim_s for s in eng.stats if s.kind != "prefill"
        )
    assert results["chunk"] < 0.5 * results["topk"]


@pytest.mark.slow
def test_train_then_serve_roundtrip(tmp_path):
    """Train a reduced model until loss drops, checkpoint, reload, serve."""
    from repro.training import load_checkpoint, save_checkpoint

    cfg = get_config("granite-3-2b").reduced()
    model = build_model(cfg)
    tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=3, total_steps=40),
                 loss_chunk=32)
    params, opt = tr.init_state(jax.random.key(0))
    step = tr.jit_train_step(donate=False)
    it = lm_batches(cfg, DataConfig(batch=8, seq_len=64, seed=0))
    first = last = None
    for i in range(15):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
    assert last < first

    save_checkpoint(str(tmp_path / "ck"), params, step=15)
    like = jax.eval_shape(model.init, jax.random.key(0))
    params2, _ = load_checkpoint(str(tmp_path / "ck"), like)

    eng = ServeEngine(model, params2, max_seq=128, batch_size=2,
                      device="agx", sparsity=0.3, method="chunk")
    batch = next(it)
    last_logits = eng.prefill({k: jnp.asarray(v[:2]) for k, v in batch.items()})
    tok = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    out = eng.decode(tok, 4)
    assert out.shape == (2, 5)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
