"""Continuous-batching serve stack: scheduler lifecycle, scan-fused decode
equivalence with the per-token loop, and chunk-plan reuse invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import (
    PoissonArrivalDriver,
    Request,
    RequestState,
    Scheduler,
    ServeEngine,
)

SMOKE = InputShape(name="smoke", seq_len=16, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model, params, method="chunk", refresh=1, batch=2, seed=1):
    return ServeEngine(model, params, max_seq=64, batch_size=batch,
                       device="nano", sparsity=0.4, method=method, seed=seed,
                       plan_refresh_interval=refresh)


def _requests(cfg, n, max_new=4, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    out = []
    for rid in range(n):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
        out.append(Request(rid=rid, prompt={"tokens": toks}, max_new_tokens=max_new))
    return out


# -- scheduler lifecycle -----------------------------------------------------


def test_admission_eviction_more_requests_than_slots(lm):
    cfg, model, params = lm
    eng = _engine(model, params, batch=2)
    sched = Scheduler(eng, round_tokens=2)
    reqs = _requests(cfg, 5, max_new=3)
    for i, r in enumerate(reqs):
        r.arrival_s = 0.001 * i
    sched.submit(reqs)

    # first iteration can admit at most the 2 slots
    assert sched.step()
    assert sched.num_running() <= 2
    stats = sched.run()
    assert stats.finished == 5
    assert all(r.state is RequestState.FINISHED for r in reqs)
    assert all(len(r.tokens_out) == 3 for r in reqs)
    # FCFS: finish order respects arrival order for equal-length requests
    assert [r.rid for r in sched.finished] == [0, 1, 2, 3, 4]
    # slots were recycled (eviction worked): all free at drain
    assert sched.free_slots() == [0, 1]
    # timing marks are causally ordered on the simulated clock
    for r in reqs:
        assert r.arrival_s <= r.admitted_s <= r.first_token_s <= r.finished_s
        assert r.latency_s() > 0 and r.ttft_s() > 0


def test_poisson_driver_monotone_arrivals(lm):
    cfg, _, _ = lm
    driver = PoissonArrivalDriver(
        100.0, lambda rid: _requests(cfg, 1)[0], seed=4
    )
    reqs = driver.generate(10)
    arrivals = [r.arrival_s for r in reqs]
    assert all(a < b for a, b in zip(arrivals, arrivals[1:]))
    assert arrivals[0] > 0
    with pytest.raises(ValueError):
        PoissonArrivalDriver(0.0, lambda rid: None)


def test_scheduler_idle_fast_forwards_to_arrival(lm):
    cfg, model, params = lm
    eng = _engine(model, params, batch=2)
    sched = Scheduler(eng, round_tokens=2)
    reqs = _requests(cfg, 1, max_new=2)
    reqs[0].arrival_s = 5.0  # far in the simulated future
    sched.submit(reqs)
    stats = sched.run()
    assert stats.finished == 1
    assert reqs[0].admitted_s >= 5.0


# -- scan-fused decode vs per-token loop -------------------------------------


@pytest.mark.parametrize("method", ["chunk", "topk", "dense", "dense_free"])
def test_fused_decode_matches_per_token(lm, method):
    cfg, model, params = lm
    batch = make_dummy_batch(cfg, SMOKE)
    eng_f = _engine(model, params, method=method, seed=3)
    eng_l = _engine(model, params, method=method, seed=3)
    tok0 = jnp.argmax(eng_f.prefill(batch), -1)[:, None].astype(jnp.int32)
    eng_l.prefill(batch)
    out_f = eng_f.decode(tok0, 6)
    out_l = eng_l.decode_per_token(tok0, 6)
    assert bool(jnp.all(out_f == out_l)), "tokens must be byte-identical"
    io_f = [s.io_est_s for s in eng_f.stats if s.kind == "decode"]
    io_l = [s.io_est_s for s in eng_l.stats if s.kind == "decode"]
    np.testing.assert_allclose(io_f, io_l, rtol=1e-6)
    np.testing.assert_allclose(sum(io_f), sum(io_l), rtol=1e-6)


def test_fused_decode_matches_per_token_with_plan_reuse(lm):
    """At refresh>1 the two modes must still agree on tokens, estimates AND
    simulated measurements — the batch simulator path consumes the RNG
    stream and event log exactly as the scalar path does (zero-estimate
    reuse steps draw no jitter and log no event)."""
    cfg, model, params = lm
    batch = make_dummy_batch(cfg, SMOKE)
    eng_f = _engine(model, params, refresh=2, seed=3)
    eng_l = _engine(model, params, refresh=2, seed=3)
    tok0 = jnp.argmax(eng_f.prefill(batch), -1)[:, None].astype(jnp.int32)
    eng_l.prefill(batch)
    out_f = eng_f.decode(tok0, 6)
    out_l = eng_l.decode_per_token(tok0, 6)
    assert bool(jnp.all(out_f == out_l))
    sim_f = [s.io_sim_s for s in eng_f.stats if s.kind == "decode"]
    sim_l = [s.io_sim_s for s in eng_l.stats if s.kind == "decode"]
    np.testing.assert_allclose(sim_f, sim_l, rtol=1e-9)
    assert len(eng_f.simulator.log) == len(eng_l.simulator.log)


def test_fused_decode_single_host_sync_accounting(lm):
    """The scan path logs one StepStats per token (same granularity as the
    loop) from ONE on-device estimate array."""
    cfg, model, params = lm
    batch = make_dummy_batch(cfg, SMOKE)
    eng = _engine(model, params)
    tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
    out = eng.decode(tok0, 5)
    assert out.shape == (2, 6)
    steps = [s for s in eng.stats if s.kind == "decode"]
    assert len(steps) == 5
    assert all(s.io_sim_s > 0 and s.io_est_s > 0 for s in steps)


# -- chunk-plan reuse --------------------------------------------------------


def test_plan_reuse_refresh_cadence_and_latency_bound(lm):
    """With plan_refresh_interval=k, selection I/O is paid on exactly
    ceil(n/k) steps; reuse steps are free (resident chunks) and no reuse-mode
    step ever exceeds the per-step refresh-mode latency estimate."""
    cfg, model, params = lm
    batch = make_dummy_batch(cfg, SMOKE)
    n = 8

    eng1 = _engine(model, params, refresh=1, seed=3)
    tok0 = jnp.argmax(eng1.prefill(batch), -1)[:, None].astype(jnp.int32)
    eng1.decode(tok0, n)
    io1 = [s.io_est_s for s in eng1.stats if s.kind == "decode"]

    engk = _engine(model, params, refresh=3, seed=3)
    engk.prefill(batch)
    engk.decode(tok0, n)
    iok = [s.io_est_s for s in engk.stats if s.kind == "decode"]

    refresh_steps = [i for i, v in enumerate(iok) if v > 0]
    assert refresh_steps == [0, 3, 6]  # every k-th step
    assert all(v == 0.0 for i, v in enumerate(iok) if i not in refresh_steps)
    assert max(iok) <= max(io1) * 1.25 + 1e-12
    assert sum(iok) < sum(io1)


def test_plan_reuse_interval_one_is_identity(lm):
    cfg, model, params = lm
    batch = make_dummy_batch(cfg, SMOKE)
    eng1 = _engine(model, params, refresh=1, seed=3)
    tok0 = jnp.argmax(eng1.prefill(batch), -1)[:, None].astype(jnp.int32)
    out1 = eng1.decode(tok0, 5)
    io1 = [s.io_est_s for s in eng1.stats if s.kind == "decode"]
    assert all(v > 0 for v in io1)  # every step refreshes → every step pays
    assert out1.shape == (2, 6)


def test_plan_refresh_interval_validation(lm):
    cfg, model, params = lm
    with pytest.raises(ValueError):
        _engine(model, params, refresh=0)


# -- slot-mode engine invariants ---------------------------------------------


def test_admit_slot_isolates_requests(lm):
    """Admitting into one slot must not disturb the other slot's cache
    length, and per-slot lengths advance together under decode_slots."""
    cfg, model, params = lm
    eng = _engine(model, params, batch=2)
    eng.enable_slots()
    reqs = _requests(cfg, 2, max_new=2)
    last0, _ = eng.admit_slot(0, reqs[0].prompt)
    assert eng.slot_lengths().tolist() == [8, 0]
    last1, _ = eng.admit_slot(1, reqs[1].prompt)
    assert eng.slot_lengths().tolist() == [8, 8]
    toks = jnp.concatenate(
        [jnp.argmax(last0, -1)[:, None], jnp.argmax(last1, -1)[:, None]]
    ).astype(jnp.int32)
    new_toks, sims = eng.decode_slots(toks, 3)
    assert new_toks.shape == (2, 3)
    assert eng.slot_lengths().tolist() == [11, 11]
    with pytest.raises(ValueError):
        eng.admit_slot(7, reqs[0].prompt)


def test_dense_free_validated_in_one_place(lm):
    """``dense_free`` (fully memory-resident weights, no flash tier) is an
    engine-level policy: ServeEngine accepts it and skips SparseExecution
    entirely; SparseExecution itself only knows the streaming methods. Both
    validate against the shared SERVE_METHODS/SPARSE_METHODS tuples."""
    from repro.serving import (
        SERVE_METHODS,
        SPARSE_METHODS,
        SparseExecution,
        validate_method,
    )

    cfg, model, params = lm
    assert set(SERVE_METHODS) == set(SPARSE_METHODS) | {"dense_free"}
    assert validate_method("dense_free", allow_dense_free=True) == "dense_free"
    with pytest.raises(ValueError):
        validate_method("dense_free")  # streaming contexts reject it
    with pytest.raises(ValueError):
        validate_method("bogus", allow_dense_free=True)
    with pytest.raises(ValueError):
        SparseExecution(cfg, method="dense_free")
    with pytest.raises(ValueError):
        _engine(model, params, method="bogus")

    eng = _engine(model, params, method="dense_free")
    assert eng.sparse_ctx is None
    batch = make_dummy_batch(cfg, SMOKE)
    tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
    out = eng.decode(tok0, 3)
    assert out.shape == (2, 4)
    s = eng.io_summary()
    assert s["io_est_s"] == 0.0 and s["io_sim_s"] == 0.0  # no flash tier


def test_slot_decode_matches_single_stream(lm):
    """A request decoded in slot mode produces the same tokens as the same
    prompt decoded through the classic single-stream path."""
    cfg, model, params = lm
    req = _requests(cfg, 1, max_new=4)[0]

    eng_s = _engine(model, params, batch=1, seed=3)
    eng_s.enable_slots()
    last, _ = eng_s.admit_slot(0, req.prompt)
    tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    slot_toks, _ = eng_s.decode_slots(tok0, 4)

    eng_c = _engine(model, params, batch=1, seed=3)
    last_c, cache = model.prefill(params, req.prompt, 64)
    eng_c.cache = cache
    out_c = eng_c.decode(tok0, 4)
    assert bool(jnp.all(slot_toks == out_c[:, 1:]))


# -- failure paths and SLO machinery (ISSUE 8 satellites) --------------------


def test_stats_empty_is_nan_not_zero(lm):
    """Zero finished requests must yield NaN percentiles (and finished=0),
    never a fabricated 0.0 a bench latency floor could pass vacuously."""
    cfg, model, params = lm
    sched = Scheduler(_engine(model, params), round_tokens=2)
    s = sched.stats()
    assert s.finished == 0 and s.decode_tokens == 0
    assert np.isnan(s.latency_p50_s) and np.isnan(s.latency_p95_s)
    assert np.isnan(s.latency_p99_s) and np.isnan(s.ttft_p50_s)
    assert np.isnan(s.slo_attainment) and s.deadlines == 0
    assert s.preempted == 0


def test_run_drain_timeout_raises(lm):
    """run(max_rounds) must fail loudly when the workload cannot drain in
    the allotted rounds instead of spinning forever."""
    cfg, model, params = lm
    sched = Scheduler(_engine(model, params), round_tokens=1)
    sched.submit(_requests(cfg, 1, max_new=8))  # needs >= 8 rounds
    with pytest.raises(RuntimeError, match="did not drain in 2 rounds"):
        sched.run(max_rounds=2)


def test_over_decode_tokens_dropped(lm):
    """A request finishing mid-round must not keep the round's filler
    tokens: max_new is exact even when round_tokens over-decodes."""
    cfg, model, params = lm
    sched = Scheduler(_engine(model, params), round_tokens=4)
    reqs = _requests(cfg, 2, max_new=3)
    sched.submit(reqs)
    stats = sched.run()
    assert stats.finished == 2
    assert all(len(r.tokens_out) == 3 for r in reqs)
    assert stats.decode_tokens == 6  # dropped filler never counted


def test_edf_admission_orders_by_deadline(lm):
    """With deadlines attached, admission is earliest-deadline-first; the
    latest-deadline request waits for a recycled slot."""
    cfg, model, params = lm
    sched = Scheduler(_engine(model, params, batch=2), round_tokens=2)
    reqs = _requests(cfg, 3, max_new=4)
    deadlines = [10.0, 1.0, 5.0]
    for r, d in zip(reqs, deadlines):
        r.deadline_s = d  # all arrive at t=0
    sched.submit(reqs)
    assert sched.step()  # 4 tokens at round_tokens=2: nobody finishes yet
    running = sorted(r.rid for r in sched.running if r is not None)
    assert running == [1, 2]  # tightest two deadlines admitted first
    stats = sched.run()
    assert stats.finished == 3 and stats.deadlines == 3
    assert [r.rid for r in sched.finished] == [1, 2, 0]


def test_preemption_recycles_slots_and_drains(lm):
    """Deadline-blown requests are evicted-and-requeued (at most once), the
    freed slots are reused, and every request still finishes with exactly
    its max_new tokens — preemption can never wedge the drain loop."""
    cfg, model, params = lm
    eng = ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                      sparsity=0.4, method="chunk", seed=1,
                      fault_profile="thermal_throttle", fault_seed=0)
    eng.simulator.noise = 0.0
    sched = Scheduler(eng, round_tokens=2)
    reqs = _requests(cfg, 8, max_new=6)
    for i, r in enumerate(reqs):
        r.arrival_s = 0.002 * i
        r.deadline_s = 0.03
    sched.submit(reqs)
    stats = sched.run()
    assert stats.finished == 8
    assert stats.preempted >= 1
    pre = [r for r in reqs if r.preemptions > 0]
    assert pre and all(r.preemptions == 1 for r in pre)  # capped at one
    assert all(r.state is RequestState.FINISHED for r in reqs)
    assert all(len(r.tokens_out) == 6 for r in reqs)
    # slots fully recycled after the drain
    assert sched.free_slots() == [0, 1]
    # requeue kept arrival bookkeeping causally ordered
    for r in pre:
        assert r.arrival_s <= r.admitted_s <= r.finished_s
