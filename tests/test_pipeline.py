"""Overlapped I/O–compute pipeline + batched multi-site selection.

Timeline invariants (core/pipeline.py): zero compute ⇒ overlapped == serial;
compute-dominant ⇒ I/O fully hidden (steady-state critical path == compute);
overlapped ≤ serial always. Batched selection (core/chunking.py →
SparseExecution.refresh_layer): per-site mask identity vs the single-site
selector and the ``select_chunks_np`` numpy oracle, and ONE while_loop
greedy dispatch per layer (not one per site). Engine integration: both
charges logged per step, bytes threaded to IOEvents, selection overhead
populated.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.chunking import select_chunks_np
from repro.core.pipeline import PipelineModel
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import ServeEngine
from repro.serving.sparse_exec import SparseExecution


# -- timeline invariants ------------------------------------------------------


def test_zero_compute_overlapped_equals_serial():
    rng = np.random.default_rng(0)
    io = rng.random((6, 5))
    tl = PipelineModel().timeline(io, np.zeros(5))
    np.testing.assert_allclose(tl.overlap_s, tl.serial_s, rtol=1e-12)
    np.testing.assert_allclose(tl.serial_s, io.sum(axis=1), rtol=1e-12)
    assert tl.overlap_efficiency() == 1.0  # nothing hideable → trivially 1


def test_compute_dominant_io_fully_hidden():
    """When compute dwarfs I/O, every steady-state step's critical path is
    exactly Σ compute; step 0 additionally pays the cold first fetch."""
    n, n_layers = 5, 4
    io = np.full((n, n_layers), 1e-4)
    comp = np.full(n_layers, 1.0)
    tl = PipelineModel().timeline(io, comp)
    np.testing.assert_allclose(tl.overlap_s[1:], comp.sum(), rtol=1e-12)
    np.testing.assert_allclose(tl.overlap_s[0], comp.sum() + io[0, 0], rtol=1e-12)
    # everything hideable was hidden except the one cold fetch:
    # efficiency = (n·L − 1) / (n·L)
    np.testing.assert_allclose(
        tl.overlap_efficiency(), (n * n_layers - 1) / (n * n_layers), rtol=1e-9
    )


@pytest.mark.parametrize("seed", [1, 2, 3])
@pytest.mark.parametrize("depth", [0, 1, 3])
def test_overlapped_never_exceeds_serial(seed, depth):
    rng = np.random.default_rng(seed)
    io = rng.random((8, 6)) * rng.choice([0.0, 1.0, 10.0], size=(8, 1))
    comp = rng.random((8, 6))
    tl = PipelineModel(prefetch_depth=depth).timeline(io, comp)
    assert np.all(tl.overlap_s <= tl.serial_s + 1e-12)
    assert np.all(tl.stall_s >= 0) and np.all(tl.bubble_s >= 0)
    assert 0.0 <= tl.overlap_efficiency() <= 1.0


def test_prefetch_depth_zero_is_serial():
    rng = np.random.default_rng(4)
    io, comp = rng.random((5, 3)), rng.random(3)
    tl = PipelineModel().serial_timeline(io, comp)
    np.testing.assert_allclose(tl.overlap_s, tl.serial_s, rtol=1e-12)


def test_reuse_steps_zero_io_charge_compute_only():
    """Plan-reuse-shaped input: refresh steps pay I/O, reuse steps are pure
    compute — cross-step prefetch may hide part of a refresh's I/O under
    the preceding reuse steps' compute, never the reverse."""
    io = np.zeros((6, 3))
    io[0] = io[3] = 0.01  # refresh every 3rd step
    comp = np.full(3, 1e-3)
    tl = PipelineModel().timeline(io, comp)
    reuse = [1, 2, 4, 5]
    np.testing.assert_allclose(tl.overlap_s[reuse], comp.sum(), rtol=1e-9)
    assert tl.overlap_total_s <= tl.serial_total_s + 1e-12


def test_pipeline_validation():
    with pytest.raises(ValueError):
        PipelineModel(prefetch_depth=-1)
    with pytest.raises(ValueError):
        PipelineModel().timeline(np.ones((2, 2)) * -1.0, np.ones(2))


# -- batched multi-site selection --------------------------------------------


@pytest.fixture(scope="module")
def ctx():
    cfg = get_config("tinyllama-1.1b").reduced()
    return SparseExecution(cfg, device="nano", sparsity=0.4, method="chunk")


@pytest.mark.parametrize("seed", [0, 7, 21])
def test_batched_selector_matches_single_site_and_oracle(ctx, seed):
    """Lane i of the batched (n_sites, K) problem must reproduce site i's
    single-site ``ChunkSelector.select`` AND the numpy oracle exactly —
    same utility, same stable tie-breaking, same budget rule — on the serve
    stack's real site shapes."""
    rng = np.random.default_rng(seed)
    batched = ctx.batched
    vs = np.zeros((batched.n_sites, batched.n_max), np.float32)
    budgets = []
    per_site = {}
    for i, kind in enumerate(ctx.site_order):
        site = ctx.sites[kind]
        v = rng.gamma(2.0, 1.0, site.n).astype(np.float32)
        vs[i, : site.n] = v
        budget = int(round((1.0 - site.sparsity) * site.n))
        budgets.append(budget)
        per_site[kind] = (v, budget, site)
    masks, selected = batched.select(
        jnp.asarray(vs), jnp.asarray(budgets, jnp.int32)
    )
    masks = np.asarray(masks)
    for i, kind in enumerate(ctx.site_order):
        v, budget, site = per_site[kind]
        m_single, n_single, _ = site.selector.select(
            jnp.asarray(v), jnp.int32(budget)
        )
        m_oracle = select_chunks_np(
            v, budget, site.selector.row_bytes, site.selector.table,
            site.selector.cfg,
        )
        np.testing.assert_array_equal(masks[i, : site.n], np.asarray(m_single))
        np.testing.assert_array_equal(masks[i, : site.n], m_oracle)
        assert int(selected[i]) == int(n_single) <= budget
        # padded rows are never selected
        assert not masks[i, site.n:].any()


def test_batched_selector_matches_oracle_with_residency(ctx):
    rng = np.random.default_rng(11)
    batched = ctx.batched
    vs = np.zeros((batched.n_sites, batched.n_max), np.float32)
    res = np.zeros((batched.n_sites, batched.n_max), bool)
    budgets, sites = [], []
    for i, kind in enumerate(ctx.site_order):
        site = ctx.sites[kind]
        vs[i, : site.n] = rng.gamma(2.0, 1.0, site.n).astype(np.float32)
        res[i, : site.n] = rng.random(site.n) < 0.3
        budgets.append(int(round((1.0 - site.sparsity) * site.n)))
        sites.append(site)
    masks, _ = batched.select(
        jnp.asarray(vs), jnp.asarray(budgets, jnp.int32), jnp.asarray(res)
    )
    masks = np.asarray(masks)
    for i, site in enumerate(sites):
        m_oracle = select_chunks_np(
            vs[i, : site.n], budgets[i], site.selector.row_bytes,
            site.selector.table, site.selector.cfg,
            resident=res[i, : site.n],
        )
        np.testing.assert_array_equal(masks[i, : site.n], m_oracle)


def test_batched_selector_prefilter_truncation_cannot_change_result(ctx):
    """Regression: the top-C prefilter must be a trip-count bound, never a
    truncation — with top_c far below K the completion segment has to take
    over and the masks must STILL match the oracle exactly (an earlier
    draft dropped candidates beyond C, under-filling the budget on
    full-size configs)."""
    from repro.core.chunking import BatchedChunkSelector

    sels = [ctx.sites[k].selector for k in ctx.site_order]
    tiny = BatchedChunkSelector.build(sels, top_c=16)
    assert tiny.top_c == 16  # prefilter genuinely engaged
    rng = np.random.default_rng(5)
    vs = np.zeros((tiny.n_sites, tiny.n_max), np.float32)
    budgets = []
    for i, kind in enumerate(ctx.site_order):
        site = ctx.sites[kind]
        vs[i, : site.n] = rng.gamma(2.0, 1.0, site.n).astype(np.float32)
        budgets.append(int(round((1.0 - site.sparsity) * site.n)))
    masks, selected = tiny.select(jnp.asarray(vs), jnp.asarray(budgets, jnp.int32))
    masks = np.asarray(masks)
    for i, kind in enumerate(ctx.site_order):
        site = ctx.sites[kind]
        m_oracle = select_chunks_np(
            vs[i, : site.n], budgets[i], site.selector.row_bytes,
            site.selector.table, site.selector.cfg,
        )
        np.testing.assert_array_equal(masks[i, : site.n], m_oracle)
        # and the budget is actually filled as far as the oracle fills it
        assert int(selected[i]) == int(m_oracle.sum())


def test_refresh_layer_honors_static_cached_without_residency_tier():
    """Legacy §5 contract on the PLANNED path with cache_mb == 0: static
    `cached` (memory-resident) neurons get zero selection importance —
    never streamed — and are always OR'd into the applied compute mask,
    exactly like the unplanned mask() path (a refactor once dropped this
    for the batched refresh)."""
    cfg = get_config("tinyllama-1.1b").reduced()
    n = cfg.d_model
    cached = jnp.zeros((n,), bool).at[jnp.arange(0, n, 4)].set(True)
    ctx_c = SparseExecution(cfg, device="nano", sparsity=0.4, method="chunk",
                            cached={"hidden_attn": cached})
    ctx_n = SparseExecution(cfg, device="nano", sparsity=0.4, method="chunk")
    rng = np.random.default_rng(2)

    def one_refresh(ctx):
        plan = jax.tree.map(lambda a: a[0], ctx.init_plan(1))
        for kind in ctx.site_order:
            v = rng.gamma(2.0, 1.0, (2, 4, ctx.sites[kind].n)).astype(np.float32)
            plan = ctx.record_importance(kind, jnp.asarray(v), plan)
        return ctx.refresh_layer(plan, jnp.bool_(True))

    plan_c, lat_c = one_refresh(ctx_c)
    plan_n, lat_n = one_refresh(ctx_n)
    # cached neurons always present in the applied mask
    assert bool(jnp.all(plan_c["hidden_attn"]["mask"][::4] == 1.0))
    # and caching never grows the I/O charge (cached rows stream nothing)
    assert float(lat_c) <= float(lat_n) * 1.2


def _count_while_eqns(jaxpr) -> int:
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            count += 1
        for v in eqn.params.values():
            objs = v if isinstance(v, (tuple, list)) else [v]
            for o in objs:
                inner = getattr(o, "jaxpr", None)
                if inner is not None:
                    count += _count_while_eqns(inner)
    return count


def test_refresh_is_one_batched_dispatch_per_layer(ctx):
    """The planned refresh path must run ONE vmapped while_loop greedy for
    all of a layer's sites — not one per site (the seed ran four)."""
    plan_full = ctx.init_plan(2)
    layer_plan = jax.tree.map(lambda a: a[0], plan_full)
    jaxpr = jax.make_jaxpr(
        lambda p: ctx.refresh_layer(p, jnp.bool_(True))
    )(layer_plan)
    assert _count_while_eqns(jaxpr.jaxpr) == 1


# -- engine integration -------------------------------------------------------


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, InputShape("pipe", 8, 2, "train"))
    return cfg, model, params, batch


def _engine(lm, overlap=True, method="chunk", seed=3):
    cfg, model, params, batch = lm
    eng = ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                      sparsity=0.4, method=method, seed=seed, overlap=overlap)
    eng.simulator.noise = 0.0
    tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
    return eng, tok0


def test_engine_overlap_below_serial_tokens_identical(lm):
    eng_o, tok0 = _engine(lm, overlap=True)
    eng_s, _ = _engine(lm, overlap=False)
    out_o = eng_o.decode(tok0, 6)
    out_s = eng_s.decode(tok0, 6)
    assert bool(jnp.all(out_o == out_s)), "overlap mode must not change tokens"
    s = eng_o.io_summary()
    assert 0.0 < s["decode_overlap_s"] < s["decode_serial_s"]
    assert 0.5 <= s["overlap_efficiency"] <= 1.0
    for st in eng_o.stats:
        if st.kind == "decode":
            assert st.overlap_s <= st.serial_s + 1e-15
            assert st.compute_s > 0 and st.stall_s >= 0


def test_engine_bytes_threaded_to_simulator(lm):
    """total_bytes() must be meaningful on the estimate-driven scan path
    (it used to log nbytes=0): decode events carry miss-rows × row-bytes
    and the per-token loop agrees exactly."""
    eng, tok0 = _engine(lm)
    eng.decode(tok0, 5)
    dec_events = [e for e in eng.simulator.log if e.name.startswith("decode")]
    assert dec_events and all(e.nbytes > 0 for e in dec_events)
    assert eng.simulator.total_bytes() > 0
    decode_bytes = sum(e.nbytes for e in dec_events)
    assert decode_bytes == sum(
        s.nbytes for s in eng.stats if s.kind == "decode"
    )
    eng_p, tok0p = _engine(lm)
    eng_p.decode_per_token(tok0p, 5)
    assert sum(s.nbytes for s in eng_p.stats if s.kind == "decode") == decode_bytes


def test_engine_select_overhead_populated(lm):
    """Both decode paths report the fig13 quantity (selection seconds per
    step): the per-token loop on refresh steps, the scan path amortized."""
    cfg, model, params, batch = lm
    eng = ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                      sparsity=0.4, method="chunk", seed=3,
                      plan_refresh_interval=2)
    tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
    eng.decode_per_token(tok0, 4)
    per_tok = [s.select_overhead_s for s in eng.stats if s.kind == "decode"]
    assert per_tok[0] > 0 and per_tok[2] > 0  # refresh steps timed
    assert per_tok[1] == 0.0 and per_tok[3] == 0.0  # reuse steps free
    eng.prefill(batch)
    eng.decode(tok0, 4)
    scan = [s.select_overhead_s for s in eng.stats if s.kind == "decode"][4:]
    assert all(v > 0 for v in scan)  # amortized uniformly
    np.testing.assert_allclose(sum(scan), sum(per_tok), rtol=1e-6)


def test_engine_scan_and_per_token_pipeline_agree(lm):
    """With a deterministic simulator the two decode loops must agree on
    the pipeline charges, not just tokens and estimates."""
    eng_s, tok0 = _engine(lm)
    eng_p, _ = _engine(lm)
    eng_s.decode(tok0, 5)
    eng_p.decode_per_token(tok0, 5)
    for key in ("serial_s", "overlap_s", "stall_s"):
        a = [getattr(s, key) for s in eng_s.stats if s.kind == "decode"]
        b = [getattr(s, key) for s in eng_p.stats if s.kind == "decode"]
        np.testing.assert_allclose(a, b, rtol=1e-9, err_msg=key)


def test_dense_free_pipeline_is_compute_bound(lm):
    eng, tok0 = _engine(lm, method="dense_free")
    eng.decode(tok0, 4)
    s = eng.io_summary()
    assert s["io_sim_s"] == 0.0
    assert s["decode_compute_s"] > 0
    np.testing.assert_allclose(s["decode_overlap_s"], s["decode_serial_s"],
                               rtol=1e-12)
    assert s["overlap_efficiency"] == 1.0  # nothing hideable
