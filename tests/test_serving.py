"""Serving engine + SparseExecution: end-to-end policies and invariants.

Engine-compiling tests are marked ``slow`` individually (reduced-VLM
engine runs take ~100 s total); the fast tier's serving coverage lives in
tests/test_scheduler.py. The ``io_summary`` key-contract test stays in the
fast tier — it builds a compile-free dense_free engine, and its whole
point is failing the same push that drifts the keys.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

slow = pytest.mark.slow

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import ServeEngine, SparseExecution

SMOKE = InputShape(name="smoke", seq_len=32, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def vlm():
    cfg = get_config("internvl2-76b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _run(model, params, cfg, method, sparsity=0.4):
    eng = ServeEngine(model, params, max_seq=128, batch_size=2,
                      device="nano", sparsity=sparsity, method=method, seed=3)
    batch = make_dummy_batch(cfg, SMOKE)
    last = eng.prefill(batch)
    rng = np.random.default_rng(0)
    frame = jnp.asarray(rng.normal(0, 1, (2, 8, cfg.d_frontend)), jnp.bfloat16)
    eng.append_frame(frame)
    tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    out = eng.decode(tok0, 4)
    return eng, out


@slow
def test_engine_all_methods_run(vlm):
    cfg, model, params = vlm
    for method in ("dense", "topk", "chunk"):
        eng, out = _run(model, params, cfg, method)
        assert out.shape == (2, 5)
        assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
        s = eng.io_summary()
        assert s["io_sim_s"] > s["io_est_s"] > 0  # simulator lift applied


@slow
def test_chunk_beats_topk_io(vlm):
    """The paper's claim at engine level: chunk selection's I/O ≪ top-k's at
    the same sparsity."""
    cfg, model, params = vlm
    eng_t, _ = _run(model, params, cfg, "topk")
    eng_c, _ = _run(model, params, cfg, "chunk")
    # compare decode/frame steps only (prefill identical)
    t = sum(s.io_est_s for s in eng_t.stats if s.kind != "prefill")
    c = sum(s.io_est_s for s in eng_c.stats if s.kind != "prefill")
    assert c < 0.5 * t


@slow
def test_sparse_ctx_mask_invariants(vlm):
    cfg, model, params = vlm
    ctx = SparseExecution(cfg, device="nano", sparsity=0.5, method="chunk")
    rng = np.random.default_rng(0)
    acts = jnp.asarray(rng.normal(0, 1, (2, 4, cfg.d_model)), jnp.float32)
    m, lat = ctx.mask("hidden_attn", acts)
    assert m.shape == (cfg.d_model,)
    assert float(lat) > 0
    kept = float(m.sum()) / cfg.d_model
    assert kept <= 0.5 + 1e-6  # budget respected
    # unknown site → no masking, no latency
    m2, lat2 = ctx.mask("nonexistent", acts)
    assert m2 is None and float(lat2) == 0.0


@slow
def test_sparse_decode_error_shrinks_with_sparsity(vlm):
    """Sparse decode is finite, accounts I/O, and its deviation from dense
    shrinks monotonically as sparsity → 0. (Absolute logit agreement is a
    property of TRAINED networks — random-weight reduced models amplify any
    perturbation, so we assert the trend, not a threshold.)"""
    cfg, model, params = vlm
    batch = make_dummy_batch(cfg, SMOKE)
    _, cache_a = model.prefill(params, batch, 64)
    tok = batch["tokens"][:, :1]
    dense_logits, _, _ = model.decode_step(params, tok, cache_a)

    errs, ios = [], []
    for sp in (0.5, 0.2, 0.05):
        ctx = SparseExecution(cfg, device="nano", sparsity=sp, method="chunk")
        _, cache_b = model.prefill(params, batch, 64)
        sparse_logits, _, io = model.decode_step(params, tok, cache_b, sparse_ctx=ctx)
        assert bool(jnp.all(jnp.isfinite(sparse_logits)))
        errs.append(
            float(jnp.linalg.norm(sparse_logits - dense_logits)
                  / jnp.linalg.norm(dense_logits))
        )
        ios.append(float(io))
    assert all(i > 0 for i in ios)
    assert errs[-1] < errs[0]  # lower sparsity → closer to dense
    assert ios[-1] >= ios[0] * 0.5  # lower sparsity → no less I/O (chunky)


@slow
def test_reordering_integration(vlm):
    from repro.core import hot_cold_reordering

    cfg, model, params = vlm
    rng = np.random.default_rng(0)
    cal = rng.random((16, cfg.d_model)).astype(np.float32)
    reo = {"hidden_attn": hot_cold_reordering(cal)}
    ctx = SparseExecution(cfg, device="nano", sparsity=0.4, method="chunk",
                          reorderings=reo)
    acts = jnp.asarray(rng.normal(0, 1, (2, 4, cfg.d_model)), jnp.float32)
    m, lat = ctx.mask("hidden_attn", acts)
    assert m.shape == (cfg.d_model,) and float(lat) > 0


def test_io_summary_key_contract(vlm):
    """io_summary()'s key set is a documented API: the docstring table, the
    IO_SUMMARY_KEYS constant and the implementation must all agree — a new
    counter that skips any of the three fails here."""
    import re

    from repro.serving import IO_SUMMARY_KEYS

    cfg, model, params = vlm
    # dense_free: no SparseExecution, no compile — cheap engine, empty stats
    eng = ServeEngine(model, params, max_seq=32, batch_size=1,
                      method="dense_free")
    summary = eng.io_summary()
    assert set(summary) == set(IO_SUMMARY_KEYS), (
        "io_summary() keys drifted from IO_SUMMARY_KEYS"
    )
    # the docstring table documents exactly the same fields
    doc = ServeEngine.io_summary.__doc__
    documented = set(re.findall(r"\| ``([a-z_]+)``", doc))
    assert documented == set(IO_SUMMARY_KEYS), (
        f"io_summary docstring table drifted: "
        f"missing={set(IO_SUMMARY_KEYS) - documented} "
        f"extra={documented - set(IO_SUMMARY_KEYS)}"
    )
    # every documented field names the PR that introduced it
    for key in IO_SUMMARY_KEYS:
        row = next(line for line in doc.splitlines() if f"``{key}``" in line)
        assert re.search(r"PR \d+", row), f"{key} row lacks a 'since PR' tag"


@slow
def test_hot_neuron_caching_complementary(vlm):
    """Paper §5: cached (memory-resident) neurons get zero importance —
    never loaded — and the remaining uncached selection still benefits from
    chunking. Cached neurons always appear in the applied mask."""
    cfg, model, params = vlm
    rng = np.random.default_rng(0)
    n = cfg.d_model
    cached = jnp.zeros((n,), bool).at[jnp.arange(0, n, 4)].set(True)  # 25% hot
    ctx = SparseExecution(cfg, device="nano", sparsity=0.5, method="chunk",
                          cached={"hidden_attn": cached})
    ctx_nc = SparseExecution(cfg, device="nano", sparsity=0.5, method="chunk")
    acts = jnp.asarray(rng.normal(0, 1, (2, 4, n)), jnp.float32)
    m, lat = ctx.mask("hidden_attn", acts)
    m_nc, lat_nc = ctx_nc.mask("hidden_attn", acts)
    # cached neurons always present in the compute mask
    assert bool(jnp.all(m[::4] == 1.0))
    # and I/O latency does not grow by caching (selection budget unchanged,
    # cached rows are free)
    assert float(lat) <= float(lat_nc) * 1.2
