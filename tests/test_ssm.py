"""Mamba2 chunked SSD vs naive recurrence; xLSTM state handling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import init_params
from repro.models.ssm import (
    Mamba2Config,
    mamba2_decode_step,
    mamba2_forward,
    mamba2_param_defs,
    mamba2_state_init,
)
from repro.models.xlstm import (
    XLSTMConfig,
    mlstm_forward,
    mlstm_param_defs,
    slstm_forward,
    slstm_param_defs,
)


def _mamba(rng, chunk=8):
    cfg = Mamba2Config(d_model=16, d_state=8, d_conv=4, expand=2,
                       head_dim=8, chunk=chunk)
    defs = mamba2_param_defs(cfg)
    params, _ = init_params(defs, jax.random.key(0), jnp.float32)
    return cfg, params


def test_chunked_equals_stepwise_decode(rng):
    """Chunked SSD forward == running the O(1) recurrent decode per token."""
    cfg, params = _mamba(rng)
    b, s = 2, 21  # non-multiple of chunk → exercises padding
    x = jnp.asarray(rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32)
    y_chunked = mamba2_forward(x, params, cfg)
    state = mamba2_state_init(cfg, b, jnp.float32)
    outs = []
    for t in range(s):
        o, state = mamba2_decode_step(x[:, t : t + 1], state, params, cfg)
        outs.append(o)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_step),
                               atol=2e-4, rtol=1e-3)


def test_prefill_state_continues_exactly(rng):
    """forward(return_state) → decode continues identically to full forward."""
    cfg, params = _mamba(rng)
    b, s = 2, 19
    x = jnp.asarray(rng.normal(0, 1, (b, s + 1, cfg.d_model)), jnp.float32)
    y_full = mamba2_forward(x, params, cfg)
    _, state = mamba2_forward(x[:, :s], params, cfg, return_state=True)
    o, _ = mamba2_decode_step(x[:, s : s + 1], state, params, cfg)
    np.testing.assert_allclose(np.asarray(o), np.asarray(y_full[:, s : s + 1]),
                               atol=2e-4, rtol=1e-3)


def test_chunk_size_invariance(rng):
    cfg8, params = _mamba(rng, chunk=8)
    cfg4 = Mamba2Config(d_model=16, d_state=8, d_conv=4, expand=2,
                        head_dim=8, chunk=4)
    x = jnp.asarray(rng.normal(0, 1, (1, 16, 16)), jnp.float32)
    y8 = mamba2_forward(x, params, cfg8)
    y4 = mamba2_forward(x, params, cfg4)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y4), atol=1e-4)


# ------------------------------------------------------------------- xLSTM


def _xcfg():
    return XLSTMConfig(d_model=16, n_heads=2, chunk=8)


def test_mlstm_streaming_state(rng):
    """Forward over s tokens == forward over first half + second half with
    carried state (the property that makes decode exact)."""
    cfg = _xcfg()
    defs = mlstm_param_defs(cfg)
    params, _ = init_params(defs, jax.random.key(0), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 14, 16)), jnp.float32)
    y_full, _ = mlstm_forward(x, params, cfg)
    y1, st = mlstm_forward(x[:, :9], params, cfg)
    y2, _ = mlstm_forward(x[:, 9:], params, cfg, state=st)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full), atol=1e-4)


def test_slstm_streaming_state(rng):
    cfg = _xcfg()
    defs = slstm_param_defs(cfg)
    params, _ = init_params(defs, jax.random.key(0), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 14, 16)), jnp.float32)
    y_full, _ = slstm_forward(x, params, cfg)
    y1, st = slstm_forward(x[:, :9], params, cfg)
    y2, _ = slstm_forward(x[:, 9:], params, cfg, state=st)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full), atol=1e-4)
