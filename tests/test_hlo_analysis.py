"""Trip-count-aware HLO analyzer (launch/hlo_analysis.py) — the roofline's
measurement instrument, so it gets its own oracle tests."""
import pytest

from repro.launch.hlo_analysis import analyze_hlo, parse_module

HLO = """
HloModule test

%fused_computation (param_0: f32[8,16], param_1: f32[16,32]) -> f32[8,32] {
  %param_0 = f32[8,16]{1,0} parameter(0)
  %param_1 = f32[16,32]{1,0} parameter(1)
  ROOT %dot.9 = f32[8,32]{1,0} dot(%param_0, %param_1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%body (p: (s32[], f32[8,16], f32[16,32], f32[8,32])) -> (s32[], f32[8,16], f32[16,32], f32[8,32]) {
  %p = (s32[], f32[8,16], f32[16,32], f32[8,32]) parameter(0)
  %gte0 = s32[] get-tuple-element(%p), index=0
  %gte1 = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %gte2 = f32[16,32]{1,0} get-tuple-element(%p), index=2
  %fusion.1 = f32[8,32]{1,0} fusion(%gte1, %gte2), kind=kLoop, calls=%fused_computation
  %ar = f32[8,32]{1,0} all-reduce(%fusion.1), to_apply=%add
  ROOT %tup = (s32[], f32[8,16], f32[16,32], f32[8,32]) tuple(%gte0, %gte1, %gte2, %ar)
}

%cond (p2: (s32[], f32[8,16], f32[16,32], f32[8,32])) -> pred[] {
  %p2 = (s32[], f32[8,16], f32[16,32], f32[8,32]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %a = f32[] add(%x, %y)
}

ENTRY %main (a: f32[8,16], b: f32[16,32]) -> f32[8,32] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,32]{1,0} parameter(1)
  %init = (s32[], f32[8,16], f32[16,32], f32[8,32]) tuple(%a, %a, %b, %a)
  %w = (s32[], f32[8,16], f32[16,32], f32[8,32]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %dot.top = f32[8,32]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[8,32]{1,0} get-tuple-element(%w), index=3
}
"""


def test_parse_module_structure():
    comps, entry = parse_module(HLO)
    assert entry == "main"
    assert set(comps) >= {"main", "body", "cond", "add", "fused_computation"}
    assert comps["fused_computation"].is_fusion_body


def test_trip_count_multiplied_flops():
    a = analyze_hlo(HLO)
    # dot inside the while body's fusion: 2*8*32*16 = 8192 flops × 10 trips,
    # plus the top-level dot once.
    assert a["flops"] == pytest.approx(8192 * 10 + 8192)


def test_collectives_multiplied():
    a = analyze_hlo(HLO)
    # all-reduce result f32[8,32] = 1024 B × 10 trips
    assert a["collective_bytes"] == pytest.approx(1024 * 10)
    assert a["collective_per_kind"]["all-reduce"] == pytest.approx(1024 * 10)


def test_memory_counts_fusion_boundary_not_internals():
    a = analyze_hlo(HLO)
    # fusion call site contributes (out + operands) per trip; the dot inside
    # the fusion body must not also be counted as memory traffic.
    # fusion: out 8*32*4 + in 8*16*4 + 16*32*4 = 1024+512+2048 = 3584 × 10
    assert a["bytes"] >= 3584 * 10
    comps, _ = parse_module(HLO)
    # sanity: entry dot counted once in flops (already covered above)


def test_malformed_hlo_graceful():
    out = analyze_hlo("not an hlo module at all")
    assert out["flops"] == 0.0 and out.get("parse_error") == 1.0
