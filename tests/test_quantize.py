"""The quantized chunk storage format (kernels/quantize.py) and its byte
accounting (PR 6): per-8-row-block symmetric int8 payloads + f32 scale
lanes, the scale=0 guard, saturation at the int8 extremes, the stacked
param-leaf injection the engine performs at wbits=8, and the fractional
per-row byte pricing that selectors/residency cache see (satellite 2:
hand-computed payload + scale-overhead totals)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.latency_model import row_stream_bytes
from repro.kernels import SCALE_BYTES, dequantize_rows, quantize_params, quantize_rows
from repro.kernels.quantize import (
    INT8_QMAX,
    QUANT_SUFFIX_PAYLOAD,
    QUANT_SUFFIX_SCALE,
)

# ---------------------------------------------------------------------------
# quantize/dequantize roundtrip + edge cases
# ---------------------------------------------------------------------------


def test_roundtrip_error_bounded_by_half_step(rng):
    w = jnp.asarray(rng.normal(0, 0.5, (64, 32)), jnp.float32)
    q, s = quantize_rows(w, 8)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert q.shape == w.shape and s.shape == (8,)
    wq = dequantize_rows(q, s, 8)
    # symmetric rounding: per-block error ≤ scale/2 (half a quantization step)
    err = jnp.max(jnp.abs(wq - w).reshape(8, 8, 32), axis=(1, 2))
    assert bool(jnp.all(err <= s / 2 + 1e-7))


def test_zero_magnitude_block_scale_zero_guard():
    """An all-zero 8-row block must produce scale 0 and payload 0 — no
    inf/nan from the divide, and dequantization is exactly zero."""
    w = np.ones((24, 16), np.float32)
    w[8:16] = 0.0  # middle block entirely zero
    q, s = quantize_rows(jnp.asarray(w), 8)
    assert float(s[1]) == 0.0
    assert bool(jnp.all(jnp.isfinite(s)))
    assert int(jnp.max(jnp.abs(q[8:16]))) == 0
    wq = dequantize_rows(q, s, 8)
    assert float(jnp.max(jnp.abs(wq[8:16]))) == 0.0
    # the nonzero blocks still roundtrip
    assert float(jnp.max(jnp.abs(wq[:8] - 1.0))) < 1e-6


def test_max_magnitude_saturates_at_qmax():
    """The block max maps exactly to ±127; nothing exceeds the int8 range
    even when every element sits at the extreme."""
    w = np.full((8, 4), 3.0, np.float32)
    w[0, 0] = -3.0
    q, s = quantize_rows(jnp.asarray(w), 8)
    assert float(s[0]) == pytest.approx(3.0 / INT8_QMAX)
    assert int(jnp.max(q)) == int(INT8_QMAX)
    assert int(jnp.min(q)) == -int(INT8_QMAX)
    wq = dequantize_rows(q, s, 8)
    assert float(jnp.max(jnp.abs(wq - jnp.asarray(w)))) < 1e-6


def test_rows_must_divide_block_rows():
    with pytest.raises(ValueError, match="multiple of block_rows"):
        quantize_rows(jnp.ones((12, 4)), 8)


def test_quantize_params_leaf_names_and_shapes(rng):
    layers = {
        "wq": jnp.asarray(rng.normal(0, 1, (3, 16, 8)), jnp.bfloat16),
        "w_gate": jnp.asarray(rng.normal(0, 1, (3, 24, 8)), jnp.bfloat16),
        "ln": jnp.ones((3, 16)),  # not in names → untouched
    }
    out = quantize_params(layers, ("wq", "w_gate", "w_fc"))
    # w_fc missing → skipped; ln not requested → absent
    assert sorted(out) == ["w_gate_q8", "w_gate_sc", "wq_q8", "wq_sc"]
    assert out["wq" + QUANT_SUFFIX_PAYLOAD].shape == (3, 16, 8)
    assert out["wq" + QUANT_SUFFIX_SCALE].shape == (3, 2)
    assert out["w_gate" + QUANT_SUFFIX_PAYLOAD].dtype == jnp.int8
    # the L dim is a true vmap: layer 0's leaves match the single-matrix path
    q0, s0 = quantize_rows(layers["wq"][0], 8)
    assert bool(jnp.all(out["wq_q8"][0] == q0))
    assert bool(jnp.all(out["wq_sc"][0] == s0))


# ---------------------------------------------------------------------------
# byte accounting (satellite 2): payload + amortized scale overhead
# ---------------------------------------------------------------------------


def test_row_stream_bytes_hand_computed():
    # fp16: plain 2 bytes/element, no scale lane
    assert row_stream_bytes(128, 16) == 128 * 2.0
    # int8: 1 byte/element + one f32 scale amortized over the 8-row block
    assert row_stream_bytes(128, 8) == 128 * 1.0 + SCALE_BYTES / 8
    assert row_stream_bytes(64, 8, block_rows=16) == 64 + SCALE_BYTES / 16
    with pytest.raises(ValueError):
        row_stream_bytes(128, 4)


def test_site_row_bytes_includes_scale_overhead():
    """SparseExecution's per-site pricing at wbits=8 equals the
    hand-computed Σ over the site's matrices of (cols × 1 byte +
    SCALE_BYTES/block_rows) — the exact payload+scales total an offloaded
    row streams (satellite 2 regression)."""
    from repro.configs import get_config
    from repro.core.offload import decode_site_shapes
    from repro.serving import SparseExecution
    from repro.serving.sparse_exec import KERNEL_BLOCK_ROWS

    cfg = get_config("internvl2-76b").reduced()
    sp16 = SparseExecution(cfg, device="nano", sparsity=0.4, method="chunk")
    sp8 = SparseExecution(cfg, device="nano", sparsity=0.4, method="chunk",
                          wbits=8)
    shapes = {kind: out_cols for kind, _n, out_cols in decode_site_shapes(cfg)}
    assert set(shapes) == set(sp8.sites)
    for kind, cols in shapes.items():
        expect8 = sum(c * 1.0 + SCALE_BYTES / KERNEL_BLOCK_ROWS for c in cols)
        expect16 = sum(c * 2.0 for c in cols)
        assert sp8.site_row_bytes(kind) == pytest.approx(expect8)
        assert sp16.site_row_bytes(kind) == pytest.approx(expect16)
        # int8 strictly cheaper per row on every site
        assert sp8.site_row_bytes(kind) < sp16.site_row_bytes(kind)


def test_io_event_totals_match_hand_computed_bytes():
    """The simulator's event log at a fractional row_bytes: nbytes and
    total_bytes must be the exact Σ rows × (payload + amortized scale),
    float-precise — not silently int-truncated."""
    from repro.core.offload import FlashOffloadSimulator

    sim = FlashOffloadSimulator(device="nano")
    rb = row_stream_bytes(32, 8)  # 32 cols int8 → 32.5 bytes/row
    mask = np.zeros(64, bool)
    mask[:8] = True
    mask[16:40] = True  # 32 selected rows in two chunks
    sim.measure(mask, row_bytes=rb, name="q8")
    assert sim.log[-1].nbytes == pytest.approx(32 * 32.5)
    assert sim.total_bytes() == pytest.approx(32 * 32.5)
