"""Data pipeline: shapes, determinism, learnable structure, file streaming."""
import numpy as np

from repro.configs import get_config
from repro.data import ByteTokenizer, DataConfig, lm_batches


def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer()
    text = "VLM in a flash ✓"
    ids = tok.encode(text, add_bos=True, add_eos=True)
    assert ids[0] == 256 and ids[-1] == 257
    assert tok.decode(ids) == text


def test_batch_shapes_text_lm():
    cfg = get_config("tinyllama-1.1b").reduced()
    it = lm_batches(cfg, DataConfig(batch=4, seq_len=32, seed=1))
    b = next(it)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].dtype == np.int32
    assert b["tokens"].max() < cfg.vocab_size


def test_batch_shapes_vlm():
    cfg = get_config("internvl2-76b").reduced()
    it = lm_batches(cfg, DataConfig(batch=2, seq_len=64, seed=1))
    b = next(it)
    n_front = b["frontend"].shape[1]
    assert b["frontend"].shape == (2, n_front, cfg.d_frontend)
    assert b["tokens"].shape[1] + n_front == 64


def test_determinism():
    cfg = get_config("granite-3-2b").reduced()
    a = next(lm_batches(cfg, DataConfig(batch=2, seq_len=16, seed=7)))
    b = next(lm_batches(cfg, DataConfig(batch=2, seq_len=16, seed=7)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_markov_structure_is_learnable():
    """Synthetic stream must have sub-uniform entropy (structure to fit)."""
    cfg = get_config("tinyllama-1.1b").reduced()
    toks = next(lm_batches(cfg, DataConfig(batch=8, seq_len=512, seed=0)))["tokens"]
    flat = toks.reshape(-1)
    pairs = {}
    for a, b in zip(flat[:-1], flat[1:]):
        pairs.setdefault(int(a), []).append(int(b))
    # conditional distribution concentrated: top successor ≫ uniform (1/64)
    top_frac = np.mean(
        [max(np.bincount(v).max() / len(v), 0) for v in pairs.values() if len(v) > 10]
    )
    assert top_frac > 0.2


def test_file_stream(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world " * 100)
    cfg = get_config("tinyllama-1.1b").reduced()
    it = lm_batches(cfg, DataConfig(batch=2, seq_len=16, seed=0, text_path=str(p)))
    b = next(it)
    assert b["tokens"].shape == (2, 16)
    assert b["tokens"].max() < 259
