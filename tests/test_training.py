"""Training substrate: chunked CE, AdamW, schedules, checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, lm_batches
from repro.models import build_model
from repro.training import (
    AdamWConfig,
    Trainer,
    adamw_update,
    apply_row_permutations,
    init_opt_state,
    load_checkpoint,
    lr_schedule,
    save_checkpoint,
)
from repro.training.train_step import _chunked_softmax_xent


def test_chunked_xent_equals_direct(rng):
    b, s, d, v = 2, 13, 8, 32
    hidden = jnp.asarray(rng.normal(0, 1, (b, s, d)), jnp.float32)
    head = jnp.asarray(rng.normal(0, 1, (d, v)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    got = _chunked_softmax_xent(hidden, targets, head, loss_chunk=4)
    logits = hidden @ head
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    want = (lse - gold).mean()
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
    assert float(lr_schedule(cfg, jnp.asarray(55))) < 1.0


def test_grad_clip_and_decay(rng):
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.full((4,), 100.0)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.0)
    new_params, new_state, metrics = adamw_update(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0 * np.sqrt(20), rel=1e-4)
    # post-clip update magnitude bounded by lr (Adam step ≤ lr per coord)
    assert float(jnp.max(jnp.abs(new_params["w"] - params["w"]))) <= 0.11
    assert int(new_state.step) == 1


def test_loss_decreases_tinyllama():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=50),
                 loss_chunk=32)
    params, opt = tr.init_state(jax.random.key(0))
    step = tr.jit_train_step(donate=False)
    it = lm_batches(cfg, DataConfig(batch=8, seq_len=64, seed=0))
    losses = []
    for _ in range(12):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = get_config("granite-3-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    save_checkpoint(str(tmp_path / "ckpt"), params, step=7)
    like = jax.eval_shape(model.init, jax.random.key(0))
    restored, step = load_checkpoint(str(tmp_path / "ckpt"), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_apply_row_permutations(rng):
    params = {"layers": {"w_gate": jnp.asarray(rng.normal(0, 1, (8, 4)))}}
    perm = np.array([3, 1, 0, 2, 7, 6, 5, 4])
    out = apply_row_permutations(params, {"w_gate": perm})
    np.testing.assert_allclose(
        np.asarray(out["layers"]["w_gate"]),
        np.asarray(params["layers"]["w_gate"])[perm],
    )
