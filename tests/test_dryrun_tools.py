"""Dry-run tooling: HLO collective parser + input geometry."""
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.shapes import SHAPES, get_shape
from repro.launch.dryrun import _shape_bytes, collective_bytes
from repro.models.inputs import input_specs, make_dummy_batch

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[128,128]{1,0} all-gather(%p), replica_groups={}, dimensions={0}
  %ar = f32[64]{0} all-reduce(%x), to_apply=%add
  %rs = bf16[4,128]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = (f32[16]{0}, f32[16]{0}) all-to-all(%a, %b)
  %cp = u32[2]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %ags = bf16[256]{0} all-gather-start(%p2)
  %agd = bf16[256]{0} all-gather-done(%ags)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("(f32[16], f32[16])") == 128
    assert _shape_bytes("u32[2]") == 8
    assert _shape_bytes("f32[]") == 4  # scalar


def test_collective_parser():
    got = collective_bytes(HLO_SAMPLE)
    pk = got["per_kind_bytes"]
    assert pk["all-gather"] == 128 * 128 * 2 + 256 * 2  # incl. -start, not -done
    assert pk["all-reduce"] == 64 * 4
    assert pk["reduce-scatter"] == 4 * 128 * 2
    assert pk["all-to-all"] == 2 * 16 * 4
    assert pk["collective-permute"] == 8
    assert got["total_bytes"] == sum(pk.values())


@pytest.mark.parametrize("shape_name", sorted(SHAPES))
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "internvl2-76b", "whisper-small"])
def test_input_specs_geometry(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    specs = input_specs(cfg, shape)
    if shape.is_decode:
        assert specs["tokens"].shape == (shape.global_batch, 1)
    else:
        total = sum(
            s.shape[1] for k, s in specs.items()
            if k == "tokens" or (cfg.d_frontend and not cfg.is_encdec and k == "frontend")
        )
        if cfg.is_encdec:
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
            assert specs["frontend"].shape[1] == cfg.frontend_tokens
        else:
            assert total == shape.seq_len  # early fusion sums to S


def test_dummy_batch_matches_specs():
    cfg = get_config("internvl2-76b")
    shape = get_shape("train_4k")
    specs = input_specs(cfg, shape)
    batch = make_dummy_batch(cfg, shape)
    for k, s in specs.items():
        assert batch[k].shape == s.shape and batch[k].dtype == s.dtype
    assert int(jnp.max(batch["tokens"])) < cfg.vocab_size
