"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED variant (2 layers, d_model ≤ 512, ≤ 4 experts)
and runs one forward + one train step on CPU, asserting shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.training import AdamWConfig, Trainer

SMOKE = InputShape(name="smoke", seq_len=32, global_batch=2, kind="train")


def _assert_finite(tree, what):
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), what


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, SMOKE)

    hidden, aux = model.forward(params, batch, remat=False)
    assert hidden.shape == (2, 32, cfg.d_model)
    logits = model.logits(params, hidden)
    assert logits.shape == (2, 32, cfg.vocab_size)
    _assert_finite(logits, f"{arch} forward produced NaNs")

    trainer = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10),
                      loss_chunk=16)
    opt = trainer.init_state(jax.random.key(1))[1]
    batch_j = {k: jnp.asarray(v) for k, v in batch.items()}
    params2, opt2, metrics = trainer.train_step(params, opt, batch_j)
    assert float(metrics["loss"]) > 0
    _assert_finite(metrics["loss"], f"{arch} train loss NaN")
    _assert_finite(params2, f"{arch} updated params NaN")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2, io = model.decode_step(params, tok, cache)
    assert logits.shape == (2, cfg.vocab_size)
    _assert_finite(logits, f"{arch} decode NaN")
    # cache length advanced
    assert int(cache2["length"]) == 1
