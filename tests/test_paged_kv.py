"""Paged KV cache (PR 10): allocator property suite, KVPagePool
invariants, prefix hashing, paged-vs-dense token identity across
backend x wbits, and cross-feature regressions with the fault/integrity
stack.

The allocator/pool tests are seeded randomized property tests (plain
``np.random.default_rng`` — hypothesis is optional in this environment,
see conftest.py) that run ``check()`` after every single operation:
free-list conservation, no double free, no page reachable from two
tables unless its refcount covers both, COW-fork isolation, and
eviction never reclaiming a live-referenced page.

Identity scoping (deliberate): paged-vs-dense byte identity is asserted
on FULLY-OCCUPIED slot workloads (every slot admitted, no eviction
mid-decode). Under slot recycling, free-slot rows keep flowing through
the fused decode scan and their garbage activations feed the *batched*
chunk-selection importance; dense free-slot garbage (stale per-slot
cache) and paged free-slot garbage (shared garbage page 0) legitimately
differ, so cross-layout identity is not a property of recycled
workloads. The scheduler cross-feature tests instead pin what IS
invariant there: pool steady state after drain, zero leaked refcounts,
and paged-run determinism.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.paged_kv import GARBAGE_PAGE, KVPoolExhausted, PagedKVAllocator
from repro.models import build_model
from repro.serving import KVPagePool, Request, Scheduler, ServeEngine
from repro.serving.kv_pool import prompt_prefix_hashes

slow = pytest.mark.slow


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _paged_engine(model, params, batch=2, pt=8, pages=None, **kw):
    kw.setdefault("cache_mb", 64.0)
    return ServeEngine(model, params, max_seq=32, batch_size=batch,
                       device="nano", sparsity=0.4, method="chunk", seed=5,
                       kv_page_tokens=pt, kv_pages=pages, **kw)


def _dense_engine(model, params, batch=2, **kw):
    kw.setdefault("cache_mb", 64.0)
    return ServeEngine(model, params, max_seq=32, batch_size=batch,
                       device="nano", sparsity=0.4, method="chunk", seed=5,
                       **kw)


def _prompt(cfg, seed, n=12):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, n)), jnp.int32)
    return {"tokens": toks}


def _shared_prefix_prompts(cfg, n, prefix_len=16, tail=4, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (1, prefix_len))
    out = []
    for _ in range(n):
        t = np.concatenate(
            [prefix, rng.integers(0, cfg.vocab_size, (1, tail))], axis=1
        )
        out.append({"tokens": jnp.asarray(t, jnp.int32)})
    return out


# -- allocator: construction and basic lifecycle ------------------------------


def test_allocator_validation():
    with pytest.raises(ValueError):
        PagedKVAllocator(1, 8)
    with pytest.raises(ValueError):
        PagedKVAllocator(8, 0)


def test_allocator_double_free_and_garbage_page_guards():
    a = PagedKVAllocator(4, 8)
    p = a.alloc()
    a.release(p)
    with pytest.raises(ValueError, match="double free"):
        a.release(p)
    with pytest.raises(ValueError):
        a.release(GARBAGE_PAGE)
    with pytest.raises(ValueError):
        a.retain(GARBAGE_PAGE)
    with pytest.raises(ValueError):
        a.retain(p)  # ref 0: not live
    a.check()


def test_allocator_exhaustion_raises_then_recovers():
    a = PagedKVAllocator(3, 8)  # capacity 2
    p0, p1 = a.alloc(), a.alloc()
    with pytest.raises(KVPoolExhausted):
        a.alloc()
    a.release(p0)
    assert a.alloc() == p0  # LIFO reuse
    a.check()
    assert a.n_live == 2 and a.n_free == 0
    del p1


# -- allocator: COW fork isolation --------------------------------------------


def test_cow_fork_isolation():
    a = PagedKVAllocator(8, 8)
    row = [a.alloc(), a.alloc()]
    forked = a.fork(row)
    assert forked == row  # same physical pages, shared
    assert all(a.refcount(p) == 2 for p in row)
    # a write to the fork must first materialize a private copy
    w, src = a.prepare_write(forked[0])
    assert src == row[0] and w != row[0]
    assert a.refcount(row[0]) == 1  # original owner keeps its page
    assert a.refcount(w) == 1
    assert a.cow_copies == 1
    # row[1] is still shared (ref 2): its write must copy too
    w2, src2 = a.prepare_write(row[1])
    assert src2 == row[1] and w2 != row[1]
    assert a.cow_copies == 2
    # a now-private anonymous page writes in place, no copy
    w3, src3 = a.prepare_write(w2)
    assert (w3, src3) == (w2, None)
    a.check()


def test_prepare_write_copies_registered_page_even_at_ref_one():
    """Registered prefix content must stay immutable: a future admission
    may revive it by hash, so even a sole owner writes a private copy."""
    a = PagedKVAllocator(8, 8)
    p = a.alloc()
    a.register_prefix(p, "h0")
    w, src = a.prepare_write(p)
    assert src == p and w != p
    a.check()


# -- allocator: eviction ------------------------------------------------------


def test_eviction_never_reclaims_live_pages():
    a = PagedKVAllocator(6, 8)  # capacity 5
    live = [a.alloc() for _ in range(3)]
    cold = []
    for i in range(2):
        p = a.alloc()
        a.register_prefix(p, f"h{i}")
        a.release(p)  # -> cold, evictable
        cold.append(p)
    assert a.n_live == 3 and a.n_cold == 2 and a.n_free == 0
    # an allocation burst may only ever reclaim the cold pages
    extra = [a.alloc(), a.alloc()]
    assert set(extra) == set(cold)
    assert all(a.refcount(p) == 1 for p in live)
    with pytest.raises(KVPoolExhausted):
        a.alloc()
    assert a.evictions == 2
    a.check()


def test_cold_lru_eviction_and_revival():
    a = PagedKVAllocator(8, 8)
    pages = []
    for i in range(3):
        p = a.alloc()
        a.register_prefix(p, f"h{i}")
        pages.append(p)
    for p in pages:  # cold in order h0, h1, h2
        a.release(p)
    assert a.evict_cold(1) == 1  # LRU: h0 goes first
    assert a.lookup_prefix("h0") is None
    revived = a.lookup_prefix("h1")
    assert revived == pages[1] and a.refcount(revived) == 1
    assert a.shared_hits == 1
    a.check()


# -- allocator: randomized property suite -------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_allocator_randomized_invariants(seed):
    """400 random alloc/retain/release/fork/register/lookup/evict/COW ops
    with ``check()`` (conservation, no dup free-list entries, refcount
    sanity) after every single operation. A shadow model tracks how many
    references we hold per page; terminal state must release cleanly."""
    rng = np.random.default_rng(seed)
    a = PagedKVAllocator(int(rng.integers(4, 20)), 8)
    held: list = []   # one entry per reference we own
    nkeys = 0
    for _ in range(400):
        op = rng.integers(0, 7)
        if op == 0:  # alloc
            try:
                held.append(a.alloc())
            except KVPoolExhausted:
                assert a.n_reclaimable == 0
        elif op == 1 and held:  # release one of our references
            a.release(held.pop(int(rng.integers(len(held)))))
        elif op == 2 and held:  # retain (fork a single page)
            p = held[int(rng.integers(len(held)))]
            held.append(a.retain(p))
        elif op == 3 and held:  # register a prefix hash
            p = held[int(rng.integers(len(held)))]
            a.register_prefix(p, f"k{nkeys}")
            nkeys += 1
        elif op == 4 and nkeys:  # lookup (live retain or cold revival)
            p = a.lookup_prefix(f"k{int(rng.integers(nkeys))}")
            if p is not None:
                held.append(p)
        elif op == 5:  # evict some cold pages
            a.evict_cold(int(rng.integers(1, 3)))
        elif op == 6 and held:  # COW write barrier
            i = int(rng.integers(len(held)))
            try:
                w, src = a.prepare_write(held[i])
            except KVPoolExhausted:  # copy needs a page the pool lacks
                assert a.n_reclaimable == 0
            else:
                if src is not None:
                    held[i] = w  # our ref moved to the fresh private copy
        a.check()
        # every reference we hold is on a live page
        for p in held:
            assert a.refcount(p) > 0
    for p in held:  # full teardown must conserve pages
        a.release(p)
        a.check()
    assert a.n_live == 0
    assert a.n_cold + a.n_free == a.capacity


# -- prefix hashing -----------------------------------------------------------


def test_prefix_hashes_batch_dim_validation():
    with pytest.raises(ValueError):
        prompt_prefix_hashes({"tokens": jnp.zeros((2, 8), jnp.int32)}, 4)


def test_prefix_hashes_chain_and_length_folding():
    t = np.arange(16).reshape(1, 16)
    n, h = prompt_prefix_hashes({"tokens": jnp.asarray(t)}, 4)
    assert n == 16 and len(h) == 4
    # changing a token in the LAST page only perturbs the last hash
    t2 = t.copy()
    t2[0, 14] += 1
    _, h2 = prompt_prefix_hashes({"tokens": jnp.asarray(t2)}, 4)
    assert h2[:3] == h[:3] and h2[3] != h[3]
    # changing a token in the FIRST page perturbs every chained hash
    t3 = t.copy()
    t3[0, 0] += 1
    _, h3 = prompt_prefix_hashes({"tokens": jnp.asarray(t3)}, 4)
    assert all(x != y for x, y in zip(h3, h))
    # same 8-token prefix under a different TOTAL length must not collide:
    # prefill's reduction shape depends on seq_len (same-length-only sharing)
    _, h4 = prompt_prefix_hashes({"tokens": jnp.asarray(t[:, :8])}, 4)
    assert h4[0] != h[0]
    # partial tail page gets no hash
    n5, h5 = prompt_prefix_hashes({"tokens": jnp.asarray(t[:, :14])}, 4)
    assert n5 == 14 and len(h5) == 3


def test_prefix_hashes_cover_frontend_and_extra_keys():
    t = jnp.arange(8).reshape(1, 8)
    fr = jnp.ones((1, 2, 4), jnp.float32)
    n, h = prompt_prefix_hashes({"tokens": t, "frontend": fr}, 4)
    assert n == 10  # 2 frontend rows fuse ahead of the tokens
    _, h2 = prompt_prefix_hashes({"tokens": t, "frontend": fr + 1}, 4)
    assert h != h2
    _, h3 = prompt_prefix_hashes({"tokens": t, "frontend": fr, "aux": jnp.ones(2)}, 4)
    assert h != h3


# -- KVPagePool ---------------------------------------------------------------


def test_pool_validation():
    with pytest.raises(ValueError):
        KVPagePool(2, max_seq=30, page_tokens=8, n_pages=8, page_bytes=1.0)
    with pytest.raises(ValueError):
        KVPagePool(3, max_seq=32, page_tokens=8, n_pages=8, page_bytes=1.0,
                   n_data_shards=2)


def test_pool_share_release_revive_cycle():
    pool = KVPagePool(2, max_seq=32, page_tokens=8, n_pages=16, page_bytes=1.0)
    seq, hashes = 20, ["a", "b"]  # 2 full pages + partial tail
    e0 = pool.admit(0, seq, hashes)
    assert [f for _, f in e0] == [True, True, True]
    e1 = pool.admit(1, seq, hashes)  # full pages shared, tail private
    assert [f for _, f in e1] == [False, False, True]
    assert e1[0][0] == e0[0][0] and e1[2][0] != e0[2][0]
    assert pool.shared_pages == 2 and pool.pages_in_use == 4
    assert pool.shared_pages_hit == 2
    pool.check()
    pool.release(0)
    assert pool.pages_in_use == 3 and pool.shared_pages == 0
    pool.release(1)
    # registered pages go cold, not free: a re-admission revives them
    assert pool.steady_state() and pool.alloc.n_cold == 2
    e2 = pool.admit(0, seq, hashes)
    assert [f for _, f in e2] == [False, False, True]
    pool.check()


def test_pool_exhaustion_rolls_back_partial_admission():
    pool = KVPagePool(1, max_seq=32, page_tokens=8, n_pages=3, page_bytes=1.0)
    assert not pool.can_admit(24, ["a", "b", "c"])
    with pytest.raises(KVPoolExhausted):
        pool.admit(0, 24, ["a", "b", "c"])  # needs 3 pages, capacity 2
    # the partial admission fully rolled back
    assert pool.pages_in_use == 0 and pool.steady_state()
    pool.check()
    assert pool.can_admit(16, ["a", "b"])
    pool.admit(0, 16, ["a", "b"])
    pool.check()


def test_pool_max_seq_prompt_keeps_final_page_private():
    """Review regression: a prompt of exactly max_seq tokens fills its
    final page, but decode clamps writes to max_seq-1 — inside it. The
    final page must stay private and unregistered or the clamped decode
    write would mutate shared bytes and poison the prefix registry."""
    pool = KVPagePool(2, max_seq=32, page_tokens=8, n_pages=16, page_bytes=1.0)
    hashes = ["a", "b", "c", "d"]  # 4 full pages: seq_len == max_seq
    e0 = pool.admit(0, 32, hashes)
    assert [f for _, f in e0] == [True] * 4
    last0 = e0[-1][0]
    assert last0 not in pool.alloc._hash_of  # clamp target: unregistered
    assert pool.can_admit(32, hashes)
    e1 = pool.admit(1, 32, hashes)
    # first three pages share; each slot gets its own private final page
    assert [f for _, f in e1] == [False, False, False, True]
    assert e1[-1][0] != last0
    assert pool.shared_pages == 3
    pool.check()
    pool.release(0)
    pool.release(1)
    # only the shareable pages cold-retire; the private finals went free
    assert pool.alloc.n_cold == 3 and pool.steady_state()
    pool.check()


def test_pool_exhaustion_rollback_never_cold_retires_unwritten_pages():
    """Review regression: a mid-admit rollback must forget the hashes of
    fresh pages registered during the failed admission — their KV bytes
    were never written (the engine writes prefill bytes only after admit
    returns), so letting them cold-retire would let a later same-prefix
    admission revive zero-filled KV as real prompt content."""
    pool = KVPagePool(1, max_seq=32, page_tokens=8, n_pages=3, page_bytes=1.0)
    with pytest.raises(KVPoolExhausted):
        pool.admit(0, 24, ["a", "b", "c"])  # registers "a","b", then fails
    assert pool.alloc.n_cold == 0  # nothing revivable survived the rollback
    assert pool.alloc._by_hash == {} and pool.alloc._hash_of == {}
    assert pool.fresh_pages == 0  # counter rolled back with the pages
    pool.check()
    # a retry of the same prefix must allocate FRESH pages, never "share"
    entries = pool.admit(0, 16, ["a", "b"])
    assert [f for _, f in entries] == [True, True]
    assert pool.shared_pages_hit == 0
    pool.check()


def test_pool_page_home_follows_recycled_cold_eviction():
    """Review regression: a page recycled after cold eviction must take
    its NEW owner's data shard as home — setdefault kept the stale one,
    drifting the per-shard split pages_per_shard reports."""
    pool = KVPagePool(4, max_seq=32, page_tokens=8, n_pages=4, page_bytes=1.0,
                      n_data_shards=2)  # slots 0-1 -> shard 0, 2-3 -> shard 1
    pool.admit(0, 8, ["a"])
    assert pool.pages_per_shard() == [1, 0]
    pool.release(0)  # registered page goes cold, home retained
    # a shard-1 admission needs all 3 pages: the cold page is evicted and
    # recycled, and its home must follow the new owner
    pool.admit(2, 24, [])
    assert pool.pages_per_shard() == [0, 3]
    assert sum(pool.pages_per_shard()) == pool.pages_in_use
    pool.check()


def test_pool_ensure_grows_private_pages_and_clamps():
    pool = KVPagePool(1, max_seq=32, page_tokens=8, n_pages=8, page_bytes=1.0)
    pool.admit(0, 12, ["a"])  # 2 pages
    # pages_needed is the pure twin of ensure: counts, allocates nothing
    assert pool.pages_needed(0, 15) == 0
    assert pool.pages_needed(0, 17) == 1
    assert pool.pages_needed(0, 100) == 2    # clamped to max_seq-1
    assert len(pool.slot_pages(0)) == 2      # nothing allocated by counting
    assert pool.ensure(0, 15) == []          # still inside page 1
    assert len(pool.ensure(0, 17)) == 1      # page 2
    assert len(pool.ensure(0, 100)) == 1     # clamped to max_seq-1 -> page 3
    assert len(pool.slot_pages(0)) == 4
    pool.check()


def test_pool_pages_per_shard_sums_to_global():
    pool = KVPagePool(4, max_seq=32, page_tokens=8, n_pages=32, page_bytes=1.0,
                      n_data_shards=2)
    for slot, seed in enumerate([0, 0, 1, 2]):  # slots 0,1 share a prompt
        seq, hashes = 16, [f"s{seed}p0", f"s{seed}p1"]
        pool.admit(slot, seq, hashes)
    per = pool.pages_per_shard()
    assert sum(per) == pool.pages_in_use
    assert len(per) == 2 and all(p > 0 for p in per)
    pool.check()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pool_randomized_invariants(seed):
    """Random admit/ensure/release storms over few pages: ``check()``
    (table mirror <-> refcount cross-invariant) after every op, exhaustion
    always rolls back cleanly, and teardown reaches steady state."""
    rng = np.random.default_rng(seed)
    pool = KVPagePool(4, max_seq=64, page_tokens=8,
                      n_pages=int(rng.integers(6, 24)), page_bytes=1.0)
    prompts = []
    for i in range(5):  # few distinct prompts -> plenty of sharing
        seq = int(rng.integers(4, 40))
        n_full = seq // 8
        prompts.append((seq, [f"p{i}.{j}" for j in range(n_full)]))
    for _ in range(300):
        op = rng.integers(0, 3)
        slot = int(rng.integers(4))
        if op == 0:
            seq, hashes = prompts[int(rng.integers(len(prompts)))]
            before = pool.pages_in_use
            fits = pool.can_admit(seq, hashes)
            had = len(pool.slot_pages(slot))
            try:
                pool.admit(slot, seq, hashes)
            except KVPoolExhausted:
                # can_admit may pass yet admit fail only if the slot's own
                # prior pages were recycled into the estimate; state must
                # still be exactly "slot released, nothing allocated"
                assert pool.slot_pages(slot) == []
                assert pool.pages_in_use <= before
                if had == 0:
                    assert not fits
        elif op == 1 and pool.slot_pages(slot):
            try:
                pool.ensure(slot, int(rng.integers(64)))
            except KVPoolExhausted:
                # partial growth is kept (already mapped into the table);
                # check() below proves the state stayed consistent
                assert pool.alloc.n_reclaimable == 0
        elif op == 2:
            pool.release(slot)
        pool.check()
        assert sum(pool.pages_per_shard()) == pool.pages_in_use
    for slot in range(4):
        pool.release(slot)
        pool.check()
    assert pool.steady_state()


# -- engine integration: validation and budget split --------------------------


def test_engine_paged_validation(lm):
    cfg, model, params = lm
    with pytest.raises(ValueError, match="kv_pages requires"):
        _dense_engine(model, params, kv_pages=8)
    with pytest.raises(ValueError, match="kv_page_tokens"):
        _paged_engine(model, params, pt=7)  # 32 % 7 != 0
    eng = _paged_engine(model, params)
    with pytest.raises(NotImplementedError):
        eng.prefill(_prompt(cfg, 0))
    with pytest.raises(NotImplementedError):
        eng.append_frame(jnp.zeros((1, 1, cfg.d_model)))


def test_engine_budget_split_and_io_summary(lm):
    from repro.serving import IO_SUMMARY_KEYS
    cfg, model, params = lm
    for k in ("kv_cache_mb", "weight_cache_mb", "kv_pages_in_use",
              "kv_shared_pages"):
        assert k in IO_SUMMARY_KEYS
    dense = _dense_engine(model, params)
    sd = dense.io_summary()
    assert sd["kv_cache_mb"] == 0.0
    assert sd["weight_cache_mb"] == pytest.approx(dense.cache_mb)
    paged = _paged_engine(model, params)
    sp = paged.io_summary()
    assert sp["kv_cache_mb"] > 0.0
    assert sp["weight_cache_mb"] == pytest.approx(
        paged.cache_mb - sp["kv_cache_mb"])
    # the weight tier budget the sparse executor sees is the carved split
    assert paged.sparse_ctx.cache_mb == pytest.approx(sp["weight_cache_mb"])
    assert sp["kv_pages_in_use"] == 0 and sp["kv_shared_pages"] == 0


# -- engine integration: paged vs dense byte identity -------------------------


def _identity_run(model, params, cfg, batch=2, new_tokens=6, shared=False,
                  **kw):
    """Admit every slot (full occupancy — see module docstring), decode,
    and return (dense_tokens, paged_tokens, paged_engine)."""
    dense = _dense_engine(model, params, batch=batch, **kw)
    paged = _paged_engine(model, params, batch=batch, **kw)
    if shared:
        prompts = _shared_prefix_prompts(cfg, batch, prefix_len=16, tail=4)
    else:
        prompts = [_prompt(cfg, 100 + i) for i in range(batch)]
    outs = []
    for eng in (dense, paged):
        eng.enable_slots()
        lasts = []
        for slot, p in enumerate(prompts):
            last, _ = eng.admit_slot(slot, p)
            lasts.append(jnp.argmax(last, -1)[:, None])
        toks = jnp.concatenate(lasts).astype(jnp.int32)
        out, _ = eng.decode_slots(toks, new_tokens)
        outs.append(np.asarray(out))
    return outs[0], outs[1], paged


def test_paged_vs_dense_identity(lm):
    cfg, model, params = lm
    d, p, eng = _identity_run(model, params, cfg)
    np.testing.assert_array_equal(d, p)
    pool = eng.kv_pool
    assert pool.pages_in_use > 0
    pool.check()
    # decode growth allocated only private anonymous pages
    assert pool.shared_pages == 0


def test_paged_shared_prefix_identity_and_page_savings(lm):
    cfg, model, params = lm
    d, p, eng = _identity_run(model, params, cfg, shared=True)
    np.testing.assert_array_equal(d, p)
    pool = eng.kv_pool
    assert pool.shared_pages_hit >= 2  # 16-token prefix = 2 shared pages
    # sharing saved real pages vs. the unshared dense-equivalent footprint
    unshared = sum(len(pool.slot_pages(s)) for s in range(pool.n_slots))
    assert pool.pages_in_use < unshared
    pool.check()


@slow
@pytest.mark.parametrize("backend,wbits", [("reference", 8), ("kernel", 16),
                                           ("kernel", 8)])
def test_paged_vs_dense_identity_backend_wbits(lm, backend, wbits):
    cfg, model, params = lm
    d, p, eng = _identity_run(model, params, cfg, backend=backend,
                              wbits=wbits)
    np.testing.assert_array_equal(d, p)
    eng.kv_pool.check()


@slow
@pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
def test_paged_vs_dense_identity_2x2_mesh(lm):
    from repro.sharding.serve import ServeMesh
    cfg, model, params = lm
    dense = _dense_engine(model, params, mesh=ServeMesh.create(2, 2))
    paged = _paged_engine(model, params, mesh=ServeMesh.create(2, 2))
    prompts = [_prompt(cfg, 100 + i) for i in range(2)]
    outs = []
    for eng in (dense, paged):
        eng.enable_slots()
        lasts = []
        for slot, p in enumerate(prompts):
            last, _ = eng.admit_slot(slot, p)
            lasts.append(jnp.argmax(last, -1)[:, None])
        toks = jnp.concatenate(lasts).astype(jnp.int32)
        out, _ = eng.decode_slots(toks, 6)
        outs.append(np.asarray(out))
    np.testing.assert_array_equal(outs[0], outs[1])
    per = paged.shard_summary()["kv_pages_per_shard"]
    assert len(per) == 2 and sum(per) == paged.kv_pool.pages_in_use
    paged.kv_pool.check()


def test_paged_vs_dense_identity_max_seq_prompts(lm):
    """Review regression (engine level): two slots admitted with the SAME
    max_seq-length prompt share every shareable page; decode's clamped
    write at max_seq-1 must land in each slot's private final page. The
    streams are forced to diverge (different first decode inputs), so a
    shared final page would cross-contaminate the slots and break dense
    identity."""
    cfg, model, params = lm
    dense = _dense_engine(model, params)
    paged = _paged_engine(model, params)
    p = _prompt(cfg, 7, n=32)  # exactly max_seq
    outs = []
    for eng in (dense, paged):
        eng.enable_slots()
        last0, _ = eng.admit_slot(0, p)
        eng.admit_slot(1, p)
        t0 = int(np.asarray(jnp.argmax(last0, -1))[0])
        toks = jnp.asarray([[t0], [(t0 + 1) % cfg.vocab_size]], jnp.int32)
        out, _ = eng.decode_slots(toks, 4)
        outs.append(np.asarray(out))
    np.testing.assert_array_equal(outs[0], outs[1])
    pool = paged.kv_pool
    finals = [pool.slot_pages(s)[-1] for s in range(2)]
    assert finals[0] != finals[1]  # private clamp targets, one per slot
    assert all(f not in pool.alloc._hash_of for f in finals)
    assert pool.shared_pages == 3  # the first three pages still share
    pool.check()


# -- engine integration: release and growth -----------------------------------


def test_engine_release_slot_returns_pages(lm):
    cfg, model, params = lm
    eng = _paged_engine(model, params)
    eng.enable_slots()
    last, _ = eng.admit_slot(0, _prompt(cfg, 0))
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    tok = jnp.concatenate([tok, jnp.zeros((1, 1), jnp.int32)])
    eng.decode_slots(tok, 4)
    assert eng.kv_pool.pages_in_use > 0
    assert int(eng.slot_lengths()[0]) > 0
    eng.release_slot(0)
    assert int(eng.slot_lengths()[0]) == 0
    assert eng.kv_pool.steady_state()
    assert eng.io_summary()["kv_pages_in_use"] == 0
    eng.kv_pool.check()
    with pytest.raises(ValueError):
        eng.release_slot(9)


def test_engine_decode_growth_exhaustion_is_atomic(lm):
    """Review regression: when a decode round's page growth cannot fit
    the pool, decode_slots must raise BEFORE allocating anything or
    mutating any page table — so the caller can preempt a slot and retry
    instead of the engine dying with half-grown state."""
    cfg, model, params = lm
    eng = _paged_engine(model, params, pages=5)  # capacity 4
    eng.enable_slots()
    lasts = []
    for slot in range(2):  # 2 pages each (full + tail): pool is now full
        last, _ = eng.admit_slot(slot, _prompt(cfg, 100 + slot, n=12))
        lasts.append(jnp.argmax(last, -1)[:, None])
    pool = eng.kv_pool
    assert pool.pages_in_use == 4 and pool.reclaimable_pages == 0
    pages_before = [pool.slot_pages(s) for s in range(2)]
    table_before = pool.table.copy()
    toks = jnp.concatenate(lasts).astype(jnp.int32)
    with pytest.raises(KVPoolExhausted):
        eng.decode_slots(toks, 8)  # both slots need a third page
    # nothing was allocated, no table mutated: the failure is recoverable
    assert [pool.slot_pages(s) for s in range(2)] == pages_before
    np.testing.assert_array_equal(pool.table, table_before)
    assert pool.pages_in_use == 4
    pool.check()
    eng.release_slot(1)  # mimic a preemption freeing pages…
    out, _ = eng.decode_slots(toks, 8)  # …and the retry succeeds
    assert np.asarray(out).shape == (2, 8)
    pool.check()


# -- cross-feature regressions: scheduler, faults, preemption -----------------


def _paged_sched_run(model, params, cfg, **eng_kw):
    eng = _paged_engine(model, params, batch=2, **eng_kw)
    eng.simulator.noise = 0.0
    sched = Scheduler(eng, round_tokens=2)
    reqs = []
    for i in range(6):
        p = _prompt(cfg, seed=i % 3, n=12)  # repeats -> prefix sharing
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=4,
                            arrival_s=0.002 * i))
    for r in reqs[:4]:
        r.deadline_s = 0.03  # force preemption traffic
    sched.submit(reqs)
    stats = sched.run()
    return eng, sched, reqs, stats


def test_scheduler_paged_faults_preemption_no_page_leaks(lm):
    """Paged KV + PR-8 fault preemption + PR-9 corruption rungs: every
    release path (eviction, preemption, drop) funnels through the pool,
    so a fault-heavy run must drain to pool steady state with zero leaked
    refcounts and coherent io_summary counters."""
    cfg, model, params = lm
    eng, sched, reqs, stats = _paged_sched_run(
        model, params, cfg, fault_profile="thermal_throttle", fault_seed=0,
        corruption_profile="bit_rot", corruption_seed=7)
    assert stats.finished == 6
    assert all(len(r.tokens_out) == 4 for r in reqs)
    pool = eng.kv_pool
    assert pool.steady_state(), pool.summary()
    pool.check()
    assert pool.released >= pool.admitted - 2  # every occupant released
    s = eng.io_summary()
    assert s["kv_pages_in_use"] == 0 and s["kv_shared_pages"] == 0
    assert pool.shared_pages_hit > 0  # repeated prompts actually shared
    assert sum(eng.shard_summary()["kv_pages_per_shard"]) == 0


def test_scheduler_paged_run_deterministic(lm):
    """Same submission replayed on a fresh paged engine yields the same
    tokens — recycled-slot garbage cannot leak nondeterminism in."""
    cfg, model, params = lm
    outs = []
    for _ in range(2):
        _, _, reqs, _ = _paged_sched_run(model, params, cfg)
        outs.append([list(r.tokens_out) for r in reqs])
    assert outs[0] == outs[1]


def test_scheduler_release_accounting_through_pool(lm):
    """Satellite-3 regression: Scheduler eviction/preemption must route
    release through ``engine.release_slot`` (pool-aware), so a drained
    run leaves every slot length zero and every page returned."""
    cfg, model, params = lm
    eng = _paged_engine(model, params, batch=2)
    eng.simulator.noise = 0.0
    sched = Scheduler(eng, round_tokens=2)
    reqs = [Request(rid=i, prompt=_prompt(cfg, i), max_new_tokens=3,
                    arrival_s=0.001 * i) for i in range(3)]
    sched.submit(reqs)
    stats = sched.run()
    assert stats.finished == 3
    assert eng.kv_pool.steady_state()
    assert eng.kv_pool.released == eng.kv_pool.admitted
    eng.kv_pool.check()


def test_scheduler_preempts_on_kv_page_pressure(lm):
    """Review regression: decode-time page growth outrunning a small pool
    must not kill the run — the scheduler preempts the least-urgent
    co-runner (EDF mirror), retries the round, and the preemptee drains
    after readmission."""
    cfg, model, params = lm
    eng = _paged_engine(model, params, pages=5)  # capacity 4
    eng.simulator.noise = 0.0
    sched = Scheduler(eng, round_tokens=8)
    reqs = [Request(rid=i, prompt=_prompt(cfg, 100 + i, n=12),
                    max_new_tokens=8, arrival_s=0.0) for i in range(2)]
    sched.submit(reqs)
    stats = sched.run()
    assert stats.finished == 2
    assert stats.preempted >= 1  # page pressure, not deadlines, forced it
    assert all(len(r.tokens_out) == 8 for r in reqs)
    assert reqs[1].preemptions >= 1  # rid 1: latest (arrival, rid) victim
    assert eng.kv_pool.steady_state()
    eng.kv_pool.check()


def test_scheduler_lone_runner_page_exhaustion_fails_fast(lm):
    """With no co-runner to preempt, decode growth past the pool must
    surface as a clear sizing error, not an engine-killing traceback from
    half-grown state."""
    cfg, model, params = lm
    eng = _paged_engine(model, params, pages=3)  # capacity 2: prompt only
    eng.simulator.noise = 0.0
    sched = Scheduler(eng, round_tokens=8)
    sched.submit(Request(rid=0, prompt=_prompt(cfg, 0, n=12),
                         max_new_tokens=8, arrival_s=0.0))
    with pytest.raises(RuntimeError, match="no\\s+co-runner"):
        sched.run()
    eng.kv_pool.check()  # pool state stayed consistent through the failure
