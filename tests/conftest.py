"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 devices
(in its own process)."""
import sys
import types

import jax
import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis fallback: the package is not installable in every environment
# this suite runs in. Property-based tests degrade to a skip instead of
# failing the whole module at import time; everything else in those modules
# still collects and runs.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")

    def _strategy(*_args, **_kwargs):
        return None

    def _st_getattr(_name):
        return _strategy

    _st.__getattr__ = _st_getattr  # PEP 562: st.integers / st.floats / ...

    def _given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed: property test skipped")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
