"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 devices
(in its own process)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
