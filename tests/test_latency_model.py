"""Chunk-based latency model (paper §3.1, App. D)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    JETSON_AGX,
    JETSON_NANO,
    TPU_V5E_HBM,
    get_profile,
    profile_table,
    table_from_measurements,
)

KB = 1024.0


def test_calibrated_profiles():
    """Peak bandwidths are the spec-sheet numbers (§4.1); per-request costs
    are calibrated to reproduce the paper's Fig. 6/7 speedups (see
    latency_model.py docstring) and give AGX the WIDER scattered-vs-
    contiguous gap the paper reports."""
    assert JETSON_AGX.peak_bw == pytest.approx(7450 * KB * KB)
    assert JETSON_NANO.peak_bw == pytest.approx(3500 * KB * KB)
    s = 17.5 * KB  # typical top-k run (≈2.5 LLaVA-7B rows)
    pen_nano = float(JETSON_NANO.latency_bytes(s)) / (s / JETSON_NANO.peak_bw)
    pen_agx = float(JETSON_AGX.latency_bytes(s)) / (s / JETSON_AGX.peak_bw)
    assert pen_agx > pen_nano > 1.5  # fragmentation costly, AGX gap wider


def test_two_regime_shape():
    """Request-cost-bound for small blocks (≈flat), bandwidth-bound above."""
    p = JETSON_AGX
    small = float(p.latency_bytes(4 * KB))
    smaller = float(p.latency_bytes(1 * KB))
    assert small == pytest.approx(smaller, rel=0.25)  # near-flat small blocks
    big, bigger = p.latency_bytes(1e7), p.latency_bytes(2e7)
    assert bigger == pytest.approx(2 * big, rel=0.05)  # ~linear when BW-bound
    # throughput monotone nondecreasing
    sizes = np.logspace(3, 7, 40)
    thr = p.throughput_bytes(sizes)
    assert (np.diff(thr) >= -1e-6).all()


def test_scattered_vs_contiguous_gap():
    """The Fig. 4 effect: same bytes, very different latency by contiguity."""
    row = 7 * KB  # LLaVA-7B down-proj row
    t = profile_table("agx", int(row), max_rows=2048)
    n_rows = 1024
    scattered = n_rows * float(t.lookup(jnp.asarray(1)))
    contiguous = float(t.lookup(jnp.asarray(n_rows)))
    assert scattered / contiguous > 5  # paper reports up to ~5.8× end-to-end


def test_mask_latency_additive():
    t = profile_table("nano", 1024, max_rows=64)
    mask = np.zeros(100, bool)
    mask[0:10] = True
    mask[50:60] = True
    want = 2 * float(t.lookup(jnp.asarray(10)))
    assert float(t.mask_latency(jnp.asarray(mask))) == pytest.approx(want, rel=1e-5)


def test_lookup_extrapolation():
    t = profile_table("nano", 1024, max_rows=64)
    # beyond-table sizes extrapolate on the bandwidth slope
    t128 = float(t.lookup(jnp.asarray(128)))
    t64 = float(t.lookup(jnp.asarray(64)))
    slope = float(t.lookup(jnp.asarray(64))) - float(t.lookup(jnp.asarray(63)))
    assert t128 == pytest.approx(t64 + 64 * slope, rel=1e-4)


@given(st.integers(1, 500))
@settings(max_examples=30, deadline=None)
def test_latency_monotone_in_rows(rows):
    t = profile_table("agx", 2048, max_rows=512)
    a = float(t.lookup(jnp.asarray(rows)))
    b = float(t.lookup(jnp.asarray(rows + 1)))
    assert b >= a - 1e-12


def test_table_from_measurements():
    sizes = np.array([1, 4, 16, 64])
    lats = np.array([1e-4, 1e-4, 2e-4, 8e-4])
    t = table_from_measurements("custom", 512, sizes, lats)
    assert float(t.lookup(jnp.asarray(4))) == pytest.approx(1e-4, rel=1e-5)
    # linear interpolation between (16, 2e-4) and (64, 8e-4) at 32
    assert float(t.lookup(jnp.asarray(32))) == pytest.approx(4e-4, rel=0.01)


def test_profile_registry():
    assert get_profile("agx") is JETSON_AGX
    assert get_profile("tpu") is TPU_V5E_HBM
    with pytest.raises(KeyError):
        get_profile("nonexistent")


def test_table_from_measurements_rejects_bad_measurements():
    """Duplicate sizes and latencies that shrink as size grows are
    measurement errors — reject them instead of interpolating a garbage
    table (ISSUE 8 satellite)."""
    with pytest.raises(ValueError, match="duplicate measurement sizes"):
        table_from_measurements(
            "custom", 512, np.array([1, 4, 4, 64]),
            np.array([1e-4, 2e-4, 2.1e-4, 8e-4]),
        )
    with pytest.raises(ValueError, match="reading more can't be faster"):
        table_from_measurements(
            "custom", 512, np.array([1, 4, 16, 64]),
            np.array([1e-4, 3e-4, 2e-4, 8e-4]),
        )
    # validation runs on the size-sorted view: an unsorted but monotone
    # log is fine, and an IOPS-bound plateau (equal latencies) is fine
    t = table_from_measurements(
        "custom", 512, np.array([64, 1, 16, 4]),
        np.array([8e-4, 1e-4, 1e-4, 1e-4]),
    )
    assert float(t.lookup(jnp.asarray(16))) == pytest.approx(1e-4, rel=1e-5)
