"""MeshRules resolution logic + real sharded execution on a small host-device
mesh (subprocess so the 512-device dry-run flag never leaks into this
process's single-device tests)."""
import subprocess
import sys

import pytest

from jax.sharding import PartitionSpec as P


def _rules(shape=(2, 2), axes=("data", "model"), fsdp=False):
    # rules resolution is pure metadata — a 1-device mesh suffices via
    # jax.make_mesh only when sizes match; use Mesh over a numpy grid of
    # the single device replicated? Not possible. Test the logic with a
    # fake mesh-like object instead.
    class FakeMesh:
        def __init__(self, shape, axes):
            self.axis_names = axes
            self.shape = dict(zip(axes, shape))

    from repro.sharding.specs import MeshRules

    mesh = FakeMesh(shape, axes)
    return MeshRules.for_mesh(mesh, fsdp=fsdp)  # type: ignore[arg-type]


def test_divisibility_dropping():
    rules = _rules((4, 16))
    # 36 heads % 16 → replicated; 64 → sharded
    assert rules.spec(("batch", None, "heads", None), (8, 1, 36, 128)) == P("data")
    assert rules.spec(("batch", None, "heads", None), (8, 1, 64, 128)) == P(
        "data", None, "model"
    )


def test_cache_seq_fallback():
    rules = _rules((16, 16))
    # kv=8 can't take model(16) → cache_seq picks it up
    spec = rules.spec(
        ("layer", "batch", "cache_seq", "cache_kv_heads", "head_dim"),
        (22, 128, 32768, 8, 64),
    )
    assert spec == P(None, "data", "model")
    # kv=16 divides → kv gets model, seq stays unsharded
    spec2 = rules.spec(
        ("layer", "batch", "cache_seq", "cache_kv_heads", "head_dim"),
        (22, 128, 32768, 16, 64),
    )
    assert spec2 == P(None, "data", None, "model")


def test_no_axis_reuse():
    rules = _rules((2, 2))
    # two dims both wanting 'model': only the first gets it
    spec = rules.spec(("heads", "ffn"), (4, 8))
    assert spec == P("model")  # trailing None trimmed


def test_multipod_batch_axes():
    rules = _rules((2, 16, 16), axes=("pod", "data", "model"))
    assert rules.spec(("batch", None), (256, 4096)) == P(("pod", "data"))
    # batch=1 (long_500k): replicated
    assert rules.spec(("batch", None), (1, 1)) == P()


def test_fsdp_embed():
    rules = _rules((16, 16), fsdp=True)
    assert rules.spec(("embed", "ffn"), (8192, 28672)) == P("data", "model")
    no_fsdp = _rules((16, 16), fsdp=False)
    assert no_fsdp.spec(("embed", "ffn"), (8192, 28672)) == P(None, "model")


SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch.dryrun import run_dryrun, collective_bytes
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rep = run_dryrun("tinyllama-1.1b", "train_4k", mesh=mesh, verbose=False)
assert rep["flops_per_device"] and rep["flops_per_device"] > 0
assert rep["collectives"]["total_bytes"] > 0, "train step must communicate"
rep2 = run_dryrun("olmoe-1b-7b", "decode_32k", mesh=mesh, verbose=False)
assert rep2["flops_per_device"] > 0
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_real_sharded_lowering_small_mesh():
    """Real lower+compile on an 8-host-device (2,2,2) mesh in a subprocess."""
    out = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SCRIPT],
        capture_output=True,
        text=True,
        timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr
