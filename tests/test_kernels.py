"""Pallas kernel validation: shape/dtype sweeps + property tests vs ref.py
oracles, executed in interpret mode (CPU container; TPU is the target)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.contiguity import mask_to_chunks_np
from repro.kernels import (
    align_chunk_table,
    chunk_gather_matmul_ref,
    chunk_gather_swiglu_ref,
    chunk_table_to_mask,
    plan_to_kernel_table,
    sparse_matmul,
    sparse_swiglu,
)

SHAPES = [(128, 128, 1), (256, 256, 4), (512, 384, 2), (64, 128, 8)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rel_err(a, b):
    denom = max(1.0, float(jnp.max(jnp.abs(b))))
    return float(jnp.max(jnp.abs(a - b))) / denom


@pytest.mark.parametrize("n,d,b", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_chunk_gather_matmul_sweep(n, d, b, dtype, rng):
    w = jnp.asarray(rng.normal(0, 1, (n, d)), dtype)
    x = jnp.asarray(rng.normal(0, 1, (b, n)), dtype)
    mask = rng.random(n) < 0.5
    s, z = plan_to_kernel_table(mask, block_rows=8, max_chunks=max(n // 8, 1),
                                max_chunk_rows=64)
    y = sparse_matmul(w, x, jnp.asarray(s), jnp.asarray(z),
                      tile_d=128, max_chunk_rows=64)
    yref = chunk_gather_matmul_ref(w, x, s, z)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert _rel_err(y, yref) < tol


@pytest.mark.parametrize("n,f,b", [(128, 128, 1), (256, 256, 4)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_chunk_gather_swiglu_sweep(n, f, b, dtype, rng):
    wg = jnp.asarray(rng.normal(0, 1, (n, f)), dtype)
    wu = jnp.asarray(rng.normal(0, 1, (n, f)), dtype)
    x = jnp.asarray(rng.normal(0, 1, (b, n)), dtype)
    mask = rng.random(n) < 0.4
    s, z = plan_to_kernel_table(mask, block_rows=8, max_chunks=max(n // 8, 1),
                                max_chunk_rows=64)
    y = sparse_swiglu(wg, wu, x, jnp.asarray(s), jnp.asarray(z),
                      tile_f=128, max_chunk_rows=64)
    yref = chunk_gather_swiglu_ref(wg, wu, x, s, z)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert _rel_err(y, yref) < tol


def test_empty_plan_gives_zeros(rng):
    w = jnp.asarray(rng.normal(0, 1, (64, 128)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 64)), jnp.float32)
    s = jnp.zeros((4,), jnp.int32)
    z = jnp.zeros((4,), jnp.int32)
    y = sparse_matmul(w, x, s, z, tile_d=128, max_chunk_rows=32)
    assert float(jnp.max(jnp.abs(y))) == 0.0


def test_full_plan_equals_dense(rng):
    n, d, b = 128, 128, 3
    w = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (b, n)), jnp.float32)
    s, z = plan_to_kernel_table(np.ones(n, bool), block_rows=8,
                                max_chunks=n // 8, max_chunk_rows=64)
    y = sparse_matmul(w, x, jnp.asarray(s), jnp.asarray(z),
                      tile_d=128, max_chunk_rows=64)
    dense = x.astype(jnp.float32) @ w.astype(jnp.float32)
    assert _rel_err(y, dense) < 1e-5


def test_align_chunk_table_merge_then_resplit():
    """Regression: two unaligned runs whose rounded-out blocks become
    adjacent must MERGE, and the merged run must re-split at
    max_chunk_rows — the boundary lands mid-way through what was the
    second input run."""
    starts = np.asarray([2, 9], np.int64)
    sizes = np.asarray([5, 13], np.int64)  # rounds to [0,8) and [8,24)
    s, z = align_chunk_table(starts, sizes, block_rows=8, n=64,
                             max_chunk_rows=16)
    assert s.tolist() == [0, 16]
    assert z.tolist() == [16, 8]
    # the split is coverage-preserving
    covered = np.asarray(chunk_table_to_mask(s, z, 64))
    assert covered[:24].all() and not covered[24:].any()


def test_align_chunk_table_dtype_validation():
    """float tables used to be accepted silently (and floored in the index
    arithmetic); exact float values cast, fractional ones raise."""
    s, z = align_chunk_table(np.asarray([8.0]), np.asarray([8.0]),
                             block_rows=8, n=32)
    assert s.dtype == np.int32 and z.dtype == np.int32
    assert s.tolist() == [8] and z.tolist() == [8]
    with pytest.raises(TypeError):
        align_chunk_table(np.asarray([8.5]), np.asarray([8.0]), 8, 32)
    with pytest.raises(ValueError):
        align_chunk_table(np.asarray([8]), np.asarray([8, 16]), 8, 32)


@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.95))
@settings(max_examples=25, deadline=None)
def test_align_chunk_table_properties(seed, density):
    """Alignment covers the original selection, is block-aligned, within
    max_chunk_rows, and non-overlapping."""
    rng = np.random.default_rng(seed)
    n, br, mc = 256, 8, 64
    mask = rng.random(n) < density
    chunks = mask_to_chunks_np(mask)
    s0 = np.asarray([c.start for c in chunks], np.int32)
    z0 = np.asarray([c.size for c in chunks], np.int32)
    s, z = align_chunk_table(s0, z0, br, n, max_chunk_rows=mc)
    covered = np.asarray(chunk_table_to_mask(s, z, n))
    assert (covered | ~mask).all()  # superset of the selection
    assert (s % br == 0).all() and (z % br == 0).all()
    assert (z <= mc).all() and (z > 0).all() if len(z) else True
    ends = s + z
    assert (s[1:] >= ends[:-1]).all() if len(s) > 1 else True
