"""Interpret-mode parity suite for the double-buffered DMA gather kernels
(kernels/chunk_gather_dma.py) against the kernels/ref.py oracles, the
jit-safe batched-plan → kernel-table bridge, and the serve-stack wiring
(prefetch depth byte-identity, plan-routed fused MLP)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.offload import ComputeModel
from repro.core.pipeline import PipelineModel
from repro.kernels import (
    blocked_masked_matmul,
    chunk_gather_matmul_dma,
    chunk_gather_matmul_ref,
    chunk_gather_mlp_dma,
    chunk_gather_mlp_ref,
    chunk_table_to_mask,
    dequantize_rows,
    masks_to_block_tables,
    plan_to_kernel_table,
    quantize_rows,
    sparse_matmul_dma,
    sparse_mlp_fused,
)

DEPTHS = (0, 1, 2)


def _rel_err(a, b):
    denom = max(1.0, float(jnp.max(jnp.abs(b))))
    return float(jnp.max(jnp.abs(a - b))) / denom


def _stack_lanes(tables, k):
    """Pad per-lane (starts, sizes) pairs to a common K and stack (L, K)."""
    out_s = np.zeros((len(tables), k), np.int32)
    out_z = np.zeros((len(tables), k), np.int32)
    for i, (s, z) in enumerate(tables):
        out_s[i, : len(s)] = s
        out_z[i, : len(z)] = z
    return jnp.asarray(out_s), jnp.asarray(out_z)


# ---------------------------------------------------------------------------
# single-site DMA matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("n,d,b", [(128, 128, 1), (256, 256, 4), (64, 128, 8)])
def test_matmul_dma_parity(n, d, b, depth, rng):
    w = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (b, n)), jnp.float32)
    mask = rng.random(n) < 0.5
    s, z = plan_to_kernel_table(mask, block_rows=8, max_chunks=max(n // 8, 1),
                                max_chunk_rows=64)
    y = sparse_matmul_dma(w, x, jnp.asarray(s), jnp.asarray(z),
                          max_chunk_rows=64, prefetch_depth=depth)
    yref = chunk_gather_matmul_ref(w, x, s, z)
    assert _rel_err(y, yref) < 1e-5


def test_matmul_dma_bf16(rng):
    n, d, b = 128, 128, 2
    w = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.bfloat16)
    x = jnp.asarray(rng.normal(0, 1, (b, n)), jnp.bfloat16)
    mask = rng.random(n) < 0.5
    s, z = plan_to_kernel_table(mask, block_rows=8, max_chunks=n // 8,
                                max_chunk_rows=64)
    y = sparse_matmul_dma(w, x, jnp.asarray(s), jnp.asarray(z), max_chunk_rows=64)
    yref = chunk_gather_matmul_ref(w, x, s, z)
    assert _rel_err(y, yref) < 2e-2


@pytest.mark.parametrize("depth", DEPTHS)
def test_matmul_dma_all_padded(depth, rng):
    """Degenerate plan: every chunk padded (size 0) → exact zeros, and no
    slot is ever waited on (the rotation skips inactive steps)."""
    w = jnp.asarray(rng.normal(0, 1, (64, 128)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 64)), jnp.float32)
    s = jnp.zeros((5,), jnp.int32)
    z = jnp.zeros((5,), jnp.int32)
    y = sparse_matmul_dma(w, x, s, z, max_chunk_rows=32, prefetch_depth=depth)
    assert float(jnp.max(jnp.abs(y))) == 0.0


@pytest.mark.parametrize("depth", DEPTHS)
def test_matmul_dma_single_max_chunk(depth, rng):
    """One chunk of exactly max_chunk_rows (every block step active)."""
    n, d = 128, 128
    w = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (3, n)), jnp.float32)
    s = jnp.asarray([32], jnp.int32)
    z = jnp.asarray([64], jnp.int32)
    y = sparse_matmul_dma(w, x, s, z, max_chunk_rows=64, prefetch_depth=depth)
    yref = chunk_gather_matmul_ref(w, x, s, z)
    assert _rel_err(y, yref) < 1e-5


def test_matmul_dma_k_exceeds_real_chunks(rng):
    """K far larger than the number of real chunks: the padded tail is
    pure no-op steps at every depth."""
    n, d = 64, 128
    w = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, n)), jnp.float32)
    s = np.zeros(32, np.int32)
    z = np.zeros(32, np.int32)
    s[0], z[0] = 8, 16
    outs = [
        sparse_matmul_dma(w, x, jnp.asarray(s), jnp.asarray(z),
                          max_chunk_rows=32, prefetch_depth=depth)
        for depth in DEPTHS
    ]
    yref = chunk_gather_matmul_ref(w, x, s, z)
    for y in outs:
        assert _rel_err(y, yref) < 1e-5
    # the schedule is numerically identical at every depth, not just close
    for y in outs[1:]:
        assert bool(jnp.all(y == outs[0]))


def test_matmul_dma_depth_deeper_than_steps(rng):
    """prefetch_depth larger than the total step count: warm-up must guard
    against starting copies past the end."""
    w = jnp.asarray(rng.normal(0, 1, (16, 128)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (1, 16)), jnp.float32)
    s = jnp.asarray([0], jnp.int32)
    z = jnp.asarray([8], jnp.int32)
    y = sparse_matmul_dma(w, x, s, z, max_chunk_rows=8, prefetch_depth=7)
    yref = chunk_gather_matmul_ref(w, x, s, z)
    assert _rel_err(y, yref) < 1e-5


# ---------------------------------------------------------------------------
# fused multi-site MLP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", DEPTHS)
def test_mlp_fused_parity(depth, rng):
    n, f, d, b = 128, 256, 128, 2
    wg = jnp.asarray(rng.normal(0, 0.2, (n, f)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.2, (n, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.2, (f, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (b, n)), jnp.float32)
    # non-uniform site budgets: dense-ish hidden lane, sparse ffn lane
    th = plan_to_kernel_table(rng.random(n) < 0.7, 8, n // 8, 64)
    tf = plan_to_kernel_table(rng.random(f) < 0.3, 8, f // 8, 64)
    s2, z2 = _stack_lanes([th, tf], max(n, f) // 8)
    y = sparse_mlp_fused(wg, wu, wd, x, s2, z2, max_chunk_rows=64,
                         prefetch_depth=depth)
    yref = chunk_gather_mlp_ref(wg, wu, wd, x, s2, z2)
    assert _rel_err(y, yref) < 1e-5


@pytest.mark.parametrize("empty_lane", [0, 1])
def test_mlp_fused_empty_lane(empty_lane, rng):
    """Either lane fully padded → output exactly zero (empty hidden lane
    zeroes h; empty ffn lane gathers no down rows)."""
    n = f = d = 128
    wg = jnp.asarray(rng.normal(0, 0.2, (n, f)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.2, (n, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.2, (f, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, n)), jnp.float32)
    full = plan_to_kernel_table(np.ones(n, bool), 8, n // 8, 64)
    empty = (np.zeros(n // 8, np.int32), np.zeros(n // 8, np.int32))
    lanes = [full, full]
    lanes[empty_lane] = empty
    s2, z2 = _stack_lanes(lanes, n // 8)
    y = sparse_mlp_fused(wg, wu, wd, x, s2, z2, max_chunk_rows=64)
    assert float(jnp.max(jnp.abs(y))) == 0.0


def test_mlp_fused_full_lanes_equal_dense(rng):
    n = f = d = 128
    wg = jnp.asarray(rng.normal(0, 0.2, (n, f)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.2, (n, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.2, (f, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, n)), jnp.float32)
    full = plan_to_kernel_table(np.ones(n, bool), 8, n // 8, 64)
    s2, z2 = _stack_lanes([full, full], n // 8)
    y = sparse_mlp_fused(wg, wu, wd, x, s2, z2, max_chunk_rows=64)
    g = x @ wg
    dense = (g * (1.0 / (1.0 + jnp.exp(-g))) * (x @ wu)) @ wd
    assert _rel_err(y, dense) < 1e-5


# ---------------------------------------------------------------------------
# quantized chunk storage through the kernels (PR 6, satellite edge cases)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", DEPTHS)
def test_quantized_matmul_kernel_vs_twin_bitwise(depth, rng):
    """The in-kernel dequant (scales lane through the slot rotation) is
    bitwise the reference twin's per-block multiply — at every prefetch
    depth, on a chunk table covering the mask exactly."""
    n, d, b = 128, 128, 2
    w = jnp.asarray(rng.normal(0, 0.5, (n, d)), jnp.float32)
    q, s = quantize_rows(w, 8)
    x = jnp.asarray(rng.normal(0, 1, (b, n)), jnp.float32)
    mask = rng.random(n) < 0.5
    ks, kz = plan_to_kernel_table(mask, 8, n // 8, 64)
    # the twin sees the block-rounded mask (what the kernel actually gathers)
    cov = np.asarray(chunk_table_to_mask(jnp.asarray(ks), jnp.asarray(kz), n))
    y = chunk_gather_matmul_dma(q, x, jnp.asarray(ks), jnp.asarray(kz), s,
                                max_chunk_rows=64, prefetch_depth=depth,
                                interpret=True)
    y_twin = blocked_masked_matmul(x * cov.astype(np.float32), q, 8, s)
    assert bool(jnp.all(y == y_twin))


@pytest.mark.parametrize("depth", DEPTHS)
def test_quantized_zero_magnitude_chunk(depth, rng):
    """A selected chunk whose rows are entirely zero: scale 0, payload 0 —
    the kernel's dequant multiply must yield exact zeros for that block's
    contribution (the scale=0 guard), with the other chunks unaffected."""
    n, d = 64, 128
    w = np.asarray(rng.normal(0, 0.5, (n, d)), np.float32)
    w[8:16] = 0.0  # one full block of zeros, selected below
    q, s = quantize_rows(jnp.asarray(w), 8)
    assert float(s[1]) == 0.0
    x = jnp.asarray(rng.normal(0, 1, (2, n)), jnp.float32)
    ks = jnp.asarray([0, 32], jnp.int32)  # covers rows 0..32 incl. the zeros
    kz = jnp.asarray([32, 16], jnp.int32)
    y = chunk_gather_matmul_dma(q, x, ks, kz, s, max_chunk_rows=32,
                                prefetch_depth=depth, interpret=True)
    yref = chunk_gather_matmul_ref(dequantize_rows(q, s), x, ks, kz)
    assert _rel_err(y, yref) < 1e-6
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("depth", DEPTHS)
def test_quantized_all_padded_plan(depth, rng):
    """Every quantized plan lane padded (size 0) → exact zeros; the scales
    lane is fetched through the same inactive-step skip."""
    w = np.asarray(rng.normal(0, 1, (64, 128)), np.float32)
    q, s = quantize_rows(jnp.asarray(w), 8)
    x = jnp.asarray(rng.normal(0, 1, (2, 64)), jnp.float32)
    z = jnp.zeros((5,), jnp.int32)
    y = chunk_gather_matmul_dma(q, x, z, z, s, max_chunk_rows=32,
                                prefetch_depth=depth, interpret=True)
    assert float(jnp.max(jnp.abs(y))) == 0.0


def test_quantized_k_exceeds_real_chunks(rng):
    """K far beyond the real chunk count: the padded tail must not fetch
    (or dequantize) anything, and the schedule stays depth-invariant."""
    n, d = 64, 128
    w = np.asarray(rng.normal(0, 1, (n, d)), np.float32)
    q, s = quantize_rows(jnp.asarray(w), 8)
    x = jnp.asarray(rng.normal(0, 1, (2, n)), jnp.float32)
    ks = np.zeros(32, np.int32)
    kz = np.zeros(32, np.int32)
    ks[0], kz[0] = 8, 16
    outs = [
        chunk_gather_matmul_dma(q, x, jnp.asarray(ks), jnp.asarray(kz), s,
                                max_chunk_rows=32, prefetch_depth=depth,
                                interpret=True)
        for depth in DEPTHS
    ]
    yref = chunk_gather_matmul_ref(dequantize_rows(q, s), x, ks, kz)
    for y in outs:
        assert _rel_err(y, yref) < 1e-6
    for y in outs[1:]:
        assert bool(jnp.all(y == outs[0]))


@pytest.mark.parametrize("depth", DEPTHS)
def test_quantized_saturation_extremes(depth):
    """Blocks pinned at the int8 extremes (±127 payload): the dequant must
    reproduce the extreme values exactly — no overflow, no off-by-one in
    the clip."""
    n, d = 32, 128
    w = np.zeros((n, d), np.float32)
    w[:8] = 4.0
    w[8:16] = -4.0
    w[16:24, 0] = 1e-3  # tiny-magnitude block exercises small scales
    q, s = quantize_rows(jnp.asarray(w), 8)
    assert int(jnp.max(q)) == 127 and int(jnp.min(q)) == -127
    x = jnp.asarray(np.ones((1, n), np.float32))
    ks = jnp.asarray([0], jnp.int32)
    kz = jnp.asarray([32], jnp.int32)
    y = chunk_gather_matmul_dma(q, x, ks, kz, s, max_chunk_rows=32,
                                prefetch_depth=depth, interpret=True)
    yref = chunk_gather_matmul_ref(dequantize_rows(q, s), x, ks, kz)
    assert _rel_err(y, yref) < 1e-6


@pytest.mark.parametrize("depth", DEPTHS)
def test_quantized_mlp_fused_parity(depth, rng):
    """The fused MLP with all three weights quantized (three scale lanes
    riding the rotation) against the dequantized-weights oracle."""
    n, f, d, b = 128, 256, 128, 2
    wg = jnp.asarray(rng.normal(0, 0.2, (n, f)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.2, (n, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.2, (f, d)), jnp.float32)
    qg, sg = quantize_rows(wg, 8)
    qu, su = quantize_rows(wu, 8)
    qd, sd = quantize_rows(wd, 8)
    x = jnp.asarray(rng.normal(0, 1, (b, n)), jnp.float32)
    th = plan_to_kernel_table(rng.random(n) < 0.7, 8, n // 8, 64)
    tf = plan_to_kernel_table(rng.random(f) < 0.3, 8, f // 8, 64)
    s2, z2 = _stack_lanes([th, tf], max(n, f) // 8)
    y = chunk_gather_mlp_dma(qg, qu, qd, x, s2, z2, scales=(sg, su, sd),
                             max_chunk_rows=64, prefetch_depth=depth,
                             interpret=True)
    yref = chunk_gather_mlp_ref(
        dequantize_rows(qg, sg), dequantize_rows(qu, su),
        dequantize_rows(qd, sd), x, s2, z2,
    )
    assert _rel_err(y, yref) < 1e-5


# ---------------------------------------------------------------------------
# the jit-safe batched-plan → kernel-table bridge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("density", [0.1, 0.5, 0.9])
def test_masks_to_block_tables_matches_numpy_path(density, rng):
    n, br, mc = 256, 8, 64
    masks = np.stack([rng.random(n) < density for _ in range(3)])
    ks, kz = masks_to_block_tables(jnp.asarray(masks), br, mc)
    assert ks.shape == (3, n // br)
    for i in range(3):
        s0, z0 = plan_to_kernel_table(masks[i], block_rows=br,
                                      max_chunks=n // br, max_chunk_rows=mc)
        real = int((z0 > 0).sum())
        assert (np.asarray(ks[i])[:real] == s0[:real]).all()
        assert (np.asarray(kz[i])[:real] == z0[:real]).all()
        assert (np.asarray(kz[i])[real:] == 0).all()


def test_masks_to_block_tables_covers_block_rounded_mask(rng):
    n = 200  # deliberately not a multiple of block_rows (tail block)
    mask = rng.random(n) < 0.4
    ks, kz = masks_to_block_tables(jnp.asarray(mask[None]), 8, 32)
    n_pad = ((n + 7) // 8) * 8
    cov = np.asarray(chunk_table_to_mask(ks[0], kz[0], n_pad))
    rounded = np.repeat(
        np.pad(mask, (0, n_pad - n)).reshape(-1, 8).any(1), 8
    )
    assert (cov == rounded).all()
    assert (np.asarray(kz[0]) <= 32).all()


def test_masks_to_block_tables_empty_and_full():
    n = 64
    ks, kz = masks_to_block_tables(
        jnp.asarray(np.stack([np.zeros(n, bool), np.ones(n, bool)])), 8, 32
    )
    assert int(kz[0].sum()) == 0
    # full mask: one run split into max_chunk_rows pieces covering all rows
    assert int(kz[1].sum()) == n
    assert int((kz[1] > 0).sum()) == n // 32


# ---------------------------------------------------------------------------
# serve-stack wiring
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.models import build_model
    from repro.models.inputs import make_dummy_batch

    cfg = get_config("internvl2-76b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_dummy_batch(cfg, InputShape("t", 8, 2, "train"))
    return cfg, model, params, batch


def _decode(model, params, batch, n_tokens=5, **kw):
    from repro.serving import ServeEngine

    eng = ServeEngine(model, params, max_seq=64, batch_size=2, device="nano",
                      sparsity=0.4, method="chunk", seed=3, **kw)
    tok0 = jnp.argmax(eng.prefill(batch), -1)[:, None].astype(jnp.int32)
    out = eng.decode(tok0, n_tokens)
    return eng, out


def test_decode_tokens_identical_across_prefetch_depths(served):
    """The acceptance criterion: decode tokens byte-identical at
    prefetch_depth 0/1/2 (the pipeline only re-times the same masks)."""
    cfg, model, params, batch = served
    outs = [
        _decode(model, params, batch, prefetch_depth=depth)[1]
        for depth in DEPTHS
    ]
    for out in outs[1:]:
        assert bool(jnp.all(out == outs[0]))


def test_plan_routes_fused_mlp_tables(served):
    """End-to-end: the batched refresh's kernel tables, read straight off
    the decode-plan carry, drive the fused MLP kernel to the exact output
    of the oracle evaluated on the plan's own masks."""
    cfg, model, params, batch = served
    eng, _ = _decode(model, params, batch)
    sp = eng.sparse_ctx
    plan = eng._plan
    rng = np.random.default_rng(0)
    n, f, d = sp.sites["hidden_mlp"].n, sp.sites["ffn"].n, cfg.d_model
    wg = jnp.asarray(rng.normal(0, 0.1, (n, f)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.1, (n, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.1, (f, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, n)), jnp.float32)
    for layer in (0, cfg.n_layers - 1):
        s2, z2 = sp.mlp_kernel_plan(plan, layer=layer)
        y = sparse_mlp_fused(wg, wu, wd, x, s2, z2)
        yref = chunk_gather_mlp_ref(wg, wu, wd, x, s2, z2)
        assert _rel_err(y, yref) < 1e-5
        # tables cover exactly the block-rounded selection masks (no
        # reorderings in this engine, so selection order == storage order)
        for lane, kind in ((0, "hidden_mlp"), (1, "ffn")):
            m = np.asarray(plan[kind]["mask"][layer]) > 0
            n_pad = ((len(m) + 7) // 8) * 8
            cov = np.asarray(chunk_table_to_mask(s2[lane], z2[lane], n_pad))
            rounded = np.repeat(
                np.pad(m, (0, n_pad - len(m))).reshape(-1, 8).any(1), 8
            )
            assert (cov == rounded).all()


def test_plan_tables_survive_reuse_steps(served):
    """With plan_refresh_interval > 1 the reuse steps must carry the tables
    through unchanged (same lax.cond pytree both branches)."""
    cfg, model, params, batch = served
    eng, _ = _decode(model, params, batch, plan_refresh_interval=3)
    s2, z2 = eng.sparse_ctx.mlp_kernel_plan(eng._plan, layer=0)
    assert int(jnp.sum(z2)) > 0  # refreshed at least once, tables populated


@pytest.mark.parametrize("device", ["nano", "agx"])
def test_fused_mlp_from_batched_selection_per_device(served, device, rng):
    """Both shipped device profiles: a real batched selection (the device's
    own chunk-size schedule) → jit-side tables → fused kernel == oracle,
    and the tables reproduce the selection masks exactly after block
    rounding."""
    from repro.serving import SparseExecution
    from repro.serving.sparse_exec import KERNEL_BLOCK_ROWS, KERNEL_MAX_CHUNK_ROWS

    cfg = served[0]
    sp = SparseExecution(cfg, device=device, sparsity=0.4, method="chunk")
    vs = np.zeros((sp.batched.n_sites, sp.batched.n_max), np.float32)
    for i, kind in enumerate(sp.site_order):
        vs[i, : sp.sites[kind].n] = rng.random(sp.sites[kind].n)
    masks, _ = sp.batched.select(jnp.asarray(vs), sp._budgets)
    ks, kz = masks_to_block_tables(masks, KERNEL_BLOCK_ROWS, KERNEL_MAX_CHUNK_ROWS)
    order = list(sp.site_order)
    ih, i_f = order.index("hidden_mlp"), order.index("ffn")
    n, f, d = sp.sites["hidden_mlp"].n, sp.sites["ffn"].n, cfg.d_model
    wg = jnp.asarray(rng.normal(0, 0.1, (n, f)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.1, (n, f)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.1, (f, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, n)), jnp.float32)
    s2 = jnp.stack([ks[ih], ks[i_f]])
    z2 = jnp.stack([kz[ih], kz[i_f]])
    y = sparse_mlp_fused(wg, wu, wd, x, s2, z2,
                         max_chunk_rows=KERNEL_MAX_CHUNK_ROWS)
    yref = chunk_gather_mlp_ref(wg, wu, wd, x, s2, z2)
    assert _rel_err(y, yref) < 1e-5
    for lane, idx, n_site in ((0, ih, n), (1, i_f, f)):
        m = np.asarray(masks[idx, :n_site])
        n_pad = ((n_site + 7) // 8) * 8
        cov = np.asarray(chunk_table_to_mask(s2[lane], z2[lane], n_pad))
        rounded = np.repeat(
            np.pad(m, (0, n_pad - n_site)).reshape(-1, 8).any(1), 8
        )
        assert (cov == rounded).all()


def test_kernel_tables_unknown_site_raises(served):
    cfg, model, params, batch = served
    eng, _ = _decode(model, params, batch)
    with pytest.raises(KeyError):
        eng.sparse_ctx.kernel_tables(eng._plan, "nope")


# ---------------------------------------------------------------------------
# pipeline depth generalization + per-layer compute calibration
# ---------------------------------------------------------------------------


def test_pipeline_latency_monotone_in_depth(rng):
    io = rng.random((6, 4))
    comp = rng.random((4,))
    totals = [
        PipelineModel(prefetch_depth=d).timeline(io, comp).overlap_total_s
        for d in range(5)
    ]
    for a, b in zip(totals, totals[1:]):
        assert b <= a + 1e-12
    # depth 0 == serial exactly
    tl0 = PipelineModel(prefetch_depth=0).timeline(io, comp)
    np.testing.assert_allclose(tl0.overlap_s, tl0.serial_s, rtol=0, atol=1e-12)


def test_pipeline_with_depth_helper():
    pm = PipelineModel(prefetch_depth=1)
    assert pm.with_depth(3).prefetch_depth == 3
    assert pm.prefetch_depth == 1  # frozen original untouched


def test_compute_model_layer_scale(served):
    cfg = served[0]
    cm = ComputeModel()
    uniform = cm.decode_layer_seconds(cfg, sparsity=0.4)
    scale = np.linspace(0.5, 1.5, cfg.n_layers)
    scaled = cm.decode_layer_seconds(cfg, sparsity=0.4, layer_scale=scale)
    np.testing.assert_allclose(scaled, uniform * scale)
    with pytest.raises(ValueError):
        cm.decode_layer_seconds(cfg, layer_scale=np.ones(cfg.n_layers + 1))
    with pytest.raises(ValueError):
        cm.decode_layer_seconds(cfg, layer_scale=-np.ones(cfg.n_layers))


def test_calibrate_layer_scale_mean_one():
    walls = np.array([1.0, 2.0, 3.0, 2.0])
    scale = ComputeModel.calibrate_layer_scale(walls)
    assert abs(scale.mean() - 1.0) < 1e-12
    np.testing.assert_allclose(scale * walls.mean(), walls)


def test_engine_nonuniform_compute_changes_timeline_not_tokens(served):
    cfg, model, params, batch = served
    scale = np.linspace(0.2, 1.8, cfg.n_layers)
    eng_u, out_u = _decode(model, params, batch)
    eng_n, out_n = _decode(model, params, batch, compute_layer_scale=scale)
    assert bool(jnp.all(out_u == out_n))  # calibration re-times, not re-masks
    assert not np.isclose(
        eng_u.io_summary()["decode_overlap_s"],
        eng_n.io_summary()["decode_overlap_s"],
    )


def test_reprice_timeline_matches_depth_engine(served):
    """reprice_timeline(d) must equal what an identically-seeded engine at
    prefetch_depth=d charges (the smoke benchmark relies on this) —
    including across MULTIPLE decode calls, each of which the real engine
    prices as its own cold pipeline."""
    cfg, model, params, batch = served
    eng1, _ = _decode(model, params, batch)
    eng2, _ = _decode(model, params, batch, prefetch_depth=2)
    for eng in (eng1, eng2):  # second decode call, same token streams
        tok = jnp.zeros((2, 1), jnp.int32)
        eng.decode(tok, 3)
    tl = eng1.reprice_timeline(2)
    assert len(eng1._layer_io_log) == 2
    assert np.isclose(tl.overlap_total_s, eng2.io_summary()["decode_overlap_s"])
    assert np.isclose(
        tl.overlap_efficiency(), eng2.io_summary()["overlap_efficiency"]
    )
    # and at the engine's own depth it reproduces the engine's own charge
    tl_same = eng1.reprice_timeline(1)
    assert np.isclose(tl_same.overlap_total_s,
                      eng1.io_summary()["decode_overlap_s"])
