"""Beyond-paper §Perf optimizations must preserve semantics exactly:
A) KV-cache head replication, B) gather / shard_map-EP MoE dispatch,
C) shard_map sequence-sharded attention (covered in subprocess test)."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.models.common import init_params
from repro.models.moe import MoEConfig, moe_ffn, moe_param_defs

SMOKE = InputShape(name="smoke", seq_len=12, global_batch=2, kind="train")


def test_kv_replication_decode_identical():
    """Replicated-KV cache decode == baseline decode (same math)."""
    base = dataclasses.replace(
        get_config("tinyllama-1.1b").reduced(), n_heads=4, n_kv_heads=2
    )  # GQA so replication 2 is legal (kv_eff=4 divides heads=4)
    cfg_r = dataclasses.replace(base, kv_replicate=2)
    model_a, model_b = build_model(base), build_model(cfg_r)
    params = model_a.init(jax.random.key(0))
    batch = make_dummy_batch(base, SMOKE)
    la, ca = model_a.prefill(params, batch, 32)
    lb, cb = model_b.prefill(params, batch, 32)
    np.testing.assert_allclose(np.asarray(la, np.float32), np.asarray(lb, np.float32))
    assert cb["k"].shape[3] == 2 * ca["k"].shape[3]
    tok = batch["tokens"][:, :1]
    da, ca, _ = model_a.decode_step(params, tok, ca)
    db, cb, _ = model_b.decode_step(params, tok, cb)
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), atol=1e-5)


def test_gather_dispatch_matches_scatter(rng):
    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32, capacity_factor=2.0)
    params, _ = init_params(moe_param_defs(cfg), jax.random.key(0), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, 16)), jnp.float32)
    y_s, aux_s = moe_ffn(x, params, cfg)
    y_g, aux_g = moe_ffn(x, params, dataclasses.replace(cfg, dispatch="gather"))
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_g), atol=1e-6)
    assert float(aux_s) == pytest.approx(float(aux_g))


def test_ep_dispatch_falls_back_without_mesh(rng):
    """ep_shard_map without rules installed degrades to the gather path."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32,
                    capacity_factor=8.0, dispatch="ep_shard_map")
    params, _ = init_params(moe_param_defs(cfg), jax.random.key(0), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, 16)), jnp.float32)
    y, aux = moe_ffn(x, params, cfg)
    y_ref, _ = moe_ffn(x, params, dataclasses.replace(cfg, dispatch="scatter"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-6)


def test_optimized_for_knobs():
    cfg = get_config("granite-3-2b").optimized_for(16)
    assert cfg.kv_replicate == 2 and cfg.n_cache_kv_heads == 16
    cfg = get_config("olmoe-1b-7b").optimized_for(16)
    assert cfg.moe_dispatch == "ep_shard_map" and cfg.kv_replicate == 1
    cfg = get_config("starcoder2-3b").optimized_for(16)
    assert cfg.kv_replicate == 1  # 24 heads: impossible → fallback sharding
    assert get_config("xlstm-125m").optimized_for(16).kv_replicate == 1


SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.sharding import MeshRules, use_rules
from repro.models.moe import MoEConfig, moe_ffn, moe_param_defs
from repro.models.common import init_params

mesh = make_mesh((2, 4), ("data", "model"))
rules = MeshRules.for_mesh(mesh)
cfg = MoEConfig(n_experts=8, top_k=2, d_model=16, d_ff=32, capacity_factor=8.0)
params, _ = init_params(moe_param_defs(cfg), jax.random.key(0), jnp.float32)
x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 8, 16)), jnp.float32)
y_ref, _ = moe_ffn(x, params, cfg)
with use_rules(rules), mesh:
    y_ep, _ = moe_ffn(x, params, dataclasses.replace(cfg, dispatch="ep_shard_map"))
assert float(jnp.max(jnp.abs(y_ref - y_ep))) < 1e-4, "EP mismatch"

# shard_map seq-sharded attention == blockwise (starcoder-like indivisible heads)
from repro.models.attention import _blockwise_attention, _seq_sharded_attention
rules_opt = dataclasses.replace(rules, seq_shard_attention=True)
rng = np.random.default_rng(1)
q = jnp.asarray(rng.normal(0, 1, (2, 64, 3, 8)), jnp.float32)
k = jnp.asarray(rng.normal(0, 1, (2, 64, 3, 8)), jnp.float32)
v = jnp.asarray(rng.normal(0, 1, (2, 64, 3, 8)), jnp.float32)
ref = _blockwise_attention(q, k, v, jnp.int32(0), True, None, block_q=16, block_kv=16)
with use_rules(rules_opt), mesh:
    got = _seq_sharded_attention(q, k, v, None)
assert float(jnp.max(jnp.abs(ref - got))) < 1e-4, "seq-sharded attn mismatch"
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_sharded_optimizations_exact_small_mesh():
    out = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SCRIPT],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd="/root/repo",
    )
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr
