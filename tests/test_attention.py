"""Attention mechanics: blockwise==direct, GQA, sliding window, append, RoPE."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    _blockwise_attention,
    _direct_attention,
    append_attention,
    multi_head_attention,
    repeat_kv,
)
from repro.models.common import apply_rope, causal_mask


def _qkv(rng, b=2, s=96, h=4, hd=16, skv=None):
    skv = skv or s
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, skv, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, skv, h, hd)), jnp.float32)
    return q, k, v


def test_blockwise_equals_direct_causal(rng):
    q, k, v = _qkv(rng)
    s = q.shape[1]
    mask = causal_mask(s, s, 0)
    direct = _direct_attention(q, k, v, mask)
    block = _blockwise_attention(q, k, v, jnp.int32(0), True, None,
                                 block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(block), np.asarray(direct), atol=2e-5)


def test_blockwise_equals_direct_window(rng):
    q, k, v = _qkv(rng)
    s, w = q.shape[1], 24
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = (kj <= qi) & (kj > qi - w)
    direct = _direct_attention(q, k, v, mask)
    block = _blockwise_attention(q, k, v, jnp.int32(0), True, w,
                                 block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(block), np.asarray(direct), atol=2e-5)


def test_blockwise_ragged_lengths(rng):
    """Non-multiple-of-block seq lengths must pad correctly."""
    q, k, v = _qkv(rng, s=70)
    mask = causal_mask(70, 70, 0)
    direct = _direct_attention(q, k, v, mask)
    block = _blockwise_attention(q, k, v, jnp.int32(0), True, None,
                                 block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(block), np.asarray(direct), atol=2e-5)


def test_repeat_kv(rng):
    x = jnp.asarray(rng.normal(0, 1, (2, 5, 2, 4)), jnp.float32)
    r = repeat_kv(x, 3)
    assert r.shape == (2, 5, 6, 4)
    np.testing.assert_array_equal(np.asarray(r[:, :, 0]), np.asarray(r[:, :, 1]))
    np.testing.assert_array_equal(np.asarray(r[:, :, 0]), np.asarray(x[:, :, 0]))


def test_rope_relative_property(rng):
    """RoPE: q·k depends only on relative position."""
    hd = 32
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 1, hd)), jnp.float32)

    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), 10000.0)
        kr = apply_rope(k, jnp.array([[pk]]), 10000.0)
        return float(jnp.sum(qr * kr))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), abs=1e-4)
    assert dot_at(5, 5) == pytest.approx(dot_at(0, 0), abs=1e-4)


def test_append_attention_matches_fused_prefill(rng):
    """Appending n tokens against a prefilled cache == full causal attention
    over the concatenated sequence (positions included)."""
    b, s0, n, h, hd, d = 2, 10, 6, 2, 8, 16
    params = {
        "wq": jnp.asarray(rng.normal(0, 0.1, (d, h * hd)), jnp.float32),
        "wk": jnp.asarray(rng.normal(0, 0.1, (d, h * hd)), jnp.float32),
        "wv": jnp.asarray(rng.normal(0, 0.1, (d, h * hd)), jnp.float32),
        "wo": jnp.asarray(rng.normal(0, 0.1, (h * hd, d)), jnp.float32),
    }
    x_full = jnp.asarray(rng.normal(0, 1, (b, s0 + n, d)), jnp.float32)
    full = multi_head_attention(x_full, params, h, h, hd, rope_theta=10000.0)

    # prefill cache with first s0 tokens manually
    from repro.models.common import apply_rope as rope

    pos0 = jnp.broadcast_to(jnp.arange(s0)[None], (b, s0))
    k0 = rope((x_full[:, :s0] @ params["wk"]).reshape(b, s0, h, hd), pos0, 10000.0)
    v0 = (x_full[:, :s0] @ params["wv"]).reshape(b, s0, h, hd)
    phys = s0 + n
    ck = jnp.zeros((b, phys, h, hd)).at[:, :s0].set(k0)
    cv = jnp.zeros((b, phys, h, hd)).at[:, :s0].set(v0)
    out, ck, cv = append_attention(
        x_full[:, s0:], params, ck, cv, jnp.int32(s0), h, h, hd, 10000.0
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(full[:, s0:]), atol=1e-4, rtol=1e-4
    )
