"""Utility-guided chunk selection — Algorithm 1 (paper §3.2, App. E)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ChunkConfig,
    ChunkSelector,
    chunk_stats_np,
    mask_to_chunks_np,
    retention,
    select_chunks_np,
    topk_mask_np,
)

CFG = ChunkConfig(min_chunk_kb=8, max_chunk_kb=64, step_kb=8, jump_cap_kb=8)
ROW_BYTES = 1024


def _selector(n):
    return ChunkSelector.build(n, ROW_BYTES, device="nano", cfg=CFG)


def test_np_jax_equivalence_basic(rng):
    n = 1024
    v = rng.gamma(2.0, 1.0, n).astype(np.float32)
    sel = _selector(n)
    budget = 400
    m_np = select_chunks_np(v, budget, ROW_BYTES, sel.table, CFG)
    m_j, n_sel, _ = sel.select(jnp.asarray(v), jnp.int32(budget))
    assert (np.asarray(m_j) == m_np).all()
    assert int(n_sel) == m_np.sum()


@given(st.integers(0, 2**31 - 1), st.integers(64, 512), st.floats(0.1, 0.9))
@settings(max_examples=20, deadline=None)
def test_np_jax_equivalence_property(seed, n, keep):
    rng = np.random.default_rng(seed)
    v = rng.exponential(1.0, n).astype(np.float32)
    sel = _selector(n)
    budget = int(keep * n)
    m_np = select_chunks_np(v, budget, ROW_BYTES, sel.table, CFG)
    m_j, _, _ = sel.select(jnp.asarray(v), jnp.int32(budget))
    assert (np.asarray(m_j) == m_np).all()


@given(st.integers(0, 2**31 - 1), st.floats(0.1, 0.95))
@settings(max_examples=25, deadline=None)
def test_budget_never_exceeded(seed, keep):
    rng = np.random.default_rng(seed)
    n = 512
    v = rng.gamma(1.5, 1.0, n).astype(np.float32)
    sel = _selector(n)
    budget = int(keep * n)
    m, n_sel, _ = sel.select(jnp.asarray(v), jnp.int32(budget))
    assert int(np.asarray(m).sum()) == int(n_sel) <= budget


def test_selected_chunks_are_candidate_shaped(rng):
    """Every selected chunk must decompose into candidate windows."""
    n = 512
    v = rng.gamma(2.0, 1.0, n).astype(np.float32)
    sel = _selector(n)
    m, _, _ = sel.select(jnp.asarray(v), jnp.int32(300))
    sizes = set(CFG.row_sizes(ROW_BYTES))
    min_size = min(sizes)
    for c in mask_to_chunks_np(np.asarray(m)):
        assert c.size >= min_size  # no fragment smaller than the window grid


def test_beats_topk_on_latency_at_same_budget():
    """The paper's core claim at the policy level: at a fixed row budget the
    chunk plan's I/O latency is far below top-k's, with bounded retention
    loss (smooth activations ⇒ small loss, §2.2)."""
    rng = np.random.default_rng(0)  # deterministic: marginal bounds below
    n = 4096
    # smooth VLM-like importances (gamma, CV≈0.5)
    v = rng.gamma(4.0, 1.0, n).astype(np.float32)
    sel = ChunkSelector.build(n, ROW_BYTES, device="nano",
                              cfg=ChunkConfig(8, 348, 8, 8))
    budget = int(0.6 * n)
    m_chunk, _, lat_chunk = sel.select(jnp.asarray(v), jnp.int32(budget))
    m_topk = topk_mask_np(v, budget)
    lat_topk = float(sel.table.mask_latency(jnp.asarray(m_topk)))
    assert float(lat_chunk) < 0.5 * lat_topk  # ≥2× I/O reduction
    r_chunk = float(retention(jnp.asarray(v), m_chunk))
    r_topk = float(retention(jnp.asarray(v), jnp.asarray(m_topk)))
    assert r_chunk > 0.75 * r_topk  # bounded importance loss
    # and contiguity jumps, as in Fig. 10 (avg chunk ~1-2 → tens)
    assert chunk_stats_np(np.asarray(m_chunk))[0] > 5 * chunk_stats_np(m_topk)[0]


def test_uniform_importance_prefers_large_chunks(rng):
    """With flat importance the utility ratio favors saturating chunks."""
    n = 1024
    v = np.ones(n, np.float32)
    sel = _selector(n)
    m, _, _ = sel.select(jnp.asarray(v), jnp.int32(512))
    avg, _mode = chunk_stats_np(np.asarray(m))
    assert avg >= 32  # large contiguous runs, not scattered singles


def test_select_for_sparsity(rng):
    n = 256
    sel = _selector(n)
    v = rng.random(n).astype(np.float32)
    m, n_sel, _ = sel.select_for_sparsity(jnp.asarray(v), 0.5)
    assert int(n_sel) <= 128


def test_chunk_config_row_conversion():
    cfg = ChunkConfig(min_chunk_kb=8, max_chunk_kb=32, step_kb=8, jump_cap_kb=16)
    # 2 KB rows → sizes 4..16 step 4, cap 8 rows
    assert cfg.row_sizes(2048) == [4, 8, 12, 16]
    assert cfg.jump_cap_rows(2048) == 8


def test_for_shape_heuristic_matches_paper_table2():
    # large matrices get coarser grids (Table 2: 18944×3584 → 32 KB on AGX)
    big = ChunkConfig.for_shape(18944, 3584, "agx")
    small = ChunkConfig.for_shape(896, 128, "agx")
    assert big.min_chunk_kb > small.min_chunk_kb


def test_for_shape_saturation_cap_per_device():
    """Regression: the per-device max chunk size is the throughput
    saturation point — AGX+990Pro saturates later than Nano+P31, so its cap
    must be the larger one (348 vs 236 KB; the caps were once swapped)."""
    from repro.core.latency_model import JETSON_AGX, JETSON_NANO

    for rows, cols in ((18944, 3584), (3584, 3584), (896, 128)):
        assert ChunkConfig.for_shape(rows, cols, "nano").max_chunk_kb == 236.0
        assert ChunkConfig.for_shape(rows, cols, "agx").max_chunk_kb == 348.0
        assert (
            ChunkConfig.for_shape(rows, cols, "jetson_agx_990pro").max_chunk_kb
            == 348.0
        )
    # the nano cap is the class default; the ratio of caps tracks the ratio
    # of the devices' two-regime knees (bigger knee ⇒ later saturation)
    assert ChunkConfig().max_chunk_kb == 236.0
    knee_ratio = JETSON_AGX.knee_bytes / JETSON_NANO.knee_bytes
    assert 348.0 / 236.0 == pytest.approx(knee_ratio, rel=0.05)
