"""Sort-based MoE dispatch: vs dense-expert reference, capacity, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import swish
from repro.models.moe import MoEConfig, moe_ffn, moe_param_defs
from repro.models.common import init_params


def _setup(rng, e=4, k=2, d=16, f=32, cap=8.0, shared=False):
    cfg = MoEConfig(n_experts=e, top_k=k, d_model=d, d_ff=f,
                    capacity_factor=cap, shared_expert=shared)
    defs = moe_param_defs(cfg)
    params, _ = init_params(defs, jax.random.key(0), jnp.float32)
    return cfg, params


def _dense_reference(x, params, cfg):
    """Compute ALL experts densely and combine with normalized top-k router
    weights — the mathematical spec sort-based dispatch must match when no
    tokens are dropped."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    # per-expert dense outputs
    g = jnp.einsum("td,edf->tef", xt, params["we_gate"])
    u = jnp.einsum("td,edf->tef", xt, params["we_up"])
    y_all = jnp.einsum("tef,efd->ted", swish(g) * u, params["we_down"])
    one_hot = jax.nn.one_hot(top_e, cfg.n_experts)  # (t, k, e)
    w_per_e = (one_hot * top_w[..., None]).sum(1)  # (t, e)
    y = jnp.einsum("ted,te->td", y_all, w_per_e)
    if cfg.shared_expert:
        y = y + (swish(xt @ params["ws_gate"]) * (xt @ params["ws_up"])) @ params["ws_down"]
    return y.reshape(b, s, d)


def test_sort_dispatch_matches_dense_reference(rng):
    cfg, params = _setup(rng)
    x = jnp.asarray(rng.normal(0, 1, (2, 6, 16)), jnp.float32)
    y, aux = moe_ffn(x, params, cfg)
    want = _dense_reference(x, params, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_shared_expert_path(rng):
    cfg, params = _setup(rng, k=1, shared=True)
    x = jnp.asarray(rng.normal(0, 1, (2, 4, 16)), jnp.float32)
    y, _ = moe_ffn(x, params, cfg)
    want = _dense_reference(x, params, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens(rng):
    """With capacity_factor → 0 almost everything drops → output ≈ 0."""
    cfg, params = _setup(rng, cap=0.01)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, 16)), jnp.float32)
    y, _ = moe_ffn(x, params, cfg)
    y_full, _ = moe_ffn(x, params, MoEConfig(4, 2, 16, 32, capacity_factor=8.0))
    assert float(jnp.abs(y).mean()) < float(jnp.abs(y_full).mean())


def test_aux_loss_balanced_is_near_one(rng):
    """Uniform routing ⇒ aux ≈ weight × 1.0 (Switch normalization)."""
    cfg, params = _setup(rng, e=4, k=1)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    x = jnp.asarray(rng.normal(0, 1, (4, 32, 16)), jnp.float32)
    _, aux = moe_ffn(x, params, cfg)
    assert float(aux) == pytest.approx(cfg.router_aux_weight, rel=0.05)


def test_capacity_rounding():
    cfg = MoEConfig(64, 8, 2048, 1024)
    c = cfg.capacity(1_048_576)
    assert c % 128 == 0 and c >= 1_048_576 * 8 * 1.25 / 64
    assert MoEConfig(4, 2, 8, 8).capacity(2) >= 2  # tiny decode floor
