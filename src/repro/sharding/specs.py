"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names; a MeshRules object
(installed by the launcher for the active mesh) maps logical names to mesh
axes and applies ``with_sharding_constraint``. With no rules installed (unit
tests, CPU smoke runs) every annotation is a no-op, so model code never
depends on a mesh being present.

Resolution is two-pass with divisibility + used-axis tracking:
  pass 1: each dim gets its primary mesh axis if the axis divides the dim
          and is not already used in this spec;
  pass 2: unassigned dims may pick up a fallback axis (e.g. a KV cache whose
          8 kv-heads can't split 16-way model-parallel instead shards its
          sequence dim over 'model' — without this, a 32k-decode cache for
          internvl2-76b would replicate ~43 GB per chip).

Logical axes:
  batch            → ('pod','data')      act_seq        → 'model' (seq-parallel)
  heads/kv_heads   → 'model'             ffn/vocab      → 'model'
  embed (weights)  → 'data' iff FSDP     expert         → 'model'
  expert_capacity  → ('pod','data')      ssm_heads      → 'model'
  cache_kv_heads   → 'model'             cache_seq      → fallback 'model'
  conv_dim         → 'model'             layer/state/…  → replicated
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True, eq=False)
class MeshRules:
    """Maps logical axis names → mesh axes (primary + optional fallback)."""

    mesh: Mesh
    rules: Dict[str, Axis]
    fallbacks: Dict[str, Axis]
    fsdp: bool = False
    # §Perf iteration C: allow shard_map sequence-sharded attention when the
    # head count doesn't divide the model axis (beyond-paper optimization;
    # False reproduces the baseline GSPMD behaviour).
    seq_shard_attention: bool = False

    @staticmethod
    def for_mesh(mesh: Mesh, fsdp: bool = False) -> "MeshRules":
        names = mesh.axis_names
        dp: Axis = tuple(a for a in ("pod", "data") if a in names) or None
        if isinstance(dp, tuple) and len(dp) == 1:
            dp = dp[0]
        tp: Axis = "model" if "model" in names else None
        rules: Dict[str, Axis] = {
            "batch": dp,
            "act_seq": tp,  # sequence parallelism between blocks
            "act_embed": None,
            "heads": tp,
            "kv_heads": tp,
            "head_dim": None,
            "ffn": tp,
            "embed": ("data" if (fsdp and "data" in names) else None),
            "vocab": tp,
            "expert": tp,
            "expert_capacity": dp,
            "ssm_heads": tp,
            "conv_dim": tp,
            "state": None,
            "layer": None,
            "cache_seq": None,
            "cache_kv_heads": tp,
        }
        fallbacks: Dict[str, Axis] = {
            "cache_seq": tp,  # when kv-heads can't take the model axis
            "expert": "data",  # tiny expert counts at decode time
            "act_seq": tp,  # attention seq when head counts don't divide tp
        }
        return MeshRules(mesh=mesh, rules=rules, fallbacks=fallbacks, fsdp=fsdp)

    def axis_size(self, axis: Axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            return int(np.prod([self.mesh.shape[a] for a in axis]))
        return int(self.mesh.shape[axis])

    def _axis_names(self, axis: Axis) -> Tuple[str, ...]:
        if axis is None:
            return ()
        return axis if isinstance(axis, tuple) else (axis,)

    def spec(
        self, logical: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None
    ) -> P:
        n = len(logical)
        out: list = [None] * n
        used: set = set()

        def fits(axis: Axis, dim: Optional[int]) -> bool:
            if axis is None:
                return False
            parts = self._axis_names(axis)
            if any(a in used for a in parts):
                return False
            if dim is not None and dim % self.axis_size(axis) != 0:
                return False
            return True

        # model-parallel "structure" dims claim their axis before generic
        # activation dims (a 36-head tensor must not lose 'model' to the
        # sequence dim just because seq comes first in the shape)
        priority = {"heads": 0, "kv_heads": 0, "cache_kv_heads": 0, "ffn": 0,
                    "vocab": 0, "expert": 0, "ssm_heads": 0, "conv_dim": 0,
                    "batch": 1, "expert_capacity": 1}
        order = sorted(range(n), key=lambda i: priority.get(logical[i] or "", 2))
        for i in order:
            name = logical[i]
            axis = self.rules.get(name) if name else None
            dim = shape[i] if shape is not None else None
            if fits(axis, dim):
                out[i] = axis
                used.update(self._axis_names(axis))
        for i, name in enumerate(logical):
            if out[i] is not None or not name:
                continue
            axis = self.fallbacks.get(name)
            dim = shape[i] if shape is not None else None
            if fits(axis, dim):
                out[i] = axis
                used.update(self._axis_names(axis))
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]], shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


_state = threading.local()


def set_rules(rules: Optional[MeshRules]) -> None:
    _state.rules = rules


def current_rules() -> Optional[MeshRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = current_rules()
    set_rules(rules)
    try:
        yield rules
    finally:
        set_rules(prev)


def logical_to_spec(logical: Sequence[Optional[str]], shape=None) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec(logical, shape)


def shard_act(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
