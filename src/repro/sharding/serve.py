"""ServeMesh: the serve stack's (data, model) mesh context.

The sharded decode hot path partitions WHERE bytes live and stream, never
WHAT arithmetic runs — greedy tokens must stay byte-identical between the
1×1 mesh and any (data, model) mesh at both wbits 16 and 8 (the PR-5/6
invariant extended). Three rules make that hold by construction:

  * **Selection is replicated, storage and I/O are sharded.** Chunk
    selection must produce the same masks on every shard, so importance
    vectors are replicated (``replicate`` — an explicit
    ``with_sharding_constraint`` to ``P()``) BEFORE any cross-batch
    reduction; an unconstrained mean over a data-sharded batch would let
    GSPMD reassociate the sum and change low bits.
  * **Only decode-streamed leaves shard over ``model``.** At wbits=8 the
    ``_q8``/``_sc`` chunk leaves shard; at wbits=16 a ``<name>_dec`` fp
    copy is created and sharded while the original stays replicated —
    prefill / frame-append matmuls over a row-sharded weight would
    psum-partial the contraction and perturb the KV cache. The decode
    path's ``blocked_masked_matmul`` is immune: its f32 accumulation is an
    explicit sequential ``fori_loop`` over 8-row blocks, which GSPMD
    gathers and sums in the written order (this gather IS the all-reduce
    at the SwiGLU down-projection boundary).
  * **Row slices own whole quantization blocks.** Row-sharded matrices
    (``wo``/``w_down``/``w_proj`` — the streamed dim of the ``attn_out``
    and ``ffn`` sites) require rows % (model × QUANT_BLOCK_ROWS) == 0 so
    each shard's slice is a whole number of 8-row scale blocks and the
    per-shard block tables / byte counters align with storage.

Weight specs are derived through ``MeshRules`` from the same logical axes
the ParamDefs declare (heads/kv_heads/ffn → 'model'; embed replicated), so
the serve mesh can never drift from the training-side sharding vocabulary.

Serve slots partition over ``data``: the batch dim of activations, tokens
and the KV cache shards over the data axis (validated divisible), so
``--streams`` scales with ``data`` × the per-shard slot count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.quantize import (
    DECODE_COPY_SUFFIX,
    QUANT_BLOCK_ROWS,
    QUANT_SUFFIX_PAYLOAD,
    QUANT_SUFFIX_SCALE,
)
from .specs import MeshRules

MESH_AXES = ("data", "model")

# logical axes of the offloaded per-layer matrices (mirrors the ParamDefs in
# models/attention.py and models/mlp.py; the leading dim is the stacked layer
# axis). MeshRules maps heads/kv_heads/ffn → 'model' and embed → replicated,
# so matrices whose STREAMED row dim carries a model-mapped axis shard by
# rows (wo, w_down, w_proj) and the rest shard by output columns.
DECODE_WEIGHT_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "wq": ("layer", "embed", "heads"),
    "wk": ("layer", "embed", "kv_heads"),
    "wv": ("layer", "embed", "kv_heads"),
    "wo": ("layer", "heads", "embed"),
    "w_gate": ("layer", "embed", "ffn"),
    "w_up": ("layer", "embed", "ffn"),
    "w_fc": ("layer", "embed", "ffn"),
    "w_down": ("layer", "ffn", "embed"),
    "w_proj": ("layer", "ffn", "embed"),
}

# weights whose ROW (streamed) dim shards over 'model' — these carry the
# per-shard block tables and the data-dependent per-shard miss counters of
# their sites ('attn_out' streams wo rows, 'ffn' streams w_down/w_proj rows)
ROW_SHARDED_WEIGHTS = ("wo", "w_down", "w_proj")


def validate_serve_mesh(data: int, model: int, *, batch: int = 0,
                        streams: int = 0, d_ff: int = 0,
                        n_devices: Optional[int] = None) -> None:
    """The sharded serve path's static preconditions, with actionable
    messages — ``launch.serve`` calls this at parse time so a bad ``--mesh``
    fails before any model is built. Zero-valued optional dims skip their
    check (callers validate what they know)."""
    if data < 1 or model < 1:
        raise ValueError(
            f"--mesh axes must be >= 1, got data={data} model={model}"
        )
    if n_devices is not None and data * model > n_devices:
        raise ValueError(
            f"--mesh {data},{model} needs {data * model} devices but only "
            f"{n_devices} are visible; shrink the mesh or launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data * model} "
            "(host-device simulation)"
        )
    if batch and batch % data != 0:
        raise ValueError(
            f"--batch {batch} must be divisible by the mesh data axis "
            f"({data}) — each data shard serves batch/data slot rows; use "
            f"--batch {((batch + data - 1) // data) * data} or shrink data"
        )
    if streams and streams % data != 0:
        raise ValueError(
            f"--streams {streams} must be divisible by the mesh data axis "
            f"({data}) so every data shard serves the same number of "
            f"streams; use --streams {((streams + data - 1) // data) * data} "
            "or shrink data"
        )
    if d_ff and d_ff % (model * QUANT_BLOCK_ROWS) != 0:
        raise ValueError(
            f"ffn rows ({d_ff}) must be divisible by model × the "
            f"{QUANT_BLOCK_ROWS}-row quant block ({model} × "
            f"{QUANT_BLOCK_ROWS} = {model * QUANT_BLOCK_ROWS}) so each "
            "model shard owns whole quantization blocks of w_down; pick a "
            "mesh whose model axis divides d_ff/8"
        )


@dataclasses.dataclass(frozen=True, eq=False)
class ServeMesh:
    """The serve engine's mesh context. ``mesh is None`` ⇔ the unsharded
    1×1 path: every method degrades to a no-op, so single-device code pays
    nothing and the engine never branches on device count."""

    data: int
    model: int
    mesh: Optional[Mesh] = None
    rules: Optional[MeshRules] = None

    @staticmethod
    def single() -> "ServeMesh":
        return ServeMesh(1, 1, None, None)

    @staticmethod
    def create(data: int = 1, model: int = 1) -> "ServeMesh":
        validate_serve_mesh(data, model, n_devices=len(jax.devices()))
        if data * model == 1:
            return ServeMesh.single()
        mesh = jax.make_mesh((data, model), MESH_AXES)
        return ServeMesh(data, model, mesh, MeshRules.for_mesh(mesh))

    @staticmethod
    def from_spec(spec: str) -> "ServeMesh":
        """Parse a ``--mesh data,model`` string (e.g. "2,2")."""
        parts = spec.split(",")
        if len(parts) != 2:
            raise ValueError(
                f"--mesh must be 'data,model' (e.g. 2,2), got {spec!r}"
            )
        try:
            data, model = (int(p) for p in parts)
        except ValueError:
            raise ValueError(
                f"--mesh axes must be integers, got {spec!r}"
            ) from None
        return ServeMesh.create(data, model)

    @property
    def is_sharded(self) -> bool:
        return self.mesh is not None

    @property
    def size(self) -> int:
        return self.data * self.model

    # -- placement helpers ---------------------------------------------------
    def _sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def replicate(self, x: jax.Array) -> jax.Array:
        """Constrain an in-jit value to full replication. THE bitwise
        linchpin: applied to activations before any cross-batch reduction
        (importance recording), so the reduction's operand layout — hence
        its f32 summation order — is independent of the mesh shape."""
        if not self.is_sharded:
            return x
        return jax.lax.with_sharding_constraint(x, self._sharding(P()))

    def put_replicated(self, tree: Any) -> Any:
        if not self.is_sharded:
            return tree
        s = self._sharding(P())
        return jax.tree.map(lambda x: jax.device_put(x, s), tree)

    def batch_spec(self, shape: Tuple[int, ...], axis: int = 0) -> P:
        spec: list = [None] * len(shape)
        if self.is_sharded and shape[axis] % self.data == 0:
            spec[axis] = "data"
        return P(*spec)

    def put_batch(self, x: jax.Array, axis: int = 0) -> jax.Array:
        """Commit an array to the mesh sharded over ``data`` on its batch
        dim (replicated when indivisible) — serve slots partition over the
        data axis."""
        if not self.is_sharded:
            return x
        return jax.device_put(x, self._sharding(self.batch_spec(x.shape, axis)))

    def place_cache(self, cache: Any, axes: Any) -> Any:
        """Commit a KV/state cache to the mesh: the 'batch' logical dim
        shards over ``data`` (slot rows are per-data-shard), as does the
        paged pools' 'kv_page' dim (pages partition over data shards the
        same way the slot rows that own them do); everything else
        replicates. ``axes`` is the model's ``cache_axes()`` /
        ``paged_cache_axes()`` pytree (dicts / tuples mirroring the cache
        structure; leaves are logical-axis tuples)."""
        if not self.is_sharded:
            return cache

        def rec(c, a):
            if isinstance(c, dict):
                return {
                    k: rec(v, a.get(k) if isinstance(a, dict) else None)
                    for k, v in c.items()
                }
            if isinstance(c, (tuple, list)) and not hasattr(c, "shape"):
                sub = a if isinstance(a, (tuple, list)) else (None,) * len(c)
                return type(c)(rec(v, sa) for v, sa in zip(c, sub))
            if not hasattr(c, "shape"):
                return c
            names = tuple(a) if isinstance(a, (tuple, list)) else ()
            spec = [None] * c.ndim
            for i, name in enumerate(names[: c.ndim]):
                if name in ("batch", "kv_page") and c.shape[i] % self.data == 0:
                    spec[i] = "data"
            return jax.device_put(c, self._sharding(P(*spec)))

        return rec(cache, axes)

    # -- decode-weight sharding ----------------------------------------------
    def weight_spec(self, name: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec of one stacked (L, N, D) decode-streamed leaf,
        derived through MeshRules from the matrix's declared logical axes —
        with the extra serve-side constraint that a ROW-sharded slice must
        be a whole number of QUANT_BLOCK_ROWS blocks (per-shard chunk
        tables and scale lanes align with storage). Returns ``P()``
        (replicate) for unknown names or indivisible dims."""
        axes = DECODE_WEIGHT_AXES.get(name)
        if axes is None or self.rules is None:
            return P()
        spec = self.rules.spec(axes, shape)
        if name in ROW_SHARDED_WEIGHTS:
            if shape[1] % (self.model * QUANT_BLOCK_ROWS) != 0:
                return P()
        return spec

    def scale_spec(self, weight_spec: P) -> P:
        """Spec of a weight's (L, N // QUANT_BLOCK_ROWS) per-block scale
        lane: rows shard with the payload's row dim (whole blocks per shard
        by the weight_spec constraint), otherwise replicated."""
        parts = tuple(weight_spec)
        if len(parts) >= 2 and parts[1] is not None:
            return P(None, parts[1])
        return P()

    def place_params(self, params: Dict[str, Any], wbits: int,
                     sparse_names: Tuple[str, ...]) -> Dict[str, Any]:
        """Commit a model's params to the mesh. Decode-streamed leaves of
        the stacked layer dict shard over ``model`` (the ``_q8``/``_sc``
        chunk leaves at wbits=8; freshly created ``<name>_dec`` fp copies
        at wbits=16 — see module docstring for why the originals stay
        replicated); every other leaf replicates. No-op when unsharded."""
        if not self.is_sharded:
            return params
        rep = self._sharding(P())
        layers = dict(params["layers"])
        placed: Dict[str, jax.Array] = {}
        for name in sparse_names:
            if name not in layers:
                continue
            if wbits == 8:
                qn = name + QUANT_SUFFIX_PAYLOAD
                sn = name + QUANT_SUFFIX_SCALE
                if qn not in layers:
                    continue
                wspec = self.weight_spec(name, layers[qn].shape)
                placed[qn] = jax.device_put(layers[qn], self._sharding(wspec))
                placed[sn] = jax.device_put(
                    layers[sn], self._sharding(self.scale_spec(wspec))
                )
            else:
                wspec = self.weight_spec(name, layers[name].shape)
                if tuple(wspec):  # only materialize a copy that shards
                    placed[name + DECODE_COPY_SUFFIX] = jax.device_put(
                        layers[name], self._sharding(wspec)
                    )
        new_layers = {
            k: placed.get(k, None) if k in placed else jax.device_put(v, rep)
            for k, v in layers.items()
        }
        new_layers.update(placed)
        return {
            k: (new_layers if k == "layers"
                else jax.tree.map(lambda x: jax.device_put(x, rep), v))
            for k, v in params.items()
        }

    # -- per-shard accounting geometry ---------------------------------------
    def row_shard_count(self, n_rows: int) -> int:
        """How many model-axis row slices an ``n_rows``-row site splits
        into: ``model`` when each slice is whole quantization blocks, else
        1 (the site replicates and its bytes split evenly instead)."""
        if not self.is_sharded:
            return 1
        if n_rows % (self.model * QUANT_BLOCK_ROWS) != 0:
            return 1
        return self.model


def shard_block_tables(starts, sizes, n_rows: int, n_shards: int):
    """Intersect a site's block-aligned chunk table with each model shard's
    contiguous row range ``[s·n_rows/n_shards, (s+1)·n_rows/n_shards)``.

    Returns per-shard (starts, sizes) of shape (n_shards, K) — same padded
    K as the global table, entries outside a shard's range clipped to size
    0 (the DMA kernels already skip zero-size chunks). Invariants (pinned
    by tests/test_sharded_serving.py): per-shard sizes sum to the global
    sum (the ranges partition the rows), every surviving chunk lies inside
    its shard's range, and chunk starts stay QUANT_BLOCK_ROWS-aligned
    because the range boundaries are (n_rows divisible by
    n_shards × QUANT_BLOCK_ROWS by construction). Works on jnp or np
    arrays; jit-safe."""
    import jax.numpy as jnp

    if n_rows % (n_shards * QUANT_BLOCK_ROWS) != 0:
        raise ValueError(
            f"n_rows={n_rows} must divide into {n_shards} shards of whole "
            f"{QUANT_BLOCK_ROWS}-row blocks"
        )
    seg = n_rows // n_shards
    lo = jnp.arange(n_shards)[:, None] * seg  # (S, 1)
    hi = lo + seg
    s = jnp.asarray(starts)[None, :]  # (1, K)
    e = s + jnp.asarray(sizes)[None, :]
    cs = jnp.clip(s, lo, hi)
    ce = jnp.clip(e, lo, hi)
    return cs.astype(jnp.int32), jnp.maximum(ce - cs, 0).astype(jnp.int32)
