from .serve import (
    DECODE_WEIGHT_AXES,
    ROW_SHARDED_WEIGHTS,
    ServeMesh,
    shard_block_tables,
    validate_serve_mesh,
)
from .specs import (
    MeshRules,
    current_rules,
    logical_to_spec,
    set_rules,
    shard_act,
    use_rules,
)
