from .specs import (
    MeshRules,
    current_rules,
    logical_to_spec,
    set_rules,
    shard_act,
    use_rules,
)
