"""Decode execution backends: the selection → plan → kernel chain's last hop.

Up to PR 4 the DMA gather kernels (chunk_gather_dma.py) were dispatched
standalone and parity-tested while the decode hot path computed through the
dense-weights-masked reference — the kernels never executed a served token.
``ExecutionBackend`` closes that gap: the planned decode path
(models/transformer.py ``block_decode`` with a chunk-plan carry) routes its
sparse projections through one of two implementations selected by
``ServeEngine(backend=...)`` / ``launch.serve --backend``:

  * ``reference`` (default) — pure-jnp masked matmuls, restructured as the
    kernel's **schedule twin**: f32 accumulation over ``block_rows``-sized
    row blocks in ascending order, the exact arithmetic the DMA kernel's
    slot-rotation loop performs (interpret mode executes the same jnp ops
    per block). Blocks outside the chunk tables contribute exact zeros
    (the input is pre-masked), so skipping them — as the kernel does — or
    adding them changes nothing. Result: the two backends are **bitwise
    identical**, and byte-identical decode tokens become the system's
    strongest correctness invariant (tests/test_backend.py pins it).
  * ``kernel`` — the PR-4 Pallas kernels consume the decode plan's
    ``kstarts``/``ksizes``/``mlp_kernel_plan`` lanes directly:
    ``chunk_gather_mlp_dma`` replaces the masked dense SwiGLU (ONE dispatch
    for gate/up/down, SwiGLU intermediate resident in VMEM) and
    ``chunk_gather_matmul_dma`` serves the single-site projections (q/k/v
    off the ``hidden_attn`` site, attn_out's ``wo``, both matrices of the
    non-gated gelu MLP — the full decode hot path). Interpret mode in
    CI / on CPU, compiled on real TPU (``interpret=None`` auto).

Both implementations compute the SAME masked-matmul semantics of paper
App. B.2 — the backend only changes how the arithmetic is realized, never
which neurons participate, so every future perf PR lands behind this
switch with byte-identity as its acceptance gate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .chunk_gather_dma import chunk_gather_matmul_dma, chunk_gather_mlp_dma

BACKENDS = ("reference", "kernel")


def validate_backend(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown execution backend {name!r}; expected one of {BACKENDS}"
        )
    return name


def pick_tile(dim: int, cap: int = 128) -> int:
    """Largest power-of-two tile ≤ ``cap`` dividing ``dim`` — the kernels
    require output dims to split evenly into tiles (reduced-config d_ff
    values like 704 need 64-wide tiles; full-size dims take the 128 MXU
    lane width)."""
    t = cap
    while t >= 8:
        if dim % t == 0:
            return t
        t //= 2
    raise ValueError(
        f"dim {dim} has no power-of-two tile divisor >= 8 — the kernel "
        "backend needs dims divisible by 8"
    )


def blocked_masked_matmul(
    xm: jnp.ndarray,  # (B, N) pre-masked input, any float dtype
    w: jnp.ndarray,  # (N, D); int8 payload when scales is given
    block_rows: int = 8,
    scales: jnp.ndarray | None = None,  # (N // block_rows,) f32 per-block
) -> jnp.ndarray:
    """The DMA gather kernel's schedule twin: y = Σ_blocks xm_blk @ w_blk in
    ascending ``block_rows`` blocks, f32 accumulation — per output element
    the identical multiply/add sequence the kernel's fori_loop performs, so
    the result is bitwise equal to interpret-mode ``chunk_gather_matmul_dma``
    on any chunk table covering the mask (uncovered blocks see zeroed xm
    rows and contribute exact +0.0).

    The per-block partial products are independent, so they run as ONE
    batched einsum (each (B, block_rows) · (block_rows, D) contraction is
    elementwise identical to the kernel's per-step dot); only the f32
    additions — the order-sensitive part — stay sequential. That keeps the
    decode hot path one fused matmul + nb cheap adds instead of nb
    serialized dots (bitwise equality across both forms and the kernel is
    pinned by tests/test_backend.py).

    With ``scales`` (the quantized chunk format): ``w`` is the int8 payload
    and each block is dequantized ``q.astype(f32) * scale`` before the
    identical contraction — elementwise the same multiply the kernel's
    in-VMEM dequant performs, keeping the twins bitwise equal at 8 bits."""
    b, n = xm.shape
    if n % block_rows:
        raise ValueError(f"N={n} must be a multiple of block_rows={block_rows}")
    nb = n // block_rows
    xb = xm.astype(jnp.float32).reshape(b, nb, block_rows)
    wb = w.astype(jnp.float32).reshape(nb, block_rows, w.shape[1])
    if scales is not None:
        wb = wb * scales.astype(jnp.float32)[:, None, None]
    parts = jnp.einsum("bkr,krd->kbd", xb, wb,
                       preferred_element_type=jnp.float32)

    def body(k, acc):
        return acc + parts[k]

    return jax.lax.fori_loop(
        0, nb, body, jnp.zeros((b, w.shape[1]), jnp.float32)
    )


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class ExecutionBackend:
    """Dispatch object carried by ``SparseExecution`` into the model blocks.

    ``interpret``: None = auto (interpret off-TPU, compiled on TPU) —
    resolved at construction so the jit caches stay stable.
    ``prefetch_depth``: the DMA kernels' VMEM slot count − 1; numerics are
    depth-invariant (the schedule only re-times the same fetches), so
    tokens stay byte-identical at every depth.

    ``mesh`` (sharded serving, sharding/serve.py): when set, every weight
    / scales operand is constrained to FULL REPLICATION at the compute
    boundary — the explicit all-gather of each model shard's slice that
    realizes the SwiGLU down-projection all-reduce as gather-then-ordered-
    sum. Storage and I/O stay sharded (the leaves live model-partitioned
    in device memory and the plan's per-shard byte lanes price each
    shard's slice); only the fold's operands are gathered, so the f32
    accumulation runs in the exact single-device block order and decode
    tokens are byte-identical to the 1×1 mesh BY CONSTRUCTION. Without
    the constraint GSPMD is free to partition the contraction over the
    sharded rows and reassociate the partial sums — measurably not
    bitwise-stable. Also pins the kernel path's operand layout (pallas
    calls need replicated operands on host meshes anyway).
    """

    name: str = "reference"
    prefetch_depth: int = 1
    interpret: bool = True
    block_rows: int = 8
    max_chunk_rows: int = 512
    tile_cap: int = 128
    mesh: Optional[Mesh] = None

    @staticmethod
    def create(
        name: str = "reference",
        prefetch_depth: int = 1,
        interpret: Optional[bool] = None,
        block_rows: int = 8,
        max_chunk_rows: int = 512,
        tile_cap: int = 128,
        mesh: Optional[Mesh] = None,
    ) -> "ExecutionBackend":
        validate_backend(name)
        if prefetch_depth < 0:
            raise ValueError(f"prefetch_depth must be >= 0, got {prefetch_depth}")
        return ExecutionBackend(
            name=name,
            prefetch_depth=prefetch_depth,
            interpret=not _on_tpu() if interpret is None else interpret,
            block_rows=block_rows,
            max_chunk_rows=max_chunk_rows,
            tile_cap=tile_cap,
            mesh=mesh,
        )

    @property
    def is_kernel(self) -> bool:
        return self.name == "kernel"

    def _gather(self, w: Optional[jnp.ndarray]) -> Optional[jnp.ndarray]:
        """All-gather a (possibly model-sharded) weight/scales operand to
        full replication at the compute boundary — see the class docstring.
        No-op without a serve mesh."""
        if self.mesh is None or w is None:
            return w
        return jax.lax.with_sharding_constraint(
            w, NamedSharding(self.mesh, PartitionSpec())
        )

    # -- single-site projection (attn_out wo; gelu MLP fc/proj) -------------
    def project(
        self,
        w: jnp.ndarray,  # (N, D); int8 payload when scales is given
        x: jnp.ndarray,  # (B, N)
        mask: jnp.ndarray,  # (N,) exact selected-row mask (float or bool)
        starts: jnp.ndarray,  # (K,) block-aligned chunk table (kernel lane)
        sizes: jnp.ndarray,  # (K,)
        scales: jnp.ndarray | None = None,  # (N // block_rows,) f32
        checksums: jnp.ndarray | None = None,  # (N // block_rows,) u32
    ) -> jnp.ndarray:
        """y (B, D) f32 = (x · mask) @ w. The input is pre-masked by the
        EXACT mask for both backends, so the kernel's outward block rounding
        gathers only zeroed extra rows — masked-matmul semantics hold and
        the two implementations agree bitwise. With ``scales`` (8-bit chunk
        storage) both backends dequantize per block before the identical
        f32 contraction, preserving the bitwise twin property. With
        ``checksums`` the kernel path fetches each block's integrity word
        through a third DMA lane (verification happens at the selection
        boundary); the reference path — whose operands never leave device
        memory — ignores it. Output is bit-identical either way."""
        xm = (x * mask.astype(x.dtype)).astype(jnp.float32)
        w, scales = self._gather(w), self._gather(scales)
        if self.is_kernel:
            return chunk_gather_matmul_dma(
                w, xm, starts, sizes, scales, self._gather(checksums),
                block_rows=self.block_rows,
                tile_d=pick_tile(w.shape[1], self.tile_cap),
                max_chunk_rows=self.max_chunk_rows,
                prefetch_depth=self.prefetch_depth,
                interpret=self.interpret,
            )
        return blocked_masked_matmul(xm, w, self.block_rows, scales)

    # -- fused multi-site SwiGLU MLP -----------------------------------------
    def swiglu_mlp(
        self,
        w_gate: jnp.ndarray,  # (N, F)
        w_up: jnp.ndarray,  # (N, F)
        w_down: jnp.ndarray,  # (F, D)
        x: jnp.ndarray,  # (B, N)
        hidden_mask: jnp.ndarray,  # (N,) exact hidden_mlp-site mask
        ffn_mask: jnp.ndarray,  # (F,) exact ffn-site mask
        starts: jnp.ndarray,  # (2, K) plan lanes: hidden_mlp, ffn
        sizes: jnp.ndarray,  # (2, K)
        scales: Optional[Tuple] = None,  # (sg, su, sd) per-block f32 lanes
        checksums: Optional[Tuple] = None,  # (cg, cu, cd) per-block u32 lanes
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (y (B, D) f32, h (B, F) f32) where h is the UNMASKED
        SwiGLU intermediate swish(xm @ w_gate) * (xm @ w_up) — the decode
        path records |h| as the next refresh's ffn-lane importance, so it
        must be the pre-mask value on both backends. ``scales`` switches
        all three weights to the quantized chunk format (int8 payloads +
        per-block scale lanes), dequantized identically on both backends.
        ``checksums`` adds the kernel path's per-block integrity-word DMA
        lanes (fetch-only; see ``project``) — bit-identical either way."""
        xm = (x * hidden_mask.astype(x.dtype)).astype(jnp.float32)
        fm = ffn_mask.astype(jnp.float32)
        w_gate, w_up, w_down = (
            self._gather(w_gate), self._gather(w_up), self._gather(w_down)
        )
        if scales is not None:
            scales = tuple(self._gather(s) for s in scales)
        if checksums is not None:
            checksums = tuple(self._gather(c) for c in checksums)
        if self.is_kernel:
            return chunk_gather_mlp_dma(
                w_gate, w_up, w_down, xm, starts, sizes, fm, scales, checksums,
                block_rows=self.block_rows,
                tile_f=pick_tile(w_gate.shape[1], self.tile_cap),
                tile_d=pick_tile(w_down.shape[1], self.tile_cap),
                max_chunk_rows=self.max_chunk_rows,
                prefetch_depth=self.prefetch_depth,
                interpret=self.interpret,
                return_h=True,
            )
        sg, su, sd = scales if scales is not None else (None, None, None)
        g = blocked_masked_matmul(xm, w_gate, self.block_rows, sg)
        u = blocked_masked_matmul(xm, w_up, self.block_rows, su)
        # the kernel's literal sigmoid expression (jax.nn.sigmoid lowers to
        # a different, numerically-stable formulation — bitwise matters here)
        h = g * (1.0 / (1.0 + jnp.exp(-g))) * u
        y = blocked_masked_matmul(h * fm[None, :], w_down, self.block_rows, sd)
        return y, h
