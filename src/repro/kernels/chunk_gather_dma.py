"""Explicitly double-buffered DMA chunk-gather kernels.

The BlockSpec-driven kernels (chunk_gather_matmul.py / chunk_gather_swiglu.py)
let the Pallas pipeline emitter fetch one HBM block per grid step — which the
compiler overlaps, but only one block deep and only in the schedule it picks.
These kernels drive the fetches themselves with ``pltpu.make_async_copy``:
``prefetch_depth + 1`` VMEM slots per streamed operand rotate through a
classic in-kernel pipeline —

    warm-up:  start copies for steps 0 .. depth-1
    step k:   start copy k+depth  →  wait copy k  →  MXU on slot k % (depth+1)

so chunk-block k+1's HBM→VMEM transfer is in flight while the MXU contracts
block k (depth 1 = double buffering; depth 0 degenerates to fetch-then-compute
serial, the baseline the overlap is benchmarked against). This is the kernel
realization of the host-side prefetch timeline in core/pipeline.py: the same
``prefetch_depth`` knob, the same hidden-fetch discipline, so the model and
the kernel agree on what is hidden.

Two entry points:

  * ``chunk_gather_matmul_dma`` — drop-in for ``chunk_gather_matmul``: one
    weight matrix, one chunk table, same alignment contract
    (starts/sizes multiples of ``block_rows``, size 0 = padded entry).
  * ``chunk_gather_mlp_dma`` — the **fused multi-site** path: ONE
    ``pallas_call`` gathers gate, up AND down off the two MLP lanes of a
    ``BatchedChunkSelector`` ``(n_sites, K)`` plan. A hidden-lane chunk
    block is fetched once and contracted against both W_gate and W_up
    while resident, and the SwiGLU intermediate h stays in VMEM for the
    down-lane gather — no per-site re-dispatch, no h HBM round-trip.

Interpret-mode note: this container is CPU-only; ``interpret=True`` executes
the same slot rotation (make_async_copy is emulated as a synchronous copy),
which validates the schedule's *numerics* — padded steps fetch nothing,
rotation never overwrites a live slot — while the overlap itself only exists
on real TPU hardware.

``masks_to_block_tables`` is the jit-safe bridge from the batched selector's
``(n_sites, N)`` masks straight to these kernels' padded chunk tables (block
alignment + max_chunk_rows splitting), replacing the host-side per-site
numpy re-splitting of ``plan_to_kernel_table``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# JAX renamed TPUCompilerParams -> CompilerParams (jax>=0.5); support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
_ANY = pltpu.TPUMemorySpace.ANY


# ---------------------------------------------------------------------------
# jit-safe mask -> block-aligned chunk table (the batched-plan bridge)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_rows", "max_chunk_rows"))
def masks_to_block_tables(
    masks: jnp.ndarray,  # (S, N) bool selection masks (selection row order)
    block_rows: int = 8,
    max_chunk_rows: int = 512,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched selection masks → padded kernel chunk tables, inside jit.

    Semantics match the numpy path ``plan_to_kernel_table`` exactly: each
    mask is rounded outward to the ``block_rows`` grid (any selected row
    claims its whole block — runs that merge after rounding merge here too),
    then maximal block runs are split at ``max_chunk_rows`` so every entry
    fits the kernel grid. Returns (starts, sizes) of shape (S, K) with
    K = ceil(N / block_rows) (the worst case: every block its own chunk);
    entries are row units, multiples of ``block_rows``, size 0 = padding.
    """
    if masks.ndim != 2:
        raise ValueError(f"masks must be (n_sites, N), got {masks.shape}")
    if max_chunk_rows % block_rows:
        raise ValueError("max_chunk_rows must be a multiple of block_rows")
    n = masks.shape[1]
    nb = -(-n // block_rows)  # ceil: tail partial block participates
    pad = nb * block_rows - n
    masks = jnp.pad(masks.astype(bool), ((0, 0), (0, pad)))
    maxb = max_chunk_rows // block_rows

    def one(mask):
        bm = mask.reshape(nb, block_rows).any(axis=1)
        idx = jnp.arange(nb, dtype=jnp.int32)
        prev = jnp.concatenate([jnp.zeros((1,), bool), bm[:-1]])
        run_start = bm & ~prev
        # index of the enclosing run's first block (cumulative max of starts)
        start_idx = jax.lax.associative_scan(
            jnp.maximum, jnp.where(run_start, idx, -1)
        )
        pos = idx - start_idx  # block position within the run (where bm)
        chunk_start = bm & (pos % maxb == 0)
        cid = jnp.cumsum(chunk_start.astype(jnp.int32)) - 1
        dump = jnp.where(bm, cid, nb)  # pad blocks scatter to a dropped slot
        sizes_b = (
            jnp.zeros((nb + 1,), jnp.int32).at[dump].add(bm.astype(jnp.int32))[:nb]
        )
        starts_b = (
            jnp.zeros((nb + 1,), jnp.int32)
            .at[jnp.where(chunk_start, cid, nb)]
            .max(idx)[:nb]
        )
        return starts_b * block_rows, sizes_b * block_rows

    starts, sizes = jax.vmap(one)(masks)
    return starts, sizes


# ---------------------------------------------------------------------------
# the slot-rotation pipeline (shared by both kernels)
# ---------------------------------------------------------------------------


def _chunk_step_offset(starts_ref, sizes_ref, step, blocks_per_chunk, block_rows,
                       lane=None):
    """Flat (chunk, block) step → (row offset, active). Padded chunks
    (size 0) and blocks past a chunk's size are inactive: no DMA is issued
    for them and their slot is simply skipped by the rotation."""
    ci = step // blocks_per_chunk
    bk = step - ci * blocks_per_chunk
    if lane is None:
        start, size = starts_ref[ci], sizes_ref[ci]
    else:
        start, size = starts_ref[lane, ci], sizes_ref[lane, ci]
    return start + bk * block_rows, bk * block_rows < size


def _pipelined_steps(total, n_slots, start_copy, wait_and_compute):
    """Run the slot-rotation schedule: start copies ``n_slots - 1`` steps
    ahead (prefetch_depth = n_slots - 1), wait + compute in order. With
    n_slots == 1 (depth 0) each step starts its own copy then immediately
    waits on it — the serial baseline schedule."""
    depth = n_slots - 1
    for s in range(depth):  # warm-up (static: depth is a python int)
        if s < total:
            start_copy(jnp.int32(s), s % n_slots)

    def body(step, _):
        nxt = step + depth

        @pl.when(nxt < total)
        def _():
            start_copy(nxt, nxt % n_slots)

        wait_and_compute(step, step % n_slots)
        return _

    jax.lax.fori_loop(0, total, body, None)


# ---------------------------------------------------------------------------
# single-site DMA matmul
# ---------------------------------------------------------------------------


def _matmul_dma_kernel(
    starts_ref,  # scalar prefetch (K,)
    sizes_ref,  # scalar prefetch (K,)
    x_ref,  # (B, N) VMEM
    w_hbm,  # (N, D) ANY/HBM — fetched by explicit DMA only
    *rest,  # [s_hbm,] [c_hbm,] out_ref, wslots, [sslots,] [cslots,]
    #         sems, [sems_s,] [sems_c]
    block_rows: int,
    tile_d: int,
    blocks_per_chunk: int,
    n_slots: int,
    quantized: bool,
    checksummed: bool,
):
    idx = 0
    s_hbm = c_hbm = sslots = cslots = sems_s = sems_c = None
    if quantized:
        s_hbm = rest[idx]
        idx += 1
    if checksummed:
        c_hbm = rest[idx]
        idx += 1
    out_ref, wslots = rest[idx], rest[idx + 1]
    idx += 2
    if quantized:
        sslots = rest[idx]
        idx += 1
    if checksummed:
        cslots = rest[idx]
        idx += 1
    sems = rest[idx]
    idx += 1
    if quantized:
        sems_s = rest[idx]
        idx += 1
    if checksummed:
        sems_c = rest[idx]
    dj = pl.program_id(0)
    k = starts_ref.shape[0]
    total = k * blocks_per_chunk

    def offset(step):
        return _chunk_step_offset(
            starts_ref, sizes_ref, step, blocks_per_chunk, block_rows
        )

    def start_copy(step, slot):
        off, active = offset(step)

        @pl.when(active)
        def _():
            pltpu.make_async_copy(
                w_hbm.at[pl.ds(off, block_rows), pl.ds(dj * tile_d, tile_d)],
                wslots.at[slot],
                sems.at[slot],
            ).start()
            if quantized:
                # the scales lane rides the same slot rotation: one f32
                # per block_rows block, fetched alongside its payload
                pltpu.make_async_copy(
                    s_hbm.at[pl.ds(off // block_rows, 1)],
                    sslots.at[slot],
                    sems_s.at[slot],
                ).start()
            if checksummed:
                # the checksum lane rides the rotation the same way: one
                # uint32 per block, fetched with the block it covers
                pltpu.make_async_copy(
                    c_hbm.at[pl.ds(off // block_rows, 1)],
                    cslots.at[slot],
                    sems_c.at[slot],
                ).start()

    def wait_and_compute(step, slot):
        off, active = offset(step)

        @pl.when(active)
        def _():
            pltpu.make_async_copy(
                w_hbm.at[pl.ds(off, block_rows), pl.ds(dj * tile_d, tile_d)],
                wslots.at[slot],
                sems.at[slot],
            ).wait()
            wb = wslots[slot].astype(jnp.float32)
            if quantized:
                pltpu.make_async_copy(
                    s_hbm.at[pl.ds(off // block_rows, 1)],
                    sslots.at[slot],
                    sems_s.at[slot],
                ).wait()
                # upcast + dequantize in VMEM, accumulate in f32: one
                # multiply per element before the identical dot, so the
                # reference twin's elementwise dequant stays bitwise equal
                wb = wb * sslots[slot][0]
            if checksummed:
                # checksums span full (block_rows, D) storage blocks while
                # this kernel sees (block_rows, tile_d) tiles, so the word
                # is fetched (charging the lane's DMA) but verified at the
                # selection boundary where whole blocks are visible
                pltpu.make_async_copy(
                    c_hbm.at[pl.ds(off // block_rows, 1)],
                    cslots.at[slot],
                    sems_c.at[slot],
                ).wait()
            xb = pl.load(x_ref, (slice(None), pl.ds(off, block_rows)))
            out_ref[...] += jnp.dot(
                xb.astype(jnp.float32),
                wb,
                preferred_element_type=jnp.float32,
            )

    out_ref[...] = jnp.zeros_like(out_ref)
    _pipelined_steps(total, n_slots, start_copy, wait_and_compute)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_rows", "tile_d", "max_chunk_rows", "prefetch_depth", "interpret"
    ),
)
def chunk_gather_matmul_dma(
    w: jnp.ndarray,  # (N, D); int8 payload when scales is given
    x: jnp.ndarray,  # (B, N)
    starts: jnp.ndarray,  # (K,) int32, multiples of block_rows
    sizes: jnp.ndarray,  # (K,) int32, multiples of block_rows (0 = padded)
    scales: jnp.ndarray | None = None,  # (N // block_rows,) f32 per-block
    checksums: jnp.ndarray | None = None,  # (N // block_rows,) u32 per-block
    *,
    block_rows: int = 8,
    tile_d: int = 128,
    max_chunk_rows: int = 512,
    prefetch_depth: int = 1,
    interpret: bool = False,
) -> jnp.ndarray:
    """y (B, D) f32 = Σ_chunks x_chunk @ W_chunk, fetched by an explicitly
    ``prefetch_depth``-deep double-buffered DMA pipeline. Numerically
    identical at every depth (the schedule only re-times the same fetches) —
    matches ``chunk_gather_matmul_ref`` exactly like the BlockSpec kernel.

    With ``scales`` (the quantized chunk format, ``kernels/quantize.py``):
    ``w`` is the int8 payload and each DMA step additionally fetches its
    block's f32 scale through the same slot rotation, dequantizing in VMEM
    (``q.astype(f32) * scale``) before the identical f32 accumulation —
    matching ``blocked_masked_matmul(..., scales=...)`` bitwise.

    With ``checksums`` (``kernels/quantize.block_checksums`` /
    ``core/offload.pack_checksums``): each DMA step additionally fetches
    its block's uint32 checksum through a third lane of the same slot
    rotation, so integrity metadata travels with the payload it covers at
    kernel granularity. The words are fetched and waited on but not
    verified here — a checksum covers the full (block_rows, D) storage
    block while the kernel fetches (block_rows, tile_d) tiles; the honest
    re-verification happens at the selection boundary
    (``serving/sparse_exec.refresh_layer``), identically on both backends.
    Output is bit-identical with and without the lane."""
    n, d = w.shape
    b = x.shape[0]
    if prefetch_depth < 0:
        raise ValueError(f"prefetch_depth must be >= 0, got {prefetch_depth}")
    if d % tile_d:
        raise ValueError(f"D={d} must be a multiple of tile_d={tile_d}")
    if n % block_rows:
        raise ValueError(f"N={n} must be a multiple of block_rows={block_rows}")
    if max_chunk_rows % block_rows:
        raise ValueError("max_chunk_rows must be a multiple of block_rows")
    quantized = scales is not None
    if quantized and scales.shape != (n // block_rows,):
        raise ValueError(
            f"scales must be ({n // block_rows},), got {scales.shape}"
        )
    checksummed = checksums is not None
    if checksummed and checksums.shape != (n // block_rows,):
        raise ValueError(
            f"checksums must be ({n // block_rows},), got {checksums.shape}"
        )
    n_slots = prefetch_depth + 1
    in_specs = [
        pl.BlockSpec((b, n), lambda dj, *_: (0, 0)),  # x resident in VMEM
        pl.BlockSpec(memory_space=_ANY),  # w stays in HBM; DMA'd manually
    ]
    operands = [starts, sizes, x, w]
    slots = [pltpu.VMEM((n_slots, block_rows, tile_d), w.dtype)]
    sem_lanes = [pltpu.SemaphoreType.DMA((n_slots,))]
    if quantized:
        in_specs.append(pl.BlockSpec(memory_space=_ANY))  # scales lane in HBM
        operands.append(scales.astype(jnp.float32))
        slots.append(pltpu.VMEM((n_slots, 1), jnp.float32))  # sslots
        sem_lanes.append(pltpu.SemaphoreType.DMA((n_slots,)))  # sems_s
    if checksummed:
        in_specs.append(pl.BlockSpec(memory_space=_ANY))  # checksum lane
        operands.append(checksums.astype(jnp.uint32))
        slots.append(pltpu.VMEM((n_slots, 1), jnp.uint32))  # cslots
        sem_lanes.append(pltpu.SemaphoreType.DMA((n_slots,)))  # sems_c
    scratch = slots + sem_lanes
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(d // tile_d,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((b, tile_d), lambda dj, *_: (0, dj)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(
            _matmul_dma_kernel,
            block_rows=block_rows,
            tile_d=tile_d,
            blocks_per_chunk=max_chunk_rows // block_rows,
            n_slots=n_slots,
            quantized=quantized,
            checksummed=checksummed,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# fused multi-site MLP (gate/up off the hidden lane, down off the ffn lane)
# ---------------------------------------------------------------------------


def _mlp_dma_kernel(
    starts_ref,  # scalar prefetch (2, K): lane 0 = hidden_mlp, lane 1 = ffn
    sizes_ref,  # scalar prefetch (2, K)
    x_ref,  # (B, N) VMEM
    wg_hbm,  # (N, F) ANY
    wu_hbm,  # (N, F) ANY
    wd_hbm,  # (F, D) ANY
    fmask_ref,  # (1, F) VMEM f32 — exact ffn row mask (all-ones = table only)
    *rest,  # [sg/su/sd_hbm,] [cg/cu/cd_hbm,] out_ref, h?, slots...,
    #         [scale slots,] [ck slots,] sems..., [scale sems,] [ck sems]
    block_rows: int,
    tile_f: int,
    tile_d: int,
    blocks_per_chunk: int,
    n_slots: int,
    n_f_tiles: int,
    n_d_tiles: int,
    quantized: bool,
    checksummed: bool,
):
    idx = 0
    sg_hbm = su_hbm = sd_hbm = gsc = usc = dsc = None
    sems_gs = sems_us = sems_ds = None
    cg_hbm = cu_hbm = cd_hbm = gck = uck = dck = None
    sems_gc = sems_uc = sems_dc = None
    if quantized:
        sg_hbm, su_hbm, sd_hbm = rest[idx:idx + 3]
        idx += 3
    if checksummed:
        cg_hbm, cu_hbm, cd_hbm = rest[idx:idx + 3]
        idx += 3
    out_ref, h_ref, gslots, uslots, dslots = rest[idx:idx + 5]
    idx += 5
    if quantized:
        gsc, usc, dsc = rest[idx:idx + 3]
        idx += 3
    if checksummed:
        gck, uck, dck = rest[idx:idx + 3]
        idx += 3
    acc_g, acc_u, sems_g, sems_u, sems_d = rest[idx:idx + 5]
    idx += 5
    if quantized:
        sems_gs, sems_us, sems_ds = rest[idx:idx + 3]
        idx += 3
    if checksummed:
        sems_gc, sems_uc, sems_dc = rest[idx:idx + 3]
    k = starts_ref.shape[1]
    total = k * blocks_per_chunk

    def offset(lane, step):
        return _chunk_step_offset(
            starts_ref, sizes_ref, step, blocks_per_chunk, block_rows, lane=lane
        )

    # -- phase 1: gate/up over the hidden lane, one f-tile at a time --------
    def gate_up_tile(fj):
        def start_copy(step, slot):
            off, active = offset(0, step)

            @pl.when(active)
            def _():
                # one chunk block, fetched once, feeds BOTH gate and up
                pltpu.make_async_copy(
                    wg_hbm.at[pl.ds(off, block_rows), pl.ds(fj * tile_f, tile_f)],
                    gslots.at[slot],
                    sems_g.at[slot],
                ).start()
                pltpu.make_async_copy(
                    wu_hbm.at[pl.ds(off, block_rows), pl.ds(fj * tile_f, tile_f)],
                    uslots.at[slot],
                    sems_u.at[slot],
                ).start()
                if quantized:
                    bk = off // block_rows
                    pltpu.make_async_copy(
                        sg_hbm.at[pl.ds(bk, 1)], gsc.at[slot], sems_gs.at[slot]
                    ).start()
                    pltpu.make_async_copy(
                        su_hbm.at[pl.ds(bk, 1)], usc.at[slot], sems_us.at[slot]
                    ).start()
                if checksummed:
                    bk = off // block_rows
                    pltpu.make_async_copy(
                        cg_hbm.at[pl.ds(bk, 1)], gck.at[slot], sems_gc.at[slot]
                    ).start()
                    pltpu.make_async_copy(
                        cu_hbm.at[pl.ds(bk, 1)], uck.at[slot], sems_uc.at[slot]
                    ).start()

        def wait_and_compute(step, slot):
            off, active = offset(0, step)

            @pl.when(active)
            def _():
                pltpu.make_async_copy(
                    wg_hbm.at[pl.ds(off, block_rows), pl.ds(fj * tile_f, tile_f)],
                    gslots.at[slot],
                    sems_g.at[slot],
                ).wait()
                pltpu.make_async_copy(
                    wu_hbm.at[pl.ds(off, block_rows), pl.ds(fj * tile_f, tile_f)],
                    uslots.at[slot],
                    sems_u.at[slot],
                ).wait()
                gb = gslots[slot].astype(jnp.float32)
                ub = uslots[slot].astype(jnp.float32)
                if quantized:
                    bk = off // block_rows
                    pltpu.make_async_copy(
                        sg_hbm.at[pl.ds(bk, 1)], gsc.at[slot], sems_gs.at[slot]
                    ).wait()
                    pltpu.make_async_copy(
                        su_hbm.at[pl.ds(bk, 1)], usc.at[slot], sems_us.at[slot]
                    ).wait()
                    gb = gb * gsc[slot][0]
                    ub = ub * usc[slot][0]
                if checksummed:
                    # fetched with the payload, verified at the selection
                    # boundary (see chunk_gather_matmul_dma docstring)
                    bk = off // block_rows
                    pltpu.make_async_copy(
                        cg_hbm.at[pl.ds(bk, 1)], gck.at[slot], sems_gc.at[slot]
                    ).wait()
                    pltpu.make_async_copy(
                        cu_hbm.at[pl.ds(bk, 1)], uck.at[slot], sems_uc.at[slot]
                    ).wait()
                xb = pl.load(x_ref, (slice(None), pl.ds(off, block_rows)))
                xb = xb.astype(jnp.float32)
                acc_g[...] += jnp.dot(xb, gb,
                                      preferred_element_type=jnp.float32)
                acc_u[...] += jnp.dot(xb, ub,
                                      preferred_element_type=jnp.float32)

        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)
        _pipelined_steps(total, n_slots, start_copy, wait_and_compute)
        g = acc_g[...]
        pl.store(
            h_ref,
            (slice(None), pl.ds(fj * tile_f, tile_f)),
            g * (1.0 / (1.0 + jnp.exp(-g))) * acc_u[...],
        )

    def f_body(fj, _):
        gate_up_tile(fj)
        return _

    jax.lax.fori_loop(0, n_f_tiles, f_body, None)

    # -- phase 2: down over the ffn lane, consuming h straight from VMEM ----
    def down_tile(dj):
        def start_copy(step, slot):
            off, active = offset(1, step)

            @pl.when(active)
            def _():
                pltpu.make_async_copy(
                    wd_hbm.at[pl.ds(off, block_rows), pl.ds(dj * tile_d, tile_d)],
                    dslots.at[slot],
                    sems_d.at[slot],
                ).start()
                if quantized:
                    pltpu.make_async_copy(
                        sd_hbm.at[pl.ds(off // block_rows, 1)],
                        dsc.at[slot],
                        sems_ds.at[slot],
                    ).start()
                if checksummed:
                    pltpu.make_async_copy(
                        cd_hbm.at[pl.ds(off // block_rows, 1)],
                        dck.at[slot],
                        sems_dc.at[slot],
                    ).start()

        def wait_and_compute(step, slot):
            off, active = offset(1, step)

            @pl.when(active)
            def _():
                pltpu.make_async_copy(
                    wd_hbm.at[pl.ds(off, block_rows), pl.ds(dj * tile_d, tile_d)],
                    dslots.at[slot],
                    sems_d.at[slot],
                ).wait()
                db = dslots[slot].astype(jnp.float32)
                if quantized:
                    pltpu.make_async_copy(
                        sd_hbm.at[pl.ds(off // block_rows, 1)],
                        dsc.at[slot],
                        sems_ds.at[slot],
                    ).wait()
                    db = db * dsc[slot][0]
                if checksummed:
                    pltpu.make_async_copy(
                        cd_hbm.at[pl.ds(off // block_rows, 1)],
                        dck.at[slot],
                        sems_dc.at[slot],
                    ).wait()
                # the exact ffn mask applies at the gather, NOT to the h
                # output: block-rounding may pull in rows outside the
                # selected mask, and those must contribute zero for the
                # kernel to equal the masked-matmul reference exactly
                hb = pl.load(h_ref, (slice(None), pl.ds(off, block_rows)))
                hb = hb * fmask_ref[0, pl.ds(off, block_rows)]
                cur = pl.load(out_ref, (slice(None), pl.ds(dj * tile_d, tile_d)))
                pl.store(
                    out_ref,
                    (slice(None), pl.ds(dj * tile_d, tile_d)),
                    cur + jnp.dot(hb, db,
                                  preferred_element_type=jnp.float32),
                )

        _pipelined_steps(total, n_slots, start_copy, wait_and_compute)

    out_ref[...] = jnp.zeros_like(out_ref)

    def d_body(dj, _):
        down_tile(dj)
        return _

    jax.lax.fori_loop(0, n_d_tiles, d_body, None)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_rows", "tile_f", "tile_d", "max_chunk_rows", "prefetch_depth",
        "interpret", "return_h",
    ),
)
def chunk_gather_mlp_dma(
    w_gate: jnp.ndarray,  # (N, F); int8 payloads when scales is given
    w_up: jnp.ndarray,  # (N, F)
    w_down: jnp.ndarray,  # (F, D)
    x: jnp.ndarray,  # (B, N)
    starts: jnp.ndarray,  # (2, K): lane 0 = hidden_mlp plan, lane 1 = ffn plan
    sizes: jnp.ndarray,  # (2, K)
    ffn_mask: jnp.ndarray | None = None,  # (F,) exact down-input row mask
    scales: tuple | None = None,  # (sg (N//br,), su (N//br,), sd (F//br,)) f32
    checksums: tuple | None = None,  # (cg (N//br,), cu (N//br,), cd (F//br,)) u32
    *,
    block_rows: int = 8,
    tile_f: int = 128,
    tile_d: int = 128,
    max_chunk_rows: int = 512,
    prefetch_depth: int = 1,
    interpret: bool = False,
    return_h: bool = False,
) -> jnp.ndarray:
    """Fused sparse MLP: y (B, D) f32 = SwiGLU-masked down projection where
    gate/up gather off ``starts[0]`` (the hidden_mlp lane of the batched
    plan) and down gathers off ``starts[1]`` (the ffn lane) — one
    ``pallas_call`` for what the per-site path dispatches as three. Matches
    ``chunk_gather_mlp_ref`` exactly.

    ``ffn_mask`` (optional, (F,)): the exact selected row mask of the down
    projection's input. The block tables round masks outward to the
    ``block_rows`` grid; multiplying the gathered h block by the exact mask
    restores masked-matmul semantics on the over-fetched rows, which is what
    the decode execution backend needs for byte-identical parity with the
    reference path. None keeps pure chunk-table semantics (every gathered
    row contributes), the contract the standalone oracles test.

    ``return_h=True`` additionally returns the **unmasked** SwiGLU
    intermediate h (B, F) f32 — the decode path records its |·| importance
    for the next refresh's ffn-lane selection, so it must see h before the
    mask zeroes the unselected rows. With ``return_h=False`` h stays a VMEM
    scratch buffer that never round-trips HBM (the fused kernel's whole
    point); the kernel body is identical either way because outputs and
    scratch occupy the same positional slot.

    With ``scales = (sg, su, sd)`` the three weights are int8 payloads of
    the quantized chunk format; each lane's DMA step fetches its block's
    f32 scale through the same slot rotation and dequantizes in VMEM
    before the identical f32 accumulation (bitwise equal to the reference
    backend's quantized schedule twin).

    With ``checksums = (cg, cu, cd)`` each lane's DMA step additionally
    fetches its block's uint32 checksum through the rotation —
    fetch-and-wait only, verified at the selection boundary (see
    ``chunk_gather_matmul_dma``); output is bit-identical either way."""
    n, f = w_gate.shape
    fd, d = w_down.shape
    b = x.shape[0]
    if w_up.shape != (n, f):
        raise ValueError("w_gate/w_up shape mismatch")
    if fd != f:
        raise ValueError(f"w_down rows {fd} must equal d_ff {f}")
    if starts.shape[0] != 2 or starts.shape != sizes.shape:
        raise ValueError(
            f"starts/sizes must be (2, K) plan lanes, got {starts.shape}/{sizes.shape}"
        )
    if prefetch_depth < 0:
        raise ValueError(f"prefetch_depth must be >= 0, got {prefetch_depth}")
    if f % tile_f or d % tile_d or n % block_rows or f % block_rows:
        raise ValueError("alignment violation")
    if max_chunk_rows % block_rows:
        raise ValueError("max_chunk_rows must be a multiple of block_rows")
    if ffn_mask is None:
        fmask = jnp.ones((1, f), jnp.float32)
    else:
        if ffn_mask.shape != (f,):
            raise ValueError(f"ffn_mask must be ({f},), got {ffn_mask.shape}")
        fmask = ffn_mask.astype(jnp.float32)[None, :]
    quantized = scales is not None
    if quantized:
        sg, su, sd = scales
        if sg.shape != (n // block_rows,) or su.shape != (n // block_rows,):
            raise ValueError(
                f"gate/up scales must be ({n // block_rows},), "
                f"got {sg.shape}/{su.shape}"
            )
        if sd.shape != (f // block_rows,):
            raise ValueError(
                f"down scales must be ({f // block_rows},), got {sd.shape}"
            )
    checksummed = checksums is not None
    if checksummed:
        cg, cu, cd = checksums
        if cg.shape != (n // block_rows,) or cu.shape != (n // block_rows,):
            raise ValueError(
                f"gate/up checksums must be ({n // block_rows},), "
                f"got {cg.shape}/{cu.shape}"
            )
        if cd.shape != (f // block_rows,):
            raise ValueError(
                f"down checksums must be ({f // block_rows},), got {cd.shape}"
            )
    n_slots = prefetch_depth + 1
    # h (B, F) occupies the same positional kernel-ref slot either way:
    # second OUTPUT when the caller wants it, first SCRATCH when not (so a
    # return_h=False dispatch never writes the intermediate back to HBM)
    vmem = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM)
    out_specs = (vmem, vmem) if return_h else vmem
    out_shape = jax.ShapeDtypeStruct((b, d), jnp.float32)
    if return_h:
        out_shape = (out_shape, jax.ShapeDtypeStruct((b, f), jnp.float32))
    h_scratch = [] if return_h else [pltpu.VMEM((b, f), jnp.float32)]
    in_specs = [
        vmem,  # x
        pl.BlockSpec(memory_space=_ANY),  # w_gate
        pl.BlockSpec(memory_space=_ANY),  # w_up
        pl.BlockSpec(memory_space=_ANY),  # w_down
        vmem,  # ffn mask
    ]
    operands = [starts, sizes, x, w_gate, w_up, w_down, fmask]
    scale_slots, scale_sems = [], []
    if quantized:
        in_specs += [pl.BlockSpec(memory_space=_ANY)] * 3  # scales lanes
        operands += [s.astype(jnp.float32) for s in (sg, su, sd)]
        scale_slots = [pltpu.VMEM((n_slots, 1), jnp.float32)] * 3
        scale_sems = [pltpu.SemaphoreType.DMA((n_slots,))] * 3
    ck_slots, ck_sems = [], []
    if checksummed:
        in_specs += [pl.BlockSpec(memory_space=_ANY)] * 3  # checksum lanes
        operands += [c.astype(jnp.uint32) for c in (cg, cu, cd)]
        ck_slots = [pltpu.VMEM((n_slots, 1), jnp.uint32)] * 3
        ck_sems = [pltpu.SemaphoreType.DMA((n_slots,))] * 3
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=h_scratch + [
            pltpu.VMEM((n_slots, block_rows, tile_f), w_gate.dtype),
            pltpu.VMEM((n_slots, block_rows, tile_f), w_up.dtype),
            pltpu.VMEM((n_slots, block_rows, tile_d), w_down.dtype),
        ] + scale_slots + ck_slots + [
            pltpu.VMEM((b, tile_f), jnp.float32),
            pltpu.VMEM((b, tile_f), jnp.float32),
            pltpu.SemaphoreType.DMA((n_slots,)),
            pltpu.SemaphoreType.DMA((n_slots,)),
            pltpu.SemaphoreType.DMA((n_slots,)),
        ] + scale_sems + ck_sems,
    )
    out = pl.pallas_call(
        functools.partial(
            _mlp_dma_kernel,
            block_rows=block_rows,
            tile_f=tile_f,
            tile_d=tile_d,
            blocks_per_chunk=max_chunk_rows // block_rows,
            n_slots=n_slots,
            n_f_tiles=f // tile_f,
            n_d_tiles=d // tile_d,
            quantized=quantized,
            checksummed=checksummed,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return out
