"""Fused chunk-gathered SwiGLU gate/up kernel.

gate and up projections share the hidden-state chunk plan (paper App. A), so
a fused kernel fetches each (block_rows × tile_f) block of W_gate and W_up
back-to-back while the x block is already resident, and applies SiLU·mul on
the final block step — halving VMEM x-traffic and eliding the intermediate
gate/up HBM round-trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# JAX renamed TPUCompilerParams -> CompilerParams (jax>=0.5); support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(
    starts_ref,
    sizes_ref,
    x_ref,  # (B, block_rows)
    wg_ref,  # (block_rows, tile_f)
    wu_ref,  # (block_rows, tile_f)
    out_ref,  # (B, tile_f) f32
    acc_g,  # scratch (B, tile_f) f32
    acc_u,  # scratch (B, tile_f) f32
    *,
    block_rows: int,
):
    ci = pl.program_id(1)
    bk = pl.program_id(2)
    n_chunks = pl.num_programs(1)
    n_blocks = pl.num_programs(2)

    @pl.when((ci == 0) & (bk == 0))
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    active = bk * block_rows < sizes_ref[ci]

    @pl.when(active)
    def _acc():
        x = x_ref[...].astype(jnp.float32)
        acc_g[...] += jnp.dot(x, wg_ref[...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)
        acc_u[...] += jnp.dot(x, wu_ref[...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

    @pl.when((ci == n_chunks - 1) & (bk == n_blocks - 1))
    def _finish():
        g = acc_g[...]
        out_ref[...] = g * (1.0 / (1.0 + jnp.exp(-g))) * acc_u[...]


@functools.partial(
    jax.jit, static_argnames=("block_rows", "tile_f", "max_chunk_rows", "interpret")
)
def chunk_gather_swiglu(
    w_gate: jnp.ndarray,  # (N, F)
    w_up: jnp.ndarray,  # (N, F)
    x: jnp.ndarray,  # (B, N)
    starts: jnp.ndarray,
    sizes: jnp.ndarray,
    *,
    block_rows: int = 8,
    tile_f: int = 128,
    max_chunk_rows: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n, f = w_gate.shape
    b = x.shape[0]
    k = starts.shape[0]
    if w_up.shape != (n, f):
        raise ValueError("w_gate/w_up shape mismatch")
    if f % tile_f or n % block_rows or max_chunk_rows % block_rows:
        raise ValueError("alignment violation")
    # f-tile outermost: per out tile, accumulate over all (chunk, block) steps
    grid = (f // tile_f, k, max_chunk_rows // block_rows)

    def x_index(fj, ci, bk, starts_ref, sizes_ref):
        return (0, starts_ref[ci] // block_rows + bk)

    def w_index(fj, ci, bk, starts_ref, sizes_ref):
        return (starts_ref[ci] // block_rows + bk, fj)

    def out_index(fj, ci, bk, starts_ref, sizes_ref):
        return (0, fj)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_rows), x_index),
            pl.BlockSpec((block_rows, tile_f), w_index),
            pl.BlockSpec((block_rows, tile_f), w_index),
        ],
        out_specs=pl.BlockSpec((b, tile_f), out_index),
        scratch_shapes=[
            pltpu.VMEM((b, tile_f), jnp.float32),
            pltpu.VMEM((b, tile_f), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(starts, sizes, x, w_gate, w_up)
