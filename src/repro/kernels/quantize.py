"""Per-chunk int8 quantization for offloaded weight rows (PR 6 tentpole).

Offloaded chunks ship at 8 bits with one f32 scale per ``block_rows`` row
block — the same 8-row granularity as the kernel chunk tables, so every
DMA step of the gather kernels covers exactly ONE scale. The storage
format per (N, D) matrix:

  * payload  ``q``  — int8, shape (N, D): symmetric per-block quantization,
    ``q = clip(round(w / scale), -127, 127)``;
  * scales lane ``s`` — float32, shape (N // block_rows,):
    ``scale_b = max|w[b*block_rows:(b+1)*block_rows, :]| / 127``.

A zero-magnitude block gets scale 0 and payload 0 — dequantization then
multiplies 0·0 = 0 exactly (the scale=0 guard: the divide uses
``where(scale > 0, scale, 1)`` so no inf/nan ever enters the payload).

Dequantization is ``q.astype(f32) * scale`` — one multiply per element —
performed *inside* the DMA gather kernels (upcast in VMEM, accumulate in
f32) and, elementwise-identically, by the reference backend's schedule
twin, keeping the two backends bitwise equal at ``--wbits 8``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

INT8_QMAX = 127.0
SCALE_BYTES = 4.0  # one f32 scale per block_rows rows
CHECKSUM_BYTES = 4.0  # one u32 checksum per block_rows rows (PR 9)

# the quantization block granularity — one scale per this many weight rows,
# matching the DMA kernels' chunk-table alignment (KERNEL_BLOCK_ROWS in
# serving/sparse_exec.py is this same constant; the sharded serve path also
# requires model-axis row slices to be multiples of it so every shard owns
# whole quantization blocks)
QUANT_BLOCK_ROWS = 8

# stacked-param leaves produced by quantize_params: "<name>_q8" / "<name>_sc"
QUANT_SUFFIX_PAYLOAD = "_q8"
QUANT_SUFFIX_SCALE = "_sc"

# pack-time integrity lane (PR 9): "<name>_ck" — one uint32 checksum per
# block_rows row block of the STORED payload (the int8 leaf at wbits=8, the
# fp leaf at wbits=16), verified against the fetched bytes at the gather
# boundary by the integrity subsystem (serving/sparse_exec.py)
QUANT_SUFFIX_CHECKSUM = "_ck"

# fp decode-copy leaves created by the sharded serve path at wbits=16
# ("<name>_dec"): a model-axis-sharded copy of the fp original that ONLY the
# planned decode hot path streams — the original stays replicated so prefill
# and frame-append matmuls keep their exact single-device reduction order
DECODE_COPY_SUFFIX = "_dec"


def quantize_rows(
    w: jnp.ndarray, block_rows: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize an (N, D) matrix to (int8 payload, per-block f32 scales).

    N must be a multiple of ``block_rows`` (the kernel backend validates
    this for every sparsifiable site already).
    """
    n, d = w.shape
    if n % block_rows != 0:
        raise ValueError(
            f"rows ({n}) must be a multiple of block_rows ({block_rows})"
        )
    nb = n // block_rows
    blocks = w.astype(jnp.float32).reshape(nb, block_rows, d)
    amax = jnp.max(jnp.abs(blocks), axis=(1, 2))
    scales = amax / INT8_QMAX
    safe = jnp.where(scales > 0, scales, 1.0)  # scale=0 guard
    q = jnp.clip(
        jnp.round(blocks / safe[:, None, None]), -INT8_QMAX, INT8_QMAX
    ).astype(jnp.int8)
    return q.reshape(n, d), scales


def dequantize_rows(
    q: jnp.ndarray, scales: jnp.ndarray, block_rows: int = 8
) -> jnp.ndarray:
    """Inverse of ``quantize_rows``: f32 (N, D), exact elementwise
    ``q * scale`` — the arithmetic both backends perform."""
    n, d = q.shape
    nb = n // block_rows
    blocks = q.astype(jnp.float32).reshape(nb, block_rows, d)
    return (blocks * scales[:, None, None]).reshape(n, d)


def _payload_words(w: jnp.ndarray) -> jnp.ndarray:
    """Reinterpret a payload matrix as uint32 words elementwise (no value
    conversion): int8 → uint8 bytes, 16-bit floats → uint16, f32 → uint32.
    The checksum runs over exactly the bits the DMA lane streams, so any
    bit-level perturbation of the stored payload moves the sum."""
    itemsize = jnp.dtype(w.dtype).itemsize
    if itemsize == 1:
        u = jax.lax.bitcast_convert_type(w, jnp.uint8)
    elif itemsize == 2:
        u = jax.lax.bitcast_convert_type(w, jnp.uint16)
    elif itemsize == 4:
        u = jax.lax.bitcast_convert_type(w, jnp.uint32)
    else:
        raise ValueError(f"unsupported payload dtype {w.dtype}")
    return u.astype(jnp.uint32)


def block_checksums(w: jnp.ndarray, block_rows: int = 8) -> jnp.ndarray:
    """Per-``block_rows``-block payload checksum, (N // block_rows,) uint32.

    Each block's bytes are bitcast to uint32 words and folded as a
    position-weighted sum mod 2^32 with odd weights ``2*pos + 1``. Odd
    weights make every single-element change detectable: flipping element
    ``p`` moves the sum by ``delta * (2p+1)`` with ``0 < |delta| < 2^32``
    and an odd multiplier, which is never 0 mod 2^32. Position weighting
    also catches reorderings within a block (equal-weight sums would not).
    One u32 per block rides the DMA slot rotation next to the PR 6 scales
    lane (kernels/chunk_gather_dma.py)."""
    n, d = w.shape
    if n % block_rows != 0:
        raise ValueError(
            f"rows ({n}) must be a multiple of block_rows ({block_rows})"
        )
    u = _payload_words(w).reshape(n // block_rows, block_rows * d)
    pos = jnp.arange(block_rows * d, dtype=jnp.uint32)
    weights = pos * jnp.uint32(2) + jnp.uint32(1)
    return jnp.sum(u * weights[None, :], axis=1, dtype=jnp.uint32)


def quantize_params(
    layers: Dict[str, jnp.ndarray],
    names: Tuple[str, ...],
    block_rows: int = 8,
    checksums: bool = False,
) -> Dict[str, jnp.ndarray]:
    """Quantize the named stacked (L, N, D) weight leaves of a layer-stack
    param dict; returns the new ``<name>_q8`` / ``<name>_sc`` leaves (with
    the leading L dim preserved, so they ride the decode ``lax.scan``
    unchanged). Missing names are skipped (arch families differ).

    ``checksums=True`` additionally emits the ``<name>_ck`` integrity lane
    (``block_checksums`` over the int8 payload — the exact bytes the DMA
    lane streams at wbits=8). The fp16 pack path's checksum twin lives in
    ``core/offload.py::pack_checksums``."""
    out: Dict[str, jnp.ndarray] = {}
    quant = jax.vmap(lambda w: quantize_rows(w, block_rows))
    ck = jax.vmap(lambda q: block_checksums(q, block_rows))
    for name in names:
        if name not in layers:
            continue
        q, s = quant(layers[name])
        out[name + QUANT_SUFFIX_PAYLOAD] = q
        out[name + QUANT_SUFFIX_SCALE] = s
        if checksums:
            out[name + QUANT_SUFFIX_CHECKSUM] = ck(q)
    return out
