"""Pallas TPU kernel: chunk-gathered sparse matmul.

The TPU-native realization of the paper's contiguous-chunk loads
(DESIGN.md §2): the utility-guided selector emits a chunk table
(starts, sizes); each selected chunk of weight rows becomes a sequence of
contiguous HBM→VMEM block fetches driven by a scalar-prefetched BlockSpec
index_map, and the MXU accumulates x_chunk · W_chunk into the output tile.
Rows NOT in any chunk are never read from HBM — the kernel's HBM traffic is
exactly the chunk plan's byte count, which is what the latency model scores.

Alignment contract (TPU adaptation of the paper's KB-granular chunks):
  starts % block_rows == 0 and sizes % block_rows == 0 (padded entries have
  size 0). The selection layer guarantees this by generating candidates on a
  block_rows grid — analogous to the paper aligning chunk sizes to the SSD's
  saturation granularity.

Grid: (D/tile_d, n_chunks, max_chunk/block_rows) — output tiles outermost so
each out tile's accumulation visits are consecutive; dimension semantics all
"arbitrary".
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.contiguity import mask_to_chunks_np

# JAX renamed TPUCompilerParams -> CompilerParams (jax>=0.5); support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(
    starts_ref,  # scalar prefetch: (K,) block-aligned row starts
    sizes_ref,  # scalar prefetch: (K,) block-aligned chunk sizes (0 = pad)
    x_ref,  # (B, block_rows) VMEM
    w_ref,  # (block_rows, tile_d) VMEM
    out_ref,  # (B, tile_d) VMEM, f32
    *,
    block_rows: int,
):
    ci = pl.program_id(1)  # chunk index
    bk = pl.program_id(2)  # block index within the chunk

    @pl.when((ci == 0) & (bk == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Blocks past this chunk's size contribute nothing (padded chunks: size 0)
    # — and DO nothing: the accumulate is predicated off entirely, instead of
    # the old lax.cond that still paid a zeros add into out_ref per pad step.
    active = bk * block_rows < sizes_ref[ci]

    @pl.when(active)
    def _acc():
        x = x_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "tile_d", "max_chunk_rows", "interpret")
)
def chunk_gather_matmul(
    w: jnp.ndarray,  # (N, D) weights (rows = neurons)
    x: jnp.ndarray,  # (B, N) activations
    starts: jnp.ndarray,  # (K,) int32, multiples of block_rows
    sizes: jnp.ndarray,  # (K,) int32, multiples of block_rows (0 = padded)
    *,
    block_rows: int = 8,
    tile_d: int = 128,
    max_chunk_rows: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns y (B, D) f32 = Σ_chunks x_chunk @ W_chunk."""
    n, d = w.shape
    b = x.shape[0]
    k = starts.shape[0]
    if d % tile_d:
        raise ValueError(f"D={d} must be a multiple of tile_d={tile_d}")
    if n % block_rows:
        raise ValueError(f"N={n} must be a multiple of block_rows={block_rows}")
    if max_chunk_rows % block_rows:
        raise ValueError("max_chunk_rows must be a multiple of block_rows")
    # output-tile dim OUTERMOST so the accumulated out block stays resident
    # across its consecutive (chunk, block) visits
    grid = (d // tile_d, k, max_chunk_rows // block_rows)

    def x_index(dj, ci, bk, starts_ref, sizes_ref):
        return (0, starts_ref[ci] // block_rows + bk)

    def w_index(dj, ci, bk, starts_ref, sizes_ref):
        return (starts_ref[ci] // block_rows + bk, dj)

    def out_index(dj, ci, bk, starts_ref, sizes_ref):
        return (0, dj)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_rows), x_index),
            pl.BlockSpec((block_rows, tile_d), w_index),
        ],
        out_specs=pl.BlockSpec((b, tile_d), out_index),
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(starts, sizes, x, w)


def align_chunk_table(
    starts: np.ndarray,
    sizes: np.ndarray,
    block_rows: int,
    n: int,
    max_chunk_rows: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Round an arbitrary chunk table outward to block_rows alignment
    (start down, end up), clamped to [0, n). Overlapping/adjacent coverage is
    merged, then runs longer than ``max_chunk_rows`` are split so every entry
    fits the kernel grid (splitting a contiguous run costs nothing: the
    fetches stay back-to-back)."""
    def _as_rows(name, arr):
        """Validate/cast ONCE up front: row counts must be integral — a
        float table that survived by accident used to floor silently in the
        index arithmetic below."""
        arr = np.asarray(arr)
        if arr.ndim != 1:
            raise ValueError(f"{name} must be 1-D, got shape {arr.shape}")
        cast = arr.astype(np.int64)
        if not np.issubdtype(arr.dtype, np.integer) and not np.array_equal(cast, arr):
            raise TypeError(
                f"{name} must hold integral row values, got dtype {arr.dtype} "
                "with non-integer entries"
            )
        return cast

    starts = _as_rows("starts", starts)
    sizes = _as_rows("sizes", sizes)
    if starts.shape != sizes.shape:
        raise ValueError(
            f"starts/sizes length mismatch: {starts.shape} vs {sizes.shape}"
        )
    mask = np.zeros(n, bool)
    for s, z in zip(starts, sizes):
        if z <= 0:
            continue
        lo = (s // block_rows) * block_rows
        hi = min(n, ((s + z + block_rows - 1) // block_rows) * block_rows)
        mask[lo:hi] = True

    out_s, out_z = [], []
    for c in mask_to_chunks_np(mask):
        s, z = c.start, c.size
        if max_chunk_rows:
            while z > max_chunk_rows:
                out_s.append(s)
                out_z.append(max_chunk_rows)
                s += max_chunk_rows
                z -= max_chunk_rows
        out_s.append(s)
        out_z.append(z)
    return np.asarray(out_s, np.int32), np.asarray(out_z, np.int32)
