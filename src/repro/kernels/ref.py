"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def chunk_table_to_mask(starts, sizes, n: int) -> jnp.ndarray:
    """(starts, sizes) padded chunk table → bool mask of length n."""
    idx = jnp.arange(n)
    starts = jnp.asarray(starts)[:, None]
    sizes = jnp.asarray(sizes)[:, None]
    in_chunk = (idx[None, :] >= starts) & (idx[None, :] < starts + sizes)
    return jnp.any(in_chunk, axis=0)


def chunk_gather_matmul_ref(
    w: jnp.ndarray,  # (N, D)
    x: jnp.ndarray,  # (B, N)
    starts: jnp.ndarray,  # (K,)
    sizes: jnp.ndarray,  # (K,)
) -> jnp.ndarray:
    """y = Σ_{i in selected chunks} x[:, i] · w[i, :]  (f32 accumulation).

    Mathematically identical to the masked matmul of paper App. B.2."""
    mask = chunk_table_to_mask(starts, sizes, w.shape[0])
    xm = x.astype(jnp.float32) * mask.astype(jnp.float32)[None, :]
    return xm @ w.astype(jnp.float32)


def chunk_gather_swiglu_ref(
    w_gate: jnp.ndarray,  # (N, F)
    w_up: jnp.ndarray,  # (N, F)
    x: jnp.ndarray,  # (B, N)
    starts: jnp.ndarray,
    sizes: jnp.ndarray,
) -> jnp.ndarray:
    """Fused sparse gate/up + SiLU·mul (they share the chunk plan)."""
    g = chunk_gather_matmul_ref(w_gate, x, starts, sizes)
    u = chunk_gather_matmul_ref(w_up, x, starts, sizes)
    return (g * (1.0 / (1.0 + jnp.exp(-g)))) * u


def chunk_gather_mlp_ref(
    w_gate: jnp.ndarray,  # (N, F)
    w_up: jnp.ndarray,  # (N, F)
    w_down: jnp.ndarray,  # (F, D)
    x: jnp.ndarray,  # (B, N)
    starts: jnp.ndarray,  # (2, K): lane 0 = hidden_mlp plan, lane 1 = ffn plan
    sizes: jnp.ndarray,  # (2, K)
) -> jnp.ndarray:
    """Fused multi-site MLP oracle: gate/up gather off the hidden lane of a
    batched (n_sites, K) plan, down off the ffn lane — the target for
    ``chunk_gather_mlp_dma``."""
    h = chunk_gather_swiglu_ref(w_gate, w_up, x, starts[0], sizes[0])
    mask_f = chunk_table_to_mask(starts[1], sizes[1], w_down.shape[0])
    return (h * mask_f.astype(jnp.float32)[None, :]) @ w_down.astype(jnp.float32)
