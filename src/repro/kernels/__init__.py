"""Pallas TPU kernels for the paper's compute hot-spot: chunk-gathered
sparse matmuls driven by the utility-guided selection's chunk tables."""
from .backend import (
    BACKENDS,
    ExecutionBackend,
    blocked_masked_matmul,
    pick_tile,
    validate_backend,
)
from .chunk_gather_dma import (
    chunk_gather_matmul_dma,
    chunk_gather_mlp_dma,
    masks_to_block_tables,
)
from .chunk_gather_matmul import align_chunk_table, chunk_gather_matmul
from .chunk_gather_swiglu import chunk_gather_swiglu
from .ops import (
    plan_to_kernel_table,
    sparse_matmul,
    sparse_matmul_dma,
    sparse_mlp_fused,
    sparse_swiglu,
)
from .quantize import (
    SCALE_BYTES,
    dequantize_rows,
    quantize_params,
    quantize_rows,
)
from .ref import (
    chunk_gather_matmul_ref,
    chunk_gather_mlp_ref,
    chunk_gather_swiglu_ref,
    chunk_table_to_mask,
)
