"""Jit'd public wrappers for the Pallas kernels.

``sparse_matmul`` / ``sparse_swiglu`` dispatch to the TPU kernel on TPU and
to interpret mode elsewhere (this container is CPU-only: interpret executes
the kernel body in Python for correctness validation — the BlockSpec tiling
and scalar-prefetch structure are identical).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.contiguity import mask_to_chunks_np
from .chunk_gather_dma import chunk_gather_matmul_dma, chunk_gather_mlp_dma
from .chunk_gather_matmul import align_chunk_table, chunk_gather_matmul
from .chunk_gather_swiglu import chunk_gather_swiglu


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sparse_matmul(
    w: jnp.ndarray,
    x: jnp.ndarray,
    starts: jnp.ndarray,
    sizes: jnp.ndarray,
    *,
    block_rows: int = 8,
    tile_d: int = 128,
    max_chunk_rows: int = 512,
) -> jnp.ndarray:
    """y (B, D) f32 — rows outside the chunk plan are never read from HBM."""
    return chunk_gather_matmul(
        w,
        x,
        starts,
        sizes,
        block_rows=block_rows,
        tile_d=tile_d,
        max_chunk_rows=max_chunk_rows,
        interpret=not _on_tpu(),
    )


def sparse_swiglu(
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    x: jnp.ndarray,
    starts: jnp.ndarray,
    sizes: jnp.ndarray,
    *,
    block_rows: int = 8,
    tile_f: int = 128,
    max_chunk_rows: int = 512,
) -> jnp.ndarray:
    return chunk_gather_swiglu(
        w_gate,
        w_up,
        x,
        starts,
        sizes,
        block_rows=block_rows,
        tile_f=tile_f,
        max_chunk_rows=max_chunk_rows,
        interpret=not _on_tpu(),
    )


def sparse_matmul_dma(
    w: jnp.ndarray,
    x: jnp.ndarray,
    starts: jnp.ndarray,
    sizes: jnp.ndarray,
    *,
    block_rows: int = 8,
    tile_d: int = 128,
    max_chunk_rows: int = 512,
    prefetch_depth: int = 1,
) -> jnp.ndarray:
    """``sparse_matmul`` through the explicitly double-buffered DMA kernel:
    ``prefetch_depth + 1`` VMEM slots rotate so chunk-block k+1 streams from
    HBM while the MXU contracts block k. Interpret mode off-TPU validates
    the identical slot-rotation schedule synchronously."""
    return chunk_gather_matmul_dma(
        w,
        x,
        starts,
        sizes,
        block_rows=block_rows,
        tile_d=tile_d,
        max_chunk_rows=max_chunk_rows,
        prefetch_depth=prefetch_depth,
        interpret=not _on_tpu(),
    )


def sparse_mlp_fused(
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
    x: jnp.ndarray,
    starts: jnp.ndarray,  # (2, K): hidden_mlp and ffn lanes of a batched plan
    sizes: jnp.ndarray,
    ffn_mask: Optional[jnp.ndarray] = None,
    *,
    block_rows: int = 8,
    tile_f: int = 128,
    tile_d: int = 128,
    max_chunk_rows: int = 512,
    prefetch_depth: int = 1,
    return_h: bool = False,
) -> jnp.ndarray:
    """The fused multi-site MLP: ONE dispatch gathers gate/up off the
    hidden_mlp plan lane and down off the ffn lane, with the SwiGLU
    intermediate kept in VMEM (no per-site re-dispatch, no h round-trip).
    ``ffn_mask``/``return_h`` as in ``chunk_gather_mlp_dma`` (the decode
    execution backend's exact-mask / importance-recording plumbing)."""
    return chunk_gather_mlp_dma(
        w_gate,
        w_up,
        w_down,
        x,
        starts,
        sizes,
        ffn_mask,
        block_rows=block_rows,
        tile_f=tile_f,
        tile_d=tile_d,
        max_chunk_rows=max_chunk_rows,
        prefetch_depth=prefetch_depth,
        interpret=not _on_tpu(),
        return_h=return_h,
    )


def plan_to_kernel_table(
    mask: np.ndarray,
    block_rows: int = 8,
    max_chunks: Optional[int] = None,
    max_chunk_rows: int = 512,
) -> Tuple[np.ndarray, np.ndarray]:
    """Selection mask → block-aligned padded chunk table for the kernels."""
    chunks = mask_to_chunks_np(np.asarray(mask))
    starts = np.asarray([c.start for c in chunks], np.int32)
    sizes = np.asarray([c.size for c in chunks], np.int32)
    starts, sizes = align_chunk_table(
        starts, sizes, block_rows, len(mask), max_chunk_rows=max_chunk_rows
    )
    k = max_chunks or max(len(starts), 1)
    out_s = np.zeros(k, np.int32)
    out_z = np.zeros(k, np.int32)
    out_s[: len(starts)] = starts[:k]
    out_z[: len(sizes)] = sizes[:k]
    return out_s, out_z
