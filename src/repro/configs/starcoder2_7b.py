"""StarCoder2-7B — GQA + RoPE code model [arXiv:2402.19173].

32L, d_model=4608, 36 heads (GQA kv=4), d_ff=18432, vocab=49152.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    rope_theta=1000000.0,
    norm="layernorm",
    mlp="gelu",
    sliding_window=4096,
    fsdp=True,
    citation="arXiv:2402.19173",
)
