"""Whisper-small — encoder-decoder ASR backbone [arXiv:2212.04356].

12L enc + 12L dec, d_model=768, 12 heads (MHA), d_ff=3072, vocab=51865.
The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (1500 positions × 768).
LayerNorm + GeLU MLP + learned/sinusoidal positions, no RoPE.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    n_layers=12,  # decoder depth
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    rope_theta=None,  # absolute positions
    norm="layernorm",
    mlp="gelu",
    d_frontend=768,  # conv-frontend output dim (stubbed)
    frontend_tokens=1500,  # audio context positions
    sliding_window=8192,
    citation="arXiv:2212.04356",
)
