"""Llama-4-Scout-17B-16E — MoE with early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

48L, d_model=5120, 40 heads (GQA kv=8), per-expert d_ff=8192, vocab=202048.
16 routed experts top-1 + always-on shared expert. Early-fusion multimodal:
the vision encoder is a STUB (precomputed patch embeddings through the
projector, like the VLM family).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,  # per expert (and shared expert)
    vocab_size=202048,
    rope_theta=500000.0,
    n_experts=16,
    moe_top_k=1,
    moe_shared_expert=True,
    d_frontend=1408,  # vision embedding dim (MetaCLIP-style stub)
    frontend_tokens=144,
    sliding_window=8192,  # iRoPE chunked attention analogue for long context
    fsdp=True,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
