"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 layers, d_model=3584, ssm_state=64; a single SHARED
attention+MLP block (32 heads MHA, d_ff=14336) is applied after every 6th
Mamba2 layer (weights reused at every application — Zamba's parameter-sharing
trick).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,  # 32 * 112 = 3584
    d_ff=14336,
    vocab_size=32000,
    rope_theta=10000.0,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    sliding_window=8192,  # shared-attn block windows at 500k decode
    fsdp=True,
    citation="arXiv:2411.15242",
)
