"""Architecture registry: ``get_config("<arch-id>")`` for every assigned arch."""
from typing import Dict, List

from .base import InputShape, ModelConfig
from .shapes import SHAPES, get_shape

from .tinyllama_1_1b import CONFIG as _tinyllama
from .internvl2_76b import CONFIG as _internvl2
from .zamba2_7b import CONFIG as _zamba2
from .olmoe_1b_7b import CONFIG as _olmoe
from .xlstm_125m import CONFIG as _xlstm
from .granite_3_2b import CONFIG as _granite
from .whisper_small import CONFIG as _whisper
from .starcoder2_3b import CONFIG as _sc2_3b
from .starcoder2_7b import CONFIG as _sc2_7b
from .llama4_scout_17b_a16e import CONFIG as _llama4

CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _tinyllama,
        _internvl2,
        _zamba2,
        _olmoe,
        _xlstm,
        _granite,
        _whisper,
        _sc2_3b,
        _sc2_7b,
        _llama4,
    )
}

ARCH_IDS: List[str] = sorted(CONFIGS)


def get_config(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
