"""Granite-3.0-2B-base — GQA dense decoder [hf:ibm-granite/granite-3.0-2b-base].

40L, d_model=2048, 32 heads (GQA kv=8), d_ff=8192, vocab=49155.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    arch_type="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    rope_theta=10000.0,
    tie_embeddings=True,
    sliding_window=8192,
    citation="hf:ibm-granite/granite-3.0-2b-base",
)
