"""InternVL2-Llama3-76B — InternViT + LLM backbone VLM [arXiv:2404.16821].

Backbone: 80L, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256.
Vision frontend (InternViT-6B, output dim 3200) is a STUB per the assignment:
``input_specs`` feeds precomputed patch embeddings to the projector.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,  # llama3 backbone
    d_frontend=3200,  # InternViT-6B embedding dim
    frontend_tokens=256,  # visual tokens per frame after pixel-shuffle
    sliding_window=8192,
    fsdp=True,  # 76B params: weights+opt sharded over data axis too
    citation="arXiv:2404.16821",
)
