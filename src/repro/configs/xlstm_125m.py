"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517].

12 blocks, d_model=768, 4 heads, vocab=50304; d_ff=0 (xLSTM blocks carry
their own up/down projections). sLSTM at block positions {3, 9} (xLSTM[10:2]
style mix), mLSTM elsewhere.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    rope_theta=None,
    slstm_layers=(3, 9),
    citation="arXiv:2405.04517",
)
