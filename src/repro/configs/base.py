"""ModelConfig: one dataclass describing every supported architecture family.

Families (``arch_type``): dense | moe | ssm | hybrid | vlm | audio.
Each assigned architecture gets a module in this package with the exact
published numbers; ``reduced()`` derives the smoke-test variant (≤2 layers,
d_model ≤ 512, ≤4 experts) required by the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    rope_theta: Optional[float] = 10000.0  # None → no RoPE (whisper)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_shared_expert: bool = False
    moe_dispatch: str = "scatter"  # scatter (baseline) | gather (§Perf B)

    # SSM / hybrid (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0  # hybrid: shared attention block after every k ssm layers

    # xLSTM
    slstm_layers: Tuple[int, ...] = ()  # layer indices using sLSTM (rest mLSTM)

    # VLM / audio frontends (stubs per assignment)
    d_frontend: int = 0  # vision/audio embedding dim fed to the projector
    frontend_tokens: int = 0  # tokens per frame / encoder positions
    encoder_layers: int = 0  # audio: encoder depth (enc-dec)

    # long-context handling
    sliding_window: Optional[int] = None  # used by long_500k decode for attn archs

    # KV-cache head replication (beyond-paper perf knob, EXPERIMENTS.md §Perf):
    # replicate each kv head r× in the DECODE/PREFILL cache so kv_heads·r
    # divides the model-parallel degree — cache updates and attention stay
    # local to each shard instead of all-gathering the cache every layer.
    kv_replicate: int = 1

    # distribution
    fsdp: bool = False  # additionally shard weights over the data axis
    remat: bool = True

    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_cache_kv_heads(self) -> int:
        """KV heads as stored in the decode cache (incl. replication)."""
        return self.n_kv_heads * self.kv_replicate

    def with_kv_replication(self, tp: int) -> "ModelConfig":
        """Smallest replication making cache kv-heads shardable over tp while
        still dividing n_heads (attention grouping must stay integral)."""
        if self.arch_type == "ssm":
            return self
        for r in range(1, tp + 1):
            kv_eff = self.n_kv_heads * r
            if kv_eff % tp == 0 and self.n_heads % kv_eff == 0:
                return dataclasses.replace(self, kv_replicate=r)
        return self  # impossible (e.g. 24 heads vs tp=16) — keep fallback

    def optimized_for(self, tp: int) -> "ModelConfig":
        """All beyond-paper §Perf config changes for a model-parallel degree:
        shardable KV cache (iteration A) + gather-based MoE dispatch
        (iteration B). shard_map attention (iteration C) is a MeshRules
        toggle, not a config field."""
        cfg = self.with_kv_replication(tp)
        if cfg.has_moe:
            cfg = dataclasses.replace(cfg, moe_dispatch="ep_shard_map")
        return cfg

    @property
    def is_encdec(self) -> bool:
        return self.arch_type == "audio"

    @property
    def has_moe(self) -> bool:
        return self.n_experts > 0

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family (assignment: 2 layers,
        d_model ≤ 512, ≤ 4 experts)."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        scale = d_model / self.d_model
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=max(64, int(self.d_ff * scale)) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_capacity_factor=8.0 if self.n_experts else self.moe_capacity_factor,
            ssm_head_dim=min(self.ssm_head_dim, 32) if self.ssm_state else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            attn_every=min(self.attn_every, 1) if self.attn_every else 0,
            slstm_layers=tuple(i for i in self.slstm_layers if i < 2) or ((1,) if self.slstm_layers else ()),
            d_frontend=min(self.d_frontend, 64) if self.d_frontend else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            fsdp=False,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned workload geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"
