"""StarCoder2-3B — GQA + RoPE code model [arXiv:2402.19173].

30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288, vocab=49152.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    rope_theta=999999.0,
    norm="layernorm",
    mlp="gelu",
    sliding_window=4096,  # starcoder2 trains with 4k sliding window
    citation="arXiv:2402.19173",
)
