"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060].

16L, d_model=2048, 16 heads (MHA kv=16), per-expert d_ff=1024, vocab=50304.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,  # per expert
    vocab_size=50304,
    rope_theta=10000.0,
    n_experts=64,
    moe_top_k=8,
    sliding_window=8192,
    citation="arXiv:2409.02060",
)
