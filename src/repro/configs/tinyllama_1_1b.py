"""TinyLlama-1.1B — llama2-architecture small model [arXiv:2401.02385].

22L, d_model=2048, 32 heads (GQA kv=4), d_ff=5632, vocab=32000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10000.0,
    sliding_window=8192,  # enables long_500k decode (DESIGN.md §4)
    citation="arXiv:2401.02385",
)
