"""repro: production-grade JAX reproduction of "VLM in a flash: I/O-Efficient
Sparsification of Vision-Language Model via Neuron Chunking" (CS.LG 2025).

Layers: core/ (the paper's algorithms), models/ (6 arch families),
configs/ (10 assigned architectures), sharding/, training/, serving/,
data/, kernels/ (Pallas), launch/ (mesh + multi-pod dry-run).
"""
__version__ = "1.0.0"
