"""Adaptive degradation control for the offloaded decode path.

When the storage device degrades (thermal throttle, retry storms — see
core/faults.py), every chunk the selector planned against the clean
``LatencyTable`` costs more than it priced. The ``DegradationController``
closes the loop: it watches the EWMA of the measured-vs-estimated step
latency ratio at each decode-call boundary and, while the device looks
degraded, tightens the selector's chunk I/O budget (via the plan-carried
"bscale" multiplier, ``sparse_exec.set_plan_budget_scale``) so each step
streams fewer bytes and leans harder on residency-cache hits — then walks
the budget back up once the device stabilizes. Data corruption (see
``CorruptionModel``) feeds the same loop as a second signal: the engine
maps each call's detected-corruption rate onto the ratio axis via
``observe_corruption``, so a device shedding corrupt blocks tightens the
budget exactly like one shedding latency.

State machine (two thresholds give hysteresis):

                 ewma > degrade_ratio            ewma < recover_ratio
    HEALTHY ───────────────────────▶ DEGRADED ───────────────────────▶
      ▲            (scale -= step,      │          (scale += step,
      │             clamp min_scale)    │           clamp 1.0)
      └─────────────────────────────────┘  back to HEALTHY at scale 1.0

The controller only *observes* and *acts* at decode-call boundaries (the
engine's scan-fused and per-token loops both sync there), so both decode
paths see identical control behaviour; inside one call the budget scale is
constant. The fault-free ratio is jitter-centred at ~1.0 (the engine
normalizes out the deterministic interleave lift), so with the default
thresholds the controller never moves off scale 1.0 on a healthy device —
and scale 1.0 is bit-exact the static budgets (see sparse_exec).
"""
from __future__ import annotations

from typing import Dict

import numpy as np


class DegradationController:
    """EWMA feedback controller on the measured/estimated latency ratio.

    ``observe(ratios)`` takes the per-step ratios of one decode call
    (already normalized by the deterministic lift, so healthy ≈ 1.0) and
    updates the EWMA; ``scale`` is the budget multiplier the engine writes
    into the plan before the *next* decode call.
    """

    def __init__(
        self,
        degrade_ratio: float = 1.6,
        recover_ratio: float = 1.25,
        alpha: float = 0.5,
        step: float = 0.2,
        min_scale: float = 0.4,
        corruption_ratio_gain: float = 20.0,
    ):
        if not (recover_ratio < degrade_ratio):
            raise ValueError(
                f"need recover_ratio < degrade_ratio for hysteresis, got "
                f"{recover_ratio} >= {degrade_ratio}"
            )
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if not (0.0 < step <= 1.0):
            raise ValueError(f"step must be in (0, 1], got {step}")
        if not (0.0 < min_scale <= 1.0):
            raise ValueError(f"min_scale must be in (0, 1], got {min_scale}")
        if corruption_ratio_gain < 0.0:
            raise ValueError(
                f"corruption_ratio_gain must be >= 0, got {corruption_ratio_gain}"
            )
        self.degrade_ratio = float(degrade_ratio)
        self.recover_ratio = float(recover_ratio)
        self.alpha = float(alpha)
        self.step = float(step)
        self.min_scale = float(min_scale)
        self.corruption_ratio_gain = float(corruption_ratio_gain)
        self.scale = 1.0
        self.ewma = 1.0
        # lifetime accounting (engine.fault_summary surfaces these)
        self.observations = 0
        self.tighten_steps = 0
        self.relax_steps = 0
        self.calls_degraded = 0

    @property
    def degraded(self) -> bool:
        return self.scale < 1.0

    def observe(self, ratios) -> float:
        """Fold one decode call's per-step measured/estimated ratios into
        the EWMA and move the budget scale one step if a threshold is
        crossed. Non-finite / non-positive entries (zero-I/O reuse steps)
        are ignored. Returns the new scale."""
        r = np.asarray(ratios, dtype=np.float64).reshape(-1)
        r = r[np.isfinite(r) & (r > 0.0)]
        if r.size == 0:
            return self.scale
        self.observations += int(r.size)
        # one EWMA update per observed step, in order — a long degraded
        # call converges within the call, not one alpha-step per call
        for v in r:
            self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * float(v)
        if self.ewma > self.degrade_ratio:
            new = max(self.min_scale, self.scale - self.step)
            if new < self.scale:
                self.tighten_steps += 1
            self.scale = new
        elif self.ewma < self.recover_ratio and self.scale < 1.0:
            self.scale = min(1.0, self.scale + self.step)
            self.relax_steps += 1
        if self.degraded:
            self.calls_degraded += 1
        return self.scale

    def observe_corruption(self, rate: float) -> float:
        """Fold one decode call's corruption rate (detected corrupt blocks
        per fetched block, see engine._observe_corruption) in as a SECOND
        degrade signal, mapped onto the latency-ratio axis: a clean call
        (rate 0) observes the healthy 1.0, a corrupting device observes
        ``1.0 + corruption_ratio_gain * rate`` — with the default gain of
        20.0, a sustained ~3% block-corruption rate crosses the default
        degrade threshold (1.6) and tightens the budget, which shrinks the
        fetch footprint and with it the exposure to further corruption.
        Non-finite or negative rates are ignored. Returns the new scale."""
        if not np.isfinite(rate) or rate < 0.0:
            return self.scale
        return self.observe([1.0 + self.corruption_ratio_gain * rate])

    def summary(self) -> Dict[str, float]:
        return {
            "scale": self.scale,
            "ewma_ratio": self.ewma,
            "observations": self.observations,
            "tighten_steps": self.tighten_steps,
            "relax_steps": self.relax_steps,
            "calls_degraded": self.calls_degraded,
        }
