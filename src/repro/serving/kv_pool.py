"""KVPagePool: the serve engine's per-slot view of the paged KV cache.

Wraps the pure ``core.paged_kv.PagedKVAllocator`` with everything the
engine needs per request slot: a host-side mirror of the device page
table, prefix hashing of fused prompts (vision rows + tokens, chained per
page so a hash names the content of every position up to the page's end),
admission that reuses live/cold prefix pages by content, per-round
``ensure`` growth for decode writes, release on eviction/preemption, and
byte/shard accounting for ``io_summary`` / ``shard_summary``.

Sharing discipline (what makes the device side trivially correct): only
FULL prompt pages are content-addressed and shared; the partial tail page
and every decode-grown page are private to their slot. Decode writes land
at position ``length`` — always past the full prompt pages — so a shared
page is never written after registration and no device-side COW copy ever
runs on the hot path. (General COW forks live in the allocator and are
property-tested there; the serving path simply never needs one.) One
carve-out keeps that true at the sequence boundary: a prompt of exactly
``max_seq`` tokens has a FULL final page, but decode clamps its write
position to ``max_seq - 1`` — inside that page — so the final page of a
full-length prompt stays private and unregistered (``_shareable``), never
shared and never revivable as prefix content.

Per-data-shard accounting: each page gets a "home" shard — the data shard
of the slot that first allocated it (slot → shard is the engine's
contiguous ``slots_per_data_shard`` split). ``pages_per_shard`` partitions
the live pages by home, summing exactly to ``pages_in_use`` — the same
sum-to-global invariant as ``shard_summary()``'s byte lanes.
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.paged_kv import GARBAGE_PAGE, KVPoolExhausted, PagedKVAllocator

__all__ = ["KVPagePool", "KVPoolExhausted", "prompt_prefix_hashes"]

_HASH_SEED = b"repro-paged-kv-v1"


def prompt_prefix_hashes(batch: Dict[str, Any], page_tokens: int) -> Tuple[int, List[str]]:
    """Chained per-page content hashes of a batch-1 prompt.

    The fused prompt sequence is [frontend rows | tokens] (the decoder's
    early-fusion order, models/model.py ``_embed_input``); KV at position i
    depends only on positions ≤ i (causal), so page j's content is named by
    a hash chained over positions 0 .. (j+1)·page_tokens - 1. Any extra
    batch leaves fold into the seed hash (they could affect every
    position). Returns ``(seq_len, hashes)`` with one hash per FULL page —
    the partial tail page is never shared and gets none."""
    tokens = np.asarray(batch["tokens"])
    if tokens.ndim != 2 or tokens.shape[0] != 1:
        raise ValueError(
            f"prompt batches must have leading batch dim 1, got {tokens.shape}"
        )
    h = hashlib.sha1(_HASH_SEED)
    items: List[bytes] = []
    front = batch.get("frontend")
    if front is not None:
        front = np.asarray(front)
        for row in front[0]:
            items.append(np.ascontiguousarray(row).tobytes())
    for key in sorted(batch):
        if key not in ("tokens", "frontend"):
            h.update(key.encode())
            h.update(np.ascontiguousarray(np.asarray(batch[key])).tobytes())
    for tok in tokens[0]:
        items.append(int(tok).to_bytes(8, "little", signed=True))
    seq_len = len(items)
    # fold the TOTAL length into the seed: prefill's attention reduction
    # shape depends on it, so only same-length prompts are guaranteed
    # bit-identical prefix KV — sharing across lengths is not attempted
    h.update(seq_len.to_bytes(8, "little"))
    hashes: List[str] = []
    for j in range(seq_len // page_tokens):
        for it in items[j * page_tokens:(j + 1) * page_tokens]:
            h.update(it)
        hashes.append(h.hexdigest())
        h = h.copy()
    return seq_len, hashes


class KVPagePool:
    """Slot-indexed paged-KV bookkeeping over a ``PagedKVAllocator``."""

    def __init__(
        self,
        n_slots: int,
        max_seq: int,
        page_tokens: int,
        n_pages: int,
        page_bytes: float,
        n_data_shards: int = 1,
    ):
        if max_seq % page_tokens != 0:
            raise ValueError(
                f"max_seq ({max_seq}) must be divisible by page_tokens "
                f"({page_tokens}) so page tables cover the whole sequence"
            )
        if n_slots % n_data_shards != 0:
            raise ValueError(
                f"n_slots ({n_slots}) must divide over {n_data_shards} data shards"
            )
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_tokens = page_tokens
        self.max_pages = max_seq // page_tokens
        self.page_bytes = float(page_bytes)
        self.n_data_shards = n_data_shards
        self.alloc = PagedKVAllocator(n_pages, page_tokens)
        # host mirror of the device page table; row of GARBAGE_PAGE ⇔ free
        self.table = np.full((n_slots, self.max_pages), GARBAGE_PAGE, np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self._page_home: Dict[int, int] = {}
        # lifetime counters
        self.admitted = 0
        self.released = 0
        self.fresh_pages = 0      # pages allocated and written with new KV
        self.shared_pages_hit = 0  # prompt pages served by prefix sharing

    # -- geometry ------------------------------------------------------------
    def _shard_of(self, slot: int) -> int:
        return slot // (self.n_slots // self.n_data_shards)

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages[slot])

    # -- admission -----------------------------------------------------------
    def _shareable(self, seq_len: int, hashes: List[str]) -> List[str]:
        """Hashes of the pages this prompt may share. A prompt of exactly
        ``max_seq`` tokens fills its final page, but decode clamps the
        write position to ``max_seq - 1`` — inside that page — so sharing
        or content-registering it would let the clamped decode write
        mutate shared bytes and poison the prefix registry. The final page
        of a full-length prompt is therefore always private/anonymous."""
        if seq_len >= self.max_seq and len(hashes) * self.page_tokens >= seq_len:
            return hashes[:-1]
        return hashes

    def fresh_pages_needed(self, seq_len: int, hashes: List[str]) -> int:
        """How many pages an admission must newly allocate: prompt pages
        not already resident (live or cold) plus the private tail page."""
        hashes = self._shareable(seq_len, hashes)
        n_prompt_pages = -(-seq_len // self.page_tokens)
        fresh = n_prompt_pages - len(hashes)  # private tail/clamp pages
        for key in hashes:
            page = self.alloc._by_hash.get(key)
            if page is None:
                fresh += 1
        return fresh

    def can_admit(self, seq_len: int, hashes: List[str]) -> bool:
        """Whether an admission is guaranteed to succeed right now. Every
        non-live-shared page consumes exactly one unit of the free+cold
        reservoir: a fresh allocation pops a free page (or evicts a cold
        one), and a cold-prefix REVIVAL consumes its own cold entry — so
        revivable pages cannot double as supply for the fresh allocations
        (the bug the randomized pool property test pinned down)."""
        hashes = self._shareable(seq_len, hashes)
        fresh = 0
        cold_hits = 0
        for key in hashes:
            page = self.alloc._by_hash.get(key)
            if page is None:
                fresh += 1
            elif self.alloc.ref[page] == 0:
                cold_hits += 1
        n_prompt_pages = -(-seq_len // self.page_tokens)
        fresh += n_prompt_pages - len(hashes)  # private tail/clamp pages
        return fresh + cold_hits <= self.alloc.n_reclaimable

    def admit(self, slot: int, seq_len: int, hashes: List[str]) -> List[Tuple[int, bool]]:
        """Map a prompt's pages into ``slot``: full pages share by content
        when a live/cold twin exists, everything else allocates fresh.
        Returns ``[(page, is_fresh), ...]`` in position order — the engine
        writes prefill KV bytes only into the fresh ones. Any previous
        occupant of the slot is released first."""
        if self._slot_pages[slot]:
            self.release(slot)
        if seq_len > self.max_seq:
            raise ValueError(f"prompt of {seq_len} tokens exceeds max_seq {self.max_seq}")
        hashes = self._shareable(seq_len, hashes)
        n_prompt_pages = -(-seq_len // self.page_tokens)
        shard = self._shard_of(slot)
        entries: List[Tuple[int, bool]] = []
        try:
            for j in range(n_prompt_pages):
                if j < len(hashes):
                    page = self.alloc.lookup_prefix(hashes[j])
                    if page is not None:
                        entries.append((page, False))
                        self.shared_pages_hit += 1
                        continue
                    page = self.alloc.alloc()
                    self.alloc.register_prefix(page, hashes[j])
                else:  # tail page (partial, or clamp target): private
                    page = self.alloc.alloc()
                # unconditional: a page fresh off alloc() may be a recycled
                # cold eviction whose stale home would misattribute shards
                self._page_home[page] = shard
                self.fresh_pages += 1
                entries.append((page, True))
        except KVPoolExhausted:
            for page, is_fresh in entries:  # roll back the partial admission
                if is_fresh:
                    # a fresh page registered this admission holds no KV
                    # bytes yet (the engine writes prefill bytes only after
                    # admit returns) — forget its hash so release frees it
                    # instead of cold-retiring it, where a later same-prefix
                    # admission would revive unwritten content as real KV
                    self.alloc.forget_prefix(page)
                    self._page_home.pop(page, None)
                    self.fresh_pages -= 1
                self.alloc.release(page)
            raise
        self._slot_pages[slot] = [p for p, _ in entries]
        row = np.full(self.max_pages, GARBAGE_PAGE, np.int32)
        row[: len(entries)] = [p for p, _ in entries]
        self.table[slot] = row
        self.admitted += 1
        return entries

    # -- decode growth -------------------------------------------------------
    def pages_needed(self, slot: int, last_pos: int) -> int:
        """How many pages ``ensure(slot, last_pos)`` would allocate — a
        pure count, nothing is allocated. ``ensure`` never registers
        prefixes, so a batch of ensures is guaranteed to succeed iff the
        summed needs fit ``reclaimable_pages`` (the engine pre-checks a
        whole decode round this way and raises BEFORE mutating any table,
        so exhaustion is recoverable by preempting a slot)."""
        last_pos = min(last_pos, self.max_seq - 1)
        need = last_pos // self.page_tokens + 1
        return max(0, need - len(self._slot_pages[slot]))

    @property
    def reclaimable_pages(self) -> int:
        """Pages an allocation burst could obtain: free now + evictable cold."""
        return self.alloc.n_reclaimable

    def ensure(self, slot: int, last_pos: int) -> List[int]:
        """Grow ``slot``'s table to cover write positions up to
        ``last_pos`` (clamped to the sequence end — decode past max_seq
        overwrites the final position, matching the dense cache's clamp;
        the clamp target page is private by the sharing discipline).
        New pages are private and anonymous. Returns the pages added."""
        last_pos = min(last_pos, self.max_seq - 1)
        need = last_pos // self.page_tokens + 1
        have = len(self._slot_pages[slot])
        added: List[int] = []
        shard = self._shard_of(slot)
        for j in range(have, need):
            page = self.alloc.alloc()
            # unconditional (not setdefault): see admit
            self._page_home[page] = shard
            self._slot_pages[slot].append(page)
            self.table[slot, j] = page
            added.append(page)
        return added

    # -- release -------------------------------------------------------------
    def release(self, slot: int) -> int:
        """Drop every reference ``slot`` holds (eviction, preemption, drop
        rungs — all release paths funnel here). Shared prefix pages go cold
        once their last reference drops; private pages return to the free
        list. Returns the number of references released."""
        pages = self._slot_pages[slot]
        for page in pages:
            self.alloc.release(page)
        n = len(pages)
        self._slot_pages[slot] = []
        self.table[slot] = GARBAGE_PAGE
        if n:
            self.released += 1
        # forget homes of pages that fully left circulation (free list);
        # cold pages keep their home until evicted or re-allocated
        for page in pages:
            if self.alloc.refcount(page) == 0 and page not in self.alloc._cold:
                self._page_home.pop(page, None)
        return n

    # -- accounting ----------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.alloc.n_live

    @property
    def shared_pages(self) -> int:
        return int((self.alloc.ref[GARBAGE_PAGE + 1:] > 1).sum())

    @property
    def kv_bytes_in_use(self) -> float:
        return self.pages_in_use * self.page_bytes

    @property
    def capacity_bytes(self) -> float:
        return self.alloc.n_pages * self.page_bytes

    def pages_per_shard(self, n_shards: Optional[int] = None) -> List[int]:
        """Live pages partitioned by home data shard — sums exactly to
        ``pages_in_use`` (pages shard over ``data`` with the slot rows that
        own them; a shared page counts once, at its first owner's home)."""
        n = self.n_data_shards if n_shards is None else n_shards
        out = [0] * n
        for page in range(GARBAGE_PAGE + 1, self.alloc.n_pages):
            if self.alloc.ref[page] > 0:
                out[self._page_home.get(page, 0) % n] += 1
        return out

    def steady_state(self) -> bool:
        """True when no slot holds any page (everything free or cold) —
        the post-drain invariant the cross-feature regression pins."""
        return self.pages_in_use == 0 and not any(self._slot_pages)

    def check(self) -> None:
        """Allocator conservation + table/refcount cross-invariants: every
        live page's refcount equals the number of slot-table references it
        has, and no page is reachable from two slots unless shared."""
        self.alloc.check()
        counts = np.zeros(self.alloc.n_pages, np.int64)
        for slot in range(self.n_slots):
            pages = self._slot_pages[slot]
            assert len(set(pages)) == len(pages), f"slot {slot} references a page twice"
            for j, page in enumerate(pages):
                assert self.table[slot, j] == page, "table mirror out of sync"
                counts[page] += 1
            assert (self.table[slot, len(pages):] == GARBAGE_PAGE).all(), (
                f"slot {slot} table tail not garbage-mapped"
            )
        counts[GARBAGE_PAGE] = 1  # permanent reservation
        live = self.alloc.ref
        assert (counts == live).all(), (
            f"table references != refcounts at pages "
            f"{np.where(counts != live)[0].tolist()}"
        )

    def summary(self) -> Dict[str, Any]:
        s = self.alloc.summary()
        s.update(
            pages_in_use=self.pages_in_use,
            kv_bytes_in_use=self.kv_bytes_in_use,
            capacity_bytes=self.capacity_bytes,
            admitted=self.admitted,
            released=self.released,
            fresh_pages=self.fresh_pages,
            shared_pages_hit=self.shared_pages_hit,
        )
        return s
