from .engine import ServeEngine, StepStats
from .sparse_exec import SparseExecution
