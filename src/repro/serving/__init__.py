from .degrade import DegradationController
from .engine import IO_SUMMARY_KEYS, ServeEngine, StepStats
from .kv_pool import KVPagePool, KVPoolExhausted, prompt_prefix_hashes
from .request import PoissonArrivalDriver, Request, RequestState
from .scheduler import Scheduler, SchedulerStats
from .sparse_exec import (
    SERVE_METHODS,
    SPARSE_METHODS,
    WBITS_CHOICES,
    SparseExecution,
    plan_budget_scale,
    plan_hit_miss,
    plan_transfer_bytes,
    residency_from_score,
    set_plan_budget_scale,
    validate_method,
)
