"""Request lifecycle for the continuous-batching serve stack.

A Request is one independent generation stream: a prompt (token ids plus an
optional vision frontend), a decode budget, and timing marks filled in by
the Scheduler as the request moves WAITING → RUNNING → FINISHED on the
simulated clock. The PoissonArrivalDriver fabricates open-loop traffic —
exponential inter-arrival gaps at a configurable rate — which is the arrival
process the serving benchmarks replay.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request. ``prompt`` is a model batch dict with leading
    batch dim 1 ({"tokens": (1, s), "frontend": (1, n, d)?})."""

    rid: int
    prompt: Dict[str, jnp.ndarray]
    max_new_tokens: int
    arrival_s: float = 0.0
    # SLO deadline: the request should finish within deadline_s of arrival
    # (None = best-effort). The scheduler admits earliest-deadline-first
    # when deadlines are present, counts attainment in SchedulerStats, and
    # may preempt a deadline-blown request (evict-and-requeue) to free its
    # slot for one that can still make its deadline.
    deadline_s: Optional[float] = None

    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    tokens_out: List[int] = dataclasses.field(default_factory=list)

    # timing marks on the scheduler's simulated clock
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    # times this request was preempted (evicted mid-decode and requeued)
    preemptions: int = 0

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt["tokens"].shape[1])

    @property
    def done(self) -> bool:
        return len(self.tokens_out) >= self.max_new_tokens

    def latency_s(self) -> float:
        """End-to-end request latency (arrival → last token)."""
        if self.finished_s is None:
            raise ValueError(f"request {self.rid} not finished")
        return self.finished_s - self.arrival_s

    def ttft_s(self) -> float:
        """Time to first token (arrival → first decoded token)."""
        if self.first_token_s is None:
            raise ValueError(f"request {self.rid} has no first token yet")
        return self.first_token_s - self.arrival_s

    @property
    def deadline_abs_s(self) -> float:
        """Absolute deadline on the simulated clock (inf = best-effort)."""
        if self.deadline_s is None:
            return float("inf")
        return self.arrival_s + self.deadline_s

    def met_deadline(self) -> bool:
        """Whether the finished request met its SLO deadline. Best-effort
        requests (no deadline) trivially meet it."""
        if self.deadline_s is None:
            return True
        return self.latency_s() <= self.deadline_s


class PoissonArrivalDriver:
    """Open-loop arrival process: requests arrive with Exp(rate) gaps.

    ``make_request(rid)`` builds the prompt/budget for request ``rid`` (the
    driver only owns timing). ``generate(n)`` returns n WAITING requests
    with monotonically increasing ``arrival_s``.
    """

    def __init__(
        self,
        rate_rps: float,
        make_request: Callable[[int], Request],
        seed: int = 0,
    ):
        if rate_rps <= 0:
            raise ValueError("rate_rps must be positive")
        self.rate_rps = rate_rps
        self.make_request = make_request
        self.rng = np.random.default_rng(seed)
        self._next_rid = 0
        self._clock = 0.0

    def generate(self, n: int) -> List[Request]:
        out = []
        for _ in range(n):
            self._clock += float(self.rng.exponential(1.0 / self.rate_rps))
            req = self.make_request(self._next_rid)
            req.arrival_s = self._clock
            req.state = RequestState.WAITING
            self._next_rid += 1
            out.append(req)
        return out
