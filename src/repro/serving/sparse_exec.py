"""SparseExecution: the paper's runtime policy wired into the model blocks.

One instance per (model config × device × policy). Model blocks call
``mask(kind, acts)`` once per sparsifiable projection input —
kind ∈ {hidden_attn, hidden_mlp, ffn, attn_out} mirroring the paper's
q / gate / down / o sites (k, v, up share masks with q and gate, App. A).

Everything runs inside jit: importance → utility-guided chunk selection
(jit-compiled ``lax.while_loop`` greedy) → mask + additive-model latency.
Latency accounts for every matrix sharing the mask (q+k+v for hidden_attn,
gate+up for hidden_mlp) with per-matrix row sizes.

Methods: "chunk" (ours), "topk" (TEAL/LLMFlash-style baseline),
"dense" (no sparsification — full contiguous load).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.baselines import topk_mask
from ..core.chunking import ChunkConfig, ChunkSelector
from ..core.latency_model import DeviceProfile, LatencyTable, get_profile, profile_table
from ..core.reorder import Reordering

DTYPE_BYTES = 2  # offloaded weights stored bf16/fp16 (paper: fp16)

# The single source of truth for serving policy names (ServeEngine and
# SparseExecution both validate against these):
#   * SPARSE_METHODS run through SparseExecution (selection + I/O accounting);
#   * "dense_free" means fully memory-resident weights — dense compute with
#     NO flash tier at all, so no SparseExecution instance and zero I/O.
SPARSE_METHODS = ("chunk", "topk", "dense")
SERVE_METHODS = SPARSE_METHODS + ("dense_free",)


def validate_method(method: str, allow_dense_free: bool = False) -> str:
    allowed = SERVE_METHODS if allow_dense_free else SPARSE_METHODS
    if method not in allowed:
        raise ValueError(f"unknown sparse method {method!r}; expected one of {allowed}")
    return method


@dataclasses.dataclass(frozen=True, eq=False)
class _Site:
    """One sparsification site: a selector + latency tables for every matrix
    sharing this input (e.g. q/k/v)."""

    n: int
    selector: ChunkSelector
    tables: Tuple[LatencyTable, ...]  # one per sharing matrix
    sparsity: float
    dense_latency: float

    def budget(self) -> jnp.ndarray:
        return jnp.int32(round((1.0 - self.sparsity) * self.n))


def _site(n_rows: int, out_cols: Tuple[int, ...], device, sparsity: float) -> _Site:
    primary_rb = out_cols[0] * DTYPE_BYTES
    cfg = ChunkConfig.for_shape(n_rows, out_cols[0],
                                device if isinstance(device, str) else device.name)
    selector = ChunkSelector.build(n_rows, primary_rb, device=device, cfg=cfg)
    tables = tuple(
        profile_table(device, c * DTYPE_BYTES, max_rows=selector.max_size)
        for c in out_cols
    )
    dense = float(
        sum(
            get_profile(device if isinstance(device, str) else device.name)
            .latency_bytes(n_rows * c * DTYPE_BYTES)
            for c in out_cols
        )
    )
    return _Site(n=n_rows, selector=selector, tables=tables, sparsity=sparsity,
                 dense_latency=dense)


class SparseExecution:
    """sparse_ctx implementation passed into model block functions."""

    def __init__(
        self,
        cfg: ModelConfig,
        device: str | DeviceProfile = "nano",
        sparsity: float | Dict[str, float] = 0.4,
        method: str = "chunk",
        reorderings: Optional[Dict[str, Reordering]] = None,
        cached: Optional[Dict[str, "jnp.ndarray"]] = None,
    ):
        """``cached``: per-site bool masks of neurons whose weights are
        memory-resident (paper §5 "Leveraging Additional Memory Budget"):
        they get ZERO importance for selection (never loaded from flash) but
        always participate in compute. The paper notes remaining uncached
        accesses become more scattered — making chunk selection *more*
        valuable; `tests/test_serving.py` asserts exactly that."""
        validate_method(method)
        self.cfg = cfg
        self.method = method
        self.reorderings = reorderings or {}
        self.cached = cached or {}
        sp = sparsity if isinstance(sparsity, dict) else {
            k: float(sparsity) for k in ("hidden_attn", "hidden_mlp", "ffn", "attn_out")
        }
        d, hd_all = cfg.d_model, cfg.n_heads * cfg.resolved_head_dim
        kv_all = cfg.n_kv_heads * cfg.resolved_head_dim
        self.sites: Dict[str, _Site] = {
            # q + k + v share the hidden-state mask
            "hidden_attn": _site(d, (hd_all, kv_all, kv_all), device, sp["hidden_attn"]),
            "attn_out": _site(hd_all, (d,), device, sp["attn_out"]),
        }
        if cfg.d_ff and not cfg.has_moe:
            # gate + up share the hidden mask; down has its own (ffn) mask
            self.sites["hidden_mlp"] = _site(d, (cfg.d_ff, cfg.d_ff), device, sp["hidden_mlp"])
            self.sites["ffn"] = _site(cfg.d_ff, (d,), device, sp["ffn"])

    def mask(self, kind: str, acts: jnp.ndarray):
        """acts (..., N) → (mask (N,) float or None, est latency seconds)."""
        site = self.sites.get(kind)
        if site is None:
            return None, jnp.float32(0.0)
        if self.method == "dense":
            return None, jnp.float32(site.dense_latency)
        return self._compute_mask(kind, site, acts)

    def mask_planned(self, kind: str, acts: jnp.ndarray, cached_mask: jnp.ndarray,
                     refresh: jnp.ndarray):
        """``mask`` with temporal chunk-plan reuse (scanned decode loop).

        When ``refresh`` is true the selection runs as usual and its mask
        becomes the new plan entry; otherwise the cached mask from the last
        refresh step is reused at ZERO I/O cost — its chunks were loaded on
        that step and stay resident until the next refresh (the residency
        model benchmarks/disc5_caching.py gestures at, applied temporally).
        ``lax.cond`` skips the selection compute entirely on reuse steps.

        Returns (mask (N,) float, est latency, new plan entry (N,) float).
        """
        site = self.sites.get(kind)
        if site is None:
            return None, jnp.float32(0.0), cached_mask
        if self.method == "dense":
            # nothing resident to reuse: dense streams every matrix each step
            return None, jnp.float32(site.dense_latency), cached_mask

        def _refresh(_):
            return self._compute_mask(kind, site, acts)

        def _reuse(_):
            return cached_mask, jnp.float32(0.0)

        m, lat = jax.lax.cond(refresh, _refresh, _reuse, None)
        return m, lat, m

    def _compute_mask(self, kind: str, site: _Site, acts: jnp.ndarray):
        from ..core.importance import importance

        v = importance(acts)
        if kind in self.reorderings:
            v = self.reorderings[kind].apply_to_acts(v)
        cached = self.cached.get(kind)
        if cached is not None:
            cv = cached
            if kind in self.reorderings:
                cv = self.reorderings[kind].apply_to_acts(
                    cv.astype(jnp.float32)
                ).astype(bool)
            v = jnp.where(cv, 0.0, v)  # resident weights cost no I/O

        if self.method == "topk":
            m = topk_mask(v, site.budget())
        else:
            m, _, _ = site.selector.select(v, site.budget())
        lat = jnp.float32(0.0)
        for t in site.tables:
            lat += t.mask_latency(m)
        if kind in self.reorderings:
            # map mask back to original row order for application to acts
            inv = jnp.asarray(self.reorderings[kind].inverse)
            m = jnp.take(m, inv, axis=0)
        if cached is not None:
            m = m | cached  # cached neurons always compute, at zero I/O
        return m.astype(jnp.float32), lat

    def init_plan(self, n_layers: int) -> Dict[str, jnp.ndarray]:
        """Per-layer cached chunk masks for the scanned decode loop:
        {site: (n_layers, N) float32}, zero-initialized (the first scan step
        always refreshes, so the zeros are never applied). Empty for dense —
        there is no selection to cache."""
        if self.method == "dense":
            return {}
        return {
            kind: jnp.zeros((n_layers, site.n), jnp.float32)
            for kind, site in self.sites.items()
        }

    def dense_total_latency(self) -> float:
        """Full-load I/O latency per layer (all sites dense)."""
        return float(sum(s.dense_latency for s in self.sites.values()))
