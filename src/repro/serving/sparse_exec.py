"""SparseExecution: the paper's runtime policy wired into the model blocks.

One instance per (model config × device × policy). Model blocks call
``mask(kind, acts)`` once per sparsifiable projection input —
kind ∈ {hidden_attn, hidden_mlp, ffn, attn_out} mirroring the paper's
q / gate / down / o sites (k, v, up share masks with q and gate, App. A).

Everything runs inside jit: importance → utility-guided chunk selection
(jit-compiled ``lax.while_loop`` greedy) → mask + additive-model latency.
Latency accounts for every matrix sharing the mask (q+k+v for hidden_attn,
gate+up for hidden_mlp) with per-matrix row sizes.

Methods: "chunk" (ours), "topk" (TEAL/LLMFlash-style baseline),
"dense" (no sparsification — full contiguous load).

The planned decode path (the engine's scan/per-token loops) batches all of
a layer's sites into ONE selection dispatch per refresh step
(``refresh_layer`` → core.chunking.BatchedChunkSelector, a single vmapped
greedy instead of four sequential while_loops). To make that possible —
and to make the overlapped prefetch pipeline physically realizable, since
layer l+1's chunks must be known while layer l computes — refresh-step
selection consumes the importance vectors *recorded on the previous decode
step* (``record_importance`` stashes each site's importance into the plan
carry as the step runs; the first refresh bootstraps from uniform
importance). The unplanned paths (prefill / frame append / plain
``decode_step``) keep the original in-step per-site selection.

With ``cache_mb > 0`` a dynamic chunk residency cache (paper §5) rides the
decode-plan carry: per-(layer, site) score state whose top-``cap_rows``
entries are DRAM-resident, marginal-cost selection, miss-only I/O charging,
and hit/miss accounting — see docs/serving.md for the lifecycle.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.baselines import topk_mask
from ..core.chunking import BatchedChunkSelector, ChunkConfig, ChunkSelector
from ..core.faults import CorruptionModel, CorruptionProfile, corruption_key
from ..kernels.backend import ExecutionBackend, pick_tile
from ..kernels.chunk_gather_dma import masks_to_block_tables
from ..kernels.quantize import block_checksums
from ..core.latency_model import (
    DeviceProfile,
    LatencyTable,
    get_profile,
    profile_table,
    row_stream_bytes,
)
from ..core.offload import decode_site_shapes, normalize_site_sparsity
from ..core.reorder import Reordering
from ..sharding.serve import ServeMesh

DTYPE_BYTES = 2  # offloaded weights stored bf16/fp16 at wbits=16 (paper: fp16)

# Offloaded chunk storage widths (kernels/quantize.py): 16 = fp16 payload,
# 8 = int8 payload + one f32 scale per KERNEL_BLOCK_ROWS rows. All byte
# pricing (selector utilities, residency budget, IOEvent.nbytes) goes
# through core.latency_model.row_stream_bytes so every consumer sees the
# same per-row cost including the amortized scale overhead.
WBITS_CHOICES = (16, 8)

# Kernel chunk-table geometry for the DMA gather kernels
# (kernels/chunk_gather_dma.py): refresh steps convert each site's selected
# mask into a block-aligned padded (starts, sizes) table INSIDE jit
# (masks_to_block_tables — one vmapped call per layer over all sites, no
# per-site host re-splitting), so the plan carry always holds tables the
# kernels can consume directly.
KERNEL_BLOCK_ROWS = 8
KERNEL_MAX_CHUNK_ROWS = 512

# Dynamic residency-cache policy constants (paper §5, applied temporally):
# scores decay by RESIDENCY_DECAY per refresh step (recency) and grow by the
# row's importance when selected (frequency×magnitude) — a jit-friendly
# LFU/LRU hybrid. Pinned (pre-warmed) rows get PIN_SCORE so rank-based
# eviction never removes them.
RESIDENCY_DECAY = 0.9
PIN_SCORE = 1e30

# The single source of truth for serving policy names (ServeEngine and
# SparseExecution both validate against these):
#   * SPARSE_METHODS run through SparseExecution (selection + I/O accounting);
#   * "dense_free" means fully memory-resident weights — dense compute with
#     NO flash tier at all, so no SparseExecution instance and zero I/O.
SPARSE_METHODS = ("chunk", "topk", "dense")
SERVE_METHODS = SPARSE_METHODS + ("dense_free",)


def validate_method(method: str, allow_dense_free: bool = False) -> str:
    allowed = SERVE_METHODS if allow_dense_free else SPARSE_METHODS
    if method not in allowed:
        raise ValueError(f"unknown sparse method {method!r}; expected one of {allowed}")
    return method


def residency_from_score(score: jnp.ndarray, cap: int) -> jnp.ndarray:
    """Derive the resident set from a residency score vector: the top-``cap``
    rows by score (``topk_mask``'s stable rank — never exceeds ``cap`` rows
    even under score ties, so the byte budget holds by construction),
    excluding never-inserted rows (score <= 0). jit-safe."""
    return topk_mask(score, cap) & (score > 0.0)


def plan_hit_miss(plan) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Total residency-cache (hit_rows, miss_rows) accumulated in a decode
    plan/state pytree, summed over sites and layers. Counters accumulate
    within one engine decode call (``reset_plan_counters`` zeroes them at
    the start of each, bounding float32 round-off). Returns (0, 0) for
    empty plans. Without the residency tier ``hit`` is always 0 and
    ``miss`` counts every selected (streamed) row. jit-safe."""
    hit = jnp.float32(0.0)
    miss = jnp.float32(0.0)
    if not plan:
        return hit, miss
    for state in plan.values():
        if isinstance(state, dict) and "hit" in state:
            hit += jnp.sum(state["hit"])
            miss += jnp.sum(state["miss"])
    return hit, miss


def plan_transfer_bytes(plan) -> jnp.ndarray:
    """Total estimated flash→DRAM transfer volume accumulated in a decode
    plan pytree (cache-miss rows × per-site row bytes, summed over sites
    and layers) — the quantity the engine threads into ``IOEvent.nbytes``
    so ``FlashOffloadSimulator.total_bytes()`` is meaningful on the
    estimate-driven decode paths. jit-safe."""
    total = jnp.float32(0.0)
    if not plan:
        return total
    for state in plan.values():
        if isinstance(state, dict) and "bytes" in state:
            total += jnp.sum(state["bytes"])
    return total


def set_plan_budget_scale(plan, scale: float):
    """Rewrite the decode plan's carried budget-scale leaf ("bscale",
    present only on degradable plans — see ``SparseExecution.init_plan``)
    to ``scale`` for every layer and site. Host-side helper the engine
    calls between decode invocations with the DegradationController's
    current scale: because the scale rides the plan pytree it reaches the
    jitted refresh as a TRACED value — mutating a closed-over array on the
    SparseExecution instance would be a silent no-op once the scan is
    compiled. No-op (returns ``plan`` unchanged) on non-degradable plans."""
    if not plan:
        return plan
    s = float(scale)
    if not (0.0 < s <= 1.0):
        raise ValueError(f"budget scale must be in (0, 1], got {scale}")
    out = {}
    changed = False
    for kind, state in plan.items():
        if isinstance(state, dict) and "bscale" in state:
            state = dict(state)
            state["bscale"] = jnp.full_like(state["bscale"], s)
            changed = True
        out[kind] = state
    return out if changed else plan


def plan_budget_scale(plan) -> Optional[float]:
    """The (uniform) budget scale currently carried by a degradable plan,
    or None for plans without the "bscale" leaf. Host-side accessor."""
    for state in (plan or {}).values():
        if isinstance(state, dict) and "bscale" in state:
            return float(np.asarray(state["bscale"]).reshape(-1)[0])
    return None


# per-(layer, site) integrity counter lanes carried by the decode plan when
# corruption injection is on (PR 9): detected corrupt block-events, events
# recovered (clean re-read or rung-1 resident DRAM copy), substituted rows
# (rung 2), dropped rows (rung 3), re-reads charged, and the re-read +
# backoff seconds the engine routes through IOEvent.integrity_s
INTEGRITY_COUNTER_KEYS = ("cdet", "crec", "csub", "cdrop", "crr", "crr_s")


def plan_integrity_counters(plan) -> jnp.ndarray:
    """Total integrity counters accumulated in a decode plan pytree, as one
    (6,) float32 vector ordered like ``INTEGRITY_COUNTER_KEYS``. All-zero
    when the plan carries no integrity lanes (corruption off), so the
    engine can emit the vector unconditionally. jit-safe."""
    out = jnp.zeros((len(INTEGRITY_COUNTER_KEYS),), jnp.float32)
    if not plan:
        return out
    for state in plan.values():
        if isinstance(state, dict) and "cdet" in state:
            out = out + jnp.stack(
                [jnp.sum(state[k]) for k in INTEGRITY_COUNTER_KEYS]
            )
    return out


def reset_plan_counters(plan):
    """Zero the hit/miss/bytes (and integrity-counter) accumulators of a
    decode plan state. Called by the engine at the start of each decode
    invocation so the float32 counters only ever accumulate one call's
    rows — exact far beyond any realistic n_tokens."""
    if not plan:
        return plan
    out = {}
    for kind, state in plan.items():
        if isinstance(state, dict):
            state = dict(state)
            for key in ("hit", "miss", "bytes", "hit_shard",
                        "miss_shard") + INTEGRITY_COUNTER_KEYS:
                if key in state:
                    state[key] = jnp.zeros_like(state[key])
        out[kind] = state
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class _Site:
    """One sparsification site: a selector + latency tables for every matrix
    sharing this input (e.g. q/k/v)."""

    n: int
    selector: ChunkSelector
    tables: Tuple[LatencyTable, ...]  # one per sharing matrix
    sparsity: float
    dense_latency: float

    def budget(self) -> jnp.ndarray:
        return jnp.int32(round((1.0 - self.sparsity) * self.n))


def _site(n_rows: int, out_cols: Tuple[int, ...], device, sparsity: float,
          wbits: int = 16) -> _Site:
    primary_rb = row_stream_bytes(out_cols[0], wbits, KERNEL_BLOCK_ROWS)
    cfg = ChunkConfig.for_shape(n_rows, out_cols[0],
                                device if isinstance(device, str) else device.name)
    selector = ChunkSelector.build(n_rows, primary_rb, device=device, cfg=cfg)
    tables = tuple(
        profile_table(device, row_stream_bytes(c, wbits, KERNEL_BLOCK_ROWS),
                      max_rows=selector.max_size)
        for c in out_cols
    )
    dense = float(
        sum(
            get_profile(device if isinstance(device, str) else device.name)
            .latency_bytes(n_rows * row_stream_bytes(c, wbits, KERNEL_BLOCK_ROWS))
            for c in out_cols
        )
    )
    return _Site(n=n_rows, selector=selector, tables=tables, sparsity=sparsity,
                 dense_latency=dense)


class SparseExecution:
    """sparse_ctx implementation passed into model block functions."""

    def __init__(
        self,
        cfg: ModelConfig,
        device: str | DeviceProfile = "nano",
        sparsity: float | Dict[str, float] = 0.4,
        method: str = "chunk",
        reorderings: Optional[Dict[str, Reordering]] = None,
        cached: Optional[Dict[str, "jnp.ndarray"]] = None,
        cache_mb: float = 0.0,
        backend: str | ExecutionBackend = "reference",
        kernel_prefetch_depth: int = 1,
        kernel_interpret: Optional[bool] = None,
        wbits: int = 16,
        mesh: Optional[ServeMesh] = None,
        degradable: bool = False,
        corruption_profile: Optional[str | CorruptionProfile] = None,
        corruption_seed: int = 0,
        max_reread: int = 2,
        corruption_recover: bool = True,
    ):
        """``backend``: the decode EXECUTION backend for the planned decode
        path (kernels/backend.py) — ``"reference"`` computes the masked
        projections as the kernels' pure-jnp schedule twin, ``"kernel"``
        dispatches the PR-4 DMA gather kernels off the plan's chunk tables
        (``chunk_gather_mlp_dma`` for the SwiGLU MLP,
        ``chunk_gather_matmul_dma`` for single-site projections). The two
        are bitwise identical; an ``ExecutionBackend`` instance may be
        passed directly. ``kernel_prefetch_depth`` is the DMA kernels' VMEM
        slot count − 1 (numerics are depth-invariant);
        ``kernel_interpret=None`` auto-selects interpret mode off-TPU.

        ``cache_mb``: DRAM byte budget of the dynamic chunk residency
        cache (paper §5 "Leveraging Additional Memory Budget"). When > 0,
        the decode plan carries a per-(layer, site) residency score vector;
        selection becomes marginal-cost aware (resident rows are free),
        refresh steps insert the selected chunks and evict by decayed
        importance rank when over budget, and the I/O estimate charges only
        cache-miss rows. Capacity is resolved per layer in ``init_plan``.

        ``cached``: per-site bool masks of neurons whose weights are
        memory-resident (the static §5 experiment). With ``cache_mb == 0``
        this is the legacy static path: they get ZERO importance for
        selection (never loaded from flash) but always participate in
        compute. With ``cache_mb > 0`` the masks are re-expressed as
        residency state that is pre-warmed and pinned (PIN_SCORE — never
        evicted, clipped to the byte budget).

        ``wbits``: offloaded chunk storage width — 16 (fp16 payload) or 8
        (int8 payload + per-block f32 scales, kernels/quantize.py). At 8
        every byte figure in the system (selector utilities, latency
        tables, residency budget, ``IOEvent.nbytes``) prices the quantized
        row, so the same I/O budget admits ~2x the rows.

        ``degradable``: adaptive-degradation support (serving/degrade.py).
        When True, ``init_plan`` adds a per-layer "bscale" leaf to every
        site entry — a traced multiplier on the selection budgets that the
        engine's ``DegradationController`` tightens while the storage
        device is degraded (fewer selected rows ⇒ fewer streamed bytes,
        leaning on residency-cache hits) and relaxes on recovery. At the
        default scale 1.0 the effective budgets are bit-exact the static
        ones, and with ``degradable=False`` (default) the plan pytree
        structure is exactly the pre-degradation one.

        ``corruption_profile`` / ``corruption_seed`` / ``max_reread`` /
        ``corruption_recover``: data-plane corruption injection
        (core/faults.py CorruptionModel). When the named profile actually
        corrupts (``p_block > 0``), every plan refresh draws per-matrix
        corrupt blocks among the rows FETCHED from flash, verifies them
        against the pack-time checksum lane (``block_checksums``), and —
        with recovery on — walks the detection/recovery ladder: bounded
        re-reads (seconds surfaced through the plan's ``crr_s`` lane),
        then the resident DRAM copy from the previous refresh epoch, then
        next-best-chunk substitution with a budget rebate, then drop.
        With recovery OFF the drawn corruption pattern is carried in the
        plan ("cblk") and applied to the weight payload at the gather
        boundary by ``apply_corruption`` — tokens CAN change, identically
        on both backends. Requires a selecting method, no reorderings and
        the unsharded mesh. ``None``/"none" ⇒ bit-identical behavior to a
        build without the integrity subsystem.

        ``mesh``: the serve-stack (data, model) mesh context
        (sharding/serve.py). Selection stays REPLICATED — importance
        vectors are constrained to full replication before any cross-batch
        reduction so every shard selects identical chunks — while the
        row-sharded sites' I/O splits by each model shard's contiguous row
        slice (``miss_shard``/``hit_shard`` plan lanes; byte totals sum to
        the unsharded figures). Defaults to the unsharded 1×1 mesh."""
        validate_method(method)
        if cache_mb < 0:
            raise ValueError(f"cache_mb must be >= 0, got {cache_mb}")
        if wbits not in WBITS_CHOICES:
            raise ValueError(
                f"wbits must be one of {WBITS_CHOICES}, got {wbits!r}"
            )
        self.wbits = int(wbits)
        self.cfg = cfg
        self.method = method
        self.mesh = mesh if mesh is not None else ServeMesh.single()
        if self.mesh.is_sharded and reorderings:
            raise ValueError(
                "sharded serving does not support reorderings: per-shard "
                "block tables and byte counters assume selection row order "
                "equals storage row order (pre-reorder the stored weights "
                "offline, or serve on the 1x1 mesh)"
            )
        self.reorderings = reorderings or {}
        self.cached = cached or {}
        self.degradable = bool(degradable)
        # data-plane corruption injection (PR 9): profile "none" (or None)
        # resolves to NO model at all, so the integrity-off refresh path is
        # bit-identical to a build without the subsystem
        self.corruption: Optional[CorruptionModel] = None
        if corruption_profile is not None:
            cm = CorruptionModel(
                corruption_profile, seed=corruption_seed,
                max_reread=max_reread, recover=corruption_recover,
            )
            if cm.enabled:
                if method not in ("chunk", "topk"):
                    raise ValueError(
                        "corruption injection needs a selecting method "
                        "('chunk' | 'topk') whose recovery ladder can edit "
                        f"the chunk plan, got {method!r}"
                    )
                if self.mesh.is_sharded:
                    raise ValueError(
                        "corruption injection does not support sharded "
                        "serving: the recovery ladder edits per-shard block "
                        "tables it cannot see (serve on the 1x1 mesh)"
                    )
                if reorderings:
                    raise ValueError(
                        "corruption injection does not support reorderings: "
                        "the rung-1 resident-copy check assumes selection "
                        "row order equals storage row order"
                    )
                self.corruption = cm
        self.cache_mb = float(cache_mb)
        self.cache_caps: Optional[Dict[str, int]] = None  # set by init_plan
        sp = normalize_site_sparsity(sparsity)
        # site geometry (which matrices share which mask) comes from the
        # shared table in core.offload so the overlap pipeline's compute
        # lane (ComputeModel.decode_layer_seconds) can never drift from it
        self.sites: Dict[str, _Site] = {
            kind: _site(n, cols, device, sp[kind], self.wbits)
            for kind, n, cols in decode_site_shapes(cfg)
        }
        if self.corruption is not None:
            # the checksum lane is one u32 per KERNEL_BLOCK_ROWS rows — the
            # integrity draw/verify needs whole blocks on EVERY backend
            # (the kernel backend validates this anyway; reference doesn't)
            for kind, site in self.sites.items():
                if site.n % KERNEL_BLOCK_ROWS:
                    raise ValueError(
                        f"corruption injection needs site {kind!r} input "
                        f"dim {site.n} divisible by "
                        f"block_rows={KERNEL_BLOCK_ROWS}"
                    )
        # per-shard I/O geometry: the sites whose STREAMED row dim shards
        # over the model axis ('attn_out' streams wo rows, 'ffn' streams
        # w_down/w_proj rows) get data-dependent per-shard miss counters —
        # shard s owns contiguous rows [s*n/S, (s+1)*n/S). The col-sharded
        # sites' rows replicate, so their bytes split evenly instead.
        self.n_shards = self.mesh.model if self.mesh.is_sharded else 1
        self.row_shards: Dict[str, int] = {
            kind: (self.mesh.row_shard_count(site.n)
                   if kind in ("attn_out", "ffn") else 1)
            for kind, site in self.sites.items()
        }
        # static `cached` masks re-expressed in SELECTION (reordered) row
        # order: the pre-warmed, pinned portion of the dynamic residency tier
        self.pinned_sel: Dict[str, jnp.ndarray] = {}
        for kind, cm in self.cached.items():
            if kind not in self.sites:
                continue
            cv = cm.astype(jnp.float32)
            if kind in self.reorderings:
                cv = self.reorderings[kind].apply_to_acts(cv)
            self.pinned_sel[kind] = cv > 0.0
        # the planned decode path batches all sites of a layer into one
        # selection dispatch (one vmapped greedy instead of one per site)
        self.site_order: Tuple[str, ...] = tuple(self.sites)
        self.batched = BatchedChunkSelector.build(
            [self.sites[k].selector for k in self.site_order]
        )
        self._budgets = jnp.asarray(
            [int(self.sites[k].budget()) for k in self.site_order], jnp.int32
        )
        # padded kernel chunk-table length: worst case every block its own
        # chunk (masks_to_block_tables pads every site's table to this)
        self.kernel_k = -(-self.batched.n_max // KERNEL_BLOCK_ROWS)
        # the decode execution backend (reference schedule twin vs DMA
        # kernels) — the planned decode path computes through it
        if isinstance(backend, ExecutionBackend):
            if self.mesh.is_sharded and backend.mesh is None:
                # the backend's operand all-gather is what keeps sharded
                # decode bitwise — never let a pre-built backend skip it
                backend = dataclasses.replace(backend, mesh=self.mesh.mesh)
            self.backend = backend
        else:
            self.backend = ExecutionBackend.create(
                backend,
                prefetch_depth=kernel_prefetch_depth,
                interpret=kernel_interpret,
                block_rows=KERNEL_BLOCK_ROWS,
                max_chunk_rows=KERNEL_MAX_CHUNK_ROWS,
                mesh=self.mesh.mesh,
            )
        if self.backend.is_kernel:
            self._validate_kernel_backend(cfg)

    def _validate_kernel_backend(self, cfg: ModelConfig) -> None:
        """The DMA gather kernels' static preconditions, checked up front so
        a misconfigured engine fails at construction, not mid-scan."""
        if self.reorderings:
            raise ValueError(
                "backend='kernel' does not support reorderings: the kernels "
                "gather weight rows by storage offset, so reordered "
                "selection-order chunk tables would index the wrong rows of "
                "the original-order weights (pre-reorder the stored weights "
                "offline, or use backend='reference')"
            )
        # every decode site dispatches through the kernels now: hidden_attn's
        # q/k/v and attn_out's wo via chunk_gather_matmul_dma, the MLP
        # matrices via the fused chunk_gather_mlp_dma (or matmul_dma for the
        # non-gated gelu family) — so all site geometries are constrained.
        kernel_sites = ("hidden_attn", "attn_out", "hidden_mlp", "ffn")
        for kind, n, cols in decode_site_shapes(cfg):
            if kind not in kernel_sites:
                continue
            if n % KERNEL_BLOCK_ROWS:
                raise ValueError(
                    f"backend='kernel' needs site {kind!r} input dim {n} "
                    f"divisible by block_rows={KERNEL_BLOCK_ROWS}"
                )
            for c in cols:
                pick_tile(c)  # raises if no power-of-two tile >= 8 divides

    # -- chunk integrity (PR 9) ------------------------------------------------
    @property
    def integrity_enabled(self) -> bool:
        """True when data-plane corruption injection is active: plan
        refreshes draw/verify corrupt blocks and carry integrity lanes."""
        return self.corruption is not None

    @property
    def integrity_corrupting(self) -> bool:
        """True in the recovery-OFF mode: the drawn corruption pattern is
        carried in the plan ("cblk") and must be applied to the weight
        payloads at the gather boundary (``apply_corruption``)."""
        return self.corruption is not None and not self.corruption.recover

    def site_matrix_count(self, kind: str) -> int:
        """How many stored matrices actually stream through a site — the
        width of the integrity lanes. The non-gated gelu family's
        hidden_mlp site streams ONE matrix (w_fc) even though the latency
        geometry prices two lanes (decode_site_shapes)."""
        if kind == "hidden_attn":
            return 3  # wq, wk, wv
        if kind == "hidden_mlp" and self.cfg.mlp == "gelu":
            return 1  # w_fc
        if kind == "hidden_mlp":
            return 2  # w_gate, w_up
        return 1  # attn_out: wo; ffn: w_down / w_proj

    def apply_corruption(self, plan, kind: str, matrix_idx: int, w):
        """Recovery-OFF data plane: damage one (N, D) weight payload with
        the corruption pattern the last refresh drew for it (the plan's
        "cblk" lane) — re-deriving the exact bit/element draws from the
        same (seed, layer, epoch, site, matrix) key. Both execution
        backends consume the identical damaged operand, so even corrupted
        tokens stay byte-identical across backends. No-op (returns ``w``)
        unless ``integrity_corrupting``."""
        if not self.integrity_corrupting or kind not in plan:
            return w
        cm = self.corruption
        entry = plan[kind]
        key = corruption_key(
            cm.base_key(), entry["lid"], entry["epoch"],
            self.site_order.index(kind), matrix_idx,
        )
        return cm.corrupt_payload(
            w, entry["cblk"][matrix_idx], key, KERNEL_BLOCK_ROWS
        )

    def mask(self, kind: str, acts: jnp.ndarray):
        """acts (..., N) → (mask (N,) float or None, est latency seconds)."""
        site = self.sites.get(kind)
        if site is None:
            return None, jnp.float32(0.0)
        if self.method == "dense":
            return None, jnp.float32(site.dense_latency)
        return self._compute_mask(kind, site, acts)

    def record_importance(self, kind: str, acts: jnp.ndarray, plan):
        """Stash this site's current-step importance (selection row order)
        into the plan carry as the ``pending`` vector the NEXT refresh
        step's batched selection will consume. Runs every planned decode
        step (cheap — one |·| reduction + optional gather; no selection)."""
        if kind not in plan:
            return plan
        from ..core.importance import importance

        # replicate BEFORE the cross-batch reduction inside importance():
        # on a data-sharded batch an unconstrained mean would let GSPMD
        # psum partial sums per shard — a different f32 summation order
        # than the 1x1 mesh, breaking bitwise token identity. With the
        # explicit constraint every shard reduces the full batch in the
        # single-device order. No-op on the unsharded path.
        acts = self.mesh.replicate(acts)
        v = importance(acts)
        if kind in self.reorderings:
            v = self.reorderings[kind].apply_to_acts(v)
        entry = dict(plan[kind])
        entry["pending"] = v
        new_plan = dict(plan)
        new_plan[kind] = entry
        return new_plan

    def refresh_layer(self, plan, refresh: jnp.ndarray, weights=None):
        """One batched refresh for ALL of a layer's sites — the planned
        decode path's replacement for per-site selection calls.

        ``plan`` is one layer's slice of the decode-plan carry
        ({site: {mask, pending, hit, miss, bytes[, score]}}, see
        ``init_plan``). When ``refresh`` is true, the sites' ``pending``
        importance vectors (recorded on the previous step) are padded into
        one (n_sites, N_max) problem and solved by a single vmapped greedy
        (``BatchedChunkSelector.select``; vmapped ``topk_mask`` for the
        topk baseline) — with the residency tier enabled the selection is
        marginal-cost aware and only cache-miss rows are charged. The new
        masks (original row order) land in the plan together with the
        updated residency scores and hit/miss/bytes counters. On reuse
        steps ``lax.cond`` skips everything and the cached masks cost ZERO
        I/O — their chunks are still resident from the refresh that
        selected them.

        ``weights`` (integrity mode only): {site: ((payload, checksums),
        ...)} — each site's stored payload matrices with their pack-time
        checksum lanes, in site matrix order. Each refresh draws corrupt
        blocks among the FETCHED rows, re-verifies the damaged payload
        against the checksums (identical jnp verdict computation for both
        execution backends), and with recovery on walks the ladder:
        re-read (charged through the "crr_s" plan lane, never the returned
        estimate — plan-vs-reality separation, exactly like FaultModel) →
        rung-1 resident DRAM copy (every fetched row of the block was in
        the previous epoch's mask, so the working copy still holds it) →
        rung-2 substitution of the next-best non-selected rows by pending
        importance (the budget rebate: row count never grows) → rung-3
        drop. Substituted rows are charged as fresh fetches; removed rows'
        wasted reads stay charged (they really streamed).

        Returns (new_plan, est_io_latency_seconds for this layer).
        """
        if not plan:
            return plan, jnp.float32(0.0)
        # lanes of the batched problem are indexed by site_order position —
        # a partial plan would silently misalign budgets/schedules/tables,
        # so require exactly the full site set (init_plan always builds it)
        if set(plan) != set(self.site_order):
            raise ValueError(
                f"refresh_layer needs a plan entry per site {self.site_order}, "
                f"got {tuple(plan)}"
            )
        order = self.site_order
        cache = self.cache_enabled
        integ = self.integrity_enabled
        if integ:
            if weights is None:
                raise ValueError(
                    "corruption injection is on but refresh_layer got no "
                    "weights — the planned decode path must pass each "
                    "site's (payload, checksums) matrices"
                )
            for kind in order:
                want = self.site_matrix_count(kind)
                got = len(weights.get(kind, ()))
                if got != want:
                    raise ValueError(
                        f"site {kind!r} streams {want} matrices, integrity "
                        f"weights carry {got}"
                    )

        def _refresh(_):
            vs = jnp.zeros((self.batched.n_sites, self.batched.n_max), jnp.float32)
            residents = []
            for i, kind in enumerate(order):
                site = self.sites[kind]
                v = plan[kind]["pending"]
                pinned = self.pinned_sel.get(kind)
                if pinned is not None and not cache:
                    # legacy static §5 path (cache_mb == 0): memory-resident
                    # neurons get ZERO importance — never streamed — and are
                    # OR'd into the compute mask below, exactly like the
                    # unplanned _compute_mask path
                    v = jnp.where(pinned, 0.0, v)
                vs = vs.at[i, : site.n].set(v)
                if cache:
                    residents.append(
                        residency_from_score(plan[kind]["score"], self._cap(kind))
                    )
            if cache:
                res_pad = jnp.zeros(
                    (self.batched.n_sites, self.batched.n_max), bool
                )
                for i, kind in enumerate(order):
                    res_pad = res_pad.at[i, : self.sites[kind].n].set(residents[i])
            else:
                res_pad = None
            # degradable plans carry a traced per-layer budget multiplier
            # ("bscale"): the DegradationController's lever on the selected
            # row count. floor(b × 1.0) == b exactly (site sizes ≪ 2^24 are
            # f32-exact), so scale 1.0 is bit-identical to the static
            # budgets; the clip keeps at least one row selected per site.
            bscale = plan[order[0]].get("bscale")
            if bscale is None:
                budgets = self._budgets
            else:
                budgets = jnp.clip(
                    jnp.floor(
                        self._budgets.astype(jnp.float32) * bscale
                    ).astype(jnp.int32),
                    jnp.minimum(self._budgets, 1),
                    self._budgets,
                )
            if self.method == "topk":
                # LLM-in-a-flash-style baseline: selection ignores residency
                # (pure importance rank); only the I/O charge sees the cache.
                masks = jax.vmap(topk_mask)(vs, budgets)
                masks = masks & self.batched.row_valid
            else:
                masks, _ = self.batched.select(vs, budgets, res_pad)

            # -- chunk integrity (PR 9): draw → verify → recovery ladder ----
            # Runs between selection and the chunk-table build so rung 2/3
            # edits land in the tables both backends consume. Everything is
            # shared jnp — the verdicts are bitwise identical across
            # backends by construction.
            icnt: Dict[str, Dict[str, jnp.ndarray]] = {}
            fetch_masks = {}
            if integ:
                cm = self.corruption
                base = cm.base_key()
                lid = plan[order[0]]["lid"]
                epoch_new = plan[order[0]]["epoch"] + jnp.int32(1)
                for i, kind in enumerate(order):
                    site = self.sites[kind]
                    n = site.n
                    nb = n // KERNEL_BLOCK_ROWS
                    m = masks[i, :n]
                    res = (residents[i] if cache
                           else jnp.zeros((n,), bool))
                    # only rows that actually touch the storage data plane
                    # this epoch can arrive corrupted
                    fetched = m & ~res
                    fetched_blk = jnp.any(
                        fetched.reshape(nb, KERNEL_BLOCK_ROWS), axis=1
                    )
                    # rung-1 eligibility: every fetched row of the block was
                    # in the previous epoch's mask, so its clean bytes are
                    # still in the DRAM working copy (weights are static)
                    prev = plan[kind]["mask"] > 0.0
                    prev_cover = jnp.all(
                        (~fetched | prev).reshape(nb, KERNEL_BLOCK_ROWS),
                        axis=1,
                    )
                    cdet = jnp.float32(0.0)
                    crec = jnp.float32(0.0)
                    crr = jnp.float32(0.0)
                    crr_s = jnp.float32(0.0)
                    unrec_bad = jnp.zeros((nb,), bool)
                    cblks = []
                    for mi, (w_m, ck_m) in enumerate(weights[kind]):
                        key = corruption_key(base, lid, epoch_new, i, mi)
                        corrupt = cm.draw_blocks(key, fetched_blk)
                        damaged = cm.corrupt_payload(
                            w_m, corrupt, key, KERNEL_BLOCK_ROWS
                        )
                        # the honest verify: checksum the bytes the fetch
                        # delivered against the pack-time lane (a zeroed
                        # all-zero block is undetectable AND harmless)
                        det = corrupt & (
                            block_checksums(damaged, KERNEL_BLOCK_ROWS)
                            != ck_m
                        )
                        cdet += jnp.sum(det).astype(jnp.float32)
                        if cm.recover:
                            rr, rec = cm.draw_rereads(key, det)
                            tbl = site.tables[
                                min(mi, len(site.tables) - 1)
                            ]
                            crr += jnp.sum(rr).astype(jnp.float32)
                            crr_s += (
                                jnp.sum(rr).astype(jnp.float32)
                                * tbl.lookup(KERNEL_BLOCK_ROWS).astype(
                                    jnp.float32
                                )
                                + jnp.sum(cm.backoff_seconds(rr))
                            )
                            crec += jnp.sum(rec).astype(jnp.float32)
                            unrec = det & ~rec
                            # rung 1: serve the resident DRAM copy
                            crec += jnp.sum(
                                unrec & prev_cover
                            ).astype(jnp.float32)
                            unrec_bad = unrec_bad | (unrec & ~prev_cover)
                        else:
                            # recovery off: the damage flows to compute —
                            # carry the drawn pattern for apply_corruption
                            cblks.append(corrupt)
                    csub = jnp.float32(0.0)
                    cdrop = jnp.float32(0.0)
                    m_fetch = m
                    if cm.recover:
                        # rungs 2/3: a block unreadable in ANY matrix takes
                        # the whole site's rows with it (matrices share the
                        # mask); substitute the next-best non-selected rows
                        # by pending importance — candidates exclude the
                        # unreadable blocks themselves — and drop whatever
                        # the candidate pool cannot cover
                        removed = fetched & jnp.repeat(
                            unrec_bad, KERNEL_BLOCK_ROWS
                        )
                        k = jnp.sum(removed).astype(jnp.int32)
                        cand = ~m & ~jnp.repeat(
                            unrec_bad, KERNEL_BLOCK_ROWS
                        )
                        rank = (
                            jnp.zeros((n,), jnp.int32)
                            .at[jnp.argsort(jnp.where(
                                cand, -plan[kind]["pending"], jnp.inf
                            ))]
                            .set(jnp.arange(n, dtype=jnp.int32))
                        )
                        sub = cand & (rank < k)
                        csub = jnp.sum(sub).astype(jnp.float32)
                        cdrop = k.astype(jnp.float32) - csub
                        # the budget rebate: |final| = |m| - dropped ≤ |m|
                        masks = masks.at[i, :n].set((m & ~removed) | sub)
                        # substitutes are fresh fetches; the removed rows'
                        # wasted reads really streamed, so both stay charged
                        m_fetch = m | sub
                    fetch_masks[kind] = m_fetch
                    entry = {"epoch": epoch_new, "cdet": cdet,
                             "crec": crec, "csub": csub, "cdrop": cdrop,
                             "crr": crr, "crr_s": crr_s}
                    if not cm.recover:
                        entry["cblk"] = jnp.stack(cblks)
                    icnt[kind] = entry

            # the kernel gather plan: every site's COMPUTE mask (selection /
            # storage row order; legacy static-resident rows participate in
            # compute, so they join the gather) → block-aligned chunk tables
            # in ONE vmapped dispatch — no per-site host re-splitting
            tbl_masks = masks
            for i, kind in enumerate(order):
                pinned = self.pinned_sel.get(kind)
                if pinned is not None and not cache:
                    site_n = self.sites[kind].n
                    tbl_masks = tbl_masks.at[i, :site_n].set(
                        tbl_masks[i, :site_n] | pinned
                    )
            kstarts, ksizes = masks_to_block_tables(
                tbl_masks, KERNEL_BLOCK_ROWS, KERNEL_MAX_CHUNK_ROWS
            )

            lat = jnp.float32(0.0)
            outs = {}
            for i, kind in enumerate(order):
                site = self.sites[kind]
                m = masks[i, : site.n]
                res = residents[i] if cache else jnp.zeros((site.n,), bool)
                # integrity mode: I/O is charged for the rows that actually
                # streamed (original selection + rung-2 substitutes; the
                # dropped rows' wasted reads included) while ``m`` is the
                # post-ladder COMPUTE mask; identical to ``m`` otherwise
                mf = fetch_masks[kind] if integ else m
                for t in site.tables:
                    # one coalesced request per selected run, charged for
                    # miss rows only (resident rows never fragment it)
                    lat += t.mask_latency_miss(mf, res) if cache else t.mask_latency(mf)
                hit = jnp.sum(m & res).astype(jnp.float32)
                miss = jnp.sum(mf & ~res).astype(jnp.float32)
                nbytes = miss * jnp.float32(self.site_row_bytes(kind))
                ns = self.row_shards[kind]
                if ns > 1:
                    # which model shard each miss row streams FROM: shard s
                    # owns contiguous rows [s*n/S, (s+1)*n/S) — counted here
                    # in selection (== storage) row order, the order the
                    # sharded path guarantees (reorderings are rejected)
                    seg = site.n // ns
                    hit_shard = jnp.sum(
                        (m & res).reshape(ns, seg), axis=1
                    ).astype(jnp.float32)
                    miss_shard = jnp.sum(
                        (m & ~res).reshape(ns, seg), axis=1
                    ).astype(jnp.float32)
                if cache:
                    # recency/score eviction state: decay all, reinforce selected
                    score = RESIDENCY_DECAY * plan[kind]["score"] + jnp.where(
                        m, plan[kind]["pending"], 0.0
                    )
                    pinned = self.pinned_sel.get(kind)
                    if pinned is not None:
                        score = jnp.where(pinned, PIN_SCORE, score)
                else:
                    score = None
                if kind in self.reorderings:
                    inv = jnp.asarray(self.reorderings[kind].inverse)
                    m = jnp.take(m, inv, axis=0)
                cached_orig = self.cached.get(kind)
                if cached_orig is not None and not cache:
                    m = m | cached_orig  # cached neurons always compute, free
                entry = {"mask": m.astype(jnp.float32), "hit": hit,
                         "miss": miss, "bytes": nbytes,
                         "kstarts": kstarts[i], "ksizes": ksizes[i]}
                if ns > 1:
                    entry["hit_shard"] = hit_shard
                    entry["miss_shard"] = miss_shard
                if cache:
                    entry["score"] = score
                if integ:
                    entry.update(icnt[kind])
                outs[kind] = entry
            return outs, lat

        def _reuse(_):
            zero = jnp.float32(0.0)
            outs = {}
            for kind in order:
                entry = {"mask": plan[kind]["mask"], "hit": zero,
                         "miss": zero, "bytes": zero,
                         "kstarts": plan[kind]["kstarts"],
                         "ksizes": plan[kind]["ksizes"]}
                ns = self.row_shards[kind]
                if ns > 1:
                    entry["hit_shard"] = jnp.zeros((ns,), jnp.float32)
                    entry["miss_shard"] = jnp.zeros((ns,), jnp.float32)
                if cache:
                    entry["score"] = plan[kind]["score"]
                if integ:
                    # no fetch ⇒ no new corruption: the epoch (and, with
                    # recovery off, the damaged DRAM copy's "cblk" pattern)
                    # carries over unchanged until the next refresh
                    entry["epoch"] = plan[kind]["epoch"]
                    for key in INTEGRITY_COUNTER_KEYS:
                        entry[key] = zero
                    if "cblk" in plan[kind]:
                        entry["cblk"] = plan[kind]["cblk"]
                outs[kind] = entry
            return outs, jnp.float32(0.0)

        results, lat = jax.lax.cond(refresh, _refresh, _reuse, None)
        new_plan = dict(plan)
        for kind in order:
            entry = dict(plan[kind])
            entry["mask"] = results[kind]["mask"]
            entry["hit"] = plan[kind]["hit"] + results[kind]["hit"]
            entry["miss"] = plan[kind]["miss"] + results[kind]["miss"]
            entry["bytes"] = plan[kind]["bytes"] + results[kind]["bytes"]
            if "hit_shard" in results[kind]:
                entry["hit_shard"] = (
                    plan[kind]["hit_shard"] + results[kind]["hit_shard"]
                )
                entry["miss_shard"] = (
                    plan[kind]["miss_shard"] + results[kind]["miss_shard"]
                )
            entry["kstarts"] = results[kind]["kstarts"]
            entry["ksizes"] = results[kind]["ksizes"]
            if cache:
                entry["score"] = results[kind]["score"]
            if integ:
                entry["epoch"] = results[kind]["epoch"]
                for key in INTEGRITY_COUNTER_KEYS:
                    entry[key] = plan[kind][key] + results[kind][key]
                if "cblk" in results[kind]:
                    entry["cblk"] = results[kind]["cblk"]
            new_plan[kind] = entry
        return new_plan, lat

    def time_selection(self, repeats: int = 5) -> float:
        """Median wall-seconds of ONE layer's refresh-step selection
        dispatch (compiled & warmed) — the same quantity
        ``benchmarks/fig13_overhead.py`` measures per matrix, measured here
        for the batched per-layer dispatch the serve engine actually runs.
        The engine amortizes it into ``StepStats.select_overhead_s``."""
        if self.method == "dense":
            return 0.0
        n_max = self.batched.n_max
        v = jnp.abs(jnp.sin(jnp.arange(self.batched.n_sites * n_max, dtype=jnp.float32)))
        vs = v.reshape(self.batched.n_sites, n_max)
        if self.method == "topk":
            fn = jax.jit(lambda x: jax.vmap(topk_mask)(x, self._budgets))
        else:
            fn = jax.jit(lambda x: self.batched.select(x, self._budgets)[0])
        fn(vs).block_until_ready()  # compile + warm
        walls = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(vs).block_until_ready()
            walls.append(time.perf_counter() - t0)
        return float(np.median(walls))

    def _compute_mask(self, kind: str, site: _Site, acts: jnp.ndarray):
        from ..core.importance import importance

        # same replication-before-reduction contract as record_importance
        # (this is the unplanned mask() path used by frame append)
        acts = self.mesh.replicate(acts)
        v = importance(acts)
        if kind in self.reorderings:
            v = self.reorderings[kind].apply_to_acts(v)
        cached = self.cached.get(kind)
        if cached is not None:
            cv = cached
            if kind in self.reorderings:
                cv = self.reorderings[kind].apply_to_acts(
                    cv.astype(jnp.float32)
                ).astype(bool)
            v = jnp.where(cv, 0.0, v)  # resident weights cost no I/O

        if self.method == "topk":
            m = topk_mask(v, site.budget())
        else:
            m, _, _ = site.selector.select(v, site.budget())
        lat = jnp.float32(0.0)
        for t in site.tables:
            lat += t.mask_latency(m)
        if kind in self.reorderings:
            # map mask back to original row order for application to acts
            inv = jnp.asarray(self.reorderings[kind].inverse)
            m = jnp.take(m, inv, axis=0)
        if cached is not None:
            m = m | cached  # cached neurons always compute, at zero I/O
        return m.astype(jnp.float32), lat

    # -- kernel chunk-table plumbing -----------------------------------------
    def kernel_tables(self, plan, kind: str):
        """One site's kernel chunk tables from a decode-plan pytree:
        (starts, sizes), each (n_layers, kernel_k) — or (kernel_k,) for a
        single layer's slice — in selection (storage) row order, block
        aligned, directly consumable by the DMA gather kernels."""
        if kind not in plan:
            raise KeyError(f"no plan entry for site {kind!r}")
        return plan[kind]["kstarts"], plan[kind]["ksizes"]

    def mlp_kernel_plan(self, plan, layer: Optional[int] = None):
        """The fused multi-site MLP kernel's (2, kernel_k) plan lanes —
        lane 0 = hidden_mlp (gate/up), lane 1 = ffn (down) — stacked
        straight from the batched refresh's tables, no re-splitting.
        ``layer`` selects one layer of an (L, K) plan; None expects a
        single-layer slice."""
        for kind in ("hidden_mlp", "ffn"):
            if kind not in plan:
                raise KeyError(
                    f"plan has no {kind!r} site (MoE FFNs have no dense MLP "
                    "sites — the fused MLP kernel does not apply)"
                )
        hs, hz = self.kernel_tables(plan, "hidden_mlp")
        fs, fz = self.kernel_tables(plan, "ffn")
        if layer is not None:
            hs, hz, fs, fz = hs[layer], hz[layer], fs[layer], fz[layer]
        return jnp.stack([hs, fs]), jnp.stack([hz, fz])

    # -- residency-tier capacity ---------------------------------------------
    @property
    def cache_enabled(self) -> bool:
        """The dynamic residency tier applies to the selecting methods only:
        dense streams every matrix every step regardless of budget."""
        return self.cache_mb > 0 and self.method in ("chunk", "topk")

    def site_row_bytes(self, kind: str) -> float:
        """Total streamed bytes of one row across every matrix sharing the
        site — fractional at wbits=8 (int8 payload + the per-block scale
        overhead amortized over KERNEL_BLOCK_ROWS rows)."""
        return float(sum(t.row_bytes for t in self.sites[kind].tables))

    def sparsifiable_bytes(self, n_layers: int) -> float:
        """Total offloaded-weight footprint governed by sparsification."""
        return n_layers * sum(
            site.n * self.site_row_bytes(kind) for kind, site in self.sites.items()
        )

    def _resolve_cache(self, n_layers: int) -> Dict[str, int]:
        """Split the byte budget into per-(layer, site) row caps: the same
        fraction of every matrix is cacheable, so cap_rows = frac * N."""
        total = self.sparsifiable_bytes(n_layers)
        frac = min(1.0, self.cache_mb * 1024.0 * 1024.0 / max(total, 1))
        self.cache_caps = {
            kind: int(frac * site.n) for kind, site in self.sites.items()
        }
        return self.cache_caps

    def _cap(self, kind: str) -> int:
        if self.cache_caps is None:
            raise RuntimeError(
                "residency capacity unresolved — call init_plan(n_layers) "
                "before refresh_layer with the residency cache enabled"
            )
        return self.cache_caps[kind]

    def init_plan(self, n_layers: int) -> Dict[str, Any]:
        """Per-layer decode-plan state for the planned decode loops. Empty
        for dense — there is no selection to cache.

        Per site: {"mask": (L, N) float32 [original row order, applied to
        acts], "pending": (L, N) float32 [selection row order — the
        importance recorded last step that the next refresh's batched
        selection consumes; initialized to ones so the first refresh
        bootstraps from uniform importance], "hit"/"miss"/"bytes": (L,)
        float32 counters accumulated across the refresh steps of one engine
        decode call (zeroed per call by ``reset_plan_counters``;
        ``ServeEngine.io_summary`` reads hit/miss back as the residency
        tier's hit rate and ``bytes`` feeds ``IOEvent.nbytes``)}.

        With the residency cache enabled (``cache_mb > 0``) a "score"
        (L, N) eviction state rides along (decayed importance; the resident
        set is its top cap_rows); pre-warmed ``cached`` rows start at
        PIN_SCORE.

        With corruption injection on, every site also carries the
        integrity lanes: "lid" (L,) layer ids + "epoch" (L,) refresh
        counters (the corruption key schedule's traced inputs), the six
        ``INTEGRITY_COUNTER_KEYS`` (L,) accumulators, and — recovery OFF
        only — the drawn corrupt-block pattern "cblk"
        (L, n_matrices, n_blocks) that ``apply_corruption`` replays at the
        gather boundary.
        """
        if self.method == "dense":
            return {}
        if self.cache_enabled:
            self._resolve_cache(n_layers)
        plan: Dict[str, Any] = {}
        for kind, site in self.sites.items():
            entry = {
                "mask": jnp.zeros((n_layers, site.n), jnp.float32),
                "pending": jnp.ones((n_layers, site.n), jnp.float32),
                "hit": jnp.zeros((n_layers,), jnp.float32),
                "miss": jnp.zeros((n_layers,), jnp.float32),
                "bytes": jnp.zeros((n_layers,), jnp.float32),
                # block-aligned kernel chunk tables (selection row order),
                # refreshed alongside the masks — the DMA gather kernels'
                # direct input (all-zero until the first refresh = no chunks)
                "kstarts": jnp.zeros((n_layers, self.kernel_k), jnp.int32),
                "ksizes": jnp.zeros((n_layers, self.kernel_k), jnp.int32),
            }
            if self.row_shards[kind] > 1:
                # per-model-shard hit/miss row counters (sharded serving):
                # which shard's flash tier each streamed row comes from —
                # summed over shards these equal the scalar hit/miss lanes
                ns = self.row_shards[kind]
                entry["hit_shard"] = jnp.zeros((n_layers, ns), jnp.float32)
                entry["miss_shard"] = jnp.zeros((n_layers, ns), jnp.float32)
            if self.cache_enabled:
                score0 = jnp.zeros((n_layers, site.n), jnp.float32)
                pinned = self.pinned_sel.get(kind)
                if pinned is not None:
                    score0 = jnp.where(pinned[None, :], PIN_SCORE, score0)
                entry["score"] = score0
            if self.degradable:
                # the DegradationController's traced budget multiplier —
                # rewritten between decode calls by set_plan_budget_scale,
                # consumed inside the jitted refresh (1.0 = full budgets)
                entry["bscale"] = jnp.ones((n_layers,), jnp.float32)
            if self.integrity_enabled:
                entry["lid"] = jnp.arange(n_layers, dtype=jnp.int32)
                entry["epoch"] = jnp.zeros((n_layers,), jnp.int32)
                for key in INTEGRITY_COUNTER_KEYS:
                    entry[key] = jnp.zeros((n_layers,), jnp.float32)
                if self.integrity_corrupting:
                    entry["cblk"] = jnp.zeros(
                        (n_layers, self.site_matrix_count(kind),
                         site.n // KERNEL_BLOCK_ROWS),
                        bool,
                    )
            plan[kind] = entry
        return plan

    def plan_shard_bytes(self, plan) -> jnp.ndarray:
        """Per-model-shard flash→DRAM transfer bytes accumulated in a decode
        plan pytree, shape (n_shards,). Row-sharded sites contribute their
        data-dependent ``miss_shard`` counts × per-site row bytes; the
        col-sharded / replicated sites split their byte totals evenly (each
        shard streams 1/n_shards of every replicated row's columns).
        Sums exactly to ``plan_transfer_bytes`` up to f32 round-off —
        the ISSUE's shard-accounting invariant. jit-safe."""
        out = jnp.zeros((self.n_shards,), jnp.float32)
        if not plan:
            return out
        if self.n_shards == 1:
            return out + plan_transfer_bytes(plan)
        for kind in plan:
            state = plan[kind]
            if not isinstance(state, dict) or "bytes" not in state:
                continue
            if "miss_shard" in state:
                rb = jnp.float32(self.site_row_bytes(kind))
                out = out + jnp.sum(
                    state["miss_shard"].reshape(-1, self.n_shards), axis=0
                ) * rb
            else:
                out = out + jnp.sum(state["bytes"]) / self.n_shards
        return out

    def dense_total_latency(self) -> float:
        """Full-load I/O latency per layer (all sites dense)."""
        return float(sum(s.dense_latency for s in self.sites.values()))
