"""SparseExecution: the paper's runtime policy wired into the model blocks.

One instance per (model config × device × policy). Model blocks call
``mask(kind, acts)`` once per sparsifiable projection input —
kind ∈ {hidden_attn, hidden_mlp, ffn, attn_out} mirroring the paper's
q / gate / down / o sites (k, v, up share masks with q and gate, App. A).

Everything runs inside jit: importance → utility-guided chunk selection
(jit-compiled ``lax.while_loop`` greedy) → mask + additive-model latency.
Latency accounts for every matrix sharing the mask (q+k+v for hidden_attn,
gate+up for hidden_mlp) with per-matrix row sizes.

Methods: "chunk" (ours), "topk" (TEAL/LLMFlash-style baseline),
"dense" (no sparsification — full contiguous load).

With ``cache_mb > 0`` a dynamic chunk residency cache (paper §5) rides the
decode-plan carry: per-(layer, site) score state whose top-``cap_rows``
entries are DRAM-resident, marginal-cost selection, miss-only I/O charging,
and hit/miss accounting — see docs/serving.md for the lifecycle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.baselines import topk_mask
from ..core.chunking import ChunkConfig, ChunkSelector
from ..core.latency_model import DeviceProfile, LatencyTable, get_profile, profile_table
from ..core.reorder import Reordering

DTYPE_BYTES = 2  # offloaded weights stored bf16/fp16 (paper: fp16)

# Dynamic residency-cache policy constants (paper §5, applied temporally):
# scores decay by RESIDENCY_DECAY per refresh step (recency) and grow by the
# row's importance when selected (frequency×magnitude) — a jit-friendly
# LFU/LRU hybrid. Pinned (pre-warmed) rows get PIN_SCORE so rank-based
# eviction never removes them.
RESIDENCY_DECAY = 0.9
PIN_SCORE = 1e30

# The single source of truth for serving policy names (ServeEngine and
# SparseExecution both validate against these):
#   * SPARSE_METHODS run through SparseExecution (selection + I/O accounting);
#   * "dense_free" means fully memory-resident weights — dense compute with
#     NO flash tier at all, so no SparseExecution instance and zero I/O.
SPARSE_METHODS = ("chunk", "topk", "dense")
SERVE_METHODS = SPARSE_METHODS + ("dense_free",)


def validate_method(method: str, allow_dense_free: bool = False) -> str:
    allowed = SERVE_METHODS if allow_dense_free else SPARSE_METHODS
    if method not in allowed:
        raise ValueError(f"unknown sparse method {method!r}; expected one of {allowed}")
    return method


def residency_from_score(score: jnp.ndarray, cap: int) -> jnp.ndarray:
    """Derive the resident set from a residency score vector: the top-``cap``
    rows by score (``topk_mask``'s stable rank — never exceeds ``cap`` rows
    even under score ties, so the byte budget holds by construction),
    excluding never-inserted rows (score <= 0). jit-safe."""
    return topk_mask(score, cap) & (score > 0.0)


def plan_hit_miss(plan) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Total residency-cache (hit_rows, miss_rows) accumulated in a decode
    plan/state pytree, summed over sites and layers. Counters accumulate
    within one engine decode call (``reset_plan_counters`` zeroes them at
    the start of each, bounding float32 round-off). Returns (0, 0) for the
    legacy mask-only plan format and for empty plans. jit-safe."""
    hit = jnp.float32(0.0)
    miss = jnp.float32(0.0)
    if not plan:
        return hit, miss
    for state in plan.values():
        if isinstance(state, dict):
            hit += jnp.sum(state["hit"])
            miss += jnp.sum(state["miss"])
    return hit, miss


def reset_plan_counters(plan):
    """Zero the hit/miss accumulators of a residency plan state (no-op for
    the legacy mask-only format). Called by the engine at the start of each
    decode invocation so the float32 counters only ever accumulate one
    call's rows — exact far beyond any realistic n_tokens."""
    if not plan:
        return plan
    out = {}
    for kind, state in plan.items():
        if isinstance(state, dict):
            state = dict(state)
            state["hit"] = jnp.zeros_like(state["hit"])
            state["miss"] = jnp.zeros_like(state["miss"])
        out[kind] = state
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class _Site:
    """One sparsification site: a selector + latency tables for every matrix
    sharing this input (e.g. q/k/v)."""

    n: int
    selector: ChunkSelector
    tables: Tuple[LatencyTable, ...]  # one per sharing matrix
    sparsity: float
    dense_latency: float

    def budget(self) -> jnp.ndarray:
        return jnp.int32(round((1.0 - self.sparsity) * self.n))


def _site(n_rows: int, out_cols: Tuple[int, ...], device, sparsity: float) -> _Site:
    primary_rb = out_cols[0] * DTYPE_BYTES
    cfg = ChunkConfig.for_shape(n_rows, out_cols[0],
                                device if isinstance(device, str) else device.name)
    selector = ChunkSelector.build(n_rows, primary_rb, device=device, cfg=cfg)
    tables = tuple(
        profile_table(device, c * DTYPE_BYTES, max_rows=selector.max_size)
        for c in out_cols
    )
    dense = float(
        sum(
            get_profile(device if isinstance(device, str) else device.name)
            .latency_bytes(n_rows * c * DTYPE_BYTES)
            for c in out_cols
        )
    )
    return _Site(n=n_rows, selector=selector, tables=tables, sparsity=sparsity,
                 dense_latency=dense)


class SparseExecution:
    """sparse_ctx implementation passed into model block functions."""

    def __init__(
        self,
        cfg: ModelConfig,
        device: str | DeviceProfile = "nano",
        sparsity: float | Dict[str, float] = 0.4,
        method: str = "chunk",
        reorderings: Optional[Dict[str, Reordering]] = None,
        cached: Optional[Dict[str, "jnp.ndarray"]] = None,
        cache_mb: float = 0.0,
    ):
        """``cache_mb``: DRAM byte budget of the dynamic chunk residency
        cache (paper §5 "Leveraging Additional Memory Budget"). When > 0,
        the decode plan carries a per-(layer, site) residency score vector;
        selection becomes marginal-cost aware (resident rows are free),
        refresh steps insert the selected chunks and evict by decayed
        importance rank when over budget, and the I/O estimate charges only
        cache-miss rows. Capacity is resolved per layer in ``init_plan``.

        ``cached``: per-site bool masks of neurons whose weights are
        memory-resident (the static §5 experiment). With ``cache_mb == 0``
        this is the legacy static path: they get ZERO importance for
        selection (never loaded from flash) but always participate in
        compute. With ``cache_mb > 0`` the masks are re-expressed as
        residency state that is pre-warmed and pinned (PIN_SCORE — never
        evicted, clipped to the byte budget)."""
        validate_method(method)
        if cache_mb < 0:
            raise ValueError(f"cache_mb must be >= 0, got {cache_mb}")
        self.cfg = cfg
        self.method = method
        self.reorderings = reorderings or {}
        self.cached = cached or {}
        self.cache_mb = float(cache_mb)
        self.cache_caps: Optional[Dict[str, int]] = None  # set by init_plan
        sp = sparsity if isinstance(sparsity, dict) else {
            k: float(sparsity) for k in ("hidden_attn", "hidden_mlp", "ffn", "attn_out")
        }
        d, hd_all = cfg.d_model, cfg.n_heads * cfg.resolved_head_dim
        kv_all = cfg.n_kv_heads * cfg.resolved_head_dim
        self.sites: Dict[str, _Site] = {
            # q + k + v share the hidden-state mask
            "hidden_attn": _site(d, (hd_all, kv_all, kv_all), device, sp["hidden_attn"]),
            "attn_out": _site(hd_all, (d,), device, sp["attn_out"]),
        }
        if cfg.d_ff and not cfg.has_moe:
            # gate + up share the hidden mask; down has its own (ffn) mask
            self.sites["hidden_mlp"] = _site(d, (cfg.d_ff, cfg.d_ff), device, sp["hidden_mlp"])
            self.sites["ffn"] = _site(cfg.d_ff, (d,), device, sp["ffn"])
        # static `cached` masks re-expressed in SELECTION (reordered) row
        # order: the pre-warmed, pinned portion of the dynamic residency tier
        self.pinned_sel: Dict[str, jnp.ndarray] = {}
        for kind, cm in self.cached.items():
            if kind not in self.sites:
                continue
            cv = cm.astype(jnp.float32)
            if kind in self.reorderings:
                cv = self.reorderings[kind].apply_to_acts(cv)
            self.pinned_sel[kind] = cv > 0.0

    def mask(self, kind: str, acts: jnp.ndarray):
        """acts (..., N) → (mask (N,) float or None, est latency seconds)."""
        site = self.sites.get(kind)
        if site is None:
            return None, jnp.float32(0.0)
        if self.method == "dense":
            return None, jnp.float32(site.dense_latency)
        return self._compute_mask(kind, site, acts)

    def mask_planned(self, kind: str, acts: jnp.ndarray, state, refresh: jnp.ndarray):
        """``mask`` with temporal chunk-plan reuse (scanned decode loop).

        ``state`` is this (layer, site)'s slice of the decode plan carry —
        either the legacy mask array (N,) or, with the residency cache
        enabled, a dict {mask (N,), score (N,), hit (), miss ()} (see
        ``init_plan``). When ``refresh`` is true the selection runs —
        marginal-cost aware against the residency set derived from
        ``score`` — its mask becomes the new plan entry, the selected
        chunks are inserted into the residency tier (evicting by decayed
        importance rank when over the byte budget) and only cache-miss rows
        are charged; otherwise the cached mask from the last refresh step is
        reused at ZERO I/O cost — its chunks were loaded on that step and
        stay resident until the next refresh. ``lax.cond`` skips the
        selection compute entirely on reuse steps.

        Returns (mask (N,) float, est latency, new state).
        """
        site = self.sites.get(kind)
        if site is None:
            return None, jnp.float32(0.0), state
        if self.method == "dense":
            # nothing resident to reuse: dense streams every matrix each step
            return None, jnp.float32(site.dense_latency), state
        if not isinstance(state, dict):  # legacy plan: mask-only carry
            def _refresh(_):
                return self._compute_mask(kind, site, acts)

            def _reuse(_):
                return state, jnp.float32(0.0)

            m, lat = jax.lax.cond(refresh, _refresh, _reuse, None)
            return m, lat, m

        cap = self._cap(kind)

        def _refresh_c(_):
            return self._compute_mask_cached(kind, site, acts, state["score"], cap)

        def _reuse_c(_):
            return (state["mask"], jnp.float32(0.0), state["score"],
                    jnp.float32(0.0), jnp.float32(0.0))

        m, lat, score, hit, miss = jax.lax.cond(refresh, _refresh_c, _reuse_c, None)
        new_state = {
            "mask": m,
            "score": score,
            "hit": state["hit"] + hit,
            "miss": state["miss"] + miss,
        }
        return m, lat, new_state

    def _compute_mask(self, kind: str, site: _Site, acts: jnp.ndarray):
        from ..core.importance import importance

        v = importance(acts)
        if kind in self.reorderings:
            v = self.reorderings[kind].apply_to_acts(v)
        cached = self.cached.get(kind)
        if cached is not None:
            cv = cached
            if kind in self.reorderings:
                cv = self.reorderings[kind].apply_to_acts(
                    cv.astype(jnp.float32)
                ).astype(bool)
            v = jnp.where(cv, 0.0, v)  # resident weights cost no I/O

        if self.method == "topk":
            m = topk_mask(v, site.budget())
        else:
            m, _, _ = site.selector.select(v, site.budget())
        lat = jnp.float32(0.0)
        for t in site.tables:
            lat += t.mask_latency(m)
        if kind in self.reorderings:
            # map mask back to original row order for application to acts
            inv = jnp.asarray(self.reorderings[kind].inverse)
            m = jnp.take(m, inv, axis=0)
        if cached is not None:
            m = m | cached  # cached neurons always compute, at zero I/O
        return m.astype(jnp.float32), lat

    def _compute_mask_cached(self, kind: str, site: _Site, acts: jnp.ndarray,
                             score: jnp.ndarray, cap: int):
        """One refresh step of the dynamic residency tier (selection order):
        derive the resident set from the score state, select with marginal
        cost (resident rows free), charge only cache-miss rows, then decay
        scores and insert the selected rows' importances.

        Returns (mask (N,) float [original order], miss-only latency,
        new score (N,), hit_rows, miss_rows)."""
        from ..core.importance import importance

        v = importance(acts)
        if kind in self.reorderings:
            v = self.reorderings[kind].apply_to_acts(v)
        resident = residency_from_score(score, cap)

        if self.method == "topk":
            # LLM-in-a-flash-style baseline: selection ignores residency
            # (pure importance rank); only the I/O charge sees the cache.
            m = topk_mask(v, site.budget())
        else:
            m, _, _ = site.selector.select(v, site.budget(), resident)
        # one coalesced request per selected run, charged for miss rows only
        # (LatencyTable.mask_latency_miss — resident rows never fragment it)
        lat = jnp.float32(0.0)
        for t in site.tables:
            lat += t.mask_latency_miss(m, resident)
        hit_rows = jnp.sum(m & resident).astype(jnp.float32)
        miss_rows = jnp.sum(m & ~resident).astype(jnp.float32)

        # recency/score eviction state: decay everything, reinforce selected
        new_score = RESIDENCY_DECAY * score + jnp.where(m, v, 0.0)
        pinned = self.pinned_sel.get(kind)
        if pinned is not None:
            new_score = jnp.where(pinned, PIN_SCORE, new_score)

        if kind in self.reorderings:
            inv = jnp.asarray(self.reorderings[kind].inverse)
            m = jnp.take(m, inv, axis=0)
        return m.astype(jnp.float32), lat, new_score, hit_rows, miss_rows

    # -- residency-tier capacity ---------------------------------------------
    @property
    def cache_enabled(self) -> bool:
        """The dynamic residency tier applies to the selecting methods only:
        dense streams every matrix every step regardless of budget."""
        return self.cache_mb > 0 and self.method in ("chunk", "topk")

    def site_row_bytes(self, kind: str) -> int:
        """Total bytes of one row across every matrix sharing the site."""
        return int(sum(t.row_bytes for t in self.sites[kind].tables))

    def sparsifiable_bytes(self, n_layers: int) -> int:
        """Total offloaded-weight footprint governed by sparsification."""
        return n_layers * sum(
            site.n * self.site_row_bytes(kind) for kind, site in self.sites.items()
        )

    def _resolve_cache(self, n_layers: int) -> Dict[str, int]:
        """Split the byte budget into per-(layer, site) row caps: the same
        fraction of every matrix is cacheable, so cap_rows = frac * N."""
        total = self.sparsifiable_bytes(n_layers)
        frac = min(1.0, self.cache_mb * 1024.0 * 1024.0 / max(total, 1))
        self.cache_caps = {
            kind: int(frac * site.n) for kind, site in self.sites.items()
        }
        return self.cache_caps

    def _cap(self, kind: str) -> int:
        if self.cache_caps is None:
            raise RuntimeError(
                "residency capacity unresolved — call init_plan(n_layers) "
                "before mask_planned with the residency cache enabled"
            )
        return self.cache_caps[kind]

    def init_plan(self, n_layers: int) -> Dict[str, Any]:
        """Per-layer decode-plan state for the scanned decode loop. Empty
        for dense — there is no selection to cache.

        Legacy format (``cache_mb == 0``): {site: (n_layers, N) float32}
        cached chunk masks, zero-initialized (the first scan step always
        refreshes, so the zeros are never applied).

        Residency format (``cache_mb > 0``): {site: {"mask": (L, N),
        "score": (L, N), "hit": (L,), "miss": (L,)}}. ``score`` is the
        eviction state (decayed importance; the resident set is its top
        cap_rows); pre-warmed ``cached`` rows start at PIN_SCORE. ``hit`` /
        ``miss`` accumulate selected-row counts across the refresh steps of
        one engine decode call (zeroed per call by ``reset_plan_counters``)
        — ``ServeEngine.io_summary`` reads them back as the tier's hit rate.
        """
        if self.method == "dense":
            return {}
        if not self.cache_enabled:
            return {
                kind: jnp.zeros((n_layers, site.n), jnp.float32)
                for kind, site in self.sites.items()
            }
        self._resolve_cache(n_layers)
        plan: Dict[str, Any] = {}
        for kind, site in self.sites.items():
            score0 = jnp.zeros((n_layers, site.n), jnp.float32)
            pinned = self.pinned_sel.get(kind)
            if pinned is not None:
                score0 = jnp.where(pinned[None, :], PIN_SCORE, score0)
            plan[kind] = {
                "mask": jnp.zeros((n_layers, site.n), jnp.float32),
                "score": score0,
                "hit": jnp.zeros((n_layers,), jnp.float32),
                "miss": jnp.zeros((n_layers,), jnp.float32),
            }
        return plan

    def dense_total_latency(self) -> float:
        """Full-load I/O latency per layer (all sites dense)."""
        return float(sum(s.dense_latency for s in self.sites.values()))
