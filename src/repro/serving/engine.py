"""ServeEngine: streaming-VLM serving with flash-offload simulation.

Pipeline per the paper (§2.1): prefill(prompt) → append_frame(frame)* →
decode(n)*. Prefill and frame-append run as one jit-compiled step each; the
decode path is a **fused ``lax.scan`` multi-token loop** — the whole n-token
generation is one jit call that accumulates per-step PER-LAYER additive-model
I/O estimates on device and returns (tokens, io_estimates) once, eliminating
the per-token ``float(io)`` host round-trip the seed engine paid. The legacy
one-python-iteration-per-token loop survives as ``decode_per_token`` for
A/B comparison (benchmarks/serve_throughput.py) and regression tests.

Step latency is charged through the **overlapped I/O–compute pipeline**
(core/pipeline.py): per-layer simulated I/O and the ComputeModel's per-layer
compute seconds run through a two-stage prefetch timeline (layer l+1's
chunks stream while layer l computes — double buffering), so the default
per-step latency is the pipeline's critical path, not Σ io + Σ compute.
``overlap=False`` retains the serial charge as the baseline; token outputs
are byte-identical across the two modes (the pipeline only re-times the same
masks). ``StepStats`` carries both charges plus stall/bubble accounting and
``io_summary()`` reports ``overlap_efficiency``.

Inside the scan, ``plan_refresh_interval`` enables temporal chunk-plan
reuse: utility-guided selection reruns every k steps — ONE batched dispatch
per layer over all sites (SparseExecution.refresh_layer), consuming the
importances recorded on the previous step — and the cached masks are reused
(at zero I/O — their chunks are still resident) in between. ``cache_mb``
adds the dynamic chunk residency cache (paper §5): a byte-budgeted DRAM tier
whose per-(layer, site) score state rides the same plan carry — selection
becomes marginal-cost aware, refresh steps insert / evict, and only
cache-miss rows are charged (hit rate lands in ``io_summary``). See
docs/serving.md for the full decode contract and the residency-state
lifecycle.

Two operating modes share the engine:

  * classic single-stream mode: prefill / append_frame / decode drive one
    batch of lockstep requests through a scalar-length KV cache;
  * slot mode (``enable_slots`` + Scheduler): each batch row is an
    independent request slot with its own cache length; ``admit_slot``
    prefills one request into a free slot and ``decode_slots`` runs the
    fused loop over all slots at once (continuous batching).

``method`` ∈ SERVE_METHODS: "chunk" | "topk" | "dense" stream weights from
simulated flash through SparseExecution; "dense_free" means fully
memory-resident weights (no flash tier, zero I/O, no SparseExecution).

Works with any dense/moe/vlm architecture; recurrent archs serve through
decode_step only (their state is the cache).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.faults import (
    CorruptionModel,
    CorruptionProfile,
    FaultModel,
    FaultProfile,
)
from ..core.latency_model import MB
from ..core.offload import ComputeModel, FlashOffloadSimulator, pack_checksums
from ..core.pipeline import PipelineModel, PipelineTimeline, overlap_efficiency
from ..models.model import Model
from ..models.transformer import SPARSE_WEIGHT_NAMES
from ..kernels.backend import validate_backend
from ..kernels.quantize import quantize_params
from ..sharding.serve import ServeMesh, validate_serve_mesh
from .degrade import DegradationController
from .kv_pool import KVPagePool, KVPoolExhausted, prompt_prefix_hashes
from .sparse_exec import (
    INTEGRITY_COUNTER_KEYS,
    KERNEL_BLOCK_ROWS,
    WBITS_CHOICES,
    SparseExecution,
    plan_hit_miss,
    plan_integrity_counters,
    plan_transfer_bytes,
    reset_plan_counters,
    set_plan_budget_scale,
    validate_method,
)


@dataclasses.dataclass
class StepStats:
    kind: str  # prefill | frame | decode
    tokens: int
    io_est_s: float
    io_sim_s: float
    select_overhead_s: float
    wall_s: float
    # residency-tier accounting: selected rows served from the DRAM cache
    # (free) vs streamed from flash this step; 0/0 when the tier is off
    hit_rows: float = 0.0
    miss_rows: float = 0.0
    # estimated flash→DRAM transfer volume of the step (miss rows × row
    # bytes from the plan counters; also stamped on the IOEvent)
    nbytes: float = 0.0
    # overlapped-pipeline accounting (decode steps; core/pipeline.py):
    # serial charge Σ(io+compute), critical-path charge with prefetch,
    # compute lane total, compute-waiting-on-fetch stall, and
    # fetch-engine-idle bubble (the window scheduler admission hides in)
    compute_s: float = 0.0
    serial_s: float = 0.0
    overlap_s: float = 0.0
    stall_s: float = 0.0
    bubble_s: float = 0.0


# the exact key set io_summary() returns — the docstring table and
# tests/test_serving.py both pin against this, so docs, code and tests
# cannot drift independently
IO_SUMMARY_KEYS = (
    "io_est_s",
    "io_sim_s",
    "steps",
    "hit_rows",
    "miss_rows",
    "cache_hit_rate",
    "io_bytes",
    "select_overhead_s",
    "decode_compute_s",
    "decode_serial_s",
    "decode_overlap_s",
    "decode_stall_s",
    "decode_bubble_s",
    "overlap_efficiency",
    "admitted_during_stall",
    "stall_hidden_s",
    "bubble_utilization",
    "fault_events",
    "fault_spikes",
    "fault_retries",
    "fault_backoff_s",
    "fault_extra_s",
    "min_throttle_scale",
    "corruptions_detected",
    "corruptions_recovered",
    "corruptions_substituted",
    "corruptions_dropped",
    "integrity_reread_s",
    "kv_cache_mb",
    "weight_cache_mb",
    "kv_pages_in_use",
    "kv_shared_pages",
)


class ServeEngine:
    # retention bound of the per-layer I/O log behind reprice_timeline
    _LAYER_IO_LOG_MAX_STEPS = 4096

    def __init__(
        self,
        model: Model,
        params: Any,
        max_seq: int,
        batch_size: int,
        device: str = "nano",
        sparsity: float | Dict[str, float] = 0.4,
        method: str = "chunk",  # see SERVE_METHODS
        reorderings: Optional[dict] = None,
        seed: int = 0,
        plan_refresh_interval: int = 1,
        cache_mb: Optional[float] = None,
        overlap: bool = True,
        prefetch_depth: int = 1,
        compute_layer_scale=None,
        backend: str = "reference",
        wbits: int = 16,
        mesh: Optional[ServeMesh] = None,
        fault_profile: Optional[str | FaultProfile] = None,
        fault_seed: int = 0,
        degrade: bool = False,
        corruption_profile: Optional[str | CorruptionProfile] = None,
        corruption_seed: int = 0,
        max_reread: int = 2,
        recover: bool = True,
        kv_page_tokens: Optional[int] = None,
        kv_pages: Optional[int] = None,
    ):
        """``backend``: the decode execution backend ("reference" |
        "kernel", see kernels/backend.py). "reference" computes the planned
        decode path's sparse projections as the DMA kernels' pure-jnp
        schedule twin; "kernel" dispatches the Pallas chunk-gather kernels
        off the decode plan's ``kstarts``/``ksizes``/``mlp_kernel_plan``
        lanes (interpret mode off-TPU, compiled on real TPU). Decode tokens
        are byte-identical across backends — the switch changes how the
        masked arithmetic is realized, never which neurons participate.
        Ignored by ``dense_free`` (no sparse execution at all).

        ``cache_mb``: DRAM budget (MB) of the dynamic chunk residency
        cache (paper §5). None → the device profile's ``dram_cache_mb``
        default; 0 disables the tier.

        ``overlap``: charge decode steps through the two-stage prefetch
        pipeline (default) instead of the serial Σ io + Σ compute baseline.
        Token outputs are identical either way — the flag only selects
        which timeline prices the step (StepStats keeps both).

        ``prefetch_depth``: how many layers the pipeline's fetch engine may
        run ahead of compute — the same knob as the DMA gather kernels' slot
        count (kernels/chunk_gather_dma.py). 1 = double buffering; 0
        degenerates the timeline to the serial schedule. Tokens are
        byte-identical at every depth.

        ``compute_layer_scale``: optional (n_layers,) per-layer calibration
        multipliers for the pipeline's compute lane
        (``ComputeModel.decode_layer_seconds``); None = uniform.

        ``wbits``: offloaded chunk storage width (16 = fp16, 8 = int8
        payload + per-block f32 scales, kernels/quantize.py). At 8 the
        engine quantizes the sparsifiable layer matrices once at
        construction (the ``_q8``/``_sc`` leaves ride the decode scan next
        to the fp originals) and every byte/latency figure prices the
        quantized rows; decode tokens stay byte-identical across backends
        at fixed wbits. Ignored by ``dense_free`` (nothing streams).

        ``mesh``: the (data, model) serve mesh (sharding/serve.py). Serve
        slots partition over ``data`` (batch must divide); the offloaded
        decode-streamed weights, chunk payloads/scales and per-shard block
        tables partition over ``model``; selection stays replicated so
        greedy tokens are byte-identical between the 1×1 mesh and any
        (d, m) mesh at both wbits. None → unsharded (the default).

        ``fault_profile`` / ``fault_seed``: storage fault injection
        (core/faults.py) — a named ``FAULT_PROFILES`` entry (or a
        ``FaultProfile``) attached to the simulator's MEASUREMENT boundary
        with its own seeded RNG. Selection keeps planning against the
        clean latency table; faults only perturb the charged time of each
        I/O event, never which neurons are selected or which tokens come
        out. None (default) or "none" ⇒ bit-identical behavior to an
        engine without the fault machinery.

        ``corruption_profile`` / ``corruption_seed`` / ``max_reread`` /
        ``recover``: data-plane corruption injection (core/faults.py
        ``CORRUPTION_PROFILES``). Unlike ``fault_profile`` (time-only),
        corruption damages the BYTES of fetched chunk blocks; plan
        refreshes verify them against pack-time checksum lanes the engine
        emits at construction (``_ck`` leaves — ``quantize_params`` over
        the int8 payload at wbits=8, ``pack_checksums`` over the fp leaves
        at 16). With ``recover=True`` (default) the detection/recovery
        ladder keeps greedy tokens byte-identical to a fault-off engine
        whenever every corruption is recoverable; re-read + backoff
        seconds are charged through ``IOEvent.integrity_s``. With
        ``recover=False`` the corruption flows into the gather and tokens
        CAN change (identically on both backends). Counters surface in
        ``io_summary()``. Requires a selecting method, no reorderings and
        the unsharded mesh; None/"none" ⇒ bit-identical to a build
        without the integrity subsystem.

        ``kv_page_tokens`` / ``kv_pages``: paged KV cache (PR 10). None
        (default) keeps the dense per-slot cache. Set, the KV cache becomes
        a pool of ``kv_pages`` fixed-size pages of ``kv_page_tokens``
        tokens each (page 0 reserved as the garbage page), per-slot page
        tables riding the decode scan carry, and copy-on-write prefix
        sharing keyed on chained token-prefix hashes (serving/kv_pool.py).
        ``kv_pages`` defaults to the dense-equivalent capacity
        (batch·max_pages + the garbage page, rounded up to the data-shard
        count) so every dense workload still fits. The pool's byte
        capacity is carved out of the unified ``--cache-mb`` DRAM budget:
        ``io_summary()`` surfaces the ``kv_cache_mb`` / ``weight_cache_mb``
        split and the chunk residency cache gets only the weight share.
        Paged mode is slot-mode only (continuous batching via
        ``admit_slot`` / ``decode_slots``); greedy tokens are
        byte-identical to the dense-KV engine at both wbits, on both
        backends and on any serve mesh.

        ``degrade``: enable the adaptive ``DegradationController``
        (serving/degrade.py): at every decode-call boundary the engine
        observes the measured/estimated step-latency ratio (normalized by
        the deterministic interleave lift, so healthy ≈ 1.0) and tightens
        the selector's chunk I/O budget through the plan-carried "bscale"
        multiplier while the device looks degraded, relaxing on recovery.
        Requires a selecting method ("chunk" | "topk")."""
        validate_method(method, allow_dense_free=True)
        validate_backend(backend)
        if wbits not in WBITS_CHOICES:
            raise ValueError(
                f"wbits must be one of {WBITS_CHOICES}, got {wbits!r}"
            )
        if plan_refresh_interval < 1:
            raise ValueError("plan_refresh_interval must be >= 1")
        self.mesh = mesh if mesh is not None else ServeMesh.single()
        if self.mesh.is_sharded:
            validate_serve_mesh(
                self.mesh.data, self.mesh.model, batch=batch_size,
                d_ff=(model.cfg.d_ff
                      if (self.mesh.model > 1 and model.cfg.d_ff
                          and not model.cfg.has_moe) else 0),
            )
        if degrade and method not in ("chunk", "topk"):
            raise ValueError(
                f"degrade=True needs a selecting method ('chunk' | 'topk') "
                f"whose budget the controller can tighten, got {method!r}"
            )
        # data-plane corruption injection (PR 9): resolve/validate the
        # profile up front — dense_free has no flash data plane to corrupt
        # (SparseExecution validates the sparse-method constraints itself)
        _corruption_probe = (
            CorruptionModel(corruption_profile, seed=corruption_seed,
                            max_reread=max_reread, recover=recover)
            if corruption_profile is not None else None
        )
        if (method == "dense_free" and _corruption_probe is not None
                and _corruption_probe.enabled):
            raise ValueError(
                "corruption injection needs an offloaded data plane — "
                "method='dense_free' streams nothing from flash"
            )
        self.backend = backend
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        # PipelineModel validates prefetch_depth >= 0
        self.prefetch_depth = prefetch_depth
        # storage turbulence: a seeded FaultModel on the simulator's
        # measurement boundary (None ⇒ the clean pre-fault simulator)
        self.faults = (
            FaultModel(fault_profile, seed=fault_seed)
            if fault_profile is not None else None
        )
        self.simulator = FlashOffloadSimulator(
            device, seed=seed,
            pipeline=PipelineModel(prefetch_depth=prefetch_depth),
            faults=self.faults,
        )
        self.degrade_controller = DegradationController() if degrade else None
        self.compute_model = ComputeModel()
        self.method = method
        self.plan_refresh_interval = plan_refresh_interval
        self.overlap = overlap
        # scheduler-admission-during-stall accounting (Scheduler reports
        # hidden prefill time back through note_stall_admission)
        self.admitted_during_stall = 0
        self.stall_hidden_s = 0.0
        # profile-default resolution + >= 0 validation live on the profile
        self.cache_mb = self.simulator.profile.cache_capacity_bytes(cache_mb) / MB
        # paged KV (PR 10): resolve the pool geometry and carve its bytes
        # out of the unified DRAM budget BEFORE SparseExecution is built —
        # the chunk residency cache only ever sees the weight share
        self.kv_page_tokens = kv_page_tokens
        if kv_page_tokens is not None:
            if not model.supports_paged_kv:
                raise ValueError(
                    f"paged KV is only supported for decoder families "
                    f"(dense/moe/vlm), not {model.family!r}"
                )
            if kv_page_tokens < 1 or max_seq % kv_page_tokens != 0:
                raise ValueError(
                    f"kv_page_tokens ({kv_page_tokens}) must be >= 1 and "
                    f"divide max_seq ({max_seq})"
                )
            max_pages = max_seq // kv_page_tokens
            if kv_pages is None:
                # dense-equivalent capacity + the reserved garbage page,
                # rounded up so the pool's page axis shards over 'data'
                kv_pages = batch_size * max_pages + 1
                d = self.mesh.data if self.mesh.is_sharded else 1
                kv_pages += -kv_pages % d
            if kv_pages < 2:
                raise ValueError(f"kv_pages must be >= 2, got {kv_pages}")
            cfg = model.cfg
            # bf16 K + V entries per page position, summed over layers
            self.kv_page_bytes = float(
                2 * 2 * cfg.n_layers * kv_page_tokens
                * cfg.n_cache_kv_heads * cfg.resolved_head_dim
            )
            self.kv_pages = kv_pages
            self.kv_cache_mb = kv_pages * self.kv_page_bytes / MB
        else:
            if kv_pages is not None:
                raise ValueError("kv_pages requires kv_page_tokens")
            self.kv_pages = 0
            self.kv_page_bytes = 0.0
            self.kv_cache_mb = 0.0
        self.weight_cache_mb = max(0.0, self.cache_mb - self.kv_cache_mb)
        self.sparse_ctx = (
            None
            if method == "dense_free"
            else SparseExecution(model.cfg, device=device, sparsity=sparsity,
                                 method=method, reorderings=reorderings,
                                 cache_mb=self.weight_cache_mb, backend=backend,
                                 kernel_prefetch_depth=prefetch_depth,
                                 wbits=wbits, mesh=self.mesh,
                                 degradable=degrade,
                                 corruption_profile=corruption_profile,
                                 corruption_seed=corruption_seed,
                                 max_reread=max_reread,
                                 corruption_recover=recover)
        )
        self.wbits = wbits
        # the resolved corruption model (None when off) + engine-lifetime
        # integrity counter totals, ordered like INTEGRITY_COUNTER_KEYS
        self.corruption = (
            self.sparse_ctx.corruption if self.sparse_ctx is not None else None
        )
        self._integrity_totals = np.zeros(len(INTEGRITY_COUNTER_KEYS))
        # per-shard I/O accounting width (1 on the unsharded path — the
        # shard lanes stay out of the logs entirely so single-device
        # StepStats/IOEvents are byte-identical to pre-mesh engines)
        self.n_shards = (
            self.sparse_ctx.n_shards if self.sparse_ctx is not None
            else (self.mesh.model if self.mesh.is_sharded else 1)
        )
        integrity_on = self.corruption is not None
        if self.sparse_ctx is not None and wbits == 8:
            # quantize the offloaded matrices once: the int8 payload +
            # per-block scale leaves (leading L dim preserved) join the
            # stacked layer params so they ride the decode scan unchanged;
            # prefill / append / the unplanned paths keep the fp originals.
            # Corruption injection adds the pack-time checksum lane (_ck)
            # over the int8 payload — the exact bytes the DMA lane streams
            layers = dict(self.params["layers"])
            layers.update(quantize_params(layers, SPARSE_WEIGHT_NAMES,
                                          checksums=integrity_on))
            self.params = {**self.params, "layers": layers}
        elif self.sparse_ctx is not None and integrity_on:
            # fp pack path (wbits=16): checksum the fp payload leaves
            layers = dict(self.params["layers"])
            layers.update(pack_checksums(layers, SPARSE_WEIGHT_NAMES))
            self.params = {**self.params, "layers": layers}
        if self.mesh.is_sharded:
            # commit params to the mesh: decode-streamed leaves shard over
            # 'model' (the _q8/_sc chunk leaves at wbits=8; fresh <name>_dec
            # fp copies at 16 — originals stay replicated for prefill), the
            # rest replicates. dense_free has nothing decode-streamed.
            if self.sparse_ctx is not None:
                self.params = self.mesh.place_params(
                    self.params, wbits, SPARSE_WEIGHT_NAMES
                )
            else:
                self.params = self.mesh.put_replicated(self.params)
        # per-layer compute lane of the overlap pipeline: selecting methods
        # compute over their kept rows, dense/dense_free over everything
        eff_sparsity = sparsity if method in ("chunk", "topk") else 0.0
        self.compute_layer_s = self.compute_model.decode_layer_seconds(
            model.cfg, sparsity=eff_sparsity, tokens=batch_size,
            layer_scale=compute_layer_scale,
        )
        if self.kv_page_tokens is not None:
            # paged engines are slot-mode from birth: pool + page table +
            # per-slot lengths (prefill/append_frame raise; use admit_slot)
            self.kv_pool: Optional[KVPagePool] = None
            self._init_paged_state()
        else:
            self.kv_pool = None
            self.cache = self.mesh.place_cache(
                model.init_cache(batch_size, max_seq), self._cache_axes()
            )
        self.stats: List[StepStats] = []
        self._plan = None  # chunk-plan carry, persists across decode calls
        self._select_s_per_refresh: Optional[float] = None  # lazy, wall-timed
        # per-decode-call (n_steps, n_layers) simulated-I/O matrices, kept so
        # the host-side timeline can be repriced at other prefetch depths;
        # bounded to the most recent _LAYER_IO_LOG_MAX_STEPS decode steps so
        # a long-lived serving engine doesn't grow without bound
        self._layer_io_log: List[np.ndarray] = []

        # per-token baseline shares the fused loop's step function (the
        # planned path), so the two decode modes differ ONLY in host-loop
        # structure — that's what makes their outputs byte-identical
        def _decode_one_impl(p, t, c, plan, i):
            logits, cache, io, new_plan = model.decode_step_planned(
                p, t, c, self.sparse_ctx, plan,
                (i % self.plan_refresh_interval) == 0,
            )
            h0, m0 = plan_hit_miss(plan)
            h1, m1 = plan_hit_miss(new_plan)
            db = plan_transfer_bytes(new_plan) - plan_transfer_bytes(plan)
            dsb = self._plan_shard_bytes(new_plan) - self._plan_shard_bytes(plan)
            # per-step integrity counter deltas ((6,) zeros with
            # corruption off — see INTEGRITY_COUNTER_KEYS)
            dci = (plan_integrity_counters(new_plan)
                   - plan_integrity_counters(plan))
            return logits, cache, io, new_plan, h1 - h0, m1 - m0, db, dsb, dci

        self._decode_one = jax.jit(_decode_one_impl)
        self._append = jax.jit(
            lambda p, f, c: model.append_frame(p, f, c, self.sparse_ctx)
        )
        self._decode_scan = jax.jit(self._decode_scan_impl, static_argnums=3)
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(p, b, self.max_seq)
        )

    def _cache_axes(self):
        """The model's logical cache-axes pytree for mesh placement, or
        None (→ fully replicated cache) for families that don't expose
        one."""
        try:
            return self.model.cache_axes()
        except (AttributeError, NotImplementedError):
            return None

    # -- fused decode loop ----------------------------------------------------
    def _init_plan(self):
        if self.sparse_ctx is None:
            return {}
        return self.sparse_ctx.init_plan(self.model.cfg.n_layers)

    def _plan_shard_bytes(self, plan) -> jnp.ndarray:
        """Per-model-shard transfer bytes accumulated in ``plan``, shape
        (n_shards,) — (0,)-summing zeros when there is no sparse context.
        jit-safe (rides the decode step functions)."""
        if self.sparse_ctx is None:
            return jnp.zeros((self.n_shards,), jnp.float32)
        return self.sparse_ctx.plan_shard_bytes(plan)

    def _decode_scan_impl(self, params, token, cache, n_tokens: int, plan):
        """One jit: scan ``decode_step_planned`` over n_tokens greedy steps.

        Returns (tokens (b, n), final cache, final plan, io (n, n_layers),
        hits (n,), misses (n,), bytes (n,), shard_bytes (n, n_shards),
        integrity (n, 6)) — per-step per-layer I/O estimates plus
        residency-cache row/byte counters, per-model-shard byte splits and
        integrity-counter deltas (INTEGRITY_COUNTER_KEYS order; zeros with
        corruption off) ride along. Everything stays on device until the
        caller syncs once.
        """
        k = self.plan_refresh_interval

        def step(carry, i):
            tok, cache, plan = carry
            refresh = (i % k) == 0
            logits, cache, io, new_plan = self.model.decode_step_planned(
                params, tok, cache, self.sparse_ctx, plan, refresh
            )
            h0, m0 = plan_hit_miss(plan)
            h1, m1 = plan_hit_miss(new_plan)
            db = plan_transfer_bytes(new_plan) - plan_transfer_bytes(plan)
            dsb = self._plan_shard_bytes(new_plan) - self._plan_shard_bytes(plan)
            dci = (plan_integrity_counters(new_plan)
                   - plan_integrity_counters(plan))
            nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            return (nxt, cache, new_plan), (
                nxt[:, 0], io, h1 - h0, m1 - m0, db, dsb, dci
            )

        (_, cache, plan), (toks, ios, hits, misses, byts, sbyts,
                           civ) = jax.lax.scan(
            step, (token, cache, plan), jnp.arange(n_tokens)
        )
        # toks: (n, b) -> (b, n)
        return toks.T, cache, plan, ios, hits, misses, byts, sbyts, civ

    def _selection_seconds_per_refresh(self) -> float:
        """Wall seconds one refresh step spends on chunk selection: the
        batched per-layer dispatch (SparseExecution.time_selection — the
        same quantity benchmarks/fig13_overhead.py reports per matrix)
        × n_layers. Measured lazily once per engine, on the compiled path."""
        if self.sparse_ctx is None or self.method == "dense":
            return 0.0
        if self._select_s_per_refresh is None:
            self._select_s_per_refresh = (
                self.sparse_ctx.time_selection() * self.model.cfg.n_layers
            )
        return self._select_s_per_refresh

    def _dense_step_bytes(self) -> float:
        """Per-decode-step transfer volume of the dense streaming policy
        (everything re-streams every step; the plan carries no counters)."""
        if self.sparse_ctx is None or self.method != "dense":
            return 0.0
        return float(self.sparse_ctx.sparsifiable_bytes(self.model.cfg.n_layers))

    def _run_decode_scan(self, tokens: jnp.ndarray, n_tokens: int):
        """Shared fused-loop body: run the scan, sync the estimate arrays
        once, convert them to simulated measurements, run the overlap
        pipeline, log per-step stats. Returns (new_tokens (b, n), per-step
        charged latency (n,) — overlapped or serial per ``self.overlap``)."""
        if self._plan is None:
            self._plan = self._init_plan()
        self._plan = reset_plan_counters(self._plan)
        if self.degrade_controller is not None:
            # the controller acts only at decode-call boundaries: write its
            # current budget scale into the plan's traced "bscale" leaf so
            # the jitted refresh sees it (mutating engine state after jit
            # compilation would be a silent no-op)
            self._plan = set_plan_budget_scale(
                self._plan, self.degrade_controller.scale
            )
        tokens = self.mesh.put_batch(tokens)
        t0 = time.perf_counter()
        (toks, self.cache, self._plan, ios, hits, misses, byts,
         sbyts, civ) = self._decode_scan(
            self.params, tokens, self.cache, n_tokens, self._plan
        )
        # ONE blocking host transfer for the whole scan (per-layer estimates
        # + residency/integrity counters)
        ios, hits, misses, byts, sbyts, civ = jax.device_get(
            (ios, hits, misses, byts, sbyts, civ)
        )
        ios = np.asarray(ios, np.float64)  # (n, n_layers)
        hits, misses = np.asarray(hits, np.float64), np.asarray(misses, np.float64)
        byts = np.asarray(byts, np.float64)
        sbyts = np.asarray(sbyts, np.float64)  # (n, n_shards)
        civ = np.asarray(civ, np.float64)  # (n, len(INTEGRITY_COUNTER_KEYS))
        self._integrity_totals += civ.sum(axis=0)
        if self.method == "dense":
            byts = np.full_like(byts, self._dense_step_bytes())
            sbyts = np.full_like(sbyts, self._dense_step_bytes() / self.n_shards)
        wall = time.perf_counter() - t0
        io_steps = ios.sum(axis=1)
        rows = hits + misses
        hit_rates = np.where(rows > 0, hits / np.maximum(rows, 1.0), 0.0)
        sims = self.simulator.measure_from_estimate_batch(
            io_steps, name="decode", hit_rates=hit_rates, nbytes=byts,
            shard_bytes=sbyts if self.n_shards > 1 else None,
            integrity_s=civ[:, 5],
        )
        # the simulator's lift+jitter applies per step; spread it over the
        # step's layers proportionally so the pipeline sees simulated time
        scale = np.where(io_steps > 0, sims / np.maximum(io_steps, 1e-30), 1.0)
        layer_io = ios * scale[:, None]
        self._log_layer_io(layer_io)
        tl = self.simulator.pipeline.timeline(layer_io, self.compute_layer_s)
        n_refresh = math.ceil(n_tokens / self.plan_refresh_interval)
        select_amortized = (
            self._selection_seconds_per_refresh() * n_refresh / max(n_tokens, 1)
        )
        per_step_wall = wall / max(n_tokens, 1)
        compute_step = float(np.asarray(self.compute_layer_s).sum())
        for i, (est, sim, h, m) in enumerate(zip(io_steps, sims, hits, misses)):
            self.stats.append(
                StepStats("decode", 1, float(est), float(sim),
                          select_amortized, per_step_wall,
                          hit_rows=float(h), miss_rows=float(m),
                          nbytes=float(byts[i]), compute_s=compute_step,
                          serial_s=float(tl.serial_s[i]),
                          overlap_s=float(tl.overlap_s[i]),
                          stall_s=float(tl.stall_s[i]),
                          bubble_s=float(tl.bubble_s[i]))
            )
        self._observe_degradation(io_steps, sims)
        self._observe_corruption(float(civ[:, 0].sum()), float(misses.sum()))
        charged = tl.overlap_s if self.overlap else tl.serial_s
        return toks, charged

    def _decode_lift(self) -> float:
        """The deterministic lift decode measurements carry
        (``measure_from_estimate``'s diversity-0.5 factor) — the healthy
        measured/estimated ratio is jitter-centred at 1.0 after dividing
        it out, which is the DegradationController's reference point."""
        return self.simulator.profile.interleave_lift * 1.05

    def _observe_degradation(self, io_est, io_sim) -> None:
        """Feed one decode call's per-step (estimate, measurement) pairs to
        the degradation controller (no-op when ``degrade`` is off)."""
        if self.degrade_controller is None:
            return
        est = np.asarray(io_est, np.float64).reshape(-1)
        sim = np.asarray(io_sim, np.float64).reshape(-1)
        pos = est > 0.0
        if not np.any(pos):
            return
        self.degrade_controller.observe(sim[pos] / (est[pos] * self._decode_lift()))

    def _observe_corruption(self, detected: float, miss_rows: float) -> None:
        """Feed one decode call's corruption rate — detected corrupt blocks
        per fetched block (miss rows / KERNEL_BLOCK_ROWS) — to the
        degradation controller as its second degrade signal. No-op when
        degradation control or corruption injection is off."""
        if self.degrade_controller is None or self.corruption is None:
            return
        blocks = max(miss_rows / KERNEL_BLOCK_ROWS, 1.0)
        self.degrade_controller.observe_corruption(detected / blocks)

    @staticmethod
    def _validate_greedy(greedy: bool) -> None:
        """Both decode loops are argmax-only; the ``greedy`` kwarg used to
        be silently ignored — now a ``greedy=False`` request fails loudly
        instead of quietly returning greedy tokens."""
        if not greedy:
            raise NotImplementedError(
                "sampled decoding is not implemented: ServeEngine.decode / "
                "decode_per_token always take the argmax. Pass greedy=True "
                "(the default) or implement a sampling step function."
            )

    def decode(self, first_token: jnp.ndarray, n_tokens: int, greedy: bool = True):
        """Greedy-decode n_tokens with the fused scan loop. Returns
        (b, n_tokens+1) including ``first_token`` — same contract (and, at
        equal settings, byte-identical output) as the legacy
        ``decode_per_token`` loop."""
        self._validate_greedy(greedy)
        toks, _ = self._run_decode_scan(first_token, n_tokens)
        return jnp.concatenate([first_token, toks], axis=1)

    def decode_per_token(self, first_token: jnp.ndarray, n_tokens: int,
                         greedy: bool = True):
        """The seed engine's decode loop: one jit call + one ``float(io)``
        host sync per python iteration. Runs the same step function as the
        fused scan (including plan reuse and residency-cache updates), so at
        equal settings the two modes produce byte-identical tokens — the
        only difference is the per-token host round-trip the scan
        eliminates. Pipeline accounting is backfilled once the loop ends
        (the overlap timeline needs every step's per-layer I/O)."""
        self._validate_greedy(greedy)
        if self._plan is None:
            self._plan = self._init_plan()
        self._plan = reset_plan_counters(self._plan)
        if self.degrade_controller is not None:
            # same call-boundary contract as the fused path: one scale for
            # the whole call, observations folded in once at the end — the
            # two decode modes see identical control behaviour
            self._plan = set_plan_budget_scale(
                self._plan, self.degrade_controller.scale
            )
        token = self.mesh.put_batch(first_token)
        out = [token]
        start_idx = len(self.stats)
        io_rows = []
        det_call = 0.0
        select_per_refresh = self._selection_seconds_per_refresh()
        for i in range(n_tokens):
            t0 = time.perf_counter()
            (logits, self.cache, io_vec, self._plan, dh, dm, db,
             dsb, dci) = self._decode_one(
                self.params, token, self.cache, self._plan, jnp.int32(i)
            )
            io_vec = np.asarray(io_vec, np.float64)  # the per-token host sync
            dci = np.asarray(dci, np.float64)
            self._integrity_totals += dci
            det_call += float(dci[0])
            io = float(io_vec.sum())
            hit, miss = float(dh), float(dm)
            nbytes = self._dense_step_bytes() if self.method == "dense" else float(db)
            if self.n_shards > 1:
                if self.method == "dense":
                    sb = (nbytes / self.n_shards,) * self.n_shards
                else:
                    sb = tuple(float(x) for x in np.asarray(dsb, np.float64))
            else:
                sb = None
            wall = time.perf_counter() - t0
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(token)
            rate = hit / (hit + miss) if (hit + miss) > 0 else 0.0
            sim = self.simulator.measure_from_estimate(
                io, name="decode", hit_rate=rate, nbytes=nbytes, shard_bytes=sb,
                integrity_s=float(dci[5]),
            )
            io_rows.append(io_vec * (sim / io if io > 0 else 1.0))
            sel = select_per_refresh if (i % self.plan_refresh_interval) == 0 else 0.0
            self.stats.append(StepStats("decode", 1, io, sim, sel, wall,
                                        hit_rows=hit, miss_rows=miss,
                                        nbytes=nbytes))
        if not io_rows:  # n_tokens == 0: nothing to time
            return jnp.concatenate(out, axis=1)
        recent = self.stats[start_idx:]
        self._observe_degradation(
            [s.io_est_s for s in recent], [s.io_sim_s for s in recent]
        )
        self._observe_corruption(
            det_call, float(sum(s.miss_rows for s in recent))
        )
        # backfill the overlap-pipeline accounting for the whole loop
        self._log_layer_io(np.asarray(io_rows))
        tl = self.simulator.pipeline.timeline(
            np.asarray(io_rows), self.compute_layer_s
        )
        compute_step = float(np.asarray(self.compute_layer_s).sum())
        for j, st in enumerate(self.stats[start_idx:]):
            st.compute_s = compute_step
            st.serial_s = float(tl.serial_s[j])
            st.overlap_s = float(tl.overlap_s[j])
            st.stall_s = float(tl.stall_s[j])
            st.bubble_s = float(tl.bubble_s[j])
        return jnp.concatenate(out, axis=1)

    # -- classic single-stream stages ----------------------------------------
    def prefill(self, batch: Dict[str, jnp.ndarray]):
        if self.kv_pool is not None:
            raise NotImplementedError(
                "paged KV is slot-mode only: admit requests with admit_slot "
                "(single-stream prefill would bypass the page allocator)"
            )
        t0 = time.perf_counter()
        last, self.cache = self.model.prefill(self.params, batch, self.max_seq)
        wall = time.perf_counter() - t0
        n = int(batch["tokens"].shape[1])
        # prefill loads every matrix once, contiguously (weights streamed)
        est = self._dense_io() if self.sparse_ctx else 0.0
        nbytes = (
            self.sparse_ctx.sparsifiable_bytes(self.model.cfg.n_layers)
            if self.sparse_ctx else 0.0
        )
        sim = self.simulator.measure_from_estimate(
            est, name="prefill", nbytes=nbytes,
            shard_bytes=self._even_shard_bytes(nbytes),
        )
        self.stats.append(StepStats("prefill", n, est, sim, 0.0, wall,
                                    nbytes=float(nbytes)))
        self._plan = None  # new sequence → stale plan
        return last

    def append_frame(self, frame_embeds: jnp.ndarray):
        """One video frame's patch embeddings → KV cache extension."""
        if self.kv_pool is not None:
            raise NotImplementedError(
                "paged KV is slot-mode only: append_frame extends the "
                "single-stream linear cache, which paged engines don't keep"
            )
        t0 = time.perf_counter()
        hidden, self.cache, io = self._append(self.params, frame_embeds, self.cache)
        io = float(io)
        wall = time.perf_counter() - t0
        sim = self.simulator.measure_from_estimate(io, name="frame")
        self.stats.append(
            StepStats("frame", int(frame_embeds.shape[1]), io, sim, 0.0, wall)
        )
        return hidden

    # -- slot mode (continuous batching; used by serving.scheduler) ----------
    def _init_paged_state(self):
        """(Re)build the paged-KV pool, page pools and table from scratch."""
        self.kv_pool = KVPagePool(
            self.batch_size, self.max_seq, self.kv_page_tokens,
            self.kv_pages, self.kv_page_bytes,
            n_data_shards=self.mesh.data if self.mesh.is_sharded else 1,
        )
        cache = self.model.init_paged_cache(
            self.batch_size, self.max_seq, self.kv_page_tokens, self.kv_pages
        )
        self.cache = self.mesh.place_cache(cache, self.model.paged_cache_axes())

    def _push_table(self) -> jnp.ndarray:
        """Commit the pool's host page table to the device/mesh."""
        return self.mesh.put_batch(jnp.asarray(self.kv_pool.table))

    def enable_slots(self):
        """Switch the cache to per-slot lengths: each batch row becomes an
        independent request slot (empty until ``admit_slot``)."""
        if self.kv_pool is not None:
            self._init_paged_state()
        else:
            cache = self.model.init_cache(self.batch_size, self.max_seq)
            cache["length"] = jnp.zeros((self.batch_size,), jnp.int32)
            self.cache = self.mesh.place_cache(cache, self._cache_axes())
        self._plan = None

    def kv_can_admit(self, batch: Dict[str, jnp.ndarray]) -> bool:
        """Admission check against FREE PAGES, not free slots: True when
        the pool can cover this prompt's unshared pages (always True on
        the dense path — there a free slot is the only requirement)."""
        if self.kv_pool is None:
            return True
        seq_len, hashes = prompt_prefix_hashes(batch, self.kv_page_tokens)
        return self.kv_pool.can_admit(seq_len, hashes)

    def release_slot(self, slot: int):
        """Free a slot's KV storage — the single funnel every scheduler
        release path (eviction, PR-8 preemption, PR-9 drop rungs) must go
        through. Paged: drop the slot's page references (shared prefix
        pages go cold, private pages return to the free list) and push the
        cleared table row. Dense: zero the slot's length so ``slot_lengths``
        / byte accounting stop counting the dead occupant's KV."""
        if not (0 <= slot < self.batch_size):
            raise ValueError(f"slot {slot} out of range [0, {self.batch_size})")
        if self.kv_pool is not None and self.kv_pool.release(slot):
            self.cache["page_table"] = self._push_table()
        self.cache["length"] = self.cache["length"].at[slot].set(0)

    def admit_slot(self, slot: int, batch: Dict[str, jnp.ndarray]):
        """Prefill one request (leading batch dim 1) into ``slot``,
        overwriting whatever a previous occupant left there. Returns the
        request's last-position logits (1, vocab) and the prefill I/O
        estimate (the request's weights stream in once, contiguously).

        Paged mode: the prompt's full pages are content-addressed — pages
        already resident (live or cold) are shared by reference and their
        KV bytes are NOT rewritten; only fresh pages receive the batch-1
        prefill's cache slices. Raises ``KVPoolExhausted`` when the pool
        cannot cover the unshared pages (``kv_can_admit`` pre-checks)."""
        if not (0 <= slot < self.batch_size):
            raise ValueError(f"slot {slot} out of range [0, {self.batch_size})")
        last, cache1 = self._prefill_one(self.params, batch)
        if self.kv_pool is not None:
            seq_len, hashes = prompt_prefix_hashes(batch, self.kv_page_tokens)
            entries = self.kv_pool.admit(slot, seq_len, hashes)
            fresh = [(j, page) for j, (page, is_fresh) in enumerate(entries)
                     if is_fresh]
            if fresh:
                pages = jnp.asarray([page for _, page in fresh])
                srcs = jnp.asarray([j for j, _ in fresh])
                pt, mp = self.kv_page_tokens, self.max_seq // self.kv_page_tokens
                for key in ("k", "v"):
                    n_layers = cache1[key].shape[0]
                    view = cache1[key][:, 0].reshape(
                        n_layers, mp, pt, *cache1[key].shape[3:]
                    )
                    self.cache[key] = self.cache[key].at[:, pages].set(
                        view[:, srcs]
                    )
            self.cache["page_table"] = self._push_table()
            self.cache["length"] = self.cache["length"].at[slot].set(seq_len)
        else:
            for key in ("k", "v"):
                self.cache[key] = jax.lax.dynamic_update_slice_in_dim(
                    self.cache[key], cache1[key], slot, axis=1
                )
            self.cache["length"] = (
                self.cache["length"].at[slot].set(cache1["length"].astype(jnp.int32))
            )
        est = self._dense_io() if self.sparse_ctx else 0.0
        nbytes = (
            self.sparse_ctx.sparsifiable_bytes(self.model.cfg.n_layers)
            if self.sparse_ctx else 0.0
        )
        sim = self.simulator.measure_from_estimate(
            est, name=f"admit[{slot}]", nbytes=nbytes,
            shard_bytes=self._even_shard_bytes(nbytes),
        )
        self.stats.append(
            StepStats("prefill", int(batch["tokens"].shape[1]), est, sim, 0.0, 0.0,
                      nbytes=float(nbytes))
        )
        return last, sim

    def decode_slots(self, tokens: jnp.ndarray, n_tokens: int):
        """Fused decode round over all slots. ``tokens``: (batch, 1) current
        input token per slot (free slots decode garbage that callers drop).
        Returns (new_tokens (batch, n), per-step charged latency (n,) —
        the overlapped-pipeline critical path by default, the serial
        Σ io + Σ compute charge with ``overlap=False``)."""
        if self.kv_pool is not None and n_tokens > 0:
            # grow each occupied slot's page table to cover this round's
            # write positions [length, length + n_tokens) before the table
            # rides the scan carry (free slots scatter to the garbage page).
            # The whole round's growth is checked up front so exhaustion
            # raises BEFORE any host table mutates or page allocates —
            # recoverable: the scheduler preempts a slot and retries.
            lengths = self.slot_lengths()
            occupied = [s for s in range(self.batch_size)
                        if self.kv_pool.slot_pages(s)]
            need = {
                s: self.kv_pool.pages_needed(s, int(lengths[s]) + n_tokens - 1)
                for s in occupied
            }
            total = sum(need.values())
            if total > self.kv_pool.reclaimable_pages:
                raise KVPoolExhausted(
                    f"decode round needs {total} new KV pages but only "
                    f"{self.kv_pool.reclaimable_pages} are free or "
                    "cold-evictable — release or preempt a slot first "
                    "(no page was allocated; engine state is unchanged)"
                )
            grew = False
            for slot in occupied:
                if need[slot] and self.kv_pool.ensure(
                    slot, int(lengths[slot]) + n_tokens - 1
                ):
                    grew = True
            if grew:
                self.cache["page_table"] = self._push_table()
        return self._run_decode_scan(tokens, n_tokens)

    def slot_lengths(self) -> np.ndarray:
        return np.asarray(self.cache["length"]).reshape(-1)

    # -- accounting ----------------------------------------------------------
    def _even_shard_bytes(self, nbytes: float):
        """Even per-model-shard split of a transfer that streams every
        matrix contiguously (prefill / slot admission load ALL weights, so
        each shard streams exactly its slice); None on the unsharded path
        so single-device IOEvents are unchanged."""
        if self.n_shards == 1:
            return None
        return (float(nbytes) / self.n_shards,) * self.n_shards

    def _dense_io(self) -> float:
        per_layer = self.sparse_ctx.dense_total_latency()
        return per_layer * self.model.cfg.n_layers

    def _log_layer_io(self, layer_io: np.ndarray) -> None:
        """Append one decode call's (n_steps, n_layers) simulated-I/O matrix
        and trim the oldest WHOLE calls past the retention bound (whole
        calls, because each logged call is repriced as its own cold
        pipeline)."""
        self._layer_io_log.append(layer_io)
        total = sum(m.shape[0] for m in self._layer_io_log)
        while len(self._layer_io_log) > 1 and total > self._LAYER_IO_LOG_MAX_STEPS:
            total -= self._layer_io_log.pop(0).shape[0]

    def reprice_timeline(self, prefetch_depth: int):
        """Re-run the prefetch timeline over the retained decode calls'
        recorded per-layer simulated I/O at a different depth. Each logged
        call is priced as its own cold pipeline — exactly how the engine
        charges a decode call — so the result matches what an
        identically-seeded engine constructed with
        ``prefetch_depth=depth`` would log for those calls: a free depth
        sweep without re-decoding (benchmarks use it to assert depth
        monotonicity). Covers the most recent ``_LAYER_IO_LOG_MAX_STEPS``
        decode steps (whole calls). Returns a combined ``PipelineTimeline``
        whose per-step arrays are the per-call timelines concatenated."""
        if not self._layer_io_log:
            raise RuntimeError("no decode steps logged yet — nothing to reprice")
        model = self.simulator.pipeline.with_depth(prefetch_depth)
        tls = [model.timeline(ios, self.compute_layer_s) for ios in self._layer_io_log]
        if len(tls) == 1:
            return tls[0]
        return PipelineTimeline(
            io_s=np.concatenate([t.io_s for t in tls]),
            compute_s=np.concatenate([t.compute_s for t in tls]),
            serial_s=np.concatenate([t.serial_s for t in tls]),
            overlap_s=np.concatenate([t.overlap_s for t in tls]),
            stall_s=np.concatenate([t.stall_s for t in tls]),
            bubble_s=np.concatenate([t.bubble_s for t in tls]),
        )

    def note_stall_admission(self, hidden_s: float) -> None:
        """Record one scheduler admission whose prefill was (partially)
        hidden inside measured decode stall windows — the Scheduler reports
        it here so ``io_summary`` can expose realized bubble utilization
        next to the stall totals the windows came from."""
        if hidden_s < 0:
            raise ValueError(f"hidden_s must be >= 0, got {hidden_s}")
        self.admitted_during_stall += 1
        self.stall_hidden_s += float(hidden_s)

    def shard_summary(self) -> Dict[str, Any]:
        """Per-shard rollup of the sharded serve path (mesh geometry,
        per-model-shard transfer bytes, per-shard residency budget, slots
        per data shard). Lives NEXT TO ``io_summary`` — whose key set is
        pinned — rather than inside it; on the 1×1 mesh everything
        degrades to one shard holding the unsharded totals.

        ``io_bytes_per_shard`` sums exactly to ``io_summary()['io_bytes']``
        (the ISSUE's accounting invariant): row-sharded sites split by each
        shard's actual miss rows, everything else splits evenly.
        ``cache_mb_per_shard`` is the uniform capacity split — resident
        rows partition across model shards with the weights, so each shard
        provisions 1/n_shards of the residency budget."""
        per_shard = self.simulator.total_bytes_by_shard(self.n_shards)
        n_data = self.mesh.data
        return {
            "mesh_data": self.mesh.data,
            "mesh_model": self.mesh.model,
            "n_shards": self.n_shards,
            "io_bytes": float(sum(per_shard)),
            "io_bytes_per_shard": [float(b) for b in per_shard],
            "cache_mb_per_shard": self.cache_mb / self.n_shards,
            "slots_per_data_shard": self.batch_size // self.mesh.data,
            # paged-KV occupancy by data shard (page "home" = the shard of
            # the slot that first allocated it); sums to kv_pages_in_use —
            # the same sum-to-global invariant as io_bytes_per_shard
            "kv_pages_in_use": (
                self.kv_pool.pages_in_use if self.kv_pool is not None else 0
            ),
            "kv_pages_per_shard": (
                self.kv_pool.pages_per_shard(n_data)
                if self.kv_pool is not None else [0] * n_data
            ),
        }

    def fault_summary(self) -> Dict[str, Any]:
        """Fault-injection + degradation rollup. Lives NEXT TO
        ``io_summary`` — whose key set is pinned bit-identical across the
        fault-off/on switch — exactly like ``shard_summary``. With no
        fault model and no controller it reports the quiescent defaults
        (profile "none", scale 1.0), so callers can read it
        unconditionally.

        Fault lanes (core/faults.py): the profile/seed, perturbed event
        count, tail-spike count, transient-failure retries and their total
        backoff seconds, the total extra charged seconds, and the deepest
        thermal-throttle derate seen. ``device_time_s`` is the simulator's
        cumulative charged I/O clock (the throttle trajectory's input).
        Degradation lanes (serving/degrade.py, "degrade_" prefix): current
        budget scale, EWMA ratio, observation/tighten/relax counters."""
        out: Dict[str, Any] = {
            "fault_profile": "none",
            "fault_seed": 0,
            "fault_enabled": False,
            "device_time_s": self.simulator.device_time_s,
            "fault_events": 0,
            "fault_spikes": 0,
            "fault_retries": 0,
            "fault_backoff_s": 0.0,
            "fault_extra_s": 0.0,
            "min_throttle_scale": 1.0,
            "degrade_enabled": self.degrade_controller is not None,
            "degrade_scale": 1.0,
            "degrade_ewma_ratio": 1.0,
            "degrade_observations": 0,
            "degrade_tighten_steps": 0,
            "degrade_relax_steps": 0,
            "degrade_calls_degraded": 0,
        }
        if self.faults is not None:
            fs = self.faults.summary()
            out.update({
                "fault_profile": fs["profile"],
                "fault_seed": fs["seed"],
                "fault_enabled": self.faults.enabled,
                "fault_events": fs["events"],
                "fault_spikes": fs["spikes"],
                "fault_retries": fs["retries"],
                "fault_backoff_s": fs["backoff_s"],
                "fault_extra_s": fs["fault_extra_s"],
                "min_throttle_scale": fs["min_throttle_scale"],
            })
        if self.degrade_controller is not None:
            ds = self.degrade_controller.summary()
            out.update({f"degrade_{k}": v for k, v in ds.items()})
        return out

    def io_summary(self) -> Dict[str, float]:
        """Engine-lifetime I/O / pipeline / cache / admission rollup.

        The returned dict carries EXACTLY the keys below (pinned against
        ``IO_SUMMARY_KEYS`` by ``tests/test_serving.py`` so the table can't
        drift from the implementation):

        | field                  | meaning                                          | since |
        |------------------------|--------------------------------------------------|-------|
        | ``io_est_s``           | Σ additive-model I/O estimate over all steps     | PR 0  |
        | ``io_sim_s``           | Σ simulator-measured I/O (lift + jitter applied) | PR 0  |
        | ``steps``              | number of logged StepStats entries               | PR 0  |
        | ``hit_rows``           | residency-cache rows served from DRAM (free)     | PR 2  |
        | ``miss_rows``          | selected rows streamed from flash                | PR 2  |
        | ``cache_hit_rate``     | hit_rows / (hit_rows + miss_rows), 0 when idle   | PR 2  |
        | ``io_bytes``           | Σ estimated flash→DRAM transfer volume (nbytes)  | PR 3  |
        | ``select_overhead_s``  | Σ chunk-selection wall seconds (fig13 quantity)  | PR 3  |
        | ``decode_compute_s``   | Σ compute-lane seconds over decode steps         | PR 3  |
        | ``decode_serial_s``    | Σ serial Σio+Σcompute charge (decode steps)      | PR 3  |
        | ``decode_overlap_s``   | Σ prefetch-pipeline critical-path charge         | PR 3  |
        | ``decode_stall_s``     | Σ compute-idle seconds (waiting on a fetch)      | PR 3  |
        | ``decode_bubble_s``    | Σ fetch-engine-idle seconds (no free buffer)     | PR 4  |
        | ``overlap_efficiency`` | hidden time / hideable time, clipped to [0, 1]   | PR 3  |
        | ``admitted_during_stall`` | scheduler admissions hidden in idle windows   | PR 4  |
        | ``stall_hidden_s``     | Σ prefill seconds those admissions hid           | PR 4  |
        | ``bubble_utilization`` | stall_hidden_s / (stall + bubble), ≤ 1           | PR 4  |
        | ``fault_events``       | I/O events the fault model perturbed             | PR 9  |
        | ``fault_spikes``       | tail-latency spikes the fault model injected     | PR 9  |
        | ``fault_retries``      | transient-failure re-reads (fault model)         | PR 9  |
        | ``fault_backoff_s``    | Σ retry backoff seconds charged                  | PR 9  |
        | ``fault_extra_s``      | Σ extra charged seconds vs the clean clock       | PR 9  |
        | ``min_throttle_scale`` | deepest thermal-throttle derate seen (≤ 1)       | PR 9  |
        | ``corruptions_detected``    | checksum-mismatched (matrix, block) fetches | PR 9  |
        | ``corruptions_recovered``   | detections healed by re-read or DRAM copy   | PR 9  |
        | ``corruptions_substituted`` | unreadable rows swapped for next-best rows  | PR 9  |
        | ``corruptions_dropped``     | unreadable rows dropped (no substitute)     | PR 9  |
        | ``integrity_reread_s``      | Σ re-read + backoff seconds charged         | PR 9  |
        | ``kv_cache_mb``        | paged-KV pool share of the unified DRAM budget   | PR 10 |
        | ``weight_cache_mb``    | chunk-residency share (cache_mb − kv_cache_mb)   | PR 10 |
        | ``kv_pages_in_use``    | live (referenced) KV pages right now             | PR 10 |
        | ``kv_shared_pages``    | live pages referenced by more than one slot      | PR 10 |

        The fault lanes mirror ``fault_summary()`` (quiescent defaults —
        0 counts, throttle scale 1.0 — with no fault model); the corruption
        lanes total the plan's INTEGRITY_COUNTER_KEYS accumulators over the
        engine lifetime (all zero with corruption injection off). The
        paged-KV lanes read the live pool (dense engines report
        ``kv_cache_mb`` 0, ``weight_cache_mb`` == the full ``cache_mb``,
        and zero page counts).
        """
        tot_est = sum(s.io_est_s for s in self.stats)
        tot_sim = sum(s.io_sim_s for s in self.stats)
        hit = sum(s.hit_rows for s in self.stats)
        miss = sum(s.miss_rows for s in self.stats)
        dec = [s for s in self.stats if s.kind == "decode"]
        serial = sum(s.serial_s for s in dec)
        overlap = sum(s.overlap_s for s in dec)
        stall = sum(s.stall_s for s in dec)
        bubble = sum(s.bubble_s for s in dec)
        fs = self.fault_summary()
        it = self._integrity_totals
        return {
            "io_est_s": tot_est,
            "io_sim_s": tot_sim,
            "steps": len(self.stats),
            "hit_rows": hit,
            "miss_rows": miss,
            "cache_hit_rate": hit / (hit + miss) if (hit + miss) > 0 else 0.0,
            "io_bytes": sum(s.nbytes for s in self.stats),
            "select_overhead_s": sum(s.select_overhead_s for s in self.stats),
            # overlapped-pipeline rollup (decode steps)
            "decode_compute_s": sum(s.compute_s for s in dec),
            "decode_serial_s": serial,
            "decode_overlap_s": overlap,
            "decode_stall_s": stall,
            "decode_bubble_s": bubble,
            "overlap_efficiency": overlap_efficiency(
                [s.serial_s for s in dec],
                [s.overlap_s for s in dec],
                [s.io_sim_s for s in dec],
                [s.compute_s for s in dec],
            ),
            # scheduler admissions landed inside measured idle windows
            # (stall + bubble) and the fraction of those windows their
            # hidden prefill time realized
            "admitted_during_stall": self.admitted_during_stall,
            "stall_hidden_s": self.stall_hidden_s,
            "bubble_utilization": (
                min(self.stall_hidden_s / (stall + bubble), 1.0)
                if (stall + bubble) > 0 else 0.0
            ),
            # storage-fault + corruption-integrity lanes (PR 9): numeric
            # fault_summary() mirrors + lifetime integrity-counter totals
            "fault_events": fs["fault_events"],
            "fault_spikes": fs["fault_spikes"],
            "fault_retries": fs["fault_retries"],
            "fault_backoff_s": fs["fault_backoff_s"],
            "fault_extra_s": fs["fault_extra_s"],
            "min_throttle_scale": fs["min_throttle_scale"],
            "corruptions_detected": float(it[0]),
            "corruptions_recovered": float(it[1]),
            "corruptions_substituted": float(it[2]),
            "corruptions_dropped": float(it[3]),
            "integrity_reread_s": float(it[5]),
            # unified-budget split + live paged-KV pool occupancy (PR 10)
            "kv_cache_mb": self.kv_cache_mb,
            "weight_cache_mb": self.weight_cache_mb,
            "kv_pages_in_use": (
                self.kv_pool.pages_in_use if self.kv_pool is not None else 0
            ),
            "kv_shared_pages": (
                self.kv_pool.shared_pages if self.kv_pool is not None else 0
            ),
        }
