"""ServeEngine: batched streaming-VLM serving with flash-offload simulation.

Pipeline per the paper (§2.1): prefill(prompt) → append_frame(frame)* →
decode(n)*. Each stage runs as one jit-compiled step; the sparse policy
(SparseExecution) executes inside the jit and returns the additive-model I/O
latency estimate; the FlashOffloadSimulator converts estimates into
"measured" samples with the pattern-dependent lift (Fig. 5 behaviour).

Works with any dense/moe/vlm architecture; recurrent archs serve through
decode_step only (their state is the cache).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.offload import ComputeModel, FlashOffloadSimulator
from ..models.model import Model
from .sparse_exec import SparseExecution


@dataclasses.dataclass
class StepStats:
    kind: str  # prefill | frame | decode
    tokens: int
    io_est_s: float
    io_sim_s: float
    select_overhead_s: float
    wall_s: float


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params: Any,
        max_seq: int,
        batch_size: int,
        device: str = "nano",
        sparsity: float | Dict[str, float] = 0.4,
        method: str = "chunk",  # chunk | topk | dense
        reorderings: Optional[dict] = None,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.simulator = FlashOffloadSimulator(device, seed=seed)
        self.compute_model = ComputeModel()
        self.method = method
        self.sparse_ctx = (
            None
            if method == "dense_free"
            else SparseExecution(model.cfg, device=device, sparsity=sparsity,
                                 method=method, reorderings=reorderings)
        )
        self.cache = model.init_cache(batch_size, max_seq)
        self.stats: List[StepStats] = []

        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, self.sparse_ctx)
        )
        self._append = jax.jit(
            lambda p, f, c: model.append_frame(p, f, c, self.sparse_ctx)
        )

    # -- stages --------------------------------------------------------------
    def prefill(self, batch: Dict[str, jnp.ndarray]):
        t0 = time.perf_counter()
        last, self.cache = self.model.prefill(self.params, batch, self.max_seq)
        wall = time.perf_counter() - t0
        n = int(batch["tokens"].shape[1])
        # prefill loads every matrix once, contiguously (weights streamed)
        est = self._dense_io() if self.sparse_ctx else 0.0
        sim = self.simulator.measure_from_estimate(est, name="prefill")
        self.stats.append(StepStats("prefill", n, est, sim, 0.0, wall))
        return last

    def append_frame(self, frame_embeds: jnp.ndarray):
        """One video frame's patch embeddings → KV cache extension."""
        t0 = time.perf_counter()
        hidden, self.cache, io = self._append(self.params, frame_embeds, self.cache)
        io = float(io)
        wall = time.perf_counter() - t0
        sim = self.simulator.measure_from_estimate(io, name="frame")
        self.stats.append(
            StepStats("frame", int(frame_embeds.shape[1]), io, sim, 0.0, wall)
        )
        return hidden

    def decode(self, first_token: jnp.ndarray, n_tokens: int, greedy: bool = True):
        token = first_token
        out = [token]
        for _ in range(n_tokens):
            t0 = time.perf_counter()
            logits, self.cache, io = self._decode(self.params, token, self.cache)
            io = float(io)
            wall = time.perf_counter() - t0
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(token)
            sim = self.simulator.measure_from_estimate(io, name="decode")
            self.stats.append(StepStats("decode", 1, io, sim, 0.0, wall))
        return jnp.concatenate(out, axis=1)

    # -- accounting ----------------------------------------------------------
    def _dense_io(self) -> float:
        per_layer = self.sparse_ctx.dense_total_latency()
        return per_layer * self.model.cfg.n_layers

    def io_summary(self) -> Dict[str, float]:
        tot_est = sum(s.io_est_s for s in self.stats)
        tot_sim = sum(s.io_sim_s for s in self.stats)
        return {
            "io_est_s": tot_est,
            "io_sim_s": tot_sim,
            "steps": len(self.stats),
        }
