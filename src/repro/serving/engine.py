"""ServeEngine: streaming-VLM serving with flash-offload simulation.

Pipeline per the paper (§2.1): prefill(prompt) → append_frame(frame)* →
decode(n)*. Prefill and frame-append run as one jit-compiled step each; the
decode path is a **fused ``lax.scan`` multi-token loop** — the whole n-token
generation is one jit call that accumulates per-step additive-model I/O
estimates on device and returns (tokens, io_estimates) once, eliminating the
per-token ``float(io)`` host round-trip the seed engine paid. The legacy
one-python-iteration-per-token loop survives as ``decode_per_token`` for
A/B comparison (benchmarks/serve_throughput.py) and regression tests.

Inside the scan, ``plan_refresh_interval`` enables temporal chunk-plan
reuse: utility-guided selection reruns every k steps and the cached masks
are reused (at zero I/O — their chunks are still resident) in between.
``cache_mb`` adds the dynamic chunk residency cache (paper §5): a
byte-budgeted DRAM tier whose per-(layer, site) score state rides the same
plan carry — selection becomes marginal-cost aware, refresh steps insert /
evict, and only cache-miss rows are charged (hit rate lands in
``io_summary``). See docs/serving.md for the full decode contract and the
residency-state lifecycle.

Two operating modes share the engine:

  * classic single-stream mode: prefill / append_frame / decode drive one
    batch of lockstep requests through a scalar-length KV cache;
  * slot mode (``enable_slots`` + Scheduler): each batch row is an
    independent request slot with its own cache length; ``admit_slot``
    prefills one request into a free slot and ``decode_slots`` runs the
    fused loop over all slots at once (continuous batching).

``method`` ∈ SERVE_METHODS: "chunk" | "topk" | "dense" stream weights from
simulated flash through SparseExecution; "dense_free" means fully
memory-resident weights (no flash tier, zero I/O, no SparseExecution).

Works with any dense/moe/vlm architecture; recurrent archs serve through
decode_step only (their state is the cache).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.latency_model import MB
from ..core.offload import ComputeModel, FlashOffloadSimulator
from ..models.model import Model
from .sparse_exec import (
    SparseExecution,
    plan_hit_miss,
    reset_plan_counters,
    validate_method,
)


@dataclasses.dataclass
class StepStats:
    kind: str  # prefill | frame | decode
    tokens: int
    io_est_s: float
    io_sim_s: float
    select_overhead_s: float
    wall_s: float
    # residency-tier accounting: selected rows served from the DRAM cache
    # (free) vs streamed from flash this step; 0/0 when the tier is off
    hit_rows: float = 0.0
    miss_rows: float = 0.0


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params: Any,
        max_seq: int,
        batch_size: int,
        device: str = "nano",
        sparsity: float | Dict[str, float] = 0.4,
        method: str = "chunk",  # see SERVE_METHODS
        reorderings: Optional[dict] = None,
        seed: int = 0,
        plan_refresh_interval: int = 1,
        cache_mb: Optional[float] = None,
    ):
        """``cache_mb``: DRAM budget (MB) of the dynamic chunk residency
        cache (paper §5). None → the device profile's ``dram_cache_mb``
        default; 0 disables the tier."""
        validate_method(method, allow_dense_free=True)
        if plan_refresh_interval < 1:
            raise ValueError("plan_refresh_interval must be >= 1")
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.simulator = FlashOffloadSimulator(device, seed=seed)
        self.compute_model = ComputeModel()
        self.method = method
        self.plan_refresh_interval = plan_refresh_interval
        # profile-default resolution + >= 0 validation live on the profile
        self.cache_mb = self.simulator.profile.cache_capacity_bytes(cache_mb) / MB
        self.sparse_ctx = (
            None
            if method == "dense_free"
            else SparseExecution(model.cfg, device=device, sparsity=sparsity,
                                 method=method, reorderings=reorderings,
                                 cache_mb=self.cache_mb)
        )
        self.cache = model.init_cache(batch_size, max_seq)
        self.stats: List[StepStats] = []
        self._plan = None  # chunk-plan carry, persists across decode calls

        # per-token baseline shares the fused loop's step function (the
        # planned path), so the two decode modes differ ONLY in host-loop
        # structure — that's what makes their outputs byte-identical
        def _decode_one_impl(p, t, c, plan, i):
            logits, cache, io, new_plan = model.decode_step_planned(
                p, t, c, self.sparse_ctx, plan,
                (i % self.plan_refresh_interval) == 0,
            )
            h0, m0 = plan_hit_miss(plan)
            h1, m1 = plan_hit_miss(new_plan)
            return logits, cache, io, new_plan, h1 - h0, m1 - m0

        self._decode_one = jax.jit(_decode_one_impl)
        self._append = jax.jit(
            lambda p, f, c: model.append_frame(p, f, c, self.sparse_ctx)
        )
        self._decode_scan = jax.jit(self._decode_scan_impl, static_argnums=3)
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(p, b, self.max_seq)
        )

    # -- fused decode loop ----------------------------------------------------
    def _init_plan(self):
        if self.sparse_ctx is None:
            return {}
        return self.sparse_ctx.init_plan(self.model.cfg.n_layers)

    def _decode_scan_impl(self, params, token, cache, n_tokens: int, plan):
        """One jit: scan ``decode_step_planned`` over n_tokens greedy steps.

        Returns (tokens (b, n), final cache, final plan, io (n,),
        hits (n,), misses (n,)) — per-step residency-cache row counts ride
        along with the I/O estimates. Everything stays on device until the
        caller syncs once.
        """
        k = self.plan_refresh_interval

        def step(carry, i):
            tok, cache, plan = carry
            refresh = (i % k) == 0
            logits, cache, io, new_plan = self.model.decode_step_planned(
                params, tok, cache, self.sparse_ctx, plan, refresh
            )
            h0, m0 = plan_hit_miss(plan)
            h1, m1 = plan_hit_miss(new_plan)
            nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            return (nxt, cache, new_plan), (nxt[:, 0], io, h1 - h0, m1 - m0)

        (_, cache, plan), (toks, ios, hits, misses) = jax.lax.scan(
            step, (token, cache, plan), jnp.arange(n_tokens)
        )
        return toks.T, cache, plan, ios, hits, misses  # toks: (n, b) -> (b, n)

    def _run_decode_scan(self, tokens: jnp.ndarray, n_tokens: int):
        """Shared fused-loop body: run the scan, sync the estimate array
        once, convert it to simulated measurements, log per-step stats.
        Returns (new_tokens (b, n), per-step simulated io (n,))."""
        if self._plan is None:
            self._plan = self._init_plan()
        self._plan = reset_plan_counters(self._plan)
        t0 = time.perf_counter()
        toks, self.cache, self._plan, ios, hits, misses = self._decode_scan(
            self.params, tokens, self.cache, n_tokens, self._plan
        )
        # ONE host sync for the whole scan (estimates + residency counters)
        packed = np.asarray(
            jnp.stack([ios.astype(jnp.float32), hits, misses]), np.float64
        )
        ios, hits, misses = packed[0], packed[1], packed[2]
        wall = time.perf_counter() - t0
        rows = hits + misses
        hit_rates = np.where(rows > 0, hits / np.maximum(rows, 1.0), 0.0)
        sims = self.simulator.measure_from_estimate_batch(
            ios, name="decode", hit_rates=hit_rates
        )
        per_step_wall = wall / max(n_tokens, 1)
        for est, sim, h, m in zip(ios, sims, hits, misses):
            self.stats.append(
                StepStats("decode", 1, float(est), float(sim), 0.0, per_step_wall,
                          hit_rows=float(h), miss_rows=float(m))
            )
        return toks, sims

    @staticmethod
    def _validate_greedy(greedy: bool) -> None:
        """Both decode loops are argmax-only; the ``greedy`` kwarg used to
        be silently ignored — now a ``greedy=False`` request fails loudly
        instead of quietly returning greedy tokens."""
        if not greedy:
            raise NotImplementedError(
                "sampled decoding is not implemented: ServeEngine.decode / "
                "decode_per_token always take the argmax. Pass greedy=True "
                "(the default) or implement a sampling step function."
            )

    def decode(self, first_token: jnp.ndarray, n_tokens: int, greedy: bool = True):
        """Greedy-decode n_tokens with the fused scan loop. Returns
        (b, n_tokens+1) including ``first_token`` — same contract (and, at
        equal settings, byte-identical output) as the legacy
        ``decode_per_token`` loop."""
        self._validate_greedy(greedy)
        toks, _ = self._run_decode_scan(first_token, n_tokens)
        return jnp.concatenate([first_token, toks], axis=1)

    def decode_per_token(self, first_token: jnp.ndarray, n_tokens: int,
                         greedy: bool = True):
        """The seed engine's decode loop: one jit call + one ``float(io)``
        host sync per python iteration. Runs the same step function as the
        fused scan (including plan reuse and residency-cache updates), so at
        equal settings the two modes produce byte-identical tokens — the
        only difference is the per-token host round-trip the scan
        eliminates."""
        self._validate_greedy(greedy)
        if self._plan is None:
            self._plan = self._init_plan()
        self._plan = reset_plan_counters(self._plan)
        token = first_token
        out = [token]
        for i in range(n_tokens):
            t0 = time.perf_counter()
            logits, self.cache, io, self._plan, dh, dm = self._decode_one(
                self.params, token, self.cache, self._plan, jnp.int32(i)
            )
            io = float(io)  # the per-token host sync the scan path avoids
            hit, miss = float(dh), float(dm)
            wall = time.perf_counter() - t0
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(token)
            rate = hit / (hit + miss) if (hit + miss) > 0 else 0.0
            sim = self.simulator.measure_from_estimate(
                io, name="decode", hit_rate=rate
            )
            self.stats.append(StepStats("decode", 1, io, sim, 0.0, wall,
                                        hit_rows=hit, miss_rows=miss))
        return jnp.concatenate(out, axis=1)

    # -- classic single-stream stages ----------------------------------------
    def prefill(self, batch: Dict[str, jnp.ndarray]):
        t0 = time.perf_counter()
        last, self.cache = self.model.prefill(self.params, batch, self.max_seq)
        wall = time.perf_counter() - t0
        n = int(batch["tokens"].shape[1])
        # prefill loads every matrix once, contiguously (weights streamed)
        est = self._dense_io() if self.sparse_ctx else 0.0
        sim = self.simulator.measure_from_estimate(est, name="prefill")
        self.stats.append(StepStats("prefill", n, est, sim, 0.0, wall))
        self._plan = None  # new sequence → stale plan
        return last

    def append_frame(self, frame_embeds: jnp.ndarray):
        """One video frame's patch embeddings → KV cache extension."""
        t0 = time.perf_counter()
        hidden, self.cache, io = self._append(self.params, frame_embeds, self.cache)
        io = float(io)
        wall = time.perf_counter() - t0
        sim = self.simulator.measure_from_estimate(io, name="frame")
        self.stats.append(
            StepStats("frame", int(frame_embeds.shape[1]), io, sim, 0.0, wall)
        )
        return hidden

    # -- slot mode (continuous batching; used by serving.scheduler) ----------
    def enable_slots(self):
        """Switch the cache to per-slot lengths: each batch row becomes an
        independent request slot (empty until ``admit_slot``)."""
        self.cache = self.model.init_cache(self.batch_size, self.max_seq)
        self.cache["length"] = jnp.zeros((self.batch_size,), jnp.int32)
        self._plan = None

    def admit_slot(self, slot: int, batch: Dict[str, jnp.ndarray]):
        """Prefill one request (leading batch dim 1) into ``slot``,
        overwriting whatever a previous occupant left there. Returns the
        request's last-position logits (1, vocab) and the prefill I/O
        estimate (the request's weights stream in once, contiguously)."""
        if not (0 <= slot < self.batch_size):
            raise ValueError(f"slot {slot} out of range [0, {self.batch_size})")
        last, cache1 = self._prefill_one(self.params, batch)
        for key in ("k", "v"):
            self.cache[key] = jax.lax.dynamic_update_slice_in_dim(
                self.cache[key], cache1[key], slot, axis=1
            )
        self.cache["length"] = (
            self.cache["length"].at[slot].set(cache1["length"].astype(jnp.int32))
        )
        est = self._dense_io() if self.sparse_ctx else 0.0
        sim = self.simulator.measure_from_estimate(est, name=f"admit[{slot}]")
        self.stats.append(
            StepStats("prefill", int(batch["tokens"].shape[1]), est, sim, 0.0, 0.0)
        )
        return last, sim

    def decode_slots(self, tokens: jnp.ndarray, n_tokens: int):
        """Fused decode round over all slots. ``tokens``: (batch, 1) current
        input token per slot (free slots decode garbage that callers drop).
        Returns (new_tokens (batch, n), per-step simulated io (n,))."""
        return self._run_decode_scan(tokens, n_tokens)

    def slot_lengths(self) -> np.ndarray:
        return np.asarray(self.cache["length"]).reshape(-1)

    # -- accounting ----------------------------------------------------------
    def _dense_io(self) -> float:
        per_layer = self.sparse_ctx.dense_total_latency()
        return per_layer * self.model.cfg.n_layers

    def io_summary(self) -> Dict[str, float]:
        tot_est = sum(s.io_est_s for s in self.stats)
        tot_sim = sum(s.io_sim_s for s in self.stats)
        hit = sum(s.hit_rows for s in self.stats)
        miss = sum(s.miss_rows for s in self.stats)
        return {
            "io_est_s": tot_est,
            "io_sim_s": tot_sim,
            "steps": len(self.stats),
            "hit_rows": hit,
            "miss_rows": miss,
            "cache_hit_rate": hit / (hit + miss) if (hit + miss) > 0 else 0.0,
        }
