"""Continuous-batching scheduler over the slot-mode ServeEngine.

vLLM-style iteration-level scheduling, adapted to the flash-offload
simulator: the engine's batch dimension is a fixed array of request slots;
requests are admitted into free slots earliest-deadline-first (plain FCFS
when no request carries a ``deadline_s``; prefill scatters their KV into
the shared cache), every decode round runs the engine's fused ``lax.scan``
loop across ALL slots at once, and slots are recycled the moment their
request hits its token budget — no waiting for the rest of the batch.
Deadline-blown running requests can be preempted (evict-and-requeue) to
free their slot for a request that can still meet its SLO; see the
``Scheduler`` docstring for the exact policy.

Time is simulated: the clock advances by the engine's charged per-step
latency — the overlapped I/O–compute pipeline's critical path by default
(serial Σ io + Σ compute with ``overlap=False``; the quantities the paper's
policies change) plus an optional extra per-token compute constant — so
tokens/s and request-latency percentiles reflect the policy under test
rather than host-python speed. Wall time is tracked separately by the
engine's StepStats.

**Bubble-aware admission** (``admit_in_bubbles``, default on): the
overlapped pipeline's per-step ``StepStats.stall`` measures time the
compute engine spent idle waiting on an unfinished fetch, and
``StepStats.bubble_s`` time the fetch engine spent idle waiting for a free
buffer. Both are idle engine windows inside an already-charged round —
schedulable capacity: a waiting request's admission work (prefill compute
in the stall windows, its weight streaming in the fetch-idle bubbles; a
first-order model that does not distinguish which lane absorbs which part)
can ride inside them instead of extending the clock after the round. The
scheduler banks each decode round's measured stall + bubble seconds as
credit and discounts subsequent admissions' prefill charge against it, so
admission effectively happens *during* the round rather than serially at
the boundary. ``admitted_during_stall`` / ``stall_hidden_s`` count the
realized hiding (also surfaced via the engine's ``io_summary`` as
``bubble_utilization`` = hidden ÷ (stall + bubble)). Credit only accrues
when the engine actually charges the overlapped timeline (``overlap=True``
and a positive prefetch depth) — under the serial charge there is no
pipeline and no idle windows.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import jax.numpy as jnp
import numpy as np

from .engine import ServeEngine
from .kv_pool import KVPoolExhausted
from .request import Request, RequestState


@dataclasses.dataclass
class SchedulerStats:
    """Aggregate serving metrics over one ``run``.

    With zero finished requests every percentile is NaN (not a fabricated
    0.0) so downstream asserts can never pass vacuously. The SLO lanes:
    ``deadlines`` counts finished requests that carried a ``deadline_s``,
    ``deadlines_met`` how many met it, ``slo_attainment`` their ratio (NaN
    when no finished request had a deadline), and ``preempted`` how many
    evict-and-requeue preemptions of deadline-blown requests occurred.
    """

    finished: int
    sim_time_s: float
    decode_tokens: int
    tokens_per_s: float
    latency_p50_s: float
    latency_p95_s: float
    ttft_p50_s: float
    # bubble-aware admission: requests admitted inside measured decode
    # stall windows, and the prefill seconds those windows absorbed
    admitted_during_stall: int = 0
    stall_hidden_s: float = 0.0
    # SLO / deadline accounting
    latency_p99_s: float = float("nan")
    deadlines: int = 0
    deadlines_met: int = 0
    slo_attainment: float = float("nan")
    preempted: int = 0

    def row(self) -> str:
        return (
            f"{self.finished:4d} req  {self.decode_tokens:5d} tok  "
            f"{self.tokens_per_s:8.1f} tok/s  "
            f"p50 {self.latency_p50_s*1e3:7.2f} ms  "
            f"p95 {self.latency_p95_s*1e3:7.2f} ms  "
            f"p99 {self.latency_p99_s*1e3:7.2f} ms  "
            f"slo {self.slo_attainment:5.3f}"
        )


class Scheduler:
    """Continuous batching over ``engine.batch_size`` slots.

    ``round_tokens`` is the fused-scan granularity: each round decodes that
    many tokens for every running slot in ONE jit call, then reconciles
    (finishes, evictions, admissions) on the host. Larger rounds amortize
    more host overhead but over-decode up to round_tokens-1 tokens for a
    request that finishes mid-round (the tokens are dropped; the slot is
    recycled at the round boundary).

    **Deadline-aware scheduling.** Admission is earliest-deadline-first
    over the arrived waiting requests: feasible deadline-carrying requests
    first (by absolute deadline), then best-effort requests (no deadline —
    their deadline is +inf, so the order among them is FCFS by arrival:
    a workload without deadlines schedules exactly as the original FCFS
    scheduler), then already-blown requests last (readmitting a blown
    request ahead of a feasible one would just spread the miss). At each
    round boundary a deadline-blown RUNNING request may be **preempted**:
    evicted from its slot and requeued WAITING, freeing the slot for an
    arrived request that can still make its deadline. Eviction is cheap
    here because chunk plans and residency state live in the decode carry
    per *slot*, not per request — the readmitted request simply prefills
    into whatever slot frees up. Preemption restarts the request's
    generation (greedy decode reproduces the same tokens deterministically)
    and is capped at once per request, so every request still drains. A
    preempted-and-requeued request keeps its original ``arrival_s`` (its
    latency accounts the full story) and counts in ``stats().preempted``.

    **KV-page-pressure preemption.** With paged KV, decode-time page
    growth can outrun the pool even though admission fit (admission only
    reserves the prompt's pages). ``decode_slots`` pre-checks the whole
    round's growth and raises ``KVPoolExhausted`` *before* allocating
    anything; the scheduler then preempts the least-urgent co-runner
    (latest deadline, then latest arrival — the EDF mirror) via the same
    evict-and-requeue path and retries the round. ``stats().preempted``
    counts both deadline and page-pressure preemptions.
    """

    def __init__(
        self,
        engine: ServeEngine,
        round_tokens: int = 4,
        compute_s_per_token: float = 0.0,
        admit_in_bubbles: bool = True,
    ):
        if round_tokens < 1:
            raise ValueError("round_tokens must be >= 1")
        self.engine = engine
        self.n_slots = engine.batch_size
        self.round_tokens = round_tokens
        self.compute_s_per_token = compute_s_per_token
        # bubble-aware admission only has windows to use when the engine
        # actually charges the overlapped timeline
        self.admit_in_bubbles = (
            admit_in_bubbles and engine.overlap and engine.prefetch_depth > 0
        )
        self.stall_credit_s = 0.0  # banked decode-stall seconds (see module doc)
        self.admitted_during_stall = 0
        self.stall_hidden_s = 0.0
        self.waiting: Deque[Request] = deque()
        self.running: List[Optional[Request]] = [None] * self.n_slots
        self.finished: List[Request] = []
        self.now_s = 0.0
        self.decode_tokens = 0
        self.preempted = 0
        # per-slot current input token fed to the next decode round
        self._slot_tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        engine.enable_slots()

    # -- admission / eviction ------------------------------------------------
    def submit(self, requests) -> None:
        for r in requests if isinstance(requests, (list, tuple)) else [requests]:
            self.waiting.append(r)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.running) if r is None]

    def num_running(self) -> int:
        return self.n_slots - len(self.free_slots())

    def _admission_key(self, req: Request):
        """EDF admission order over arrived waiting requests: feasible
        deadline requests by absolute deadline, then best-effort (inf
        deadline ⇒ FCFS by arrival among them), then already-blown
        requests last. Deterministic tie-break by arrival then rid."""
        dl = req.deadline_abs_s
        blown = dl < self.now_s
        return (blown, dl, req.arrival_s, req.rid)

    def _pop_next_waiting(self) -> Optional[Request]:
        """The next arrived waiting request under the admission order, or
        None if nothing has arrived yet. With no deadlines anywhere this is
        exactly the FCFS head (all keys are (False, inf, arrival, rid))."""
        arrived = [r for r in self.waiting if r.arrival_s <= self.now_s]
        if not arrived:
            return None
        req = min(arrived, key=self._admission_key)
        self.waiting.remove(req)
        return req

    def _admit_ready(self) -> int:
        """Admit WAITING requests that have arrived into free slots
        (earliest-deadline-first; pure FCFS when no request carries a
        deadline). Prefill advances the clock by the request's simulated
        weight-stream time, minus whatever fits into banked decode-stall
        credit (the admission rode an earlier round's I/O bubbles — see
        module doc). Returns the number admitted."""
        admitted = 0
        for slot in self.free_slots():
            req = self._pop_next_waiting()
            if req is None:
                break
            if not self.engine.kv_can_admit(req.prompt):
                # paged KV: a free slot is not enough — the pool must cover
                # the prompt's unshared pages. Requeue and retry once a
                # running request finishes (releasing its pages); if nothing
                # is running, nothing will ever free and the prompt can
                # never fit this pool.
                self.waiting.appendleft(req)
                if self.num_running() == 0 and admitted == 0:
                    raise RuntimeError(
                        f"request {req.rid} can never be admitted: its "
                        "prompt needs more KV pages than the pool can free"
                    )
                break
            last, prefill_sim = self.engine.admit_slot(slot, req.prompt)
            prefill_sim = float(prefill_sim)
            if self.admit_in_bubbles and self.stall_credit_s > 0.0:
                hidden = min(self.stall_credit_s, prefill_sim)
                self.stall_credit_s -= hidden
                prefill_sim -= hidden
                self.admitted_during_stall += 1
                self.stall_hidden_s += hidden
                self.engine.note_stall_admission(hidden)
            self.now_s += prefill_sim
            req.state = RequestState.RUNNING
            req.slot = slot
            req.admitted_s = self.now_s
            self.running[slot] = req
            tok0 = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            self._slot_tokens = self._slot_tokens.at[slot].set(tok0[0])
            admitted += 1
        return admitted

    def _evict(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        if req.finished_s is None:
            req.finished_s = self.now_s
        # release the slot's KV storage through the engine's single release
        # funnel (paged: page refs drop; dense: the slot length zeroes) so
        # freed-byte accounting can't drift from what the pool actually holds
        self.engine.release_slot(req.slot)
        # reset the freed slot's decode input: a free slot keeps riding the
        # fused scan, and its garbage activations feed the BATCHED chunk
        # selection — leaving the dead occupant's last token here would make
        # selection (and so every active slot's tokens) depend on KV-layout
        # garbage that differs between the dense and paged caches
        self._slot_tokens = self._slot_tokens.at[req.slot].set(0)
        self.running[req.slot] = None
        req.slot = None
        self.finished.append(req)

    def _requeue(self, req: Request) -> None:
        """Evict-and-requeue one RUNNING request (shared by deadline and
        KV-page-pressure preemption). Frees the slot's pages through the
        engine's single release funnel — a preempted request re-prefills
        from scratch on readmission, so holding its old pages would leak
        refs — and restarts generation: greedy decode is deterministic, so
        the regenerated tokens are identical. ``first_token_s`` keeps the
        original first-token mark (the stream already started once);
        latency runs to the final finish, accounting the preemption's full
        cost."""
        self.engine.release_slot(req.slot)
        self._slot_tokens = self._slot_tokens.at[req.slot].set(0)
        self.running[req.slot] = None
        req.slot = None
        req.state = RequestState.WAITING
        req.preemptions += 1
        req.tokens_out = []
        self.waiting.append(req)
        self.preempted += 1

    def _preempt_blown(self) -> int:
        """Preempt deadline-blown RUNNING requests: evict from the slot and
        requeue WAITING (the slot-local decode carry makes this a pure slot
        recycle — the readmission prefills fresh, and greedy decode
        regenerates the same tokens deterministically). Only fires when an
        arrived waiting request can still make its own deadline (otherwise
        the swap buys nothing), preempts at most that many slots, and never
        preempts the same request twice — so every request still drains.
        Returns the number preempted."""
        feasible = sum(
            1 for r in self.waiting
            if r.arrival_s <= self.now_s and self.now_s <= r.deadline_abs_s
        )
        n = 0
        for req in list(self.running):
            if n >= feasible:
                break
            if req is None or req.done:
                continue
            if req.deadline_abs_s < self.now_s and req.preemptions < 1:
                self._requeue(req)
                n += 1
        return n

    def _preempt_for_pages(self) -> bool:
        """Preempt ONE running request to free KV pages for a decode round
        that cannot grow (paged KV: ``decode_slots`` pre-checks the whole
        round's page growth and raises ``KVPoolExhausted`` before touching
        any state). Victim is the least-urgent runner — latest absolute
        deadline, ties broken by latest arrival then rid (the mirror of
        the EDF admission order) — and requeues through ``_requeue`` like
        a deadline preemption. Returns False when there is no co-runner to
        preempt (preempting the lone runner frees nothing it does not
        itself need): the pool genuinely cannot serve this decode."""
        runners = [r for r in self.running if r is not None and not r.done]
        if len(runners) < 2:
            return False
        victim = max(
            runners, key=lambda r: (r.deadline_abs_s, r.arrival_s, r.rid)
        )
        self._requeue(victim)
        return True

    # -- decode rounds -------------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration: admit, decode a round, reconcile.
        Returns False when there is nothing left to do."""
        # fast-forward an idle engine to the next arrival (requeued
        # preemptees can put the deque out of arrival order — scan it)
        if self.num_running() == 0:
            if not self.waiting:
                return False
            self.now_s = max(
                self.now_s, min(r.arrival_s for r in self.waiting)
            )
        self._admit_ready()
        if self.num_running() == 0:
            return bool(self.waiting)

        n_stats0 = len(self.engine.stats)
        while True:
            try:
                toks, step_lat = self.engine.decode_slots(
                    self._slot_tokens, self.round_tokens
                )
                break
            except KVPoolExhausted:
                # decode-time page growth cannot fit the pool: free pages by
                # preempting the least-urgent co-runner and retry the round
                # (the engine raised before allocating, so retry is safe)
                if not self._preempt_for_pages():
                    raise RuntimeError(
                        "KV page pool exhausted mid-decode with no "
                        "co-runner to preempt: the lone running request's "
                        "decode growth exceeds the pool — raise kv_pages "
                        "or lower max_new_tokens"
                    )
        if self.admit_in_bubbles:
            # bank this round's measured idle windows (compute stalls +
            # fetch-engine bubbles) as admission credit
            self.stall_credit_s += sum(
                s.stall_s + s.bubble_s
                for s in self.engine.stats[n_stats0:] if s.kind == "decode"
            )
        toks_np = np.asarray(toks)  # (slots, round_tokens)
        active = [r for r in self.running if r is not None]
        for i, sim in enumerate(step_lat):
            # the batch shares each model step; clock advances once per step
            self.now_s += float(sim) + self.compute_s_per_token
            for req in active:
                if req.done:
                    continue  # over-decoded filler for an already-done request
                req.tokens_out.append(int(toks_np[req.slot, i]))
                self.decode_tokens += 1
                if req.first_token_s is None:
                    req.first_token_s = self.now_s
                if req.done:
                    # latency marks the token's mid-round time; the slot is
                    # only recycled at the round boundary below
                    req.finished_s = self.now_s
        self._slot_tokens = toks[:, -1:]
        for req in list(active):
            if req.done:
                self._evict(req)
        self._preempt_blown()
        return bool(self.waiting) or self.num_running() > 0

    def run(self, max_rounds: int = 100_000) -> SchedulerStats:
        """Drive until every submitted request has finished."""
        rounds = 0
        while self.step():
            rounds += 1
            if rounds >= max_rounds:
                raise RuntimeError(f"scheduler did not drain in {max_rounds} rounds")
        return self.stats()

    def stats(self) -> SchedulerStats:
        if self.finished:
            lats = np.array([r.latency_s() for r in self.finished])
            ttfts = np.array([r.ttft_s() for r in self.finished])
            p50, p95, p99 = (
                float(np.percentile(lats, q)) for q in (50, 95, 99)
            )
            ttft_p50 = float(np.percentile(ttfts, 50))
        else:
            # no finished requests → NaN percentiles, never a fabricated
            # 0.0 a bench floor could pass vacuously
            p50 = p95 = p99 = ttft_p50 = float("nan")
        with_dl = [r for r in self.finished if r.deadline_s is not None]
        met = sum(1 for r in with_dl if r.met_deadline())
        return SchedulerStats(
            finished=len(self.finished),
            sim_time_s=self.now_s,
            decode_tokens=self.decode_tokens,
            tokens_per_s=self.decode_tokens / max(self.now_s, 1e-12),
            latency_p50_s=p50,
            latency_p95_s=p95,
            ttft_p50_s=ttft_p50,
            admitted_during_stall=self.admitted_during_stall,
            stall_hidden_s=self.stall_hidden_s,
            latency_p99_s=p99,
            deadlines=len(with_dl),
            deadlines_met=met,
            slo_attainment=(met / len(with_dl)) if with_dl else float("nan"),
            preempted=self.preempted,
        )
