"""Continuous-batching scheduler over the slot-mode ServeEngine.

vLLM-style iteration-level scheduling, adapted to the flash-offload
simulator: the engine's batch dimension is a fixed array of request slots;
requests are admitted FCFS into free slots (prefill scatters their KV into
the shared cache), every decode round runs the engine's fused ``lax.scan``
loop across ALL slots at once, and slots are recycled the moment their
request hits its token budget — no waiting for the rest of the batch.

Time is simulated: the clock advances by the engine's charged per-step
latency — the overlapped I/O–compute pipeline's critical path by default
(serial Σ io + Σ compute with ``overlap=False``; the quantities the paper's
policies change) plus an optional extra per-token compute constant — so
tokens/s and request-latency percentiles reflect the policy under test
rather than host-python speed. Wall time is tracked separately by the
engine's StepStats.

**Bubble-aware admission** (``admit_in_bubbles``, default on): the
overlapped pipeline's per-step ``StepStats.stall`` measures time the
compute engine spent idle waiting on an unfinished fetch, and
``StepStats.bubble_s`` time the fetch engine spent idle waiting for a free
buffer. Both are idle engine windows inside an already-charged round —
schedulable capacity: a waiting request's admission work (prefill compute
in the stall windows, its weight streaming in the fetch-idle bubbles; a
first-order model that does not distinguish which lane absorbs which part)
can ride inside them instead of extending the clock after the round. The
scheduler banks each decode round's measured stall + bubble seconds as
credit and discounts subsequent admissions' prefill charge against it, so
admission effectively happens *during* the round rather than serially at
the boundary. ``admitted_during_stall`` / ``stall_hidden_s`` count the
realized hiding (also surfaced via the engine's ``io_summary`` as
``bubble_utilization`` = hidden ÷ (stall + bubble)). Credit only accrues
when the engine actually charges the overlapped timeline (``overlap=True``
and a positive prefetch depth) — under the serial charge there is no
pipeline and no idle windows.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import jax.numpy as jnp
import numpy as np

from .engine import ServeEngine
from .request import Request, RequestState


@dataclasses.dataclass
class SchedulerStats:
    """Aggregate serving metrics over one ``run``."""

    finished: int
    sim_time_s: float
    decode_tokens: int
    tokens_per_s: float
    latency_p50_s: float
    latency_p95_s: float
    ttft_p50_s: float
    # bubble-aware admission: requests admitted inside measured decode
    # stall windows, and the prefill seconds those windows absorbed
    admitted_during_stall: int = 0
    stall_hidden_s: float = 0.0

    def row(self) -> str:
        return (
            f"{self.finished:4d} req  {self.decode_tokens:5d} tok  "
            f"{self.tokens_per_s:8.1f} tok/s  "
            f"p50 {self.latency_p50_s*1e3:7.2f} ms  "
            f"p95 {self.latency_p95_s*1e3:7.2f} ms"
        )


class Scheduler:
    """FCFS continuous batching over ``engine.batch_size`` slots.

    ``round_tokens`` is the fused-scan granularity: each round decodes that
    many tokens for every running slot in ONE jit call, then reconciles
    (finishes, evictions, admissions) on the host. Larger rounds amortize
    more host overhead but over-decode up to round_tokens-1 tokens for a
    request that finishes mid-round (the tokens are dropped; the slot is
    recycled at the round boundary).
    """

    def __init__(
        self,
        engine: ServeEngine,
        round_tokens: int = 4,
        compute_s_per_token: float = 0.0,
        admit_in_bubbles: bool = True,
    ):
        if round_tokens < 1:
            raise ValueError("round_tokens must be >= 1")
        self.engine = engine
        self.n_slots = engine.batch_size
        self.round_tokens = round_tokens
        self.compute_s_per_token = compute_s_per_token
        # bubble-aware admission only has windows to use when the engine
        # actually charges the overlapped timeline
        self.admit_in_bubbles = (
            admit_in_bubbles and engine.overlap and engine.prefetch_depth > 0
        )
        self.stall_credit_s = 0.0  # banked decode-stall seconds (see module doc)
        self.admitted_during_stall = 0
        self.stall_hidden_s = 0.0
        self.waiting: Deque[Request] = deque()
        self.running: List[Optional[Request]] = [None] * self.n_slots
        self.finished: List[Request] = []
        self.now_s = 0.0
        self.decode_tokens = 0
        # per-slot current input token fed to the next decode round
        self._slot_tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        engine.enable_slots()

    # -- admission / eviction ------------------------------------------------
    def submit(self, requests) -> None:
        for r in requests if isinstance(requests, (list, tuple)) else [requests]:
            self.waiting.append(r)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.running) if r is None]

    def num_running(self) -> int:
        return self.n_slots - len(self.free_slots())

    def _admit_ready(self) -> int:
        """Admit WAITING requests that have arrived into free slots (FCFS).
        Prefill advances the clock by the request's simulated weight-stream
        time, minus whatever fits into banked decode-stall credit (the
        admission rode an earlier round's I/O bubbles — see module doc).
        Returns the number admitted."""
        admitted = 0
        for slot in self.free_slots():
            if not self.waiting or self.waiting[0].arrival_s > self.now_s:
                break
            req = self.waiting.popleft()
            last, prefill_sim = self.engine.admit_slot(slot, req.prompt)
            prefill_sim = float(prefill_sim)
            if self.admit_in_bubbles and self.stall_credit_s > 0.0:
                hidden = min(self.stall_credit_s, prefill_sim)
                self.stall_credit_s -= hidden
                prefill_sim -= hidden
                self.admitted_during_stall += 1
                self.stall_hidden_s += hidden
                self.engine.note_stall_admission(hidden)
            self.now_s += prefill_sim
            req.state = RequestState.RUNNING
            req.slot = slot
            req.admitted_s = self.now_s
            self.running[slot] = req
            tok0 = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            self._slot_tokens = self._slot_tokens.at[slot].set(tok0[0])
            admitted += 1
        return admitted

    def _evict(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        if req.finished_s is None:
            req.finished_s = self.now_s
        self.running[req.slot] = None
        req.slot = None
        self.finished.append(req)

    # -- decode rounds -------------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration: admit, decode a round, reconcile.
        Returns False when there is nothing left to do."""
        # fast-forward an idle engine to the next arrival
        if self.num_running() == 0:
            if not self.waiting:
                return False
            self.now_s = max(self.now_s, self.waiting[0].arrival_s)
        self._admit_ready()
        if self.num_running() == 0:
            return bool(self.waiting)

        n_stats0 = len(self.engine.stats)
        toks, step_lat = self.engine.decode_slots(self._slot_tokens, self.round_tokens)
        if self.admit_in_bubbles:
            # bank this round's measured idle windows (compute stalls +
            # fetch-engine bubbles) as admission credit
            self.stall_credit_s += sum(
                s.stall_s + s.bubble_s
                for s in self.engine.stats[n_stats0:] if s.kind == "decode"
            )
        toks_np = np.asarray(toks)  # (slots, round_tokens)
        active = [r for r in self.running if r is not None]
        for i, sim in enumerate(step_lat):
            # the batch shares each model step; clock advances once per step
            self.now_s += float(sim) + self.compute_s_per_token
            for req in active:
                if req.done:
                    continue  # over-decoded filler for an already-done request
                req.tokens_out.append(int(toks_np[req.slot, i]))
                self.decode_tokens += 1
                if req.first_token_s is None:
                    req.first_token_s = self.now_s
                if req.done:
                    # latency marks the token's mid-round time; the slot is
                    # only recycled at the round boundary below
                    req.finished_s = self.now_s
        self._slot_tokens = toks[:, -1:]
        for req in list(active):
            if req.done:
                self._evict(req)
        return bool(self.waiting) or self.num_running() > 0

    def run(self, max_rounds: int = 100_000) -> SchedulerStats:
        """Drive until every submitted request has finished."""
        rounds = 0
        while self.step():
            rounds += 1
            if rounds >= max_rounds:
                raise RuntimeError(f"scheduler did not drain in {max_rounds} rounds")
        return self.stats()

    def stats(self) -> SchedulerStats:
        lats = np.array([r.latency_s() for r in self.finished]) if self.finished else np.array([0.0])
        ttfts = np.array([r.ttft_s() for r in self.finished]) if self.finished else np.array([0.0])
        return SchedulerStats(
            finished=len(self.finished),
            sim_time_s=self.now_s,
            decode_tokens=self.decode_tokens,
            tokens_per_s=self.decode_tokens / max(self.now_s, 1e-12),
            latency_p50_s=float(np.percentile(lats, 50)),
            latency_p95_s=float(np.percentile(lats, 95)),
            ttft_p50_s=float(np.percentile(ttfts, 50)),
            admitted_during_stall=self.admitted_during_stall,
            stall_hidden_s=self.stall_hidden_s,
        )
