"""Serving launcher: streaming-VLM (or plain LLM) inference with the
neuron-chunking policy and flash-offload simulation.

Single-stream mode (prefill → frames → fused decode):

  PYTHONPATH=src python -m repro.launch.serve --arch internvl2-76b --reduced \
      --method chunk --sparsity 0.4 --frames 4 --decode-tokens 16

Continuous-batching mode (Poisson arrivals over request slots):

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --streams 8 --arrival-rate 100 --method chunk
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..configs.base import InputShape
from ..core.faults import CORRUPTION_PROFILES, FAULT_PROFILES
from ..kernels.backend import BACKENDS
from ..models import build_model
from ..models.inputs import make_dummy_batch
from ..serving import (
    SERVE_METHODS,
    PoissonArrivalDriver,
    Request,
    Scheduler,
    ServeEngine,
)
from ..sharding.serve import ServeMesh, validate_serve_mesh


def _nonneg_float(name: str):
    """argparse ``type=`` for flags that must be >= 0 — a bad value fails
    at parse time with an actionable message (the --mesh treatment),
    instead of erroring deep inside engine construction."""
    def parse(text: str) -> float:
        try:
            v = float(text)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{name} must be a number, got {text!r}"
            ) from None
        if not (v >= 0.0):  # also rejects NaN
            raise argparse.ArgumentTypeError(
                f"{name} must be >= 0, got {text!r}"
            )
        return v
    return parse


def _nonneg_int(name: str):
    def parse(text: str) -> int:
        try:
            v = int(text)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{name} must be an integer, got {text!r}"
            ) from None
        if v < 0:
            raise argparse.ArgumentTypeError(
                f"{name} must be >= 0, got {text!r}"
            )
        return v
    return parse


def _positive_int(name: str):
    def parse(text: str) -> int:
        try:
            v = int(text)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"{name} must be an integer, got {text!r}"
            ) from None
        if v < 1:
            raise argparse.ArgumentTypeError(
                f"{name} must be >= 1, got {text!r}"
            )
        return v
    return parse


def _cache_mb(text: str) -> float:
    # --cache-mb keeps None as "use the profile default", so the >= 0
    # check wraps the plain float parse
    return _nonneg_float("--cache-mb")(text)


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI's argparse parser — exposed (rather than built inline
    in ``main``) so tests/test_docs.py can check every ``--flag`` the docs
    mention against the real option table."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="internvl2-76b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", choices=SERVE_METHODS, default="chunk")
    ap.add_argument("--backend", choices=BACKENDS, default="reference",
                    help="decode execution backend: 'reference' computes "
                         "the planned sparse projections as the DMA "
                         "kernels' pure-jnp schedule twin; 'kernel' "
                         "dispatches the Pallas chunk-gather kernels off "
                         "the decode plan's chunk tables (interpret mode "
                         "off-TPU, compiled on TPU). Tokens are "
                         "byte-identical across backends.")
    ap.add_argument("--wbits", type=int, choices=(16, 8), default=16,
                    help="offloaded chunk storage width: 16 = fp16 payload, "
                         "8 = int8 payload + one f32 scale per 8-row block, "
                         "dequantized inside the gather kernels (and "
                         "identically by the reference twin — tokens stay "
                         "byte-identical across backends at fixed wbits). "
                         "At 8 every byte/latency figure prices the "
                         "quantized rows, so the same I/O budget admits "
                         "about twice the neurons.")
    ap.add_argument("--sparsity", type=float, default=0.4)
    ap.add_argument("--device", choices=("nano", "agx"), default="nano")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--frames", type=int, default=2)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--plan-refresh-interval", type=int, default=1,
                    help="recompute chunk selection every k decode steps; "
                         "reuse the resident plan in between")
    ap.add_argument("--cache-mb", type=_cache_mb, default=None,
                    help="DRAM budget (MB) of the dynamic chunk residency "
                         "cache (paper §5); resident rows cost no flash I/O. "
                         "Default: the device profile's dram_cache_mb (0 = off)")
    ap.add_argument("--kv-page-tokens", type=_positive_int("--kv-page-tokens"),
                    default=None,
                    help="paged KV cache: fixed page size in tokens (must "
                         "divide --max-seq). The KV cache becomes a "
                         "free-list page pool with per-slot page tables and "
                         "copy-on-write prefix sharing; its capacity is "
                         "carved out of the unified --cache-mb budget "
                         "(io_summary reports the kv/weights split). "
                         "Requires --streams (slot mode); greedy tokens are "
                         "byte-identical to the dense KV cache. Default: "
                         "dense per-slot KV")
    ap.add_argument("--per-token", action="store_true",
                    help="use the legacy one-jit-per-token decode loop "
                         "instead of the fused lax.scan loop")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="charge decode steps through the overlapped "
                         "I/O–compute prefetch pipeline (layer l+1's chunks "
                         "stream while layer l computes); --no-overlap "
                         "retains the serial Σio+Σcompute baseline charge. "
                         "Tokens are identical either way.")
    ap.add_argument("--prefetch-depth", type=_nonneg_int("--prefetch-depth"),
                    default=1,
                    help="how many layers the prefetch pipeline's fetch "
                         "engine may run ahead of compute (the DMA kernels' "
                         "slot count - 1): 1 = double buffering, 0 = serial "
                         "schedule, >1 = deeper pipeline. Tokens are "
                         "byte-identical at every depth.")
    ap.add_argument("--mesh", type=str, default="1,1", metavar="DATA,MODEL",
                    help="serve-mesh shape 'data,model' (default 1,1 = "
                         "unsharded): serve slots partition over the data "
                         "axis (--batch and --streams must divide it), the "
                         "offloaded decode weights / chunk payloads / block "
                         "tables partition over the model axis (ffn rows "
                         "must divide model x 8). Greedy tokens are "
                         "byte-identical to the 1,1 mesh at both --wbits. "
                         "Simulate devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--streams", type=int, default=0,
                    help=">0: continuous-batching mode — serve this many "
                         "Poisson-arriving requests through --batch slots")
    ap.add_argument("--arrival-rate", type=float, default=50.0,
                    help="request arrival rate (requests/sec, sim clock)")
    ap.add_argument("--round-tokens", type=_positive_int("--round-tokens"),
                    default=4,
                    help="fused-scan decode round granularity of the "
                         "continuous-batching scheduler (tokens per jit "
                         "call per slot); must be >= 1")
    ap.add_argument("--fault-profile", choices=tuple(FAULT_PROFILES),
                    default="none",
                    help="storage-turbulence profile injected at the "
                         "simulator's measurement boundary (core/faults.py): "
                         "tail-latency spikes, transient read failures with "
                         "retry + exponential backoff, thermal-throttle "
                         "trajectories. Selection keeps planning against "
                         "the clean latency table; faults only perturb "
                         "charged time, never tokens. 'none' (default) is "
                         "bit-identical to a fault-free engine.")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the fault model's own RNG stream — a "
                         "given (--fault-profile, --fault-seed) replays "
                         "bit-identically and never shifts the simulator's "
                         "main jitter stream")
    ap.add_argument("--corruption-profile", choices=tuple(CORRUPTION_PROFILES),
                    default="none",
                    help="data-plane corruption profile injected into "
                         "fetched chunk blocks (core/faults.py): 'bit_rot' "
                         "flips one stored bit per corrupted 8-row block, "
                         "'torn_read' zeroes blocks, 'degraded_nand' "
                         "combines a high corruption rate with mostly-stuck "
                         "re-reads. Unlike --fault-profile this damages the "
                         "DATA — with --no-recover tokens can change. Every "
                         "fetched block is checksum-verified at the gather "
                         "boundary; detections climb the recovery ladder "
                         "(re-read → resident DRAM copy → substitute → "
                         "drop), counted in io_summary(). 'none' (default) "
                         "is bit-identical to a corruption-free engine.")
    ap.add_argument("--corruption-seed", type=int, default=0,
                    help="seed of the corruption model's own RNG stream — a "
                         "given (--corruption-profile, --corruption-seed) "
                         "draws the same corrupt blocks every replay; "
                         "requires a corruption profile other than 'none'")
    ap.add_argument("--max-reread", type=_nonneg_int("--max-reread"),
                    default=2,
                    help="recovery ladder rung 0: how many times a "
                         "checksum-mismatched block may be re-read (each "
                         "charged the block's latency + exponential "
                         "backoff) before escalating to the resident-copy / "
                         "substitute / drop rungs; 0 skips straight to "
                         "escalation")
    ap.add_argument("--recover", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the corruption recovery ladder (default). "
                         "--no-recover detects and counts corruption but "
                         "lets the damaged payloads flow into compute — the "
                         "measurable-corruption baseline (tokens CAN "
                         "change, deterministically per seed)")
    ap.add_argument("--degrade", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="enable the adaptive degradation controller: "
                         "watches the EWMA of measured-vs-estimated step "
                         "latency at decode-call boundaries and tightens "
                         "the selector's chunk I/O budget while the device "
                         "is degraded (leaning on residency-cache hits), "
                         "recovering when it stabilizes")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request SLO deadline (seconds from arrival, "
                         "sim clock) for --streams mode: admission becomes "
                         "earliest-deadline-first and deadline-blown "
                         "running requests may be preempted "
                         "(evict-and-requeue); stats gain p99 + SLO "
                         "attainment. Default: best-effort (no deadlines)")
    return ap


def validate_seed_flags(ap: argparse.ArgumentParser, args) -> None:
    """Reject seed flags whose matching profile is off, at argparse time.

    ``--fault-seed 7`` with ``--fault-profile none`` (and likewise
    ``--corruption-seed`` with ``--corruption-profile none``) used to parse
    fine and silently run a fault-free engine — the seed did nothing. That
    is always a typo (the user expected perturbation); fail with the
    standard argparse usage error instead of quietly measuring the wrong
    thing. Seed 0 is each stream's default and stays valid either way."""
    if args.fault_seed != 0 and args.fault_profile == "none":
        ap.error(
            f"--fault-seed {args.fault_seed} has no effect with "
            "--fault-profile none; pick a profile "
            f"({', '.join(p for p in FAULT_PROFILES if p != 'none')}) "
            "or drop the seed"
        )
    if args.corruption_seed != 0 and args.corruption_profile == "none":
        ap.error(
            f"--corruption-seed {args.corruption_seed} has no effect with "
            "--corruption-profile none; pick a profile "
            f"({', '.join(p for p in CORRUPTION_PROFILES if p != 'none')}) "
            "or drop the seed"
        )


def resolve_mesh(spec: str, cfg, batch: int, streams: int) -> ServeMesh:
    """Parse + validate ``--mesh`` against ``--batch``/``--streams``/the
    arch config BEFORE any model is built, so a bad mesh fails in
    milliseconds with an actionable message instead of mid-prefill
    (tests/test_sharded_serving.py pins the error cases)."""
    parts = spec.split(",")
    if len(parts) != 2:
        raise ValueError(
            f"--mesh must be 'data,model' (e.g. 2,2), got {spec!r}"
        )
    try:
        data, model = (int(p) for p in parts)
    except ValueError:
        raise ValueError(
            f"--mesh axes must be integers, got {spec!r}"
        ) from None
    validate_serve_mesh(
        data, model, batch=batch, streams=streams,
        d_ff=(cfg.d_ff if (model > 1 and cfg.d_ff and not cfg.has_moe) else 0),
        n_devices=len(jax.devices()),
    )
    return ServeMesh.create(data, model)


def main():
    ap = build_parser()
    args = ap.parse_args()
    validate_seed_flags(ap, args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.kv_page_tokens is not None and args.streams <= 0:
        ap.error("--kv-page-tokens requires --streams (paged KV is slot-mode "
                 "only: requests are admitted through the page allocator)")
    mesh = resolve_mesh(args.mesh, cfg, args.batch, args.streams)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_seq=args.max_seq, batch_size=args.batch,
                      device=args.device, sparsity=args.sparsity,
                      method=args.method,
                      plan_refresh_interval=args.plan_refresh_interval,
                      cache_mb=args.cache_mb, overlap=args.overlap,
                      prefetch_depth=args.prefetch_depth,
                      backend=args.backend, wbits=args.wbits, mesh=mesh,
                      fault_profile=args.fault_profile,
                      fault_seed=args.fault_seed, degrade=args.degrade,
                      corruption_profile=args.corruption_profile,
                      corruption_seed=args.corruption_seed,
                      max_reread=args.max_reread, recover=args.recover,
                      kv_page_tokens=args.kv_page_tokens)

    if args.streams > 0:
        _serve_streams(args, cfg, eng)
        return

    shape = InputShape("cli", args.prompt_len, args.batch, "train")
    batch = make_dummy_batch(cfg, shape)
    last = eng.prefill(batch)
    print(f"[prefill] {args.prompt_len} tokens")
    rng = np.random.default_rng(0)
    if cfg.d_frontend and not cfg.is_encdec:
        n_tok = max(cfg.frontend_tokens // 4, 4)
        for i in range(args.frames):
            frame = jnp.asarray(
                rng.normal(0, 1, (args.batch, n_tok, cfg.d_frontend)), jnp.bfloat16
            )
            eng.append_frame(frame)
            st = eng.stats[-1]
            print(f"[frame {i}] {n_tok} tokens  io_est {st.io_est_s*1e3:.2f} ms  "
                  f"io_sim {st.io_sim_s*1e3:.2f} ms")
    tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    decode = eng.decode_per_token if args.per_token else eng.decode
    decode(tok0, args.decode_tokens)
    dsteps = [s for s in eng.stats if s.kind == "decode"]
    mode = "per-token" if args.per_token else "fused-scan"
    print(f"[decode:{mode}] {args.decode_tokens} tokens  "
          f"mean io_sim {np.mean([s.io_sim_s for s in dsteps])*1e3:.2f} ms/token  "
          f"wall {sum(s.wall_s for s in dsteps)*1e3:.1f} ms")
    s = eng.io_summary()
    charged = "overlap" if args.overlap else "serial"
    print(f"[pipeline] charged={charged} depth={args.prefetch_depth}  "
          f"serial {s['decode_serial_s']*1e3:.2f} ms  "
          f"overlapped {s['decode_overlap_s']*1e3:.2f} ms  "
          f"stall {s['decode_stall_s']*1e3:.2f} ms  "
          f"overlap_efficiency {s['overlap_efficiency']:.3f}  "
          f"select_overhead {s['select_overhead_s']*1e3:.2f} ms")
    if eng.mesh.is_sharded:
        ss = eng.shard_summary()
        per = ", ".join(f"{b/1e6:.1f}" for b in ss["io_bytes_per_shard"])
        print(f"[mesh] data={eng.mesh.data} model={eng.mesh.model}  "
              f"slots/data_shard={ss['slots_per_data_shard']}  "
              f"cache_mb/shard={ss['cache_mb_per_shard']:g}  "
              f"io_bytes/shard MB=[{per}]")
    print(f"[total] method={args.method} backend={args.backend} "
          f"wbits={args.wbits} sparsity={args.sparsity} "
          f"refresh_interval={args.plan_refresh_interval} "
          f"cache_mb={eng.cache_mb:g} "
          f"io_est {s['io_est_s']*1e3:.1f} ms  io_sim {s['io_sim_s']*1e3:.1f} ms  "
          f"io_bytes {s['io_bytes']/1e6:.1f} MB  "
          f"cache_hit_rate {s['cache_hit_rate']:.3f}")
    fs = eng.fault_summary()
    if fs["fault_enabled"] or fs["degrade_enabled"]:
        print(f"[faults] profile={fs['fault_profile']} seed={fs['fault_seed']}  "
              f"events {fs['fault_events']}  spikes {fs['fault_spikes']}  "
              f"retries {fs['fault_retries']}  "
              f"extra {fs['fault_extra_s']*1e3:.2f} ms  "
              f"min_throttle {fs['min_throttle_scale']:.2f}  "
              f"degrade_scale {fs['degrade_scale']:.2f}")
    _print_integrity(args, s)


def _print_integrity(args, s) -> None:
    """The [integrity] rollup line (corruption injection runs only)."""
    if args.corruption_profile == "none":
        return
    print(f"[integrity] profile={args.corruption_profile} "
          f"seed={args.corruption_seed} recover={args.recover} "
          f"max_reread={args.max_reread}  "
          f"detected {s['corruptions_detected']:.0f}  "
          f"recovered {s['corruptions_recovered']:.0f}  "
          f"substituted {s['corruptions_substituted']:.0f}  "
          f"dropped {s['corruptions_dropped']:.0f}  "
          f"reread {s['integrity_reread_s']*1e3:.2f} ms")


def _serve_streams(args, cfg, eng):
    """Continuous-batching mode: Poisson arrivals into request slots."""
    rng = np.random.default_rng(0)

    def make_request(rid: int) -> Request:
        batch = make_dummy_batch(cfg, InputShape("req", args.prompt_len, 1, "train"))
        # vary prompts so streams are not identical
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab_size, batch["tokens"].shape), jnp.int32
        )
        prompt = dict(batch)
        prompt["tokens"] = toks
        return Request(rid=rid, prompt=prompt, max_new_tokens=args.decode_tokens,
                       deadline_s=args.deadline_s)

    driver = PoissonArrivalDriver(args.arrival_rate, make_request, seed=1)
    sched = Scheduler(eng, round_tokens=args.round_tokens)
    sched.submit(driver.generate(args.streams))
    stats = sched.run()
    print(f"[serve] method={args.method} slots={args.batch} "
          f"rate={args.arrival_rate}/s refresh={args.plan_refresh_interval} "
          f"cache_mb={eng.cache_mb:g}")
    print(f"[serve] {stats.row()}")
    s = eng.io_summary()
    print(f"[serve] ttft p50 {stats.ttft_p50_s*1e3:.2f} ms  "
          f"sim time {stats.sim_time_s*1e3:.1f} ms  "
          f"overlap_efficiency {s['overlap_efficiency']:.3f}  "
          f"cache_hit_rate {s['cache_hit_rate']:.3f}")
    print(f"[serve] admitted_during_stall {s['admitted_during_stall']}  "
          f"stall_hidden {s['stall_hidden_s']*1e3:.2f} ms  "
          f"bubble_utilization {s['bubble_utilization']:.3f}")
    if eng.kv_pool is not None:
        ps = eng.kv_pool.summary()
        print(f"[paged-kv] page_tokens {eng.kv_page_tokens}  "
              f"pages {eng.kv_pages} (kv {s['kv_cache_mb']:.2f} MB / "
              f"weights {s['weight_cache_mb']:.2f} MB)  "
              f"shared_hits {ps['shared_hits']}  cow {ps['cow_copies']}  "
              f"evictions {ps['evictions']}")
    if args.deadline_s is not None:
        print(f"[slo] deadline {args.deadline_s*1e3:.1f} ms  "
              f"attainment {stats.slo_attainment:.3f} "
              f"({stats.deadlines_met}/{stats.deadlines})  "
              f"p99 {stats.latency_p99_s*1e3:.2f} ms  "
              f"preempted {stats.preempted}")
    fs = eng.fault_summary()
    if fs["fault_enabled"] or fs["degrade_enabled"]:
        print(f"[faults] profile={fs['fault_profile']} "
              f"seed={fs['fault_seed']}  events {fs['fault_events']}  "
              f"spikes {fs['fault_spikes']}  retries {fs['fault_retries']}  "
              f"extra {fs['fault_extra_s']*1e3:.2f} ms  "
              f"min_throttle {fs['min_throttle_scale']:.2f}")
        print(f"[degrade] on={fs['degrade_enabled']}  "
              f"scale {fs['degrade_scale']:.2f}  "
              f"ewma {fs['degrade_ewma_ratio']:.2f}  "
              f"tighten {fs['degrade_tighten_steps']}  "
              f"relax {fs['degrade_relax_steps']}")
    _print_integrity(args, s)


if __name__ == "__main__":
    main()
