"""Serving launcher: streaming-VLM (or plain LLM) inference with the
neuron-chunking policy and flash-offload simulation.

  PYTHONPATH=src python -m repro.launch.serve --arch internvl2-76b --reduced \
      --method chunk --sparsity 0.4 --frames 4 --decode-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..configs.base import InputShape
from ..models import build_model
from ..models.inputs import make_dummy_batch
from ..serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="internvl2-76b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", choices=("dense", "topk", "chunk"), default="chunk")
    ap.add_argument("--sparsity", type=float, default=0.4)
    ap.add_argument("--device", choices=("nano", "agx"), default="nano")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--frames", type=int, default=2)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=512)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_seq=args.max_seq, batch_size=args.batch,
                      device=args.device, sparsity=args.sparsity,
                      method=args.method)

    shape = InputShape("cli", args.prompt_len, args.batch, "train")
    batch = make_dummy_batch(cfg, shape)
    last = eng.prefill(batch)
    print(f"[prefill] {args.prompt_len} tokens")
    rng = np.random.default_rng(0)
    if cfg.d_frontend and not cfg.is_encdec:
        n_tok = max(cfg.frontend_tokens // 4, 4)
        for i in range(args.frames):
            frame = jnp.asarray(
                rng.normal(0, 1, (args.batch, n_tok, cfg.d_frontend)), jnp.bfloat16
            )
            eng.append_frame(frame)
            st = eng.stats[-1]
            print(f"[frame {i}] {n_tok} tokens  io_est {st.io_est_s*1e3:.2f} ms  "
                  f"io_sim {st.io_sim_s*1e3:.2f} ms")
    tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    out = eng.decode(tok0, args.decode_tokens)
    dsteps = [s for s in eng.stats if s.kind == "decode"]
    print(f"[decode] {args.decode_tokens} tokens  "
          f"mean io_sim {np.mean([s.io_sim_s for s in dsteps])*1e3:.2f} ms/token")
    s = eng.io_summary()
    print(f"[total] method={args.method} sparsity={args.sparsity} "
          f"io_est {s['io_est_s']*1e3:.1f} ms  io_sim {s['io_sim_s']*1e3:.1f} ms")


if __name__ == "__main__":
    main()
