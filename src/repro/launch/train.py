"""Training launcher.

Local (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128

Production (TPU pod; mesh axes data×model from the device grid):
  python -m repro.launch.train --arch internvl2-76b --mesh 16,16 \
      --batch 256 --seq 4096 --steps 1000 --ckpt-dir gs://...
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..data import DataConfig, lm_batches
from ..models import build_model
from ..sharding import MeshRules, use_rules
from ..training import AdamWConfig, Trainer, save_checkpoint
from .mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None, help="e.g. 16,16 → (data, model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    trainer = Trainer(
        model,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps),
        loss_chunk=min(512, args.seq),
    )

    rules = None
    mesh_cm = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = ("data", "model")[: len(shape)] if len(shape) == 2 else (
            "pod", "data", "model")
        mesh = make_mesh(shape, axes)
        rules = MeshRules.for_mesh(mesh, fsdp=cfg.fsdp)
        mesh_cm = mesh

    def run():
        params, opt = trainer.init_state(jax.random.key(0))
        step_fn = trainer.jit_train_step(donate=True)
        it = lm_batches(cfg, DataConfig(batch=args.batch, seq_len=args.seq))
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt, m = step_fn(params, opt, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(
                    f"step {i:5d} loss {float(m['loss']):.4f} "
                    f"ce {float(m['ce']):.4f} gnorm {float(m['grad_norm']):.2f} "
                    f"lr {float(m['lr']):.2e} {(time.time()-t0)/(i+1):.2f}s/step",
                    flush=True,
                )
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, params, step=args.steps)
            print(f"saved checkpoint to {args.ckpt_dir}")
        return params

    if mesh_cm is not None:
        with use_rules(rules), mesh_cm:
            run()
    else:
        run()


if __name__ == "__main__":
    main()
