"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax initialization.

Production topology (assignment): TPU v5e, 256 chips/pod.
  single-pod: (data=16, model=16)                    = 256 devices
  multi-pod:  (pod=2, data=16, model=16)             = 512 devices
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests use small host-device meshes like (2,2))."""
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2) -> Mesh:
    """Small mesh over host devices for CI-scale sharding tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
