import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
# ^ MUST precede any jax import/initialization: jax locks the device count on
# first init, and the production dry-run needs 512 placeholder host devices.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination, builds the real
pjit program — train_step for train shapes, prefill for prefill shapes,
serve_step (one token + KV/state cache) for decode shapes — with production
shardings over abstract inputs (ShapeDtypeStruct, zero allocation), then
``.lower().compile()`` it and extracts:

  * memory_analysis (per-device bytes: proves the config fits a 16 GB v5e),
  * cost_analysis (FLOPs / bytes → roofline compute & memory terms),
  * collective bytes parsed from the post-SPMD optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute → roofline collective term).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all --out-dir results/dryrun
  python -m repro.launch.dryrun --all --multi-pod --out-dir results/dryrun
"""
import argparse
import json
import re
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..configs.shapes import SHAPES, get_shape
from ..models import build_model
from ..models.inputs import input_specs
from ..sharding import MeshRules, use_rules
from ..training import AdamWConfig, Trainer, init_opt_state
from ..training.optimizer import OptState
from .mesh import make_production_mesh

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "e4m3": 1, "e5m2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every `dtype[dims]` group in a shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Parse the optimized (post-SPMD) HLO, summing the RESULT sizes of every
    collective op (convention documented in EXPERIMENTS.md §Roofline)."""
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for kind in _COLLECTIVES:
            # match "= <shape> all-gather(" and fusion-wrapped variants
            m = re.search(r"=\s*(\(?[\w\[\],\s{}]*\)?)\s*" + kind + r"(-start)?\(", ls)
            if m and not ls.startswith("ROOT tuple"):
                if kind == "all-gather" and "all-gather-done" in ls:
                    continue
                if "-done(" in ls:
                    continue
                per_kind[kind] += _shape_bytes(m.group(1))
                counts[kind] += 1
                break
    total = sum(per_kind.values())
    return {"per_kind_bytes": per_kind, "counts": counts, "total_bytes": total}


def _shardings_for(tree_sds, axes_tree, rules: MeshRules):
    return jax.tree.map(
        lambda sds, ax: rules.sharding(ax, sds.shape), tree_sds, axes_tree
    )


def build_step(arch: str, shape_name: str, mesh, rules: MeshRules,
               optimized: bool = False):
    """Returns (fn, abstract_args, in_shardings, donate) for the pair.

    optimized=True applies the beyond-paper §Perf changes (KV-cache head
    replication sized to the mesh's model axis); False is the baseline."""
    cfg = get_config(arch)
    if optimized:
        cfg = cfg.optimized_for(int(mesh.shape["model"]))
    shape = get_shape(shape_name)
    model = build_model(cfg)
    key = jax.random.key(0)

    params_sds = jax.eval_shape(model.init, key)
    params_sh = _shardings_for(params_sds, model.param_axes(), rules)
    batch_sds = input_specs(cfg, shape)
    batch_axes = {
        "tokens": ("batch", None),
        "frontend": ("batch", None, None),
    }
    batch_sh = {
        k: rules.sharding(batch_axes[k], v.shape) for k, v in batch_sds.items()
    }

    if shape.kind == "train":
        trainer = Trainer(model, AdamWConfig(), loss_chunk=512)
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        opt_sh = OptState(
            step=rules.sharding((), ()),
            m=params_sh,
            v=jax.tree.map(lambda s: s, params_sh),
        )
        fn = trainer.train_step
        return fn, (params_sds, opt_sds, batch_sds), (params_sh, opt_sh, batch_sh), (0, 1)

    if shape.kind == "prefill":
        def fn(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        return fn, (params_sds, batch_sds), (params_sh, batch_sh), ()

    # decode: one token against a seq_len cache
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )
    cache_sh = _shardings_for(cache_sds, model.cache_axes(), rules)
    token_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    token_sh = rules.sharding(("batch", None), token_sds.shape)

    def fn(params, token, cache):
        return model.decode_step(params, token, cache)

    return fn, (params_sds, token_sds, cache_sds), (params_sh, token_sh, cache_sh), (2,)


def run_dryrun(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    mesh=None,
    verbose: bool = True,
    optimized: bool = False,
) -> Dict[str, Any]:
    t0 = time.time()
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    rules = MeshRules.for_mesh(mesh, fsdp=cfg.fsdp)
    if optimized:
        import dataclasses as _dc

        rules = _dc.replace(rules, seq_shard_attention=True)
    with use_rules(rules), mesh:
        fn, args, shardings, donate = build_step(
            arch, shape_name, mesh, rules, optimized=optimized
        )
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older JAX: one dict per device
            cost = cost[0] if cost else {}
        try:
            mem = compiled.memory_analysis()
            mem_stats = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # memory analysis can be backend-dependent
            mem_stats = {"error": str(e)}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        from .hlo_analysis import analyze_hlo

        corrected = analyze_hlo(hlo)

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": {"axes": dict(zip(mesh.axis_names, mesh.devices.shape))},
        "multi_pod": multi_pod,
        # flat XLA numbers (while bodies counted ONCE — diagnostic only)
        "flops_per_device": cost.get("flops"),
        "bytes_accessed_per_device": cost.get("bytes accessed"),
        # trip-count-corrected (launch/hlo_analysis.py) — roofline inputs
        "corrected_flops_per_device": corrected["flops"],
        "corrected_bytes_per_device": corrected["bytes"],
        "corrected_collective_bytes_per_device": corrected["collective_bytes"],
        "corrected_collective_per_kind": corrected.get("collective_per_kind"),
        "memory": mem_stats,
        "collectives": coll,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if verbose:
        print(json.dumps(report, indent=2, default=str))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="sweep all arch × shape")
    ap.add_argument("--optimized", action="store_true",
                    help="apply beyond-paper §Perf sharding changes")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    pairs = (
        [(a, s) for a in ARCH_IDS for s in sorted(SHAPES)]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape in pairs:
        tag = f"{arch}__{shape}__{'pod2' if args.multi_pod else 'pod1'}"
        if args.optimized:
            tag += "__opt"
        try:
            rep = run_dryrun(arch, shape, args.multi_pod, mesh=mesh,
                             verbose=not args.all, optimized=args.optimized)
            status = "OK"
        except Exception as e:  # noqa: BLE001 — sweep must report all failures
            rep = {"arch": arch, "shape": shape, "error": repr(e)[:2000]}
            failures.append(tag)
            status = f"FAIL: {repr(e)[:200]}"
        if args.out_dir:
            os.makedirs(args.out_dir, exist_ok=True)
            with open(os.path.join(args.out_dir, tag + ".json"), "w") as f:
                json.dump(rep, f, indent=2, default=str)
        print(f"[dryrun] {tag}: {status}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
