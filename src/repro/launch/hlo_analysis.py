"""Trip-count-aware HLO cost analysis.

XLA's flat ``compiled.cost_analysis()`` counts a while-loop body ONCE, so a
scan-over-layers model under-reports FLOPs/bytes/collectives by the trip
count (≈ n_layers × inner attention blocks…). The optimized HLO text carries
``known_trip_count`` on every counted loop, so we parse the module, build the
computation call graph (while/call/conditional/fusion edges), propagate
multipliers from ENTRY, and accumulate:

  * flops: 2 · |out| · |contracting dims| for every ``dot`` (fusion bodies
    included — dots may live inside fusions); convolutions approximated the
    same way via their window dims.
  * memory bytes: per *materialized* op (top level of non-fusion
    computations): output bytes + operand bytes — fusion internals are not
    double-counted, matching XLA's fusion semantics to first order.
  * collective bytes: result sizes of all-gather/all-reduce/reduce-scatter/
    all-to-all/collective-permute (async -start counted, -done skipped),
    each × its computation's multiplier.

Conventions are documented in EXPERIMENTS.md §Roofline. Parsing is
necessarily heuristic against HLO text, but every quantity it produces is
validated against analytic MODEL_FLOPS in benchmarks/roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_shape: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion_body: bool = False


_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\s]*?))\s*([\w\-]+)\("
)


def _parse_operands(line: str, op_start: int) -> List[str]:
    """Operand names from the first parenthesized arg list after the opcode."""
    depth = 0
    args = ""
    for ch in line[op_start:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            args += ch
    return re.findall(r"%([\w.\-]+)", args)


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], str]:
    """Returns ({computation name: Computation}, entry name)."""
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # computation header: "[ENTRY] %name (params...) -> type {"
        # params may contain '=' inside /*index=N*/ comments — match by
        # structure, not content.
        if stripped.endswith("{") and ") -> " in stripped:
            first = stripped.split(None, 1)[0]
            is_entry = first == "ENTRY"
            name_tok = stripped.split(None, 2)[1] if is_entry else first
            if name_tok.startswith("%"):
                name = name_tok.lstrip("%").split("(")[0].rstrip()
                cur = Computation(name=name, ops=[],
                                  is_fusion_body="fused" in name)
                comps[name] = cur
                if is_entry:
                    entry = name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        _, opname, shape, kind = m.groups()
        operands = _parse_operands(line, m.end() - 1)
        cur.ops.append(Op(name=opname, kind=kind, result_shape=shape,
                          operands=operands, line=line))
    return comps, entry


def _trip_count(line: str) -> int:
    m = re.search(r'known_trip_count\\?":{\\?"n\\?":\\?"(\d+)', line)
    if m:
        return int(m.group(1))
    m = re.search(r'known_trip_count"?\s*:\s*{\s*"?n"?\s*:\s*"?(\d+)', line)
    return int(m.group(1)) if m else 1


def _callees(op: Op) -> List[Tuple[str, int]]:
    """(callee computation, multiplier) edges from one op."""
    out = []
    line = op.line
    if op.kind == "while":
        body = re.search(r"body=%?([\w.\-]+)", line)
        if body:
            out.append((body.group(1), _trip_count(line)))
    elif op.kind in ("fusion", "call", "async-start", "custom-call"):
        m = re.search(r"(?:calls|to_apply|called_computation)=%?([\w.\-]+)", line)
        if m:
            out.append((m.group(1), 1))
    elif op.kind == "conditional":
        for m in re.finditer(r"branch_computations={([^}]*)}", line):
            for name in re.findall(r"%([\w.\-]+)", m.group(1)):
                out.append((name, 1))
        m = re.search(r"(?:true|false)_computation=%?([\w.\-]+)", line)
        if m:
            out.append((m.group(1), 1))
    elif op.kind in ("reduce", "sort", "scatter", "map", "reduce-window",
                     "select-and-scatter", "all-reduce", "reduce-scatter"):
        m = re.search(r"to_apply=%?([\w.\-]+)", line)
        if m:
            out.append((m.group(1), 1))
    return out


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for op in comps[name].ops:
            for callee, k in _callees(op):
                visit(callee, m * k)

    visit(entry, 1.0)
    return mult


def _dot_flops(op: Op, defs: Dict[str, str]) -> float:
    out_elems = 1
    for d in _dims(op.result_shape):
        out_elems *= d
    # contracting dim sizes from lhs shape
    lhs_shape = defs.get(op.operands[0], "") if op.operands else ""
    lhs_dims = _dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", op.line)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, defs: Dict[str, str]) -> float:
    out_elems = 1
    for d in _dims(op.result_shape):
        out_elems *= d
    rhs_shape = defs.get(op.operands[1], "") if len(op.operands) > 1 else ""
    kernel = 1
    for d in _dims(rhs_shape):
        kernel *= d
    rhs_dims = _dims(rhs_shape)
    out_feat = rhs_dims[-1] if rhs_dims else 1
    return 2.0 * out_elems * max(kernel // max(out_feat, 1), 1)


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps, entry = parse_module(hlo)
    if not entry:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "parse_error": 1.0}
    mult = _multipliers(comps, entry)
    # global def map (op name → result shape); names unique per module
    defs: Dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            defs[op.name] = op.result_shape

    flops = 0.0
    mem_bytes = 0.0
    coll_bytes = 0.0
    coll_per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, defs)
            elif op.kind == "convolution":
                flops += m * _conv_flops(op, defs)
            base_kind = op.kind.replace("-start", "")
            if base_kind in _COLLECTIVES and not op.kind.endswith("-done"):
                b = _shape_bytes(op.result_shape)
                coll_bytes += m * b
                coll_per_kind[base_kind] += m * b
            if not comp.is_fusion_body and op.kind not in (
                "parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "while", "conditional",
            ):
                b = _shape_bytes(op.result_shape)
                for operand in op.operands:
                    b += _shape_bytes(defs.get(operand, ""))
                mem_bytes += m * b
    return {
        "flops": flops,
        "bytes": mem_bytes,
        "collective_bytes": coll_bytes,
        "collective_per_kind": coll_per_kind,
        "n_computations": float(len(comps)),
    }
