"""Contiguity distribution: the paper's core abstraction (§3).

A selection mask M ∈ {0,1}^N is reduced to the multiset of maximal
contiguous run lengths ("chunks"). Example from the paper: selecting
{1,2,4,6,7} yields chunks {1,2}, {4}, {6,7} → contiguity distribution
{1: 1, 2: 2}.

Two implementations are provided:
  * numpy (`*_np`) — reference semantics, used by tests and offline tools.
  * jnp (`*_jax`)  — jit/vmap-compatible, static output shapes, used inside
    the runtime selection path and the offload simulator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Chunk:
    """A maximal contiguous run of selected neuron indices [start, start+size)."""

    start: int
    size: int

    @property
    def stop(self) -> int:
        return self.start + self.size


def mask_to_chunks_np(mask: np.ndarray) -> List[Chunk]:
    """Decompose a binary mask into maximal contiguous chunks (numpy ref)."""
    mask = np.asarray(mask).astype(bool)
    if mask.ndim != 1:
        raise ValueError(f"mask must be 1-D, got shape {mask.shape}")
    if not mask.any():
        return []
    padded = np.concatenate([[False], mask, [False]])
    diff = np.diff(padded.astype(np.int8))
    starts = np.nonzero(diff == 1)[0]
    stops = np.nonzero(diff == -1)[0]
    return [Chunk(int(a), int(b - a)) for a, b in zip(starts, stops)]


def chunks_to_mask_np(chunks: List[Chunk], n: int) -> np.ndarray:
    """Inverse of mask_to_chunks_np (chunks may be unsorted but non-overlapping)."""
    mask = np.zeros(n, dtype=bool)
    for c in chunks:
        if c.start < 0 or c.stop > n:
            raise ValueError(f"chunk {c} out of bounds for n={n}")
        if mask[c.start : c.stop].any():
            raise ValueError(f"chunk {c} overlaps a previous chunk")
        mask[c.start : c.stop] = True
    return mask


def contiguity_distribution_np(mask: np.ndarray) -> Dict[int, int]:
    """Frequency distribution {chunk_size: count} of a mask's chunks."""
    dist: Dict[int, int] = {}
    for c in mask_to_chunks_np(mask):
        dist[c.size] = dist.get(c.size, 0) + 1
    return dist


def chunk_stats_np(mask: np.ndarray) -> Tuple[float, int]:
    """(average chunk size, modal chunk size) — the two numbers the paper
    annotates in Fig. 10 / App. J. Returns (0.0, 0) for an empty mask."""
    sizes = np.array([c.size for c in mask_to_chunks_np(mask)], dtype=np.int64)
    if sizes.size == 0:
        return 0.0, 0
    values, counts = np.unique(sizes, return_counts=True)
    return float(sizes.mean()), int(values[np.argmax(counts)])


# ---------------------------------------------------------------------------
# jit-compatible variants (static shapes: outputs padded to N)
# ---------------------------------------------------------------------------


def mask_to_runs_jax(mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunk decomposition with static shapes.

    Returns (starts, sizes, n_chunks): ``starts``/``sizes`` are (N,) arrays
    whose first ``n_chunks`` entries are valid (rest zero). A mask of length N
    has at most ceil(N/1) chunks, so padding to N is always sufficient.
    """
    mask = mask.astype(jnp.int32)
    n = mask.shape[0]
    prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), mask[:-1]])
    nxt = jnp.concatenate([mask[1:], jnp.zeros((1,), jnp.int32)])
    is_start = (mask == 1) & (prev == 0)
    is_stop = (mask == 1) & (nxt == 0)  # inclusive last index of a run

    idx = jnp.arange(n, dtype=jnp.int32)
    # Compact the start/stop indices to the front, preserving order.
    start_rank = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    stop_rank = jnp.cumsum(is_stop.astype(jnp.int32)) - 1
    starts = jnp.zeros((n,), jnp.int32).at[jnp.where(is_start, start_rank, n - 1)].max(
        jnp.where(is_start, idx, 0)
    )
    stops = jnp.zeros((n,), jnp.int32).at[jnp.where(is_stop, stop_rank, n - 1)].max(
        jnp.where(is_stop, idx, 0)
    )
    n_chunks = jnp.sum(is_start.astype(jnp.int32))
    valid = jnp.arange(n) < n_chunks
    sizes = jnp.where(valid, stops - starts + 1, 0)
    starts = jnp.where(valid, starts, 0)
    return starts, sizes, n_chunks


def contiguity_histogram_jax(mask: jnp.ndarray, max_size: int) -> jnp.ndarray:
    """Histogram h[s] = number of chunks of size s (sizes > max_size clamp).

    h has shape (max_size + 1,), h[0] unused. jit-safe.
    """
    _, sizes, _ = mask_to_runs_jax(mask)
    sizes = jnp.clip(sizes, 0, max_size)
    return jnp.zeros((max_size + 1,), jnp.int32).at[sizes].add(
        (sizes > 0).astype(jnp.int32)
    )


def average_chunk_size_jax(mask: jnp.ndarray) -> jnp.ndarray:
    """Mean chunk size of a mask (0.0 if empty). jit-safe."""
    _, sizes, n_chunks = mask_to_runs_jax(mask)
    total = jnp.sum(sizes)
    return jnp.where(n_chunks > 0, total / jnp.maximum(n_chunks, 1), 0.0)


def runs_to_padded_table_np(
    mask: np.ndarray, max_chunks: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """(starts, sizes, n) padded/truncated to ``max_chunks`` — the chunk table
    format consumed by the Pallas chunk_gather_matmul kernel."""
    chunks = mask_to_chunks_np(mask)
    n = min(len(chunks), max_chunks)
    starts = np.zeros(max_chunks, np.int32)
    sizes = np.zeros(max_chunks, np.int32)
    for i, c in enumerate(chunks[:max_chunks]):
        starts[i] = c.start
        sizes[i] = c.size
    return starts, sizes, n
