"""Flash-offload I/O simulator.

This container has no NVMe flash (and the TPU target has none either), so the
storage tier is simulated: the simulator "executes" an access pattern against
a DeviceProfile and returns a latency sample that reproduces the behaviour the
paper measures:

  * per-chunk two-regime cost (IOPS-bound → bandwidth-bound), Fig. 3/4a;
  * the near-linear proportional lift between the additive chunk model's
    estimate and real interleaved-pattern latency, Fig. 5
    (``interleave_lift`` + lognormal noise, stronger on low-end devices);
  * the sparsity–latency inversion for scattered access, Fig. 4b.

The simulator is the measurement apparatus for every latency number in
EXPERIMENTS.md that refers to Jetson hardware, and is labeled as such.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .contiguity import Chunk, mask_to_chunks_np
from .faults import FaultModel
from .latency_model import DeviceProfile, get_profile
from .pipeline import PipelineModel


@dataclasses.dataclass
class IOEvent:
    """One simulated weight-matrix load.

    ``nbytes`` is the estimated flash→DRAM transfer volume of the event —
    for the estimate-driven decode paths it is the step's cache-miss rows ×
    per-site row bytes, threaded from the decode-plan counters (it used to
    be logged as 0 there, making ``total_bytes()`` meaningless for the scan
    path). Float because the per-row cost is fractional at wbits=8: int8
    payload plus the per-block quantization scale overhead amortized over
    the rows of a block (latency_model.row_stream_bytes). ``hit_rate`` is
    the DRAM residency-cache hit fraction of the rows the step *selected*
    (hit rows transfer nothing — the event's latency charges only the
    cache-miss bytes). 0.0 when the residency tier is disabled.

    ``shard_bytes`` (sharded serving, sharding/serve.py): the event's
    transfer volume split by the model shard whose flash tier each byte
    streams from — sums to ``nbytes`` up to f32 round-off. None on the
    unsharded path, so single-device event logs are unchanged.

    ``retries`` / ``fault_s`` (fault injection, core/faults.py): transient
    read failures retried on this event, and the extra seconds the fault
    model charged on top of the clean simulated latency (throttle + spikes
    + retries + backoff). Both stay at their defaults with faults disabled,
    so fault-off event logs compare equal to pre-fault builds.

    ``integrity_s`` (chunk integrity, PR 9): the checksum-verified re-read
    seconds the integrity subsystem charged on this event — detected
    payload corruptions re-pay their 8-row-block reads plus exponential
    backoff (serving/sparse_exec.py). 0.0 with corruption injection off,
    so integrity-off event logs compare equal to pre-integrity builds.
    """

    name: str
    nbytes: float
    n_chunks: int
    latency_s: float
    hit_rate: float = 0.0
    shard_bytes: Optional[Tuple[float, ...]] = None
    retries: int = 0
    fault_s: float = 0.0
    integrity_s: float = 0.0


class FlashOffloadSimulator:
    """Simulated flash device with paper-calibrated latency behaviour.

    ``measure(mask, row_bytes)`` returns a latency sample including the
    pattern-dependent effects the additive model deliberately ignores;
    ``estimate`` returns the pure additive-model value. The ratio between the
    two reproduces Fig. 5's proportional bias.
    """

    def __init__(
        self,
        device: str | DeviceProfile,
        seed: int = 0,
        noise: float = 0.04,
        pipeline: Optional[PipelineModel] = None,
        faults: Optional[FaultModel] = None,
    ):
        self.profile = device if isinstance(device, DeviceProfile) else get_profile(device)
        self.rng = np.random.default_rng(seed)
        self.noise = noise
        self.log: List[IOEvent] = []
        # the I/O–compute overlap timeline model the serve engine runs its
        # per-layer simulated latencies through (core/pipeline.py)
        self.pipeline = pipeline or PipelineModel()
        # storage turbulence (core/faults.py), applied at the measurement
        # boundary only — estimates keep planning against the clean table.
        # The model draws from its OWN seeded RNG, so attaching it never
        # shifts this simulator's lift/jitter stream.
        self.faults = faults
        # cumulative charged I/O seconds — the thermal trajectory's clock
        self.device_time_s = 0.0

    def _charge(self, latency_s: float) -> Tuple[float, int, float]:
        """Run one clean measured latency through the fault model (if any)
        and advance the device-busy clock. Returns (charged latency,
        retries, extra fault seconds) for the event log."""
        if self.faults is None or not self.faults.enabled or latency_s <= 0.0:
            self.device_time_s += latency_s
            return latency_s, 0, 0.0
        out = self.faults.perturb(latency_s, self.device_time_s)
        self.device_time_s += out.charged_s
        return out.charged_s, out.retries, out.extra_s

    # -- pure additive model (what the runtime uses) -------------------------
    def estimate_chunks(self, chunks: Sequence[Chunk], row_bytes: int) -> float:
        return float(
            sum(self.profile.latency_bytes(c.size * row_bytes) for c in chunks)
        )

    def estimate(self, mask: np.ndarray, row_bytes: int) -> float:
        return self.estimate_chunks(mask_to_chunks_np(mask), row_bytes)

    # -- simulated "measurement" ---------------------------------------------
    def measure_chunks(
        self, chunks: Sequence[Chunk], row_bytes: int, name: str = ""
    ) -> float:
        base = self.estimate_chunks(chunks, row_bytes)
        n = max(len(chunks), 1)
        # Pattern-dependent controller/queue effects: proportional lift with
        # lognormal jitter; tail effects grow with chunk-count diversity.
        sizes = np.array([c.size for c in chunks]) if chunks else np.array([1])
        diversity = float(np.unique(sizes).size) / n
        lift = self.profile.interleave_lift * (1.0 + 0.1 * diversity)
        jitter = self.rng.lognormal(mean=0.0, sigma=self.noise)
        latency, retries, fault_s = self._charge(base * lift * jitter)
        self.log.append(
            IOEvent(
                name=name,
                nbytes=float(sizes.sum()) * row_bytes,
                n_chunks=len(chunks),
                latency_s=latency,
                retries=retries,
                fault_s=fault_s,
            )
        )
        return latency

    def measure(self, mask: np.ndarray, row_bytes: int, name: str = "") -> float:
        return self.measure_chunks(mask_to_chunks_np(mask), row_bytes, name=name)

    def measure_from_estimate(
        self,
        est_s: float,
        n_chunks: int = 32,
        diversity: float = 0.5,
        name: str = "",
        hit_rate: float = 0.0,
        nbytes: float = 0.0,
        shard_bytes: Optional[Sequence[float]] = None,
        integrity_s: float = 0.0,
    ) -> float:
        """Turn an additive-model estimate (computed inside jit by the
        runtime) into a simulated measurement — same lift + jitter model as
        ``measure_chunks`` without re-deriving the pattern. The estimate
        already charges only cache-miss bytes when the residency tier is
        active; ``hit_rate`` records the tier's hit fraction on the event and
        ``nbytes`` the step's estimated transfer volume (miss rows × row
        bytes, from the decode-plan counters) so ``total_bytes()`` stays
        meaningful on the estimate-driven paths.

        ``integrity_s``: checksum-verified re-read seconds from the chunk
        integrity subsystem, added verbatim on top of the fault-perturbed
        latency (re-reads are deterministic per (profile, seed), so they
        must not consume this simulator's jitter stream). 0.0 leaves the
        charged time — and the RNG stream — bit-identical to pre-integrity
        behaviour; ``io_est_s`` stays the clean planning estimate either
        way."""
        if est_s <= 0.0 and integrity_s <= 0.0:
            return 0.0
        lift = self.profile.interleave_lift * (1.0 + 0.1 * diversity)
        if est_s > 0.0:
            jitter = self.rng.lognormal(mean=0.0, sigma=self.noise)
            latency, retries, fault_s = self._charge(est_s * lift * jitter)
        else:
            latency, retries, fault_s = 0.0, 0, 0.0
        if integrity_s > 0.0:
            latency += float(integrity_s)
            self.device_time_s += float(integrity_s)
        self.log.append(
            IOEvent(name=name, nbytes=float(nbytes), n_chunks=n_chunks,
                    latency_s=latency, hit_rate=float(hit_rate),
                    shard_bytes=(tuple(float(b) for b in shard_bytes)
                                 if shard_bytes is not None else None),
                    retries=retries, fault_s=fault_s,
                    integrity_s=float(integrity_s))
        )
        return latency

    def measure_from_estimate_batch(
        self,
        est_s: np.ndarray,
        n_chunks: int = 32,
        diversity: float = 0.5,
        name: str = "",
        hit_rates: Optional[np.ndarray] = None,
        nbytes: Optional[np.ndarray] = None,
        shard_bytes: Optional[np.ndarray] = None,
        integrity_s: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Vectorized ``measure_from_estimate`` for the scan-fused decode
        path: one call consumes the whole (n_steps,) on-device estimate
        array in a single host round-trip. Zero estimates (plan-reuse steps,
        dense_free) stay exactly zero and draw no jitter. Appends one IOEvent
        per step, matching the per-token path's log granularity.

        ``hit_rates`` (optional, (n_steps,)): per-step residency-cache hit
        fraction to record on each logged IOEvent — the estimates themselves
        already charge only cache-miss bytes. ``nbytes`` (optional,
        (n_steps,)): per-step estimated transfer volume from the decode-plan
        counters, recorded on the events for ``total_bytes()``.
        ``shard_bytes`` (optional, (n_steps, n_shards)): each step's volume
        split by source model shard (sharded serving), recorded on the
        events for ``total_bytes_by_shard()``.
        ``integrity_s`` (optional, (n_steps,)): per-step checksum-verified
        re-read seconds from the chunk integrity subsystem, added verbatim
        AFTER the fault perturbation (re-reads are deterministic per
        (profile, seed) and must not consume the jitter or fault RNG
        streams). None keeps pre-integrity behaviour bit-identical."""
        est = np.asarray(est_s, dtype=np.float64).reshape(-1)
        extra = (np.zeros_like(est) if integrity_s is None
                 else np.asarray(integrity_s, dtype=np.float64).reshape(-1))
        lift = self.profile.interleave_lift * (1.0 + 0.1 * diversity)
        # consume the RNG stream and the event log exactly as the scalar
        # path would: one draw + one IOEvent per POSITIVE estimate, in order
        pos = est > 0.0
        jitter = np.ones_like(est)
        jitter[pos] = self.rng.lognormal(
            mean=0.0, sigma=self.noise, size=int(pos.sum())
        )
        latency = np.where(pos, est * lift * jitter, 0.0)
        # faults perturb each positive event sequentially, in log order —
        # the thermal clock advances event by event, as the scalar path does
        for i, lat in enumerate(latency):
            if pos[i] or extra[i] > 0.0:
                if pos[i]:
                    charged, retries, fault_s = self._charge(float(lat))
                else:
                    charged, retries, fault_s = 0.0, 0, 0.0
                if extra[i] > 0.0:
                    charged += float(extra[i])
                    self.device_time_s += float(extra[i])
                latency[i] = charged
                self.log.append(
                    IOEvent(
                        name=f"{name}[{i}]" if name else name,
                        nbytes=float(nbytes[i]) if nbytes is not None else 0.0,
                        n_chunks=n_chunks,
                        latency_s=charged,
                        hit_rate=float(hit_rates[i]) if hit_rates is not None else 0.0,
                        shard_bytes=(tuple(float(b) for b in shard_bytes[i])
                                     if shard_bytes is not None else None),
                        retries=retries,
                        fault_s=fault_s,
                        integrity_s=float(extra[i]),
                    )
                )
        return latency

    def measure_full_load(self, n_rows: int, row_bytes: int, name: str = "") -> float:
        """Dense (no sparsification) load: one saturating sequential read."""
        return self.measure_chunks([Chunk(0, n_rows)], row_bytes, name=name)

    # -- bookkeeping ----------------------------------------------------------
    def total_io_seconds(self) -> float:
        return float(sum(e.latency_s for e in self.log))

    def total_bytes(self) -> float:
        return float(sum(e.nbytes for e in self.log))

    def total_bytes_by_shard(self, n_shards: int) -> Tuple[float, ...]:
        """Lifetime transfer volume split by source model shard. Events
        logged with ``shard_bytes`` contribute their recorded split; events
        without shard info (unsharded paths, legacy callers) split evenly —
        so the tuple always sums to ``total_bytes()`` and degrades to
        ``(total_bytes(),)`` at n_shards=1."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        out = np.zeros(n_shards, np.float64)
        for e in self.log:
            if e.shard_bytes is not None:
                if len(e.shard_bytes) != n_shards:
                    raise ValueError(
                        f"event {e.name!r} recorded {len(e.shard_bytes)} "
                        f"shard lanes, asked for {n_shards}"
                    )
                out += np.asarray(e.shard_bytes, np.float64)
            else:
                out += e.nbytes / n_shards
        return tuple(float(b) for b in out)

    def reset(self) -> None:
        self.log.clear()


def pack_checksums(layers, names, block_rows: int = 8):
    """Pack-time integrity lane for fp (unquantized, wbits=16) offloaded
    storage: one ``block_checksums`` uint32 per ``block_rows`` row block of
    each named stacked (L, N, D) fp weight leaf, returned as new
    ``<name>_ck`` leaves (leading L dim preserved so they ride the decode
    ``lax.scan``). The wbits=8 twin is ``quantize_params(checksums=True)``,
    which checksums the int8 payload instead — each width checksums exactly
    the bytes its DMA lane streams. Missing names are skipped."""
    import jax

    from ..kernels.quantize import QUANT_SUFFIX_CHECKSUM, block_checksums

    ck = jax.vmap(lambda w: block_checksums(w, block_rows))
    out = {}
    for name in names:
        if name not in layers:
            continue
        out[name + QUANT_SUFFIX_CHECKSUM] = ck(layers[name])
    return out


SITE_KINDS = ("hidden_attn", "hidden_mlp", "ffn", "attn_out")


def normalize_site_sparsity(sparsity) -> dict:
    """A scalar sparsity → the per-site dict form ({kind: fraction} over
    SITE_KINDS); dicts pass through. Shared by SparseExecution and
    ``ComputeModel.decode_layer_seconds`` so the two can't drift."""
    if isinstance(sparsity, dict):
        return sparsity
    return {k: float(sparsity) for k in SITE_KINDS}


def decode_site_shapes(cfg):
    """[(site kind, input rows, output cols per sharing matrix)] for every
    sparsification site of one decoder layer (paper App. A: q/k/v share the
    hidden mask, gate/up share theirs; MoE FFNs have no dense MLP sites).
    The single source of truth for the site geometry, shared by
    SparseExecution (selection sites + latency tables) and
    ``ComputeModel.decode_layer_seconds`` (the overlap pipeline's compute
    lane) — the two must never drift apart."""
    d = cfg.d_model
    hd_all = cfg.n_heads * cfg.resolved_head_dim
    kv_all = cfg.n_kv_heads * cfg.resolved_head_dim
    sites = [
        ("hidden_attn", d, (hd_all, kv_all, kv_all)),
        ("attn_out", hd_all, (d,)),
    ]
    if cfg.d_ff and not cfg.has_moe:
        sites.append(("hidden_mlp", d, (cfg.d_ff, cfg.d_ff)))
        sites.append(("ffn", cfg.d_ff, (d,)))
    return sites


@dataclasses.dataclass
class ComputeModel:
    """First-order compute-time model for the latency breakdown (Fig. 8).

    Edge GPU sustained GEMV throughput; default ≈ Jetson Orin Nano class
    (1.2 TFLOP/s effective fp16 for memory-resident GEMV is optimistic; the
    breakdown only needs relative magnitudes)."""

    flops_per_s: float = 1.2e12

    def matmul_seconds(self, rows_loaded: int, cols: int, tokens: int = 1) -> float:
        return 2.0 * rows_loaded * cols * tokens / self.flops_per_s

    def decode_layer_seconds(
        self, cfg, sparsity=0.0, tokens: int = 1, layer_scale=None
    ) -> np.ndarray:
        """Per-layer decode-step compute seconds, (n_layers,), for the
        active model config — the compute lane of the overlapped I/O–compute
        pipeline (core/pipeline.py).

        Uses the serve stack's sparsification-site geometry
        (``decode_site_shapes`` — the same table SparseExecution builds its
        sites from): each site's GEMV runs over its kept rows
        ``(1 - sparsity) * N``. ``sparsity`` is a float or the same
        per-site dict SparseExecution takes; pass 0.0 for the dense /
        dense_free policies. First-order GEMV-only (like ``matmul_seconds``
        — attention-score FLOPs are negligible at decode batch sizes).

        ``layer_scale`` (optional, (n_layers,)): per-layer calibration
        multipliers — real stacks are NOT uniform (first/last layers carry
        embedding/head spill, attention cost grows with cache length, MoE
        layers alternate), and the prefetch timeline's hidden-I/O accounting
        is only as good as its compute lane. Pass measured per-layer
        multipliers (e.g. ``calibrate_layer_scale`` over profiled walls) to
        make the model's notion of "hidden" match the kernel's; None keeps
        the uniform first-order vector."""
        sp = normalize_site_sparsity(sparsity)
        sec = sum(
            self.matmul_seconds((1.0 - sp.get(kind, 0.0)) * n, sum(cols), tokens)
            for kind, n, cols in decode_site_shapes(cfg)
        )
        out = np.full((cfg.n_layers,), sec, np.float64)
        if layer_scale is not None:
            scale = np.asarray(layer_scale, np.float64).reshape(-1)
            if scale.shape != (cfg.n_layers,):
                raise ValueError(
                    f"layer_scale must have shape ({cfg.n_layers},), "
                    f"got {scale.shape}"
                )
            if np.any(scale < 0):
                raise ValueError("layer_scale must be non-negative")
            out = out * scale
        return out

    @staticmethod
    def calibrate_layer_scale(layer_walls_s) -> np.ndarray:
        """Measured per-layer decode walls → mean-1 calibration multipliers
        for ``decode_layer_seconds(layer_scale=...)``: the profile keeps the
        model's per-step compute total while redistributing it across layers
        the way the hardware actually spends it."""
        walls = np.asarray(layer_walls_s, np.float64).reshape(-1)
        if walls.size == 0 or np.any(walls < 0):
            raise ValueError("layer walls must be a non-empty, non-negative vector")
        mean = walls.mean()
        if mean <= 0.0:
            return np.ones_like(walls)
        return walls / mean
