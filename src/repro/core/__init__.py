"""Neuron Chunking core: the paper's contribution as a composable library."""
from .api import NeuronChunkingPlanner, SparsePlan
from .baselines import (
    bundled_latency,
    calibrate_threshold,
    threshold_mask,
    topk_mask,
    topk_mask_np,
    unbundled_latency,
)
from .chunking import (
    BatchedChunkSelector,
    ChunkConfig,
    ChunkSelector,
    chunk_table_from_mask,
    select_chunks_np,
)
from .contiguity import (
    Chunk,
    average_chunk_size_jax,
    chunk_stats_np,
    chunks_to_mask_np,
    contiguity_distribution_np,
    contiguity_histogram_jax,
    mask_to_chunks_np,
    mask_to_runs_jax,
)
from .importance import coefficient_of_variation, importance, importance_np, retention
from .latency_model import (
    JETSON_AGX,
    JETSON_NANO,
    TPU_V5E_HBM,
    DeviceProfile,
    LatencyTable,
    get_profile,
    profile_table,
    table_from_measurements,
)
from .faults import (
    FAULT_PROFILES,
    FaultModel,
    FaultOutcome,
    FaultProfile,
    ThermalTrajectory,
    get_fault_profile,
)
from .offload import ComputeModel, FlashOffloadSimulator, IOEvent
from .paged_kv import GARBAGE_PAGE, KVPoolExhausted, PagedKVAllocator
from .pipeline import PipelineModel, PipelineTimeline, overlap_efficiency
from .reorder import (
    Reordering,
    activation_frequency,
    coactivation_reordering,
    hot_cold_reordering,
)
from .sparsity_alloc import LayerProfile, allocate_sparsity, budgets_from_sparsity

__all__ = [k for k in dir() if not k.startswith("_")]
