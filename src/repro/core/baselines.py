"""Comparison baselines (paper §4.1, App. L).

  * top-k magnitude sparsification — the paper's main baseline (TEAL [24] /
    LLM-in-a-Flash [2] style): keep the R most important neurons regardless
    of storage layout.
  * threshold sparsification — CATS [16] style: keep |a| above a calibrated
    per-layer threshold.
  * row-column bundling — LLM-in-a-Flash [2] style (App. L, Table 3): rows of
    matrices sharing input activations (q/k/v, gate/up) are interleaved in
    storage so one selected neuron's weights are one contiguous read across
    the bundle. Modeled here as a row-size multiplier on the latency table.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .latency_model import DeviceProfile, profile_table


def topk_mask(v: jnp.ndarray, budget) -> jnp.ndarray:
    """Keep the ``budget`` highest-importance neurons (bool (N,)). jit-safe
    for traced budget via rank comparison."""
    n = v.shape[0]
    order = jnp.argsort(-v.astype(jnp.float32), stable=True)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    return rank < budget


def topk_mask_np(v: np.ndarray, budget: int) -> np.ndarray:
    v = np.asarray(v, np.float32)
    n = v.shape[0]
    order = np.argsort(-v, kind="stable")
    mask = np.zeros(n, bool)
    mask[order[:budget]] = True
    return mask


def threshold_mask(v: jnp.ndarray, threshold: float) -> jnp.ndarray:
    """CATS-style: keep neurons whose importance exceeds a calibrated
    threshold (sparsity becomes input-dependent)."""
    return v.astype(jnp.float32) > threshold


def calibrate_threshold(cal_importance: np.ndarray, sparsity: float) -> float:
    """Pick the threshold achieving ``sparsity`` on the calibration set."""
    flat = np.asarray(cal_importance, np.float32).reshape(-1)
    return float(np.quantile(flat, sparsity))


# ---------------------------------------------------------------------------
# LLM-in-a-Flash row-column bundling (App. L)
# ---------------------------------------------------------------------------


def bundled_latency(
    mask: np.ndarray,
    row_bytes: int,
    bundle: int,
    device: str | DeviceProfile,
) -> float:
    """I/O latency of loading ``bundle`` matrices' rows for the selected
    neurons when those rows are interleaved on storage.

    A chunk of r selected neurons becomes one contiguous read of
    r * bundle * row_bytes, replacing ``bundle`` separate reads. This is the
    favourable modeling of bundling; Table 3 shows it still loses to chunk
    selection because the *selection* remains layout-oblivious.
    """
    from .contiguity import mask_to_chunks_np

    chunks = mask_to_chunks_np(np.asarray(mask))
    if not chunks:
        return 0.0
    max_rows = max(c.size for c in chunks)
    table = profile_table(device, row_bytes * bundle, max_rows=max_rows)
    return float(sum(float(table.lookup(jnp.asarray(c.size))) for c in chunks))


def unbundled_latency(
    mask: np.ndarray,
    row_bytes: int,
    n_matrices: int,
    device: str | DeviceProfile,
) -> float:
    """Same selection without bundling: each matrix issues its own reads
    (n_matrices independent copies of the pattern)."""
    from .contiguity import mask_to_chunks_np

    chunks = mask_to_chunks_np(np.asarray(mask))
    if not chunks:
        return 0.0
    max_rows = max(c.size for c in chunks)
    table = profile_table(device, row_bytes, max_rows=max_rows)
    one = sum(float(table.lookup(jnp.asarray(c.size))) for c in chunks)
    return float(one * n_matrices)
