"""Hot–cold offline neuron reordering (paper §3.3, App. F/G).

Count how often each input neuron is "active" (in the top 50% by importance)
over a calibration set, sort neurons by decreasing activation frequency, and
permute the corresponding weight rows so frequently-active neurons are stored
contiguously. At runtime the same permutation is applied to the activation
vector (a gather, negligible cost — the paper measures 1.5 ms mean on the
largest matrix).

The paper finds this simple scheme matches Ripple's co-activation clustering
(App. G) — we also ship a co-activation-greedy reorderer for that ablation.
"""
from __future__ import annotations

import dataclasses
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class Reordering:
    """perm[i] = original index stored at new position i.

    weights_new[i] = weights_old[perm[i]];  acts_new = acts_old[perm].
    ``inverse`` maps original → new position.
    """

    perm: np.ndarray

    @property
    def inverse(self) -> np.ndarray:
        inv = np.empty_like(self.perm)
        inv[self.perm] = np.arange(self.perm.shape[0])
        return inv

    def apply_to_rows(self, w):
        """Permute weight rows (works for np or jnp)."""
        return w[self.perm]

    def apply_to_acts(self, a):
        """Permute the trailing activation axis to match reordered rows."""
        return jnp.take(a, jnp.asarray(self.perm), axis=-1)

    def unapply_mask(self, mask: np.ndarray) -> np.ndarray:
        """Map a mask over reordered positions back to original indices."""
        out = np.zeros_like(np.asarray(mask))
        out[self.perm] = np.asarray(mask)
        return out

    @staticmethod
    def identity(n: int) -> "Reordering":
        return Reordering(np.arange(n))


def activation_frequency(
    cal_importance: np.ndarray, active_fraction: float = 0.5
) -> np.ndarray:
    """Per-neuron activation frequency over a calibration set.

    cal_importance: (S, N) importance vectors for S calibration samples.
    A neuron is "active" in a sample if it lies in the top ``active_fraction``
    by importance (paper: top 50%).
    Returns (N,) frequencies in [0, 1].
    """
    cal = np.asarray(cal_importance, np.float32)
    if cal.ndim == 1:
        cal = cal[None]
    s, n = cal.shape
    k = max(1, int(round(active_fraction * n)))
    # threshold per sample = k-th largest value
    thresh = np.partition(cal, n - k, axis=1)[:, n - k]
    active = cal >= thresh[:, None]
    return active.mean(axis=0)


def hot_cold_reordering(
    cal_importance: np.ndarray, active_fraction: float = 0.5
) -> Reordering:
    """Sort neurons by decreasing activation frequency (§3.3).

    Stable sort so equal-frequency neurons keep their original (and thus
    already somewhat correlated) ordering.
    """
    freq = activation_frequency(cal_importance, active_fraction)
    perm = np.argsort(-freq, kind="stable")
    return Reordering(perm)


def coactivation_reordering(
    cal_importance: np.ndarray, active_fraction: float = 0.5
) -> Reordering:
    """Ripple-style greedy co-activation chaining (App. G comparison).

    Greedily builds an ordering where each next neuron maximizes co-activation
    count with the previous one. O(N^2) memory on the co-activation matrix —
    calibration-time only, for the App. G ablation benchmark.
    """
    cal = np.asarray(cal_importance, np.float32)
    if cal.ndim == 1:
        cal = cal[None]
    s, n = cal.shape
    k = max(1, int(round(active_fraction * n)))
    thresh = np.partition(cal, n - k, axis=1)[:, n - k]
    active = (cal >= thresh[:, None]).astype(np.float32)
    co = active.T @ active  # (N, N) co-activation counts
    np.fill_diagonal(co, -1.0)
    freq = active.mean(axis=0)
    order = [int(np.argmax(freq))]
    visited = np.zeros(n, bool)
    visited[order[0]] = True
    for _ in range(n - 1):
        row = co[order[-1]].copy()
        row[visited] = -np.inf
        nxt = int(np.argmax(row))
        order.append(nxt)
        visited[nxt] = True
    return Reordering(np.asarray(order))
