"""Utility-guided chunk selection — the paper's Algorithm 1 (§3.2, App. E).

Given activation importances V ∈ R^N, a row budget R, a chunk-size schedule
and a device latency table T[·], select a binary mask maximizing
Σ V_i M_i / Latency(M):

  1. candidate generation: sliding windows of each size r (rows) at stride
     min(r, jump_cap) over the neuron axis;
  2. evaluation: utility = (prefix-sum benefit of window) / T[r];
  3. greedy: sort by utility descending, take non-overlapping candidates
     while they fit the remaining budget, stop when the budget is met.

Two implementations with identical semantics:
  * ``select_chunks_np``   — literal numpy transcription of Algorithm 1
    (the test oracle and offline tool).
  * ``ChunkSelector``      — jit-compiled JAX version with static candidate
    set and a ``lax.while_loop`` greedy pass (early exit on budget), used
    at runtime ≈ once per weight matrix per step. The paper's GPU radix
    sort becomes ``jnp.argsort`` inside the same jit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .latency_model import (
    KB,
    DeviceProfile,
    LatencyTable,
    profile_table,
    resident_rows_in_windows,
)


@dataclasses.dataclass(frozen=True)
class ChunkConfig:
    """Hyperparameters of Algorithm 1, in KB like the paper (App. H).

    stride between window starts is min(chunk_size, jump_cap); step_kb is the
    increment between successive chunk sizes; max size defaults to the device
    saturation point (§3.2.2: "the hardware-specific point where throughput
    saturates").
    """

    min_chunk_kb: float = 8.0
    max_chunk_kb: float = 236.0
    step_kb: float = 8.0
    jump_cap_kb: float = 8.0

    def row_sizes(self, row_bytes: int) -> List[int]:
        """Chunk sizes converted to row units (Algorithm 1 line 1)."""
        row_kb = row_bytes / KB
        r_min = max(1, int(self.min_chunk_kb / row_kb))
        r_max = max(1, int(self.max_chunk_kb / row_kb))
        dr = max(1, int(self.step_kb / row_kb))
        sizes = list(range(r_min, r_max + 1, dr))
        return sizes if sizes else [r_min]

    def jump_cap_rows(self, row_bytes: int) -> int:
        return max(1, int(self.jump_cap_kb / (row_bytes / KB)))

    @staticmethod
    def for_shape(rows: int, cols: int, device: str = "nano") -> "ChunkConfig":
        """Heuristic from the paper's Table 2: bigger matrices → coarser
        start size / jump cap to stay under the 2 ms selection budget.

        The max chunk size is the device's throughput-saturation point
        (§3.2.2): AGX + 990 Pro saturates later (knee ≈ 34.7 KB → 348 KB
        cap) than Nano + P31 (knee ≈ 23.9 KB → 236 KB cap, the class
        default); the 348/236 ratio matches the knee-bytes ratio of the two
        profiles in ``latency_model.py``."""
        max_kb = 348.0 if device in ("agx", "jetson_agx_990pro") else 236.0
        if rows >= 16384:
            start = 32.0
        elif rows >= 8192:
            start = 16.0
        elif rows >= 3584:
            start = 20.0 if cols >= 3584 else 8.0
        else:
            start = 8.0
        return ChunkConfig(
            min_chunk_kb=start, max_chunk_kb=max_kb, step_kb=start, jump_cap_kb=start
        )


def _candidate_schedule(
    n: int, row_bytes: int, cfg: ChunkConfig
) -> Tuple[np.ndarray, np.ndarray]:
    """Static candidate (start, size) arrays for a length-n neuron axis."""
    starts: List[int] = []
    sizes: List[int] = []
    cap = cfg.jump_cap_rows(row_bytes)
    for r in cfg.row_sizes(row_bytes):
        if r > n:
            continue
        stride = min(r, cap)
        for i in range(0, n - r + 1, stride):
            starts.append(i)
            sizes.append(r)
    if not starts:  # degenerate: single chunk covering what fits
        starts, sizes = [0], [min(n, max(1, cfg.row_sizes(row_bytes)[0]))]
    return np.asarray(starts, np.int32), np.asarray(sizes, np.int32)


# ---------------------------------------------------------------------------
# numpy reference (Algorithm 1, literal)
# ---------------------------------------------------------------------------


def select_chunks_np(
    v: np.ndarray,
    budget: int,
    row_bytes: int,
    table: LatencyTable,
    cfg: ChunkConfig,
    resident: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Literal Algorithm 1. Returns a bool mask of shape (N,).

    ``resident`` (bool (N,), optional): rows already memory-resident in the
    DRAM cache tier. A candidate window's cost counts only its NON-resident
    rows (resident rows transfer nothing), making the utility the marginal
    I/O cost of the window — the residency-aware variant the runtime
    ``ChunkSelector.select`` implements."""
    v = np.asarray(v, np.float32)
    n = v.shape[0]
    cumsum = np.concatenate([[0.0], np.cumsum(v, dtype=np.float32)])
    starts, sizes = _candidate_schedule(n, row_bytes, cfg)
    benefit = cumsum[starts + sizes] - cumsum[starts]
    if resident is None:
        cost_rows = sizes
    else:
        rcum = np.concatenate([[0.0], np.cumsum(np.asarray(resident, np.float32))])
        cost_rows = sizes - np.rint(rcum[starts + sizes] - rcum[starts]).astype(np.int64)
    cost = np.asarray(table.lookup(jnp.asarray(cost_rows)), np.float32)
    score = benefit / np.maximum(cost, 1e-30)
    order = np.argsort(-score, kind="stable")

    mask = np.zeros(n, bool)
    selected = 0
    for k in order:
        i, r = int(starts[k]), int(sizes[k])
        if r > budget - selected or mask[i : i + r].any():
            continue
        mask[i : i + r] = True
        selected += r
        if selected >= budget:
            break
    return mask


# ---------------------------------------------------------------------------
# JAX runtime selector
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash for jit static self
class ChunkSelector:
    """Jit-compiled utility-guided chunk selector for a fixed (N, device,
    chunk-config) triple. Call ``select(v, budget)``; budget may be traced."""

    n: int
    row_bytes: int
    table: LatencyTable
    cfg: ChunkConfig
    starts: jnp.ndarray  # (K,) int32, static candidate schedule
    sizes: jnp.ndarray  # (K,) int32
    max_size: int
    # smallest candidate window (rows) — once the remaining budget is
    # below it, nothing more can be selected (exact greedy early exit)
    min_size: int = 1

    @staticmethod
    def build(
        n: int,
        row_bytes: int,
        device: str | DeviceProfile = "nano",
        cfg: ChunkConfig | None = None,
        table: LatencyTable | None = None,
    ) -> "ChunkSelector":
        cfg = cfg or ChunkConfig.for_shape(n, 1, device if isinstance(device, str) else device.name)
        starts, sizes = _candidate_schedule(n, row_bytes, cfg)
        if table is None:
            table = profile_table(device, row_bytes, max_rows=int(sizes.max()))
        return ChunkSelector(
            n=n,
            row_bytes=row_bytes,
            table=table,
            cfg=cfg,
            starts=jnp.asarray(starts),
            sizes=jnp.asarray(sizes),
            max_size=int(sizes.max()),
            min_size=int(sizes.min()),
        )

    @property
    def num_candidates(self) -> int:
        return int(self.starts.shape[0])

    @functools.partial(jax.jit, static_argnums=0)
    def select(self, v: jnp.ndarray, budget: jnp.ndarray, resident=None):
        """Returns (mask bool (N,), n_selected, est_latency_seconds).

        ``resident`` (bool (N,), optional): rows already memory-resident in
        the DRAM residency tier. When given, selection is **marginal-cost
        aware**: a candidate window's utility divides its importance by the
        latency of only its non-resident rows (resident rows transfer
        nothing, so a window overlapping the cache is nearly free), and the
        returned ``est_latency`` charges only the cache-miss rows of the
        final mask. With ``resident=None`` (or all-false) this reduces
        exactly to Algorithm 1.
        """
        v = v.astype(jnp.float32)
        cumsum = jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(v)])
        benefit = cumsum[self.starts + self.sizes] - cumsum[self.starts]
        if resident is None:
            cost_rows = self.sizes
        else:
            cost_rows = self.sizes - resident_rows_in_windows(
                self.starts, self.sizes, resident
            )
        cost = jnp.maximum(self.table.lookup(cost_rows), 1e-30)
        score = benefit / cost
        order = jnp.argsort(-score, stable=True)
        starts_s = self.starts[order]
        sizes_s = self.sizes[order]

        k = starts_s.shape[0]
        pad = self.max_size
        window_iota = jnp.arange(pad, dtype=jnp.int32)
        # exact early exit: once the remaining budget cannot fit even the
        # smallest candidate, no further candidate is selectable — stop
        # instead of scanning the (possibly huge) low-utility tail
        min_size = self.min_size

        def cond(state):
            i, _, selected = state
            return (i < k) & (selected + min_size <= budget)

        def body(state):
            i, mask, selected = state
            start, size = starts_s[i], sizes_s[i]
            window = jax.lax.dynamic_slice(mask, (start,), (pad,))
            in_chunk = window_iota < size
            overlap = jnp.sum(window * in_chunk)
            fits = (overlap == 0) & (size <= budget - selected)
            new_window = jnp.where(in_chunk & fits, 1, window)
            mask = jax.lax.dynamic_update_slice(mask, new_window, (start,))
            return i + 1, mask, selected + jnp.where(fits, size, 0)

        mask0 = jnp.zeros((self.n + pad,), jnp.int32)  # pad tail for slices
        _, mask, selected = jax.lax.while_loop(
            cond, body, (jnp.int32(0), mask0, jnp.int32(0))
        )
        mask = mask[: self.n].astype(bool)
        if resident is None:
            est_latency = self.table.mask_latency(mask)
        else:
            est_latency = self.table.mask_latency_miss(mask, resident)
        return mask, selected, est_latency

    def select_for_sparsity(self, v: jnp.ndarray, sparsity: float):
        """Convenience: budget = (1 - sparsity) * N rows."""
        budget = jnp.int32(round((1.0 - float(sparsity)) * self.n))
        return self.select(v, budget)


# ---------------------------------------------------------------------------
# batched multi-site selector (one vmapped greedy per layer)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash for jit static self
class BatchedChunkSelector:
    """All of a layer's sparsification sites as ONE padded selection problem.

    The serve stack evaluates four sites per layer (q / o / gate / down,
    paper App. A); running each as its own ``lax.while_loop`` greedy costs
    four sequential dispatches per layer per refresh step. This selector
    pads the sites' candidate schedules to a single ``(n_sites, K)`` problem
    and runs ONE vmapped greedy — semantically identical per site to
    ``ChunkSelector.select`` / the ``select_chunks_np`` oracle (same
    utility, same stable tie-breaking, same budget rule), EXACTLY, by
    construction (pinned by tests/test_pipeline.py).

    Two trip-count optimizations on the sequential greedy, both
    parity-preserving:

      * **unfillable-budget exit**: the loop stops once
        ``budget - selected < min candidate size`` — no candidate can fit,
        so the oracle selects nothing more either. This removes the
        oracle's pathological tail phase (scanning tens of thousands of
        low-utility candidates after the budget is effectively full);
      * **top-C prefilter**: the greedy first runs over only the top
        ``top_c`` candidates by utility (ties broken by candidate index,
        identical to the oracle's stable sort); a second segment continues
        over the remaining sorted candidates ONLY while some lane's budget
        is still fillable — so truncation can never change the result, it
        only bounds the common-case trip count at C.
    """

    n_sites: int
    n_max: int  # padded neuron-axis length (max over sites)
    pad: int  # largest candidate window across sites
    top_c: int
    starts: jnp.ndarray  # (S, K) int32, zero-padded
    sizes: jnp.ndarray  # (S, K) int32, zero-padded
    valid: jnp.ndarray  # (S, K) bool — real candidates
    row_valid: jnp.ndarray  # (S, n_max) bool — real neuron rows
    tables: jnp.ndarray  # (S, T+1) float32 per-lane latency tables
    min_sizes: jnp.ndarray  # (S,) int32 smallest real candidate per lane
    site_ns: Tuple[int, ...]

    @staticmethod
    def build(
        selectors: Sequence[ChunkSelector], top_c: Optional[int] = None
    ) -> "BatchedChunkSelector":
        sels = list(selectors)
        if not sels:
            raise ValueError("need at least one ChunkSelector to batch")
        n_sites = len(sels)
        n_max = max(s.n for s in sels)
        k_max = max(s.num_candidates for s in sels)
        pad = max(s.max_size for s in sels)
        t_max = max(max(s.table.max_rows, s.max_size) for s in sels)
        starts = np.zeros((n_sites, k_max), np.int32)
        sizes = np.zeros((n_sites, k_max), np.int32)
        valid = np.zeros((n_sites, k_max), bool)
        row_valid = np.zeros((n_sites, n_max), bool)
        tables = np.zeros((n_sites, t_max + 1), np.float32)
        for i, s in enumerate(sels):
            k = s.num_candidates
            starts[i, :k] = np.asarray(s.starts)
            sizes[i, :k] = np.asarray(s.sizes)
            valid[i, :k] = True
            row_valid[i, : s.n] = True
            tables[i] = s.table.padded_table(t_max)
        if top_c is None:
            top_c = min(k_max, max(256, 4 * n_max))
        min_sizes = np.array(
            [int(np.asarray(s.sizes).min()) for s in sels], np.int32
        )
        return BatchedChunkSelector(
            n_sites=n_sites,
            n_max=n_max,
            pad=pad,
            top_c=int(min(top_c, k_max)),
            starts=jnp.asarray(starts),
            sizes=jnp.asarray(sizes),
            valid=jnp.asarray(valid),
            row_valid=jnp.asarray(row_valid),
            tables=jnp.asarray(tables),
            min_sizes=jnp.asarray(min_sizes),
            site_ns=tuple(s.n for s in sels),
        )

    def _greedy_lane(self, starts_s, sizes_s, budget, min_size):
        """One lane's sorted-candidate greedy — identical selections to
        ``ChunkSelector.select``; runs vmapped across sites (the batched
        cond becomes one ``any``-combined while_loop).

        Two segments over the SAME sorted order: [0, top_c) then
        [top_c, K). Each stops as soon as the remaining budget cannot fit
        the lane's smallest candidate (``min_size``) — at that point the
        oracle selects nothing more either, so early exit is exact. Under
        vmap, segment 2 costs max-over-lanes trips: zero extra when every
        lane finished inside the prefilter (the common case)."""
        k = starts_s.shape[0]
        pad = self.pad
        window_iota = jnp.arange(pad, dtype=jnp.int32)

        def seg_cond(limit):
            def cond(state):
                i, _, selected = state
                return (i < limit) & (selected + min_size <= budget)

            return cond

        def body(state):
            i, mask, selected = state
            start, size = starts_s[i], sizes_s[i]
            window = jax.lax.dynamic_slice(mask, (start,), (pad,))
            in_chunk = window_iota < size
            overlap = jnp.sum(window * in_chunk)
            fits = (overlap == 0) & (size > 0) & (size <= budget - selected)
            new_window = jnp.where(in_chunk & fits, 1, window)
            mask = jax.lax.dynamic_update_slice(mask, new_window, (start,))
            return i + 1, mask, selected + jnp.where(fits, size, 0)

        mask0 = jnp.zeros((self.n_max + pad,), jnp.int32)
        state = (jnp.int32(0), mask0, jnp.int32(0))
        state = jax.lax.while_loop(seg_cond(min(self.top_c, k)), body, state)
        if self.top_c < k:  # completion segment: parity beyond the prefilter
            state = jax.lax.while_loop(seg_cond(k), body, state)
        _, mask, selected = state
        return mask[: self.n_max].astype(bool), selected

    @functools.partial(jax.jit, static_argnums=0)
    def select(self, v: jnp.ndarray, budgets: jnp.ndarray, resident=None):
        """v: (n_sites, n_max) padded importances (selection order);
        budgets: (n_sites,) int32 row budgets; resident: optional
        (n_sites, n_max) bool DRAM-resident rows (marginal-cost selection,
        exactly as in ``ChunkSelector.select``).

        Returns (masks (n_sites, n_max) bool, selected (n_sites,) int32).
        Per-site latency stays with the callers' own LatencyTables — the
        utility's cost term here uses each lane's padded table row.
        """
        v = v.astype(jnp.float32) * self.row_valid
        zero = jnp.zeros((self.n_sites, 1), jnp.float32)
        cumsum = jnp.concatenate([zero, jnp.cumsum(v, axis=1)], axis=1)
        ends = self.starts + self.sizes
        benefit = jnp.take_along_axis(cumsum, ends, 1) - jnp.take_along_axis(
            cumsum, self.starts, 1
        )
        if resident is None:
            cost_rows = self.sizes
        else:
            res = (resident & self.row_valid).astype(jnp.float32)
            rcum = jnp.concatenate([zero, jnp.cumsum(res, axis=1)], axis=1)
            in_win = jnp.take_along_axis(rcum, ends, 1) - jnp.take_along_axis(
                rcum, self.starts, 1
            )
            cost_rows = self.sizes - jnp.round(in_win).astype(jnp.int32)
        cost_rows = jnp.clip(cost_rows, 0, self.tables.shape[1] - 1)
        cost = jnp.maximum(jnp.take_along_axis(self.tables, cost_rows, 1), 1e-30)
        score = jnp.where(self.valid, benefit / cost, -jnp.inf)
        # full stable order (ties broken by candidate index, exactly like
        # the oracle); the top_c prefilter is the first greedy segment's
        # trip bound, see _greedy_lane
        order = jnp.argsort(-score, axis=1, stable=True)
        starts_s = jnp.take_along_axis(self.starts, order, 1)
        sizes_s = jnp.where(
            jnp.take_along_axis(self.valid, order, 1),
            jnp.take_along_axis(self.sizes, order, 1),
            0,
        )
        masks, selected = jax.vmap(self._greedy_lane)(
            starts_s, sizes_s, budgets, self.min_sizes
        )
        return masks & self.row_valid, selected


def chunk_table_from_mask(
    mask: np.ndarray, max_chunks: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Selection mask → (starts, sizes, n) padded chunk table for the Pallas
    chunk_gather_matmul kernel (kernels/chunk_gather_matmul.py)."""
    from .contiguity import runs_to_padded_table_np

    return runs_to_padded_table_np(np.asarray(mask), max_chunks)
