"""Storage fault injection for the flash-offload simulator.

The serve stack plans against the *steady-state* ``LatencyTable`` — but a
real Jetson NVMe does not stay in steady state: sustained decode traffic
thermally throttles the controller, queue resonance produces tail-latency
spikes, and links drop the occasional read outright. This module models
that turbulence as a seeded, deterministic ``FaultModel`` injected at the
**measurement boundary** of ``FlashOffloadSimulator`` (core/offload.py):
chunk selection keeps planning against the clean table, and only the
simulated *measurement* of each I/O event is perturbed — so plans and
reality diverge exactly the way they do on hardware, and fault injection
can NEVER change which neurons are selected or which tokens come out
(time-only perturbation; pinned by tests/test_faults.py).

Three fault mechanisms compose, applied per logged I/O event in a fixed
order so a given (profile, seed) replays bit-identically:

  1. **Thermal throttling** — a deterministic ``ThermalTrajectory`` maps
     cumulative device-busy seconds to a throughput derate ``scale(t) ∈
     (0, 1]``; the event's clean latency is divided by it. Dividing the
     total is exactly equivalent to scaling both ``peak_bw`` and ``iops``
     of the two-regime model by ``scale`` (the Jetson profiles carry no
     separate ``base_latency`` term).
  2. **Tail-latency spikes** — with probability ``spike_prob`` the event's
     latency is multiplied by ``spike_scale`` (controller GC / queue
     resonance; the heavy tail Fig. 5's lognormal deliberately truncates).
  3. **Transient read failures** — each attempt fails with probability
     ``fail_prob``; a failed attempt charges its full (throttled, possibly
     spiked) read time plus an exponential-backoff delay
     (``backoff_base_s * backoff_mult**k`` after the k-th failure) and is
     retried. Attempt ``max_retries`` always succeeds, so the charge is
     bounded by ``(max_retries+1) * read + Σ backoff``.

The model draws from its OWN ``numpy`` Generator (``fault_seed``), never
from the simulator's: enabling faults does not shift the simulator's
lift/jitter RNG stream, and with faults disabled (the default) the
simulator's event log and RNG consumption are bit-identical to a build
without this module.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ThermalTrajectory:
    """Deterministic throughput-derate trajectory over device-busy time.

    ``scale(t)`` is 1.0 until ``onset_s`` cumulative busy seconds, ramps
    linearly down to ``floor`` over the next ``ramp_s`` seconds, then
    holds (sustained throttle). ``period_s > 0`` instead cycles: the
    pattern repeats every period with a linear recovery back to 1.0 in
    the second half of each period (thermal sawtooth — throttle under
    load, recover while the duty cycle drops).
    """

    onset_s: float = 0.0
    ramp_s: float = 1.0
    floor: float = 0.5
    period_s: float = 0.0

    def __post_init__(self):
        if not (0.0 < self.floor <= 1.0):
            raise ValueError(f"floor must be in (0, 1], got {self.floor}")
        if self.onset_s < 0 or self.ramp_s < 0 or self.period_s < 0:
            raise ValueError("onset_s/ramp_s/period_s must be >= 0")

    def scale(self, busy_s: float) -> float:
        """Throughput derate factor at ``busy_s`` cumulative device-busy
        seconds — 1.0 = full speed, ``floor`` = fully throttled."""
        t = float(busy_s)
        if self.period_s > 0.0:
            t = math.fmod(t, self.period_s)
            half = self.period_s / 2.0
            if t >= half:
                # linear recovery back to full speed over the second half
                frac = (t - half) / half
                lowest = self._ramp_value(half)
                return lowest + (1.0 - lowest) * frac
        return self._ramp_value(t)

    def _ramp_value(self, t: float) -> float:
        if t <= self.onset_s:
            return 1.0
        if self.ramp_s <= 0.0:
            return self.floor
        frac = min((t - self.onset_s) / self.ramp_s, 1.0)
        return 1.0 - (1.0 - self.floor) * frac


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """One named storage-turbulence scenario (see ``FAULT_PROFILES``)."""

    name: str
    spike_prob: float = 0.0
    spike_scale: float = 4.0
    fail_prob: float = 0.0
    max_retries: int = 3
    backoff_base_s: float = 0.5e-3
    backoff_mult: float = 2.0
    throttle: Optional[ThermalTrajectory] = None

    def __post_init__(self):
        if not (0.0 <= self.spike_prob < 1.0):
            raise ValueError(f"spike_prob must be in [0, 1), got {self.spike_prob}")
        if self.spike_scale < 1.0:
            raise ValueError(f"spike_scale must be >= 1, got {self.spike_scale}")
        if not (0.0 <= self.fail_prob < 1.0):
            raise ValueError(f"fail_prob must be in [0, 1), got {self.fail_prob}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base_s < 0 or self.backoff_mult < 1.0:
            raise ValueError("backoff_base_s must be >= 0 and backoff_mult >= 1")


# Named profiles, calibrated to be *visible* against the Jetson profiles'
# per-step decode latencies (hundreds of µs to a few ms) without burying
# the signal: tail spikes land on ~5% of events, flaky reads retry ~8% of
# attempts, and the thermal trajectories derate throughput to 25-50%.
FAULT_PROFILES: Dict[str, FaultProfile] = {
    p.name: p
    for p in (
        FaultProfile("none"),
        FaultProfile("tail_spikes", spike_prob=0.05, spike_scale=6.0),
        FaultProfile("flaky_reads", fail_prob=0.08, max_retries=4,
                     backoff_base_s=0.25e-3, backoff_mult=2.0),
        # sustained thermal throttle: full speed for the first 2 ms of
        # device-busy time, then a 10 ms ramp down to 25% throughput that
        # never recovers — the DegradationController's acceptance scenario
        FaultProfile("thermal_throttle",
                     throttle=ThermalTrajectory(onset_s=2e-3, ramp_s=10e-3,
                                                floor=0.25)),
        # thermal sawtooth: 40 ms cycle, throttling to 40% then recovering
        FaultProfile("thermal_cycle",
                     throttle=ThermalTrajectory(onset_s=0.0, ramp_s=10e-3,
                                                floor=0.4, period_s=40e-3)),
        # everything at once: the nightly-sweep worst case
        FaultProfile("degraded_nvme", spike_prob=0.03, spike_scale=5.0,
                     fail_prob=0.04, max_retries=4, backoff_base_s=0.25e-3,
                     throttle=ThermalTrajectory(onset_s=2e-3, ramp_s=10e-3,
                                                floor=0.35)),
    )
}


def get_fault_profile(name: str) -> FaultProfile:
    try:
        return FAULT_PROFILES[name]
    except KeyError:
        raise KeyError(
            f"unknown fault profile {name!r}; have {sorted(FAULT_PROFILES)}"
        )


@dataclasses.dataclass
class FaultOutcome:
    """What the fault model did to one I/O event."""

    charged_s: float  # total charged latency, faults included
    clean_s: float  # the latency the event would have charged fault-free
    throttle_scale: float = 1.0
    spiked: bool = False
    retries: int = 0
    backoff_s: float = 0.0

    @property
    def extra_s(self) -> float:
        return self.charged_s - self.clean_s


class FaultModel:
    """Seeded, deterministic storage-fault injector (see module doc).

    One instance per simulator; call ``perturb(latency_s, busy_s)`` once
    per positive-latency I/O event, in event order. The draw sequence per
    event is fixed (spike draw iff ``spike_prob > 0``, then one failure
    draw per attempt iff ``fail_prob > 0``), so a given (profile, seed)
    replays bit-identically regardless of which mechanisms are active.
    """

    def __init__(self, profile: str | FaultProfile = "none", seed: int = 0):
        self.profile = (
            profile if isinstance(profile, FaultProfile)
            else get_fault_profile(profile)
        )
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        # lifetime accounting (fault_summary surfaces these)
        self.n_events = 0
        self.n_spikes = 0
        self.n_retries = 0
        self.backoff_s = 0.0
        self.extra_s = 0.0
        self.min_throttle_scale = 1.0

    @property
    def enabled(self) -> bool:
        p = self.profile
        return bool(p.spike_prob > 0 or p.fail_prob > 0 or p.throttle is not None)

    def perturb(self, latency_s: float, busy_s: float) -> FaultOutcome:
        """Perturb one event's clean simulated latency. ``busy_s`` is the
        device's cumulative charged I/O seconds BEFORE this event (the
        thermal trajectory's clock). Pure in everything but the seeded RNG
        stream and the accounting counters."""
        if latency_s < 0:
            raise ValueError(f"latency_s must be >= 0, got {latency_s}")
        p = self.profile
        out = FaultOutcome(charged_s=float(latency_s), clean_s=float(latency_s))
        if latency_s == 0.0:
            return out
        self.n_events += 1
        lat = float(latency_s)
        if p.throttle is not None:
            out.throttle_scale = p.throttle.scale(busy_s)
            lat = lat / out.throttle_scale
            self.min_throttle_scale = min(self.min_throttle_scale,
                                          out.throttle_scale)
        if p.spike_prob > 0 and float(self.rng.random()) < p.spike_prob:
            lat *= p.spike_scale
            out.spiked = True
            self.n_spikes += 1
        charged = lat
        if p.fail_prob > 0:
            backoff = p.backoff_base_s
            for attempt in range(p.max_retries):
                if float(self.rng.random()) >= p.fail_prob:
                    break
                # the failed read is paid in full, then the backoff delay,
                # then the retry's read time
                charged += backoff + lat
                out.retries += 1
                out.backoff_s += backoff
                backoff *= p.backoff_mult
            self.n_retries += out.retries
            self.backoff_s += out.backoff_s
        out.charged_s = charged
        self.extra_s += charged - out.clean_s
        return out

    def summary(self) -> Dict[str, float]:
        return {
            "profile": self.profile.name,
            "seed": self.seed,
            "events": self.n_events,
            "spikes": self.n_spikes,
            "retries": self.n_retries,
            "backoff_s": self.backoff_s,
            "fault_extra_s": self.extra_s,
            "min_throttle_scale": self.min_throttle_scale,
        }
