"""Storage fault injection for the flash-offload simulator.

The serve stack plans against the *steady-state* ``LatencyTable`` — but a
real Jetson NVMe does not stay in steady state: sustained decode traffic
thermally throttles the controller, queue resonance produces tail-latency
spikes, and links drop the occasional read outright. This module models
that turbulence as a seeded, deterministic ``FaultModel`` injected at the
**measurement boundary** of ``FlashOffloadSimulator`` (core/offload.py):
chunk selection keeps planning against the clean table, and only the
simulated *measurement* of each I/O event is perturbed — so plans and
reality diverge exactly the way they do on hardware, and fault injection
can NEVER change which neurons are selected or which tokens come out
(time-only perturbation; pinned by tests/test_faults.py).

Three fault mechanisms compose, applied per logged I/O event in a fixed
order so a given (profile, seed) replays bit-identically:

  1. **Thermal throttling** — a deterministic ``ThermalTrajectory`` maps
     cumulative device-busy seconds to a throughput derate ``scale(t) ∈
     (0, 1]``; the event's clean latency is divided by it. Dividing the
     total is exactly equivalent to scaling both ``peak_bw`` and ``iops``
     of the two-regime model by ``scale`` (the Jetson profiles carry no
     separate ``base_latency`` term).
  2. **Tail-latency spikes** — with probability ``spike_prob`` the event's
     latency is multiplied by ``spike_scale`` (controller GC / queue
     resonance; the heavy tail Fig. 5's lognormal deliberately truncates).
  3. **Transient read failures** — each attempt fails with probability
     ``fail_prob``; a failed attempt charges its full (throttled, possibly
     spiked) read time plus an exponential-backoff delay
     (``backoff_base_s * backoff_mult**k`` after the k-th failure) and is
     retried. Attempt ``max_retries`` always succeeds, so the charge is
     bounded by ``(max_retries+1) * read + Σ backoff``.

The model draws from its OWN ``numpy`` Generator (``fault_seed``), never
from the simulator's: enabling faults does not shift the simulator's
lift/jitter RNG stream, and with faults disabled (the default) the
simulator's event log and RNG consumption are bit-identical to a build
without this module.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ThermalTrajectory:
    """Deterministic throughput-derate trajectory over device-busy time.

    ``scale(t)`` is 1.0 until ``onset_s`` cumulative busy seconds, ramps
    linearly down to ``floor`` over the next ``ramp_s`` seconds, then
    holds (sustained throttle). ``period_s > 0`` instead cycles: the
    pattern repeats every period with a linear recovery back to 1.0 in
    the second half of each period (thermal sawtooth — throttle under
    load, recover while the duty cycle drops).
    """

    onset_s: float = 0.0
    ramp_s: float = 1.0
    floor: float = 0.5
    period_s: float = 0.0

    def __post_init__(self):
        if not (0.0 < self.floor <= 1.0):
            raise ValueError(f"floor must be in (0, 1], got {self.floor}")
        if self.onset_s < 0 or self.ramp_s < 0 or self.period_s < 0:
            raise ValueError("onset_s/ramp_s/period_s must be >= 0")

    def scale(self, busy_s: float) -> float:
        """Throughput derate factor at ``busy_s`` cumulative device-busy
        seconds — 1.0 = full speed, ``floor`` = fully throttled."""
        t = float(busy_s)
        if self.period_s > 0.0:
            t = math.fmod(t, self.period_s)
            half = self.period_s / 2.0
            if t >= half:
                # linear recovery back to full speed over the second half
                frac = (t - half) / half
                lowest = self._ramp_value(half)
                return lowest + (1.0 - lowest) * frac
        return self._ramp_value(t)

    def _ramp_value(self, t: float) -> float:
        if t <= self.onset_s:
            return 1.0
        if self.ramp_s <= 0.0:
            return self.floor
        frac = min((t - self.onset_s) / self.ramp_s, 1.0)
        return 1.0 - (1.0 - self.floor) * frac


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """One named storage-turbulence scenario (see ``FAULT_PROFILES``)."""

    name: str
    spike_prob: float = 0.0
    spike_scale: float = 4.0
    fail_prob: float = 0.0
    max_retries: int = 3
    backoff_base_s: float = 0.5e-3
    backoff_mult: float = 2.0
    throttle: Optional[ThermalTrajectory] = None

    def __post_init__(self):
        if not (0.0 <= self.spike_prob < 1.0):
            raise ValueError(f"spike_prob must be in [0, 1), got {self.spike_prob}")
        if self.spike_scale < 1.0:
            raise ValueError(f"spike_scale must be >= 1, got {self.spike_scale}")
        if not (0.0 <= self.fail_prob < 1.0):
            raise ValueError(f"fail_prob must be in [0, 1), got {self.fail_prob}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base_s < 0 or self.backoff_mult < 1.0:
            raise ValueError("backoff_base_s must be >= 0 and backoff_mult >= 1")


# Named profiles, calibrated to be *visible* against the Jetson profiles'
# per-step decode latencies (hundreds of µs to a few ms) without burying
# the signal: tail spikes land on ~5% of events, flaky reads retry ~8% of
# attempts, and the thermal trajectories derate throughput to 25-50%.
FAULT_PROFILES: Dict[str, FaultProfile] = {
    p.name: p
    for p in (
        FaultProfile("none"),
        FaultProfile("tail_spikes", spike_prob=0.05, spike_scale=6.0),
        FaultProfile("flaky_reads", fail_prob=0.08, max_retries=4,
                     backoff_base_s=0.25e-3, backoff_mult=2.0),
        # sustained thermal throttle: full speed for the first 2 ms of
        # device-busy time, then a 10 ms ramp down to 25% throughput that
        # never recovers — the DegradationController's acceptance scenario
        FaultProfile("thermal_throttle",
                     throttle=ThermalTrajectory(onset_s=2e-3, ramp_s=10e-3,
                                                floor=0.25)),
        # thermal sawtooth: 40 ms cycle, throttling to 40% then recovering
        FaultProfile("thermal_cycle",
                     throttle=ThermalTrajectory(onset_s=0.0, ramp_s=10e-3,
                                                floor=0.4, period_s=40e-3)),
        # everything at once: the nightly-sweep worst case
        FaultProfile("degraded_nvme", spike_prob=0.03, spike_scale=5.0,
                     fail_prob=0.04, max_retries=4, backoff_base_s=0.25e-3,
                     throttle=ThermalTrajectory(onset_s=2e-3, ramp_s=10e-3,
                                                floor=0.35)),
    )
}


def get_fault_profile(name: str) -> FaultProfile:
    try:
        return FAULT_PROFILES[name]
    except KeyError:
        raise KeyError(
            f"unknown fault profile {name!r}; have {sorted(FAULT_PROFILES)}"
        )


@dataclasses.dataclass
class FaultOutcome:
    """What the fault model did to one I/O event."""

    charged_s: float  # total charged latency, faults included
    clean_s: float  # the latency the event would have charged fault-free
    throttle_scale: float = 1.0
    spiked: bool = False
    retries: int = 0
    backoff_s: float = 0.0

    @property
    def extra_s(self) -> float:
        return self.charged_s - self.clean_s


class FaultModel:
    """Seeded, deterministic storage-fault injector (see module doc).

    One instance per simulator; call ``perturb(latency_s, busy_s)`` once
    per positive-latency I/O event, in event order. The draw sequence per
    event is fixed (spike draw iff ``spike_prob > 0``, then one failure
    draw per attempt iff ``fail_prob > 0``), so a given (profile, seed)
    replays bit-identically regardless of which mechanisms are active.
    """

    def __init__(self, profile: str | FaultProfile = "none", seed: int = 0):
        self.profile = (
            profile if isinstance(profile, FaultProfile)
            else get_fault_profile(profile)
        )
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        # lifetime accounting (fault_summary surfaces these)
        self.n_events = 0
        self.n_spikes = 0
        self.n_retries = 0
        self.backoff_s = 0.0
        self.extra_s = 0.0
        self.min_throttle_scale = 1.0

    @property
    def enabled(self) -> bool:
        p = self.profile
        return bool(p.spike_prob > 0 or p.fail_prob > 0 or p.throttle is not None)

    def perturb(self, latency_s: float, busy_s: float) -> FaultOutcome:
        """Perturb one event's clean simulated latency. ``busy_s`` is the
        device's cumulative charged I/O seconds BEFORE this event (the
        thermal trajectory's clock). Pure in everything but the seeded RNG
        stream and the accounting counters."""
        if latency_s < 0:
            raise ValueError(f"latency_s must be >= 0, got {latency_s}")
        p = self.profile
        out = FaultOutcome(charged_s=float(latency_s), clean_s=float(latency_s))
        if latency_s == 0.0:
            return out
        self.n_events += 1
        # spike draw first (fixed draw order — replay depends on it); the
        # spike multiplier applies to every attempt's read: controller GC /
        # queue resonance persists for the duration of the event
        spike_mult = 1.0
        if p.spike_prob > 0 and float(self.rng.random()) < p.spike_prob:
            spike_mult = p.spike_scale
            out.spiked = True
            self.n_spikes += 1
        base = float(latency_s) * spike_mult

        def attempt_read(elapsed_s: float) -> tuple:
            """One attempt's read time at the throttle scale the busy clock
            has ADVANCED to ``elapsed_s`` seconds into this event — retries
            must not re-pay the read at the scale frozen from the first
            attempt (the failed reads and backoffs heat the device too)."""
            if p.throttle is None:
                return base, 1.0
            s = p.throttle.scale(busy_s + elapsed_s)
            self.min_throttle_scale = min(self.min_throttle_scale, s)
            return base / s, s

        read, out.throttle_scale = attempt_read(0.0)
        charged = read
        if p.fail_prob > 0:
            backoff = p.backoff_base_s
            for attempt in range(p.max_retries):
                if float(self.rng.random()) >= p.fail_prob:
                    break
                # the failed read was paid in full; after the backoff delay
                # the retry re-reads at the throttle scale of the advanced
                # busy clock (charged so far + this backoff)
                out.retries += 1
                out.backoff_s += backoff
                charged += backoff
                retry_read, _ = attempt_read(charged)
                charged += retry_read
                backoff *= p.backoff_mult
            self.n_retries += out.retries
            self.backoff_s += out.backoff_s
        out.charged_s = charged
        self.extra_s += charged - out.clean_s
        return out

    def summary(self) -> Dict[str, float]:
        return {
            "profile": self.profile.name,
            "seed": self.seed,
            "events": self.n_events,
            "spikes": self.n_spikes,
            "retries": self.n_retries,
            "backoff_s": self.backoff_s,
            "fault_extra_s": self.extra_s,
            "min_throttle_scale": self.min_throttle_scale,
        }


# ---------------------------------------------------------------------------
# data-plane corruption (PR 9): faults that change BYTES, not just time
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CorruptionProfile:
    """One named data-corruption scenario (see ``CORRUPTION_PROFILES``).

    Unlike ``FaultProfile`` (time-only), corruption perturbs the *payload*
    of fetched chunk blocks: with probability ``p_block`` per fetched
    8-row block per plan-refresh epoch, the block's bytes are damaged —
    ``mode="flip"`` flips one uniformly-drawn bit (NAND retention /
    read-disturb), ``mode="zero"`` zeroes the whole block (a torn read).
    A detected corruption is re-read up to ``max_reread`` times (model
    parameter, CLI ``--max-reread``); each re-read independently comes back
    corrupt again with probability ``p_stuck`` (0 = transient, re-read is
    always clean; high = retention damage that persists). Re-reads charge
    the block's read time plus exponential backoff
    (``backoff_base_s * backoff_mult**k``) through the I/O accounting.
    """

    name: str
    p_block: float = 0.0
    mode: str = "flip"
    p_stuck: float = 0.0
    backoff_base_s: float = 5e-5
    backoff_mult: float = 2.0

    def __post_init__(self):
        if not (0.0 <= self.p_block < 1.0):
            raise ValueError(f"p_block must be in [0, 1), got {self.p_block}")
        if self.mode not in ("flip", "zero"):
            raise ValueError(f"mode must be 'flip' or 'zero', got {self.mode!r}")
        if not (0.0 <= self.p_stuck < 1.0):
            raise ValueError(f"p_stuck must be in [0, 1), got {self.p_stuck}")
        if self.backoff_base_s < 0 or self.backoff_mult < 1.0:
            raise ValueError("backoff_base_s must be >= 0 and backoff_mult >= 1")


# Calibrated so short CI decode runs (a few thousand fetched blocks) see
# corruption events without drowning in them: bit_rot's flips are always
# transient (the recovered-byte-identity CI floor needs every corruption
# recoverable), degraded_nand's retention errors frequently survive the
# re-read budget and exercise the full degradation ladder.
CORRUPTION_PROFILES: Dict[str, CorruptionProfile] = {
    p.name: p
    for p in (
        CorruptionProfile("none"),
        # transient read-disturb bit flips: always clean on re-read
        CorruptionProfile("bit_rot", p_block=0.02, mode="flip", p_stuck=0.0),
        # torn reads: a block arrives zeroed; usually clean on re-read
        CorruptionProfile("torn_read", p_block=0.01, mode="zero", p_stuck=0.35),
        # worn-out NAND: frequent flips that often persist across re-reads
        CorruptionProfile("degraded_nand", p_block=0.05, mode="flip",
                          p_stuck=0.65),
    )
}


def get_corruption_profile(name: str) -> CorruptionProfile:
    try:
        return CORRUPTION_PROFILES[name]
    except KeyError:
        raise KeyError(
            f"unknown corruption profile {name!r}; "
            f"have {sorted(CORRUPTION_PROFILES)}"
        )


def corruption_key(base_key, lid, epoch, site_idx: int, matrix_idx: int):
    """The integrity subsystem's key schedule: one jax PRNG key per
    (layer, refresh epoch, site, matrix). ``lid``/``epoch`` are traced
    plan-carry values (serving/sparse_exec.py), so the SAME corruption
    pattern replays for a given (profile, seed) regardless of backend,
    wbits, prefetch depth or scan/per-token decode path."""
    import jax

    k = jax.random.fold_in(base_key, lid)
    k = jax.random.fold_in(k, epoch)
    return jax.random.fold_in(k, site_idx * 8 + matrix_idx)


class CorruptionModel:
    """Seeded, deterministic data-plane corruption injector.

    Pure configuration plus traced jnp draw/apply helpers — unlike
    ``FaultModel`` there is no host-side RNG stream: every draw derives
    from ``jax.random`` keys folded over (seed, layer, epoch, site,
    matrix) via ``corruption_key``, so the injector composes with the
    scan-fused decode path and replays bit-identically. Counters live in
    the decode plan (detected/recovered/substituted/dropped lanes) and
    surface through ``ServeEngine.io_summary()``.
    """

    def __init__(
        self,
        profile: str | CorruptionProfile = "none",
        seed: int = 0,
        max_reread: int = 2,
        recover: bool = True,
    ):
        self.profile = (
            profile if isinstance(profile, CorruptionProfile)
            else get_corruption_profile(profile)
        )
        self.seed = int(seed)
        if max_reread < 0:
            raise ValueError(f"max_reread must be >= 0, got {max_reread}")
        self.max_reread = int(max_reread)
        self.recover = bool(recover)

    @property
    def enabled(self) -> bool:
        return self.profile.p_block > 0.0

    def base_key(self):
        import jax

        return jax.random.key(self.seed)

    # -- traced draw/apply helpers (safe inside the decode lax.scan) --------
    def draw_blocks(self, key, fetched_blocks):
        """(NB,) bool: which of the blocks actually read from flash this
        epoch arrive corrupted. ``fetched_blocks`` masks the draw to blocks
        with at least one selected non-resident row — resident rows never
        touch the storage data plane."""
        import jax
        import jax.numpy as jnp

        u = jax.random.uniform(jax.random.fold_in(key, 0),
                               fetched_blocks.shape)
        return fetched_blocks & (u < jnp.float32(self.profile.p_block))

    def draw_rereads(self, key, corrupt):
        """Per corrupted block: (re-reads charged (NB,) i32, recovered
        (NB,) bool). The number of consecutive still-corrupt re-reads is a
        geometric draw with persistence ``p_stuck``; a block recovers iff
        a clean re-read lands within the ``max_reread`` budget. Recovery
        off (or budget 0) charges no re-reads and recovers nothing."""
        import jax
        import jax.numpy as jnp

        zeros = jnp.zeros(corrupt.shape, jnp.int32)
        if not self.recover or self.max_reread == 0:
            return zeros, jnp.zeros(corrupt.shape, bool)
        p = self.profile
        if p.p_stuck <= 0.0:
            fails = zeros
        else:
            u = jax.random.uniform(
                jax.random.fold_in(key, 1), corrupt.shape,
                minval=jnp.float32(1e-12),
            )
            fails = jnp.floor(
                jnp.log(u) / jnp.log(jnp.float32(p.p_stuck))
            ).astype(jnp.int32)
        rereads = jnp.where(corrupt,
                            jnp.minimum(fails + 1, self.max_reread), 0)
        recovered = corrupt & (fails < self.max_reread)
        return rereads, recovered

    def backoff_seconds(self, rereads):
        """Total exponential-backoff seconds for ``rereads`` attempts per
        block — the same ``base * mult**k`` ladder ``FaultModel`` charges
        transient read failures."""
        import jax.numpy as jnp

        p = self.profile
        r = rereads.astype(jnp.float32)
        if p.backoff_mult == 1.0:
            return jnp.float32(p.backoff_base_s) * r
        m = jnp.float32(p.backoff_mult)
        return jnp.float32(p.backoff_base_s) * (m**r - 1.0) / (m - 1.0)

    def corrupt_payload(self, w, corrupt_blocks, key, block_rows: int = 8):
        """Apply the drawn corruption to an (N, D) payload matrix — the
        bytes the fetch actually delivered. ``mode="zero"`` zeroes every
        row of a corrupted block; ``mode="flip"`` XORs one drawn bit of
        one drawn element per corrupted block (via bitcast, so int8 and
        fp payloads corrupt identically at the bit level). Deterministic
        in ``key``; both execution backends apply the identical function,
        so even corrupted tokens stay byte-identical across backends."""
        import jax
        import jax.numpy as jnp

        n, d = w.shape
        nb = n // block_rows
        if self.profile.mode == "zero":
            keep = ~jnp.repeat(corrupt_blocks, block_rows)
            return jnp.where(keep[:, None], w, jnp.zeros((), w.dtype))
        itemsize = jnp.dtype(w.dtype).itemsize
        uint = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[itemsize]
        elem = jax.random.randint(jax.random.fold_in(key, 2), (nb,), 0,
                                  block_rows * d)
        bit = jax.random.randint(jax.random.fold_in(key, 3), (nb,), 0,
                                 itemsize * 8)
        xor_word = (jnp.uint32(1) << bit.astype(jnp.uint32)).astype(uint)
        u = jax.lax.bitcast_convert_type(w, uint).reshape(nb, block_rows * d)
        flips = jnp.zeros_like(u).at[jnp.arange(nb), elem].set(
            jnp.where(corrupt_blocks, xor_word, jnp.zeros((), uint))
        )
        return jax.lax.bitcast_convert_type(
            (u ^ flips).reshape(n, d), w.dtype
        )
