"""Chunk-based latency model (paper §3.1) + device profiles.

The paper profiles, per chunk size s (bytes), the steady-state read latency
T[s] on the target storage device, then estimates the latency of an arbitrary
access pattern as the sum of its chunks' latencies:

    L_total(mask) = Σ_i T[size_i * row_bytes]

Device profiles here are synthetic reconstructions of the paper's published
measurements, calibrated to its OUTCOME metrics:

  * additive two-term latency: T(s) = base + 1/iops + s/peak_bw — a fixed
    per-request cost (Jetson NVMe interrupts are single-core-bound [8,42],
    so both boards sustain similar request rates) plus a bandwidth term;
  * ``iops`` is calibrated so the scattered-vs-contiguous penalty at
    realistic top-k run lengths (~2.5 rows ≈ 17.5 KB for LLaVA-7B rows)
    reproduces Fig. 4b's crossover and the Fig. 6/7 speedup magnitudes
    (mean 2.19×/2.89×, max 4.65×/5.76×). The same per-request cost against
    AGX's higher bandwidth yields the paper's "wider throughput gap" on AGX;
  * peak bandwidths are the spec-sheet numbers from §4.1.

The same abstraction doubles as the TPU HBM→VMEM DMA cost model used by the
Pallas chunk kernel's utility scoring: a DMA has fixed descriptor/issue
overhead and a bandwidth term, i.e. exactly the same two-regime shape.

Everything is exposed both as python floats (offline tools) and as jnp lookup
tables (runtime selection inside jit).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

KB = 1024.0
MB = 1024.0 * 1024.0
GB = 1024.0 * 1024.0 * 1024.0


def row_stream_bytes(cols: int, wbits: int = 16, block_rows: int = 8) -> float:
    """Streamed bytes per selected weight row at a given storage width.

    At 16 bits a row is ``cols * 2`` payload bytes. Quantized storage
    (``wbits=8``, kernels/quantize.py) ships ``cols`` int8 payload bytes
    plus its share of the per-``block_rows``-block f32 scale — 4 bytes
    amortized over the block, i.e. ``4 / block_rows`` per row per matrix —
    so quantized savings are charged honestly, never as a free 2×. The
    value is fractional by design; every consumer (LatencyTable pricing,
    IOEvent.nbytes, the residency cache's byte budget) accepts floats."""
    if wbits not in (16, 8):
        raise ValueError(f"wbits must be 16 or 8, got {wbits}")
    payload = cols * wbits / 8.0
    scale_overhead = (4.0 / block_rows) if wbits < 16 else 0.0
    return payload + scale_overhead


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Two-regime storage/DMA latency profile.

    Attributes:
      name: identifier.
      peak_bw: saturated read bandwidth, bytes/sec.
      iops: sustained small-request rate (requests/sec) under a
        throughput-saturating queue — contributes 1/iops per request.
      base_latency: extra additive per-request constant (0 for Jetson
        profiles — folded into 1/iops; nonzero where a separate descriptor
        cost is meaningful, e.g. the TPU DMA profile).
      interleave_lift: multiplicative lift applied by the *simulator* (not
        the model) to mimic the pattern-dependent controller effects the
        paper observes as a proportional bias in Fig. 5.
      dram_cache_mb: default DRAM budget (MB) for the dynamic chunk
        residency cache (paper §5 "Leveraging Additional Memory Budget") —
        the capacity ``ServeEngine`` uses when no explicit ``cache_mb`` is
        given. 0 disables the residency tier; the CLI ``--cache-mb`` and the
        engine argument override it per run.
    """

    name: str
    peak_bw: float
    iops: float
    base_latency: float = 0.0
    interleave_lift: float = 1.0
    dram_cache_mb: float = 0.0

    def cache_capacity_bytes(self, cache_mb: Optional[float] = None) -> int:
        """Residency-tier capacity in bytes; ``cache_mb`` overrides the
        profile default."""
        mb = self.dram_cache_mb if cache_mb is None else float(cache_mb)
        if mb < 0:
            raise ValueError(f"cache_mb must be >= 0, got {mb}")
        return int(mb * MB)

    @property
    def knee_bytes(self) -> float:
        return self.peak_bw / self.iops

    def saturation_bytes(self, frac: float = 0.99) -> float:
        """Block size at which throughput reaches ``frac`` of peak:
        thr(s)/bw = 1/(1 + knee/s) = frac ⇒ s = knee·frac/(1-frac)."""
        return self.knee_bytes * frac / (1.0 - frac)

    # -- scalar model -------------------------------------------------------
    def latency_bytes(self, nbytes) -> np.ndarray:
        """T(s): steady-state latency (sec) of one request of s bytes
        (additive per-request + transfer)."""
        s = np.asarray(nbytes, dtype=np.float64)
        return self.base_latency + 1.0 / self.iops + s / self.peak_bw

    def throughput_bytes(self, nbytes) -> np.ndarray:
        s = np.asarray(nbytes, dtype=np.float64)
        return s / self.latency_bytes(s)

    # -- row-granular lookup table (the paper's T[s]) ------------------------
    def build_table(self, row_bytes: float, max_rows: int) -> "LatencyTable":
        sizes = np.arange(max_rows + 1, dtype=np.float64) * row_bytes
        lat = self.latency_bytes(sizes)
        lat[0] = 0.0
        return LatencyTable(
            device=self.name,
            row_bytes=row_bytes,
            table=jnp.asarray(lat, dtype=jnp.float32),
        )


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: jnp field, identity hash
class LatencyTable:
    """T[r]: latency (sec) of loading one chunk of r contiguous rows.

    ``table`` has shape (max_rows+1,), table[0] == 0. Lives inside jit as a
    constant; lookups are plain gathers.
    """

    device: str
    row_bytes: float  # fractional at wbits=8 (amortized scale overhead)
    table: jnp.ndarray

    @property
    def max_rows(self) -> int:
        return int(self.table.shape[0]) - 1

    def lookup(self, rows: jnp.ndarray) -> jnp.ndarray:
        """T[rows] with clamping + linear extrapolation above max_rows.

        Extrapolation uses the bandwidth slope (table is affine past the
        knee, so this is exact for the two-regime model).
        """
        r = jnp.asarray(rows)
        rmax = self.max_rows
        slope = self.table[rmax] - self.table[rmax - 1] if rmax >= 2 else self.table[rmax]
        clamped = jnp.clip(r, 0, rmax)
        base = self.table[clamped]
        extra = jnp.maximum(r - rmax, 0).astype(self.table.dtype) * slope
        return base + extra

    def mask_latency(self, mask: jnp.ndarray) -> jnp.ndarray:
        """Estimated latency of an access pattern: Σ chunks T[size] (jit-safe)."""
        from .contiguity import mask_to_runs_jax

        _, sizes, _ = mask_to_runs_jax(mask)
        return jnp.sum(self.lookup(sizes) * (sizes > 0))

    def mask_latency_miss(self, mask: jnp.ndarray, resident: jnp.ndarray) -> jnp.ndarray:
        """Residency-aware additive model: Σ runs(mask) T[miss rows in run].

        Each selected run issues ONE request charged for its non-resident
        rows only — resident rows inside a run are served from the DRAM
        tier and do not fragment the read (read-through coalescing; a
        per-row split would wrongly pay 1/iops per fragment). Fully
        resident runs issue no request at all. With ``resident`` all-false
        this equals ``mask_latency``. jit-safe."""
        from .contiguity import mask_to_runs_jax

        starts, sizes, _ = mask_to_runs_jax(mask)
        miss = sizes - resident_rows_in_windows(starts, sizes, resident).astype(sizes.dtype)
        return jnp.sum(self.lookup(miss) * (miss > 0))

    def padded_table(self, max_rows: int) -> np.ndarray:
        """T[0..max_rows] as a dense host array, using ``lookup``'s linear
        extrapolation past the table end — the per-lane cost row a
        ``BatchedChunkSelector`` embeds when sites with different row widths
        are padded into one (n_sites, max_rows+1) lookup matrix."""
        return np.asarray(self.lookup(jnp.arange(max_rows + 1)), np.float64)

    def mask_latency_np(self, mask: np.ndarray) -> float:
        from .contiguity import mask_to_chunks_np

        return float(
            sum(float(self.lookup(jnp.asarray(c.size))) for c in mask_to_chunks_np(mask))
        )


# ---------------------------------------------------------------------------
# Published device profiles (reconstructed from the paper)
# ---------------------------------------------------------------------------

# Jetson Orin AGX + Samsung 990 Pro: peak seq read 7450 MB/s (§4.1).
# iops calibrated to the paper's Fig. 4b / Fig. 6-7 magnitudes (see class
# docstring): both boards' NVMe interrupts are single-CPU-core bound [8,42],
# so the sustained request rate is similar; AGX's higher bandwidth then
# yields the paper's wider scattered-vs-contiguous gap.
# Calibration result (benchmarks/fig6 sweep): nano 150k / agx 220k sustained
# requests/s reproduce the paper's matched-accuracy speedups —
# mean 2.26×/2.85× vs published 2.19×/2.89× (max 5.2×/7.1× vs 4.65×/5.76×).
JETSON_AGX = DeviceProfile(
    name="jetson_agx_990pro",
    peak_bw=7450 * MB,
    iops=220_000.0,
    interleave_lift=1.18,  # Fig. 5: proportional lift, larger device → smaller
)

# Jetson Orin Nano + SK Hynix Gold P31: peak 3500 MB/s.
JETSON_NANO = DeviceProfile(
    name="jetson_nano_p31",
    peak_bw=3500 * MB,
    iops=150_000.0,
    interleave_lift=1.31,  # lower-end device → stronger tail effects (Fig. 5)
)

# TPU v5e HBM→VMEM DMA: 819 GB/s per chip; per-DMA issue overhead ~1 µs
# (descriptor + wait orchestration). Same two-regime shape, different scale —
# this is the profile the chunk_gather_matmul kernel's planner uses.
TPU_V5E_HBM = DeviceProfile(
    name="tpu_v5e_hbm",
    peak_bw=819 * GB,
    iops=1.0e6,  # ≈1 µs per independent small DMA
    base_latency=0.0,
    interleave_lift=1.05,
)

PROFILES: Dict[str, DeviceProfile] = {
    p.name: p for p in (JETSON_AGX, JETSON_NANO, TPU_V5E_HBM)
}
# Paper-style aliases.
PROFILES["agx"] = JETSON_AGX
PROFILES["nano"] = JETSON_NANO
PROFILES["tpu"] = TPU_V5E_HBM


def resident_rows_in_windows(
    starts: jnp.ndarray, sizes: jnp.ndarray, resident: jnp.ndarray
) -> jnp.ndarray:
    """Resident-row count inside each [start, start+size) window, via a
    float32 prefix sum (exact for counts < 2^24) rounded back to int.
    Shared by ``LatencyTable.mask_latency_miss`` and the marginal-cost
    scoring in ``ChunkSelector.select`` so the selector's per-window cost
    and the final latency charge can never diverge. jit-safe."""
    rcum = jnp.concatenate(
        [jnp.zeros((1,), jnp.float32), jnp.cumsum(resident.astype(jnp.float32))]
    )
    res_in = rcum[starts + sizes] - rcum[starts]
    return jnp.round(res_in).astype(jnp.int32)


def get_profile(name: str) -> DeviceProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown device profile {name!r}; have {sorted(PROFILES)}")


def profile_table(
    device: str | DeviceProfile, row_bytes: float, max_rows: int
) -> LatencyTable:
    prof = device if isinstance(device, DeviceProfile) else get_profile(device)
    return prof.build_table(row_bytes=row_bytes, max_rows=max_rows)


def table_from_measurements(
    device: str, row_bytes: int, sizes_rows: np.ndarray, latencies_s: np.ndarray
) -> LatencyTable:
    """Build a LatencyTable from arbitrary measured (size, latency) points by
    monotone linear interpolation — the path a real deployment would use
    (App. D microbenchmarks) instead of the synthetic profiles above.

    Rejects duplicate sizes and latencies that strictly decrease with size:
    both are measurement errors (a re-run point or a mis-sorted log) that
    would otherwise be silently interpolated into a garbage table whose
    chunk utilities mis-rank every selection downstream. Equal latencies at
    increasing sizes are fine (IOPS-bound plateau)."""
    sizes_rows = np.asarray(sizes_rows, dtype=np.int64)
    latencies_s = np.asarray(latencies_s, dtype=np.float64)
    if sizes_rows.ndim != 1 or sizes_rows.shape != latencies_s.shape:
        raise ValueError("sizes/latencies must be matching 1-D arrays")
    order = np.argsort(sizes_rows)
    sizes_rows, latencies_s = sizes_rows[order], latencies_s[order]
    dup = np.flatnonzero(np.diff(sizes_rows) == 0)
    if dup.size:
        raise ValueError(
            f"duplicate measurement sizes {sorted(set(sizes_rows[dup].tolist()))}: "
            "each size must be measured once (aggregate repeated runs — e.g. "
            "take the median — before building the table)"
        )
    dec = np.flatnonzero(np.diff(latencies_s) < 0)
    if dec.size:
        i = int(dec[0])
        raise ValueError(
            f"non-monotone latency samples: latency drops from "
            f"{latencies_s[i]:.3e}s at {int(sizes_rows[i])} rows to "
            f"{latencies_s[i + 1]:.3e}s at {int(sizes_rows[i + 1])} rows — "
            "reading more can't be faster; re-measure or drop the outlier"
        )
    max_rows = int(sizes_rows[-1])
    grid = np.arange(max_rows + 1, dtype=np.float64)
    lat = np.interp(grid, sizes_rows.astype(np.float64), latencies_s)
    lat[0] = 0.0
    return LatencyTable(device=device, row_bytes=row_bytes, table=jnp.asarray(lat, jnp.float32))
