"""Neuron importance scores (paper App. B.2).

Importance of input neuron i of a weight matrix W ∈ R^{m×n} is |a_i| for a
single token; for multi-token inputs (VLM frame appending, prefill, batched
decoding) it is the mean of |a_i| across tokens, yielding one importance
vector shared by all tokens — the property that makes VLM importance
distributions smooth (§2.2) and latency uniform across a batch (App. N fn 5).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def importance(acts: jnp.ndarray) -> jnp.ndarray:
    """|a| averaged over all leading (token/batch) axes.

    acts: (..., N) activations entering a weight matrix's input dim.
    Returns (N,) float32 importance.
    """
    a = jnp.abs(acts.astype(jnp.float32))
    if a.ndim == 1:
        return a
    return a.reshape(-1, a.shape[-1]).mean(axis=0)


def importance_np(acts: np.ndarray) -> np.ndarray:
    a = np.abs(np.asarray(acts, np.float32))
    if a.ndim == 1:
        return a
    return a.reshape(-1, a.shape[-1]).mean(axis=0)


def coefficient_of_variation(v: jnp.ndarray) -> jnp.ndarray:
    """CV = std/mean of an importance vector — the smoothness metric of
    Table 1 (App. C). ReLU LLMs ≈ 8–12, VLMs ≈ 1–4.5."""
    v = v.astype(jnp.float32)
    mean = jnp.mean(v)
    return jnp.std(v) / jnp.maximum(mean, 1e-12)


def retention(v: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Importance retention Σ_selected V / Σ V — the accuracy proxy the paper
    uses for its plain-LLM study (App. N)."""
    v = v.astype(jnp.float32)
    return jnp.sum(v * mask) / jnp.maximum(jnp.sum(v), 1e-12)
