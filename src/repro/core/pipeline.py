"""Overlapped I/O–compute decode pipeline timeline (two-stage prefetch).

The serve stack used to charge a decode step serially:

    step latency = Σ_layers io_l + Σ_layers compute_l

but the whole premise of the paper is that flash I/O dominates sparse decode
latency — and a real runtime hides it: while layer *l* computes, the I/O
engine prefetches layer *l+1*'s selected chunks (classic double buffering).
``PipelineModel`` turns per-layer ``(io_s, compute_s)`` vectors into that
two-resource timeline and accounts, per decode step, for the critical-path
latency, the compute stalls (compute waiting on an unfinished fetch) and the
I/O bubbles (fetch engine idle waiting for a buffer).

Model
-----
Tasks are layers in decode order, cyclic across steps (layer 0 of step t+1
follows layer L-1 of step t — cross-step prefetch falls out naturally, which
is what hides the first layer's fetch in steady state). Two serial engines:

  * the **fetch engine** loads task k's chunks; it may run at most
    ``prefetch_depth`` tasks ahead of compute (depth 1 = double buffering:
    one buffer computing, one filling — fetch of task k waits for task
    k-1-depth's compute to release its buffer);
  * the **compute engine** runs task k once its fetch AND task k-1's
    compute are done.

Recurrence (f = fetch completion, c = compute completion):

    f[k] = max(f[k-1], c[k-1-depth]) + io[k]
    c[k] = max(c[k-1], f[k]) + compute[k]

``prefetch_depth=0`` degenerates to the serial schedule exactly (fetch k
waits for compute k-1), which is the retained baseline mode.

Invariants (tests/test_pipeline.py):
  * zero compute  ⇒ overlapped == serial per step (I/O engine is the chain);
  * compute-dominant ⇒ I/O fully hidden: every steady-state step's
    overlapped latency == Σ compute (step 0 additionally pays the cold
    first fetch — nothing earlier to hide it under);
  * overlapped ≤ serial, always, per step.

``overlap_efficiency`` is the fraction of the *hideable* time actually
hidden: per step the serial latency is io+compute and a perfect overlap
achieves max(io, compute), so hideable = Σ_steps min(io_t, compute_t) and

    efficiency = (Σ serial − Σ overlapped) / Σ min(io_t, compute_t)

clipped to [0, 1]; defined as 1.0 when nothing is hideable (e.g. zero
compute, or the zero-I/O ``dense_free`` policy). The CI smoke benchmark
gates on a conservative floor of this number.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineTimeline:
    """Per-step accounting of one decode call's I/O–compute pipeline.

    All arrays are (n_steps,) seconds except ``io_s``/``compute_s`` which
    keep the (n_steps, n_layers) inputs for downstream inspection.
    """

    io_s: np.ndarray  # (n, L) per-layer I/O per step
    compute_s: np.ndarray  # (n, L) per-layer compute per step
    serial_s: np.ndarray  # (n,) Σ_l (io + compute) — the baseline charge
    overlap_s: np.ndarray  # (n,) critical-path latency with prefetch
    stall_s: np.ndarray  # (n,) compute idle waiting on an unfinished fetch
    bubble_s: np.ndarray  # (n,) fetch engine idle waiting for a free buffer

    @property
    def serial_total_s(self) -> float:
        return float(self.serial_s.sum())

    @property
    def overlap_total_s(self) -> float:
        return float(self.overlap_s.sum())

    @property
    def hidden_s(self) -> float:
        """Total latency removed by overlapping (≥ 0 by construction)."""
        return self.serial_total_s - self.overlap_total_s

    @property
    def hideable_s(self) -> float:
        """Upper bound on hidden_s: per step a perfect two-stage overlap
        reaches max(io, compute), hiding min(io, compute). (A deep prefetch
        pipeline can do slightly better across step boundaries by smoothing
        I/O spikes into earlier steps' compute; ``overlap_efficiency`` clips
        at 1.0 so the metric stays a fraction.)"""
        return float(
            np.minimum(self.io_s.sum(axis=1), self.compute_s.sum(axis=1)).sum()
        )

    def overlap_efficiency(self) -> float:
        return overlap_efficiency(
            self.serial_s, self.overlap_s,
            self.io_s.sum(axis=1), self.compute_s.sum(axis=1),
        )


def overlap_efficiency(serial_s, overlap_s, io_s, compute_s) -> float:
    """Efficiency from pre-aggregated per-step (n,) arrays — the form the
    engine uses when rebuilding the metric from logged StepStats."""
    serial_s = np.asarray(serial_s, np.float64)
    overlap_s = np.asarray(overlap_s, np.float64)
    hideable = float(
        np.minimum(np.asarray(io_s, np.float64), np.asarray(compute_s, np.float64)).sum()
    )
    if hideable <= 0.0:
        return 1.0
    return float(np.clip((serial_s.sum() - overlap_s.sum()) / hideable, 0.0, 1.0))


@dataclasses.dataclass(frozen=True)
class PipelineModel:
    """Two-stage prefetch timeline over per-layer (io, compute) vectors.

    ``prefetch_depth``: how many tasks the fetch engine may run ahead of
    compute — the SAME knob (and the same hidden-fetch discipline) as the
    DMA gather kernels' slot count (kernels/chunk_gather_dma.py uses
    ``prefetch_depth + 1`` VMEM slots), so the host model and the kernel
    agree on what is hidden. 1 = double buffering (the default and the
    paper-realistic setting); 0 = fully serial (the baseline the overlapped
    mode is benchmarked against); > 1 lets a fetch start while ``depth``
    earlier buffers are still unconsumed, which hides I/O spikes a single
    spare buffer cannot (latency is monotone non-increasing in depth: a
    deeper pipeline only relaxes the buffer-free gate in the recurrence).
    """

    prefetch_depth: int = 1

    def __post_init__(self):
        if self.prefetch_depth < 0:
            raise ValueError(
                f"prefetch_depth must be >= 0, got {self.prefetch_depth}"
            )

    def with_depth(self, prefetch_depth: int) -> "PipelineModel":
        """Same model at a different prefetch depth (depth sweeps)."""
        return dataclasses.replace(self, prefetch_depth=prefetch_depth)

    def timeline(self, io_s, compute_s) -> PipelineTimeline:
        """io_s: (n_steps, n_layers) or (n_layers,) per-layer I/O seconds;
        compute_s: (n_layers,) or (n_steps, n_layers) per-layer compute.
        Returns the per-step PipelineTimeline (host-side numpy — this runs
        once per decode call on the already-synced estimate arrays)."""
        io = np.asarray(io_s, np.float64)
        if io.ndim == 1:
            io = io[None, :]
        if io.ndim != 2:
            raise ValueError(f"io_s must be (n, L) or (L,), got {io.shape}")
        n, n_layers = io.shape
        comp = np.asarray(compute_s, np.float64)
        comp = np.broadcast_to(comp, (n, n_layers)).copy()
        if np.any(io < 0) or np.any(comp < 0):
            raise ValueError("io_s and compute_s must be non-negative")

        f = io.reshape(-1)
        c = comp.reshape(-1)
        k_total = n * n_layers
        compute_done = np.zeros(k_total)
        stall = np.zeros(k_total)
        bubble = np.zeros(k_total)
        fetch_done_prev = 0.0
        compute_done_prev = 0.0
        for k in range(k_total):
            gate_idx = k - 1 - self.prefetch_depth
            buffer_free = compute_done[gate_idx] if gate_idx >= 0 else 0.0
            fetch_start = max(fetch_done_prev, buffer_free)
            bubble[k] = fetch_start - fetch_done_prev
            fetch_done_prev = fetch_start + f[k]
            stall[k] = max(0.0, fetch_done_prev - compute_done_prev)
            compute_done_prev = max(compute_done_prev, fetch_done_prev) + c[k]
            compute_done[k] = compute_done_prev

        ends = compute_done.reshape(n, n_layers)[:, -1]
        overlap = np.diff(ends, prepend=0.0)
        serial = io.sum(axis=1) + comp.sum(axis=1)
        return PipelineTimeline(
            io_s=io,
            compute_s=comp,
            serial_s=serial,
            overlap_s=overlap,
            stall_s=stall.reshape(n, n_layers).sum(axis=1),
            bubble_s=bubble.reshape(n, n_layers).sum(axis=1),
        )

    def serial_timeline(self, io_s, compute_s) -> PipelineTimeline:
        """The retained baseline: same inputs, prefetch_depth=0 — per-step
        overlap_s equals serial_s exactly."""
        return dataclasses.replace(self, prefetch_depth=0).timeline(io_s, compute_s)
