"""Paged-KV page allocator: free list, refcounts, COW, prefix cache.

The serve stack's KV cache becomes vLLM-lineage paged storage (cf. the
neuralmagic-vllm snippet in SNIPPETS.md): a fixed pool of
``page_tokens``-token pages, per-request page tables mapping logical
positions to physical pages, refcounted sharing for common prompt
prefixes, and copy-on-write semantics for forks. This module is the PURE
allocator — plain python/numpy state, no jax, no device arrays — so its
invariants can be property-tested exhaustively (tests/test_paged_kv.py)
independently of the engine that moves the actual KV bytes
(serving/kv_pool.py wraps it per slot; models/attention.py does the
device-side gather/scatter through the tables).

Page lifecycle::

    free ──alloc──▶ live (ref ≥ 1) ──release to ref 0──▶
        • registered prefix page → cold (content-addressed, evictable)
        • anonymous page         → free

    cold ──lookup_prefix hit──▶ live (revived, ref 1)
    cold ──evict_cold──▶ free        (never touches ref > 0 pages)

Conservation invariant (``check()``): live + cold + free == capacity at
every step, refcounts never go negative, and a page is reachable from two
owners only while its refcount covers both.

Page 0 is reserved as the garbage page: free table rows point at it, so
decode writes from unoccupied slots land somewhere harmless that no live
table ever reads. It is born with a permanent self-reference and is
excluded from capacity.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

GARBAGE_PAGE = 0


class KVPoolExhausted(RuntimeError):
    """No free page available (and the caller chose not to evict)."""


class PagedKVAllocator:
    """Refcounted free-list allocator over ``n_pages`` physical pages.

    ``n_pages`` counts the whole pool INCLUDING the reserved garbage page
    0; ``capacity`` (= n_pages - 1) pages are allocatable."""

    def __init__(self, n_pages: int, page_tokens: int):
        if n_pages < 2:
            raise ValueError(f"n_pages must be >= 2 (page 0 is reserved), got {n_pages}")
        if page_tokens < 1:
            raise ValueError(f"page_tokens must be >= 1, got {page_tokens}")
        self.n_pages = int(n_pages)
        self.page_tokens = int(page_tokens)
        self.ref = np.zeros(self.n_pages, np.int64)
        self.ref[GARBAGE_PAGE] = 1  # permanent — never allocated, never freed
        # LIFO free list: reuse recently-freed pages first (cache-friendlier)
        self._free: List[int] = list(range(self.n_pages - 1, GARBAGE_PAGE, -1))
        # content-addressed prefix pages: hash -> page while live or cold;
        # cold pages (ref 0, evictable) additionally sit in _cold in LRU order
        self._by_hash: Dict[str, int] = {}
        self._hash_of: Dict[int, str] = {}
        self._cold: "OrderedDict[int, None]" = OrderedDict()
        # lifetime counters (monotone; the pool surfaces them)
        self.shared_hits = 0   # lookup_prefix hits (live or revived cold)
        self.cow_copies = 0    # prepare_write copies triggered by ref > 1
        self.evictions = 0     # cold pages reclaimed to the free list

    # -- capacity ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.n_pages - 1  # page 0 excluded

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cold(self) -> int:
        return len(self._cold)

    @property
    def n_live(self) -> int:
        """Pages with at least one reference (garbage page excluded)."""
        return int((self.ref[GARBAGE_PAGE + 1:] > 0).sum())

    @property
    def n_reclaimable(self) -> int:
        """Pages an allocation burst could obtain: free now + evictable cold."""
        return self.n_free + self.n_cold

    def refcount(self, page: int) -> int:
        return int(self.ref[page])

    # -- alloc / share / release ---------------------------------------------
    def alloc(self) -> int:
        """Take one page off the free list (evicting a cold page if the
        list is empty), ref = 1. Raises KVPoolExhausted when nothing is
        free nor evictable."""
        if not self._free and not self.evict_cold(1):
            raise KVPoolExhausted(
                f"KV page pool exhausted: {self.n_live}/{self.capacity} pages "
                "live, none free or cold-evictable"
            )
        page = self._free.pop()
        assert self.ref[page] == 0
        self.ref[page] = 1
        return page

    def retain(self, page: int) -> int:
        """Add one reference to a live page (prefix sharing / fork)."""
        if page == GARBAGE_PAGE:
            raise ValueError("cannot retain the reserved garbage page")
        if self.ref[page] <= 0:
            raise ValueError(f"retain on non-live page {page} (ref {self.ref[page]})")
        self.ref[page] += 1
        return page

    def release(self, page: int) -> None:
        """Drop one reference. At ref 0 a registered prefix page goes cold
        (content kept, evictable); an anonymous page returns to the free
        list. Releasing an already-free page is a double free and raises."""
        if page == GARBAGE_PAGE:
            raise ValueError("cannot release the reserved garbage page")
        if self.ref[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            if page in self._hash_of:
                self._cold[page] = None  # most-recently-cold at the end
                self._cold.move_to_end(page)
            else:
                self._free.append(page)

    # -- prefix sharing ------------------------------------------------------
    def register_prefix(self, page: int, key: str) -> None:
        """Content-address a live page by its token-prefix hash so later
        admissions with the same prefix can share it."""
        if self.ref[page] <= 0:
            raise ValueError(f"register_prefix on non-live page {page}")
        old = self._by_hash.get(key)
        if old is not None and old != page:
            # same content stored twice (raced admissions): keep the newer
            # mapping; the old page loses its cold-revival path, and if it
            # was already cold it has nothing left to offer — free it
            self._forget_hash(old)
            if self.ref[old] == 0 and old in self._cold:
                del self._cold[old]
                self._free.append(old)
        # re-registering a page under a new key drops the old mapping, or a
        # stale _by_hash entry could later revive a page whose content the
        # new key owns
        self._forget_hash(page)
        self._by_hash[key] = page
        self._hash_of[page] = key

    def lookup_prefix(self, key: str) -> Optional[int]:
        """Find a page holding this prefix. Live hit → retain; cold hit →
        revive with ref 1. Returns the page or None."""
        page = self._by_hash.get(key)
        if page is None:
            return None
        if self.ref[page] > 0:
            self.retain(page)
        else:  # revive from cold
            del self._cold[page]
            self.ref[page] = 1
        self.shared_hits += 1
        return page

    def forget_prefix(self, page: int) -> None:
        """Drop a live page's content-addressing before its bytes were ever
        written (e.g. rolling back a failed admission): on release it then
        returns to the free list instead of cold-retiring, so it can never
        be revived as prefix content it does not actually hold."""
        if self.ref[page] <= 0:
            raise ValueError(f"forget_prefix on non-live page {page}")
        self._forget_hash(page)

    def _forget_hash(self, page: int) -> None:
        key = self._hash_of.pop(page, None)
        if key is not None and self._by_hash.get(key) == page:
            del self._by_hash[key]

    def evict_cold(self, n: int = 1) -> int:
        """Reclaim up to ``n`` least-recently-cold pages to the free list.
        Never touches a page with live references (cold ⇔ ref 0 by
        construction). Returns how many were evicted."""
        done = 0
        while done < n and self._cold:
            page, _ = self._cold.popitem(last=False)  # LRU end
            assert self.ref[page] == 0
            self._forget_hash(page)
            self._free.append(page)
            self.evictions += 1
            done += 1
        return done

    # -- copy-on-write -------------------------------------------------------
    def fork(self, pages: List[int]) -> List[int]:
        """Fork a page-table row: every page gains a reference; both owners
        now see the same physical pages until one writes (COW)."""
        return [self.retain(p) for p in pages]

    def prepare_write(self, page: int) -> Tuple[int, Optional[int]]:
        """COW write barrier: writing a page with ref > 1 (or a registered
        prefix page — shared content must stay immutable for future
        admissions) first materializes a private copy. Returns
        ``(page_to_write, copy_src)`` — ``copy_src`` is None when the page
        was already private, else the page whose bytes the caller must copy
        into the returned fresh page before writing."""
        if self.ref[page] <= 0:
            raise ValueError(f"prepare_write on non-live page {page}")
        if self.ref[page] == 1 and page not in self._hash_of:
            return page, None
        fresh = self.alloc()
        self.release(page)
        self.cow_copies += 1
        return fresh, page

    # -- invariants ----------------------------------------------------------
    def check(self) -> None:
        """Assert the conservation invariants; raises AssertionError with a
        diagnostic on any violation. O(n_pages)."""
        assert self.ref[GARBAGE_PAGE] == 1, "garbage page lost its reservation"
        assert (self.ref >= 0).all(), f"negative refcount: {np.where(self.ref < 0)[0]}"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate page on the free list"
        assert GARBAGE_PAGE not in free_set, "garbage page leaked onto the free list"
        cold_set = set(self._cold)
        assert not (free_set & cold_set), "page both free and cold"
        for p in free_set | cold_set:
            assert self.ref[p] == 0, f"page {p} on free/cold list with ref {self.ref[p]}"
        for p in cold_set:
            assert p in self._hash_of, f"cold page {p} has no prefix hash"
        live = self.n_live
        assert live + self.n_cold + self.n_free == self.capacity, (
            f"page conservation violated: live {live} + cold {self.n_cold} "
            f"+ free {self.n_free} != capacity {self.capacity}"
        )

    def summary(self) -> Dict[str, int]:
        return {
            "capacity": self.capacity,
            "live": self.n_live,
            "cold": self.n_cold,
            "free": self.n_free,
            "shared": int((self.ref[GARBAGE_PAGE + 1:] > 1).sum()),
            "shared_hits": self.shared_hits,
            "cow_copies": self.cow_copies,
            "evictions": self.evictions,
        }
