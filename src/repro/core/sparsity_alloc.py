"""TEAL-style layer-wise sparsity allocation (paper §4.1 comparison setup).

TEAL [24] profiles per-layer activation distributions on a calibration set and
allocates *different* sparsity levels per (layer, projection) so that a global
average sparsity target is met with minimal total error. We implement the
greedy marginal-error variant:

  * error proxy e_l(s): fraction of L1 activation mass removed when layer l
    keeps its top-(1-s) neurons (computed from calibration importances);
  * allocate sparsity in `step` increments, always to the layer with the
    smallest marginal error increase, until mean sparsity hits the target.

Both the top-k baseline and Neuron Chunking consume the resulting per-layer
budgets, exactly as in the paper's comparison setup.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np


@dataclasses.dataclass
class LayerProfile:
    """Calibration profile of one (layer, projection) matrix's input."""

    name: str
    importance: np.ndarray  # (N,) mean |a| over calibration tokens

    def error_at(self, sparsity: float) -> float:
        """Removed L1 mass fraction at a given sparsity (lower = better)."""
        v = np.sort(np.asarray(self.importance, np.float64))  # ascending
        n = v.shape[0]
        k = int(round(sparsity * n))  # k smallest neurons are dropped
        total = v.sum()
        if total <= 0:
            return 0.0
        return float(v[:k].sum() / total)


def allocate_sparsity(
    profiles: Sequence[LayerProfile],
    target_sparsity: float,
    step: float = 0.05,
    max_layer_sparsity: float = 0.95,
) -> Dict[str, float]:
    """Greedy marginal-error allocation. Returns {layer name: sparsity}."""
    if not 0.0 <= target_sparsity < 1.0:
        raise ValueError(f"target_sparsity must be in [0,1), got {target_sparsity}")
    n_layers = len(profiles)
    alloc = np.zeros(n_layers)
    # total increments needed so that mean(alloc) == target
    total_steps = int(round(target_sparsity * n_layers / step))
    cur_err = np.array([p.error_at(0.0) for p in profiles])
    for _ in range(total_steps):
        best, best_delta = -1, np.inf
        for i, p in enumerate(profiles):
            s_new = alloc[i] + step
            if s_new > max_layer_sparsity + 1e-9:
                continue
            delta = p.error_at(s_new) - cur_err[i]
            if delta < best_delta:
                best, best_delta = i, delta
        if best < 0:
            break
        alloc[best] += step
        cur_err[best] += best_delta
    return {p.name: float(round(a, 6)) for p, a in zip(profiles, alloc)}


def budgets_from_sparsity(
    sparsity: Dict[str, float], sizes: Dict[str, int]
) -> Dict[str, int]:
    """Per-layer row budgets R = (1 - s) * N."""
    return {k: int(round((1.0 - s) * sizes[k])) for k, s in sparsity.items()}
