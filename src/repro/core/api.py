"""High-level NeuronChunking facade: one object per offloaded weight matrix.

Typical runtime flow (what serving/sparse_exec.py drives, ~200×/frame in the
paper):

    planner = NeuronChunkingPlanner.build(n_rows, n_cols, device="nano")
    plan    = planner.plan(acts, sparsity=0.4)      # jit-compiled inside
    y       = chunk_gather_matmul(W, acts, plan)    # Pallas kernel or jnp

``plan`` carries the mask, the padded chunk table for the kernel, and the
latency estimates for both our selection and the top-k baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .baselines import topk_mask
from .chunking import ChunkConfig, ChunkSelector
from .importance import importance, retention
from .latency_model import DeviceProfile, LatencyTable
from .reorder import Reordering


@dataclasses.dataclass(frozen=True, eq=False)
class SparsePlan:
    """Output of one selection decision for one weight matrix."""

    mask: jnp.ndarray  # (N,) bool over (possibly reordered) rows
    n_selected: jnp.ndarray  # scalar int32
    est_latency_s: jnp.ndarray  # additive-model latency of this plan
    importance_retention: jnp.ndarray  # Σ selected V / Σ V


@dataclasses.dataclass(frozen=True, eq=False)
class NeuronChunkingPlanner:
    """Per-matrix planner: importance → utility-guided chunk plan."""

    n_rows: int
    n_cols: int
    row_bytes: int
    selector: ChunkSelector
    reordering: Optional[Reordering] = None

    @staticmethod
    def build(
        n_rows: int,
        n_cols: int,
        device: str | DeviceProfile = "nano",
        dtype_bytes: int = 2,
        cfg: Optional[ChunkConfig] = None,
        reordering: Optional[Reordering] = None,
        table: Optional[LatencyTable] = None,
    ) -> "NeuronChunkingPlanner":
        row_bytes = n_cols * dtype_bytes
        dev_name = device if isinstance(device, str) else device.name
        cfg = cfg or ChunkConfig.for_shape(n_rows, n_cols, dev_name)
        selector = ChunkSelector.build(
            n_rows, row_bytes, device=device, cfg=cfg, table=table
        )
        return NeuronChunkingPlanner(
            n_rows=n_rows,
            n_cols=n_cols,
            row_bytes=row_bytes,
            selector=selector,
            reordering=reordering,
        )

    def _importance(self, acts: jnp.ndarray) -> jnp.ndarray:
        v = importance(acts)
        if self.reordering is not None:
            v = self.reordering.apply_to_acts(v)
        return v

    def plan(self, acts: jnp.ndarray, sparsity: float) -> SparsePlan:
        """Utility-guided chunk selection at a given sparsity level."""
        v = self._importance(acts)
        budget = jnp.int32(round((1.0 - float(sparsity)) * self.n_rows))
        mask, n_sel, lat = self.selector.select(v, budget)
        return SparsePlan(
            mask=mask,
            n_selected=n_sel,
            est_latency_s=lat,
            importance_retention=retention(v, mask),
        )

    def plan_topk(self, acts: jnp.ndarray, sparsity: float) -> SparsePlan:
        """Baseline plan: pure magnitude top-k (layout-oblivious)."""
        v = self._importance(acts)
        budget = jnp.int32(round((1.0 - float(sparsity)) * self.n_rows))
        mask = topk_mask(v, budget)
        lat = self.selector.table.mask_latency(mask)
        return SparsePlan(
            mask=mask,
            n_selected=jnp.sum(mask.astype(jnp.int32)),
            est_latency_s=lat,
            importance_retention=retention(v, mask),
        )

    def dense_latency(self) -> float:
        """Full-matrix contiguous load latency (the no-sparsity floor)."""
        return float(self.selector.table.lookup(jnp.asarray(self.n_rows)))
