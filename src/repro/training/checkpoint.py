"""Checkpointing: pytree ⇄ .npz + JSON manifest, with hot-cold reordering
applied at load time (DESIGN.md §8: reordering is a checkpoint transform,
not a file-layout rewrite).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


_NATIVE = {np.float32, np.float64, np.int32, np.int64, np.int8, np.uint8,
            np.uint32, np.uint64, np.float16, np.bool_}


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.type not in _NATIVE:
            # bf16 etc: .npz can't round-trip ml_dtypes — store f32
            # (lossless for bf16); manifest keeps the logical dtype and
            # load casts back to the target leaf dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(path: str, params: Any, step: int = 0, extra: Optional[dict] = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(params)
    np.savez(os.path.join(path, "params.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a params pytree or eval_shape
    thereof). Returns (params, step)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "params.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems
        )
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]


def apply_row_permutations(params: Any, perms: Dict[str, np.ndarray]) -> Any:
    """Apply hot-cold reorderings at load time: perms maps a param path
    substring → row permutation applied to dim 0 of matching leaves."""
    def visit(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for pat, perm in perms.items():
            if pat in key and leaf.ndim >= 2 and leaf.shape[0] == perm.shape[0]:
                return leaf[jnp.asarray(perm)]
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)
