from .checkpoint import apply_row_permutations, load_checkpoint, save_checkpoint
from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state, lr_schedule
from .train_step import Trainer, lm_loss
