"""AdamW + schedules, pure JAX (no optax dependency offline).

Optimizer state is a pytree matching params: {m, v} in float32 ("master"
moments), plus a scalar step. Weight decay is decoupled (AdamW). Global-norm
gradient clipping included. All ops are elementwise → shard exactly like the
params they track (FSDP-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any  # pytree like params, f32
    v: Any  # pytree like params, f32


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in leaves)
    )


def _decay_mask(path: Tuple, leaf) -> bool:
    """No weight decay for norms/biases/1-D params (standard practice)."""
    return leaf.ndim >= 2


def adamw_update(
    cfg: AdamWConfig, params: Any, grads: Any, state: OptState
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        if _decay_mask((), p):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * update
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm,
        "lr": lr,
    }
