"""Training step: chunked cross-entropy LM loss + AdamW, pjit-ready.

The chunked loss scans over sequence chunks, materializing logits for at most
``loss_chunk`` positions at a time — at llama4-scout's 202k vocab this is the
difference between a ~26 GB and a ~0.4 GB peak logits buffer per device
(DESIGN.md §5).

Alignment (``text_offset``): early-fusion VLMs prepend ``n_front`` visual
positions to the residual stream; token t is predicted from hidden position
``n_front + t - 1``. For plain LMs (offset 0) this reduces to the standard
shift-by-one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from ..sharding import shard_act
from .optimizer import AdamWConfig, OptState, adamw_update


def _chunked_softmax_xent(
    hidden: jnp.ndarray,  # (b, s_tok, d) hidden states aligned with targets
    targets: jnp.ndarray,  # (b, s_tok) int32
    head: jnp.ndarray,  # (d, V)
    loss_chunk: int,
) -> jnp.ndarray:
    b, s, d = hidden.shape
    pad = (-s) % loss_chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nc = (s + pad) // loss_chunk
    hid_c = hidden.reshape(b, nc, loss_chunk, d).transpose(1, 0, 2, 3)
    tgt_c = targets.reshape(b, nc, loss_chunk).transpose(1, 0, 2)
    valid_c = (
        (jnp.arange(s + pad) < s).reshape(nc, loss_chunk)[:, None, :]
    )  # (nc,1,chunk)

    def body(total, inp):
        h, t, ok = inp
        logits = (h @ head.astype(h.dtype)).astype(jnp.float32)  # (b,chunk,V)
        logits = shard_act(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ok
        return total + nll.sum(), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hid_c, tgt_c, valid_c))
    return total / (b * s)


def lm_loss(
    model: Model, params: Any, batch: Dict[str, jnp.ndarray], loss_chunk: int = 512
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token CE over the token positions (+ MoE aux)."""
    hidden, aux = model.forward(params, batch)
    tokens = batch["tokens"]
    offset = 0
    if (not model.cfg.is_encdec) and "frontend" in batch:
        offset = batch["frontend"].shape[1]
    if offset > 0:
        # predict tokens[t] from hidden[offset + t - 1], all t
        hid = jax.lax.dynamic_slice_in_dim(hidden, offset - 1, tokens.shape[1], axis=1)
        tgt = tokens
    else:
        hid = hidden[:, :-1]
        tgt = tokens[:, 1:]
    head = params["embed"].T if model.cfg.tie_embeddings else params["head"]
    ce = _chunked_softmax_xent(hid, tgt, head, loss_chunk)
    return ce + aux, {"ce": ce, "moe_aux": aux}


@dataclasses.dataclass(frozen=True, eq=False)
class Trainer:
    """Bundles model + optimizer config into a jit-able train_step."""

    model: Model
    opt: AdamWConfig = AdamWConfig()
    loss_chunk: int = 512

    def init_state(self, key) -> Tuple[Any, OptState]:
        from .optimizer import init_opt_state

        params = self.model.init(key)
        return params, init_opt_state(params)

    def train_step(
        self, params: Any, opt_state: OptState, batch: Dict[str, jnp.ndarray]
    ):
        """(params, opt_state, batch) -> (params, opt_state, metrics)."""

        def loss_fn(p):
            return lm_loss(self.model, p, batch, self.loss_chunk)

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(self.opt, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return params, opt_state, metrics

    def jit_train_step(self, donate: bool = True):
        return jax.jit(self.train_step, donate_argnums=(0, 1) if donate else ())
