"""Byte-level tokenizer (no external vocab files needed offline).

Token ids 0..255 are raw bytes; ids ≥ 256 are specials. Models with larger
vocabularies simply leave the tail unused during CPU-scale training runs.
"""
from __future__ import annotations

from typing import List

BOS = 256
EOS = 257
PAD = 258
N_SPECIAL = 3


class ByteTokenizer:
    vocab_size = 256 + N_SPECIAL

    def encode(self, text: str, add_bos: bool = True, add_eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [BOS] + ids
        if add_eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        body = bytes(i for i in ids if 0 <= int(i) < 256)
        return body.decode("utf-8", errors="replace")
