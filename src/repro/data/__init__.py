from .pipeline import DataConfig, lm_batches
from .tokenizer import ByteTokenizer
