"""Data pipeline: deterministic synthetic corpora + text-file streaming,
packed into fixed-length LM batches (host-side numpy, device-put by caller).

Synthetic corpus is a structured Markov-ish byte stream so small models have
real signal to fit (loss measurably decreases) rather than uniform noise.
For VLM/audio archs the pipeline also emits matching frontend embeddings
(the stubbed modality input) correlated with the token stream so that
sparsification importance statistics are input-dependent, as in the paper.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig
from .tokenizer import ByteTokenizer


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    text_path: Optional[str] = None  # stream a real file if given


def _synthetic_stream(rng: np.random.Generator, vocab: int) -> Iterator[int]:
    """Order-1 Markov chain over a small alphabet embedded in the vocab —
    learnable structure with controllable entropy."""
    k = min(64, vocab)
    # sparse-ish transition matrix with a few high-probability successors
    trans = rng.dirichlet(np.full(k, 0.1), size=k)
    state = 0
    while True:
        state = int(rng.choice(k, p=trans[state]))
        yield state


def _file_stream(path: str, tok: ByteTokenizer) -> Iterator[int]:
    while True:
        with open(path, "rb") as f:
            for chunk in iter(lambda: f.read(1 << 16), b""):
                yield from chunk


def lm_batches(
    cfg: ModelConfig, data: DataConfig
) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {"tokens": (B, S) int32[, "frontend": (B, n, d) f32]} forever."""
    rng = np.random.default_rng(data.seed)
    tok = ByteTokenizer()
    stream = (
        _file_stream(data.text_path, tok)
        if data.text_path
        else _synthetic_stream(rng, cfg.vocab_size)
    )
    n_front = 0
    if cfg.d_frontend:
        n_front = min(cfg.frontend_tokens, data.seq_len // 2)
    s_text = data.seq_len if cfg.is_encdec else data.seq_len - n_front
    if cfg.is_encdec:
        n_front = cfg.frontend_tokens

    while True:
        toks = np.fromiter(
            itertools.islice(stream, data.batch * s_text), dtype=np.int32
        ).reshape(data.batch, s_text)
        out: Dict[str, np.ndarray] = {"tokens": toks % cfg.vocab_size}
        if cfg.d_frontend:
            # frontend embeddings correlated with the first tokens of the batch
            base = rng.normal(0, 1, (data.batch, n_front, cfg.d_frontend))
            drift = (toks[:, :1, None] % 17) / 17.0
            out["frontend"] = (base + drift).astype(np.float32)
        yield out
