"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Design (DESIGN.md §5): static shapes throughout so the layer pjit-shards —
expert dim over 'model' (expert parallelism), token buffers over the data
axes. GShard-style one-hot dispatch einsums would need a (tokens, E, C)
tensor (≈10^12 elements at train_4k scale); the sort-based dispatch below
replaces it with an argsort + two gathers, which GSPMD lowers to
all-to-all/all-gather collectives over the same axes.

Implements both assigned MoE architectures:
  * olmoe-1b-7b:         64 experts, top-8, SwiGLU experts
  * llama4-scout-17b-a16e: 16 experts, top-1 + always-on shared expert
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard_act
from .common import ParamDef, swish


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int  # per-expert hidden
    capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4-style always-on shared expert
    router_aux_weight: float = 0.01
    # "scatter": paper-faithful-baseline dispatch (big scatter into the
    #   expert buffer — GSPMD reshards it expensively; §Perf iteration B).
    # "gather": beyond-paper optimized dispatch — pure gathers with padded
    #   drop rows; the buffer is born with its target sharding.
    dispatch: str = "scatter"

    def capacity(self, n_tokens: int) -> int:
        """Per-expert token capacity, padded to a multiple of 128 when large
        (keeps the capacity dim shardable over up to 32 data-parallel ways)."""
        import math

        c = math.ceil(n_tokens * self.top_k * self.capacity_factor / self.n_experts)
        if c >= 256:
            c = -(-c // 128) * 128
        return max(c, self.top_k)


def moe_param_defs(cfg: MoEConfig, prefix: str = "") -> Dict[str, ParamDef]:
    p = prefix
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    defs = {
        f"{p}router": ParamDef((d, e), ("embed", None), scale=0.02),
        f"{p}we_gate": ParamDef((e, d, f), ("expert", "embed", "ffn")),
        f"{p}we_up": ParamDef((e, d, f), ("expert", "embed", "ffn")),
        f"{p}we_down": ParamDef((e, f, d), ("expert", "ffn", "embed")),
    }
    if cfg.shared_expert:
        defs.update(
            {
                f"{p}ws_gate": ParamDef((d, f), ("embed", "ffn")),
                f"{p}ws_up": ParamDef((d, f), ("embed", "ffn")),
                f"{p}ws_down": ParamDef((f, d), ("ffn", "embed")),
            }
        )
    return defs


def moe_ffn(
    x: jnp.ndarray,  # (b, s, d)
    params: Dict[str, jnp.ndarray],
    cfg: MoEConfig,
    prefix: str = "",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (b,s,d), router aux loss scalar)."""
    if cfg.dispatch == "ep_shard_map":
        from ..sharding import current_rules

        if current_rules() is not None:
            return moe_ffn_ep(x, params, cfg, prefix)
        # no mesh (CPU unit tests): EP degenerates to the gather path
        cfg = dataclasses.replace(cfg, dispatch="gather")
    p = prefix
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ params[f"{p}router"]).astype(jnp.float32)  # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)  # (t, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(axis=0)  # mean router prob per expert
    assign = jnp.zeros((t, cfg.n_experts), jnp.float32).at[
        jnp.arange(t)[:, None], top_e
    ].add(1.0)
    ce = assign.mean(axis=0) / cfg.top_k  # fraction of tokens per expert
    aux = cfg.n_experts * jnp.sum(me * ce) * cfg.router_aux_weight

    cap = cfg.capacity(t)
    flat_e = top_e.reshape(t * cfg.top_k)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), cfg.top_k)
    flat_w = top_w.reshape(t * cfg.top_k)

    order = jnp.argsort(flat_e, stable=True)  # group assignments by expert
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(se, jnp.arange(cfg.n_experts), side="left")
    rank = jnp.arange(t * cfg.top_k, dtype=jnp.int32) - first[se].astype(jnp.int32)
    keep = rank < cap
    buf_pos = jnp.where(keep, se * cap + rank, cfg.n_experts * cap)  # drop→OOB

    if cfg.dispatch == "gather":
        # token id occupying each expert slot (t = empty → zero pad row)
        slot_tok = (
            jnp.full((cfg.n_experts * cap + 1,), t, jnp.int32)
            .at[buf_pos]
            .set(st, mode="drop")[:-1]
        )
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)])
        x_buf = jnp.take(xt_pad, slot_tok, axis=0).reshape(cfg.n_experts, cap, d)
    else:
        # Dispatch: (E*C, d) buffer, dropped tokens fall off the end.
        x_buf = (
            jnp.zeros((cfg.n_experts * cap, d), x.dtype)
            .at[buf_pos]
            .set(xt[st], mode="drop")
            .reshape(cfg.n_experts, cap, d)
        )
    x_buf = shard_act(x_buf, ("expert", "expert_capacity", "act_embed"))

    gate = jnp.einsum("ecd,edf->ecf", x_buf, params[f"{p}we_gate"])
    up = jnp.einsum("ecd,edf->ecf", x_buf, params[f"{p}we_up"])
    h = swish(gate) * up
    h = shard_act(h, ("expert", "expert_capacity", "ffn"))
    y_buf = jnp.einsum("ecf,efd->ecd", h, params[f"{p}we_down"])
    y_buf = shard_act(y_buf, ("expert", "expert_capacity", "act_embed"))
    y_flat = y_buf.reshape(cfg.n_experts * cap, d)

    # Combine: gather each assignment's output, weight, scatter-add.
    # (Per-token K-gather combine was tried in §Perf iteration B2 and
    # REFUTED: each gather's backward emits a full (T, d) f32 all-reduce —
    # 1.1 TB/device/step at olmoe train_4k scale.)
    contrib = jnp.take(
        y_flat, jnp.minimum(buf_pos, cfg.n_experts * cap - 1), axis=0
    )
    contrib = contrib * (sw * keep.astype(jnp.float32))[:, None].astype(
        contrib.dtype
    )
    y = jnp.zeros((t, d), x.dtype).at[st].add(contrib.astype(x.dtype))

    if cfg.shared_expert:
        sh = swish(xt @ params[f"{p}ws_gate"]) * (xt @ params[f"{p}ws_up"])
        y = y + sh @ params[f"{p}ws_down"]

    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# §Perf iteration B3: explicit expert-parallel MoE via shard_map + all_to_all
# ---------------------------------------------------------------------------


def moe_ffn_ep(
    x: jnp.ndarray,  # (b, s, d) — batch over data axes, seq over model (SP)
    params: Dict[str, jnp.ndarray],
    cfg: MoEConfig,
    prefix: str = "",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert parallelism with hand-written dispatch/combine all_to_alls.

    GSPMD's resharding of the capacity buffer costs TBs of all-gather /
    all-reduce per step at olmoe train_4k scale (§Perf B1/B2). Here every
    token moves EXACTLY twice over the model axis (to its experts' shard and
    back): per-device volume = T·K·d·2B/n_devices per direction — the
    intrinsic routing cost. All shapes static; drops happen at send-side
    (per-destination capacity) and recv-side (per-expert capacity), matching
    the capacity-dropping semantics of the baseline.
    """
    import math

    import jax.experimental.shard_map as shmap
    from jax.sharding import PartitionSpec as P

    from ..sharding import current_rules

    p = prefix
    rules = current_rules()
    mesh = rules.mesh
    ep = rules.axis_size("model")
    dp_axis = rules.rules.get("batch")
    dp = rules.axis_size(dp_axis)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    if e % ep or (b % dp) or (s % ep):
        # fall back when the geometry doesn't divide (tiny smoke shapes)
        return moe_ffn(x, params, dataclasses.replace(cfg, dispatch="gather"), prefix)
    e_loc = e // ep
    t_dev = t // (dp * ep)
    c_send = max(k, math.ceil(t_dev * k * cfg.capacity_factor / ep))
    c_recv = max(k, math.ceil(ep * c_send * cfg.capacity_factor / e_loc))

    dp_tuple = dp_axis if isinstance(dp_axis, tuple) else ((dp_axis,) if dp_axis else ())
    tok_spec = P(dp_tuple + ("model",), None)
    rep_spec = P(None, None)
    ew_spec = P("model", None, None)

    def local(xt, router_w, we_gate, we_up, we_down, *shared):
        tl = xt.shape[0]  # t_dev
        logits = (xt @ router_w).astype(jnp.float32)  # (tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        # aux loss from global statistics (psum over all shards)
        me = jax.lax.pmean(probs.mean(axis=0), axis_name="model")
        me = jax.lax.pmean(me, axis_name=dp_tuple) if dp_tuple else me
        assign = jnp.zeros((tl, e), jnp.float32).at[
            jnp.arange(tl)[:, None], top_e
        ].add(1.0)
        ce = assign.mean(axis=0) / k
        ce = jax.lax.pmean(ce, axis_name="model")
        ce = jax.lax.pmean(ce, axis_name=dp_tuple) if dp_tuple else ce
        aux = e * jnp.sum(me * ce) * cfg.router_aux_weight

        # ---- send side: group assignments by destination expert-shard ----
        flat_e = top_e.reshape(tl * k)
        flat_tok = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)
        dest = flat_e // e_loc  # (tl·k,) destination shard
        order = jnp.argsort(dest, stable=True)
        sd, stok, sexp = dest[order], flat_tok[order], flat_e[order]
        first = jnp.searchsorted(sd, jnp.arange(ep), side="left")
        rank = jnp.arange(tl * k, dtype=jnp.int32) - first[sd].astype(jnp.int32)
        keep = rank < c_send
        slot = jnp.where(keep, sd * c_send + rank, ep * c_send)  # OOB → drop

        # token rows + expert-local ids packed per destination slot
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
        slot_tok = (
            jnp.full((ep * c_send + 1,), tl, jnp.int32).at[slot].set(stok, mode="drop")[:-1]
        )
        slot_eid = (
            jnp.full((ep * c_send + 1,), -1, jnp.int32)
            .at[slot]
            .set((sexp % e_loc).astype(jnp.int32), mode="drop")[:-1]
        )
        send_x = jnp.take(xt_pad, slot_tok, axis=0).reshape(ep, c_send, d)
        send_eid = slot_eid.reshape(ep, c_send)

        # assignment → (dest shard, slot) lookup for the combine gather
        a_slot = (
            jnp.full((tl * k,), ep * c_send, jnp.int32)
            .at[order]
            .set(jnp.where(keep, slot, ep * c_send))
            .reshape(tl, k)
        )

        # ---- all_to_all over the model axis ----
        recv_x = jax.lax.all_to_all(send_x, "model", split_axis=0, concat_axis=0,
                                    tiled=True)
        recv_eid = jax.lax.all_to_all(send_eid, "model", split_axis=0,
                                      concat_axis=0, tiled=True)
        recv_x = recv_x.reshape(ep * c_send, d)
        recv_eid = recv_eid.reshape(ep * c_send)

        # ---- recv side: group by local expert, capacity-pad, compute ----
        eid_sortable = jnp.where(recv_eid < 0, e_loc, recv_eid)  # pads last
        r_order = jnp.argsort(eid_sortable, stable=True)
        r_eid = eid_sortable[r_order]
        r_first = jnp.searchsorted(r_eid, jnp.arange(e_loc), side="left")
        r_rank = jnp.arange(ep * c_send, dtype=jnp.int32) - r_first[
            jnp.minimum(r_eid, e_loc - 1)
        ].astype(jnp.int32)
        r_keep = (r_eid < e_loc) & (r_rank < c_recv)
        r_slot = jnp.where(r_keep, r_eid * c_recv + r_rank, e_loc * c_recv)

        buf_src = (
            jnp.full((e_loc * c_recv + 1,), ep * c_send, jnp.int32)
            .at[r_slot]
            .set(r_order.astype(jnp.int32), mode="drop")[:-1]
        )
        recv_pad = jnp.concatenate([recv_x, jnp.zeros((1, d), recv_x.dtype)])
        x_buf = jnp.take(recv_pad, buf_src, axis=0).reshape(e_loc, c_recv, d)

        gate = jnp.einsum("ecd,edf->ecf", x_buf, we_gate)
        up = jnp.einsum("ecd,edf->ecf", x_buf, we_up)
        y_buf = jnp.einsum("ecf,efd->ecd", swish(gate) * up, we_down)
        y_buf = y_buf.reshape(e_loc * c_recv, d)

        # ---- un-sort back to received layout, all_to_all home ----
        # received row i → its expert slot (or drop): invert buf_src mapping
        row_slot = (
            jnp.full((ep * c_send + 1,), e_loc * c_recv, jnp.int32)
            .at[buf_src]
            .set(jnp.arange(e_loc * c_recv, dtype=jnp.int32), mode="drop")[:-1]
        )
        y_pad = jnp.concatenate([y_buf, jnp.zeros((1, d), y_buf.dtype)])
        ret = jnp.take(y_pad, row_slot, axis=0).reshape(ep, c_send, d)
        back = jax.lax.all_to_all(ret, "model", split_axis=0, concat_axis=0,
                                  tiled=True).reshape(ep * c_send, d)

        # ---- combine: per-assignment gather + weighted sum over K ----
        back_pad = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)])
        y = jnp.zeros((tl, d), xt.dtype)
        for kk in range(k):
            yk = jnp.take(back_pad, a_slot[:, kk], axis=0)
            y = y + (yk * top_w[:, kk : kk + 1].astype(yk.dtype)).astype(xt.dtype)
        return y, aux

    xt = x.reshape(t, d)
    y, aux = shmap.shard_map(
        local,
        mesh=mesh,
        in_specs=(tok_spec, rep_spec, ew_spec, ew_spec, ew_spec),
        out_specs=(tok_spec, P()),
        check_rep=False,
    )(xt, params[f"{p}router"], params[f"{p}we_gate"], params[f"{p}we_up"],
      params[f"{p}we_down"])

    y = y.reshape(b, s, d)
    if cfg.shared_expert:
        sh = swish(xt @ params[f"{p}ws_gate"]) * (xt @ params[f"{p}ws_up"])
        y = y + (sh @ params[f"{p}ws_down"]).reshape(b, s, d)
    return y, aux
