"""Input construction for every (architecture × input shape) pair.

``input_specs``: ShapeDtypeStruct stand-ins (no allocation) — the dry-run
path. ``make_dummy_batch``: concrete random arrays — tests/examples.

Geometry rules (DESIGN.md §4):
  * text LMs: tokens (B, S).
  * early-fusion VLM/moe-with-frontend: tokens (B, S - frontend_tokens) +
    frontend (B, frontend_tokens, d_frontend); total residual length = S.
  * audio enc-dec: tokens (B, S) decoder tokens + frontend
    (B, frontend_tokens, d_frontend) encoder frames (conv stub output).
  * decode shapes: token (B, 1) + KV/state cache of logical length S.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import InputShape, ModelConfig

TOKEN_DT = jnp.int32
FRONT_DT = jnp.bfloat16


def _geometry(cfg: ModelConfig, shape: InputShape) -> Dict[str, Tuple[int, ...]]:
    b, s = shape.global_batch, shape.seq_len
    if shape.is_decode:
        out: Dict[str, Tuple[int, ...]] = {"tokens": (b, 1)}
        return out
    if cfg.is_encdec:
        return {"tokens": (b, s), "frontend": (b, cfg.frontend_tokens, cfg.d_frontend)}
    if cfg.d_frontend:
        # early fusion: vision prefix + text; clamp so tiny smoke shapes work
        n_front = min(cfg.frontend_tokens, s // 2)
        s_text = s - n_front
        return {"tokens": (b, s_text), "frontend": (b, n_front, cfg.d_frontend)}
    return {"tokens": (b, s)}


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    geo = _geometry(cfg, shape)
    out = {}
    for name, shp in geo.items():
        dt = TOKEN_DT if name == "tokens" else FRONT_DT
        out[name] = jax.ShapeDtypeStruct(shp, dt)
    return out


def make_dummy_batch(cfg: ModelConfig, shape: InputShape, seed: int = 0) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    geo = _geometry(cfg, shape)
    out: Dict[str, jnp.ndarray] = {}
    for name, shp in geo.items():
        if name == "tokens":
            out[name] = jnp.asarray(rng.integers(0, cfg.vocab_size, shp), TOKEN_DT)
        else:
            out[name] = jnp.asarray(rng.normal(0, 1, shp), FRONT_DT)
    return out
