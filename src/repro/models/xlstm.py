"""xLSTM blocks (sLSTM + mLSTM) — the xlstm-125m substrate.

mLSTM: matrix-memory cell C ∈ R^{dh×dh} per head with exponential gating and
max-stabilizer state; pre-up-projection (factor 2) block, qkv from the inner
stream, gated output, down-projection.

sLSTM: scalar-memory cell with hidden-state recurrence feeding the gates,
followed by a GeLU feed-forward (factor 4/3) as in the xLSTM paper's block.

Sequence processing is a chunked ``lax.scan`` (chunk boundaries checkpointed)
so training at 4k tokens does not store every step's matrix memory. Decode is
the O(1) recurrent update (→ long_500k capable).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from ..sharding import shard_act
from .common import ParamDef, rms_norm, swish


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    m_proj_factor: float = 2.0
    s_ff_factor: float = 1.3334
    chunk: int = 64

    @property
    def d_inner(self) -> int:
        return int(self.m_proj_factor * self.d_model)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def s_head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff_s(self) -> int:
        return int(self.s_ff_factor * self.d_model)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_param_defs(cfg: XLSTMConfig, prefix: str = "") -> Dict[str, ParamDef]:
    p = prefix
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        f"{p}w_up": ParamDef((d, 2 * di), ("embed", "ffn")),
        f"{p}w_q": ParamDef((di, di), ("ffn", "heads")),
        f"{p}w_k": ParamDef((di, di), ("ffn", "heads")),
        f"{p}w_v": ParamDef((di, di), ("ffn", "heads")),
        f"{p}w_ig": ParamDef((di, h), ("ffn", None), scale=0.02),
        f"{p}b_ig": ParamDef((h,), (None,), init="zeros"),
        f"{p}w_fg": ParamDef((di, h), ("ffn", None), scale=0.02),
        f"{p}b_fg": ParamDef((h,), (None,), init="ones"),
        f"{p}norm_w": ParamDef((di,), ("ffn",), init="ones"),
        f"{p}w_down": ParamDef((di, d), ("ffn", "embed")),
    }


def _mlstm_scan(q, k, v, log_i, log_f, state, chunk: int):
    """Recurrent mLSTM over (b, s, h, dh) with chunked remat.

    state: (c (b,h,dh,dh), n (b,h,dh), m (b,h)). Returns (y, state)."""
    b, s, h, dh = q.shape
    pad = (-s) % chunk
    if pad:
        def zf(x):
            return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))

        q, k, v, log_i, log_f = map(zf, (q, k, v, log_i, log_f))
    nc = (s + pad) // chunk
    valid = jnp.arange(s + pad) < s  # padded steps must not touch the state

    def step(state, inp):
        c0, n0, m0 = state
        qt, kt, vt, li, lf, ok = inp  # (b,h,dh) ×3, (b,h) ×2, ()
        m_new = jnp.maximum(lf + m0, li)
        i_p = jnp.exp(li - m_new)[..., None]  # (b,h,1)
        f_p = jnp.exp(lf + m0 - m_new)[..., None]
        c = f_p[..., None] * c0 + i_p[..., None] * jnp.einsum("bhv,bhk->bhvk", vt, kt)
        n = f_p * n0 + i_p * kt
        num = jnp.einsum("bhvk,bhk->bhv", c, qt)  # (b,h,dh)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        state = (
            jnp.where(ok, c, c0),
            jnp.where(ok, n, n0),
            jnp.where(ok, m_new, m0),
        )
        return state, num / den

    @jax.checkpoint
    def chunk_step(state, inp):
        return jax.lax.scan(step, state, inp)

    def to_chunks(x):  # (b, s, ...) -> (nc, chunk, b, ...)
        x = jnp.moveaxis(x, 1, 0).reshape(nc, chunk, *x.shape[:1], *x.shape[2:])
        return x

    inputs = tuple(map(to_chunks, (q, k, v, log_i, log_f))) + (
        valid.reshape(nc, chunk),
    )
    state, y = jax.lax.scan(chunk_step, state, inputs)
    y = jnp.moveaxis(y.reshape(nc * chunk, b, h, dh), 0, 1)[:, :s]
    return y, state


def mlstm_state_init(cfg: XLSTMConfig, batch: int):
    h, dh = cfg.n_heads, cfg.head_dim
    return (
        jnp.zeros((batch, h, dh, dh), jnp.float32),
        jnp.zeros((batch, h, dh), jnp.float32),
        jnp.full((batch, h), -1e30, jnp.float32),
    )


def mlstm_forward(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    cfg: XLSTMConfig,
    state=None,
    prefix: str = "",
):
    """(b, s, d) -> (b, s, d); returns (out, new_state)."""
    p = prefix
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    up = x @ params[f"{p}w_up"]
    xm, z = jnp.split(up, 2, axis=-1)  # (b,s,di) each
    xm = shard_act(xm, ("batch", None, "ffn"))

    qf = (xm @ params[f"{p}w_q"]).reshape(b, s, h, dh).astype(jnp.float32)
    kf = (xm @ params[f"{p}w_k"]).reshape(b, s, h, dh).astype(jnp.float32) / (dh**0.5)
    vf = (xm @ params[f"{p}w_v"]).reshape(b, s, h, dh).astype(jnp.float32)
    log_i = (xm @ params[f"{p}w_ig"] + params[f"{p}b_ig"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (xm @ params[f"{p}w_fg"] + params[f"{p}b_fg"]).astype(jnp.float32)
    )

    if state is None:
        state = mlstm_state_init(cfg, b)
    y, state = _mlstm_scan(qf, kf, vf, log_i, log_f, state, cfg.chunk)
    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y, params[f"{p}norm_w"]) * swish(z)
    return y @ params[f"{p}w_down"], state


def mlstm_decode_step(x, params, cfg, state, prefix: str = ""):
    out, state = mlstm_forward(x, params, cfg, state=state, prefix=prefix)
    return out, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_param_defs(cfg: XLSTMConfig, prefix: str = "") -> Dict[str, ParamDef]:
    p = prefix
    d, h = cfg.d_model, cfg.n_heads
    return {
        f"{p}w_gates": ParamDef((d, 4 * d), ("embed", "ffn")),  # z,i,f,o pre-acts
        f"{p}r_gates": ParamDef((h, cfg.s_head_dim, 4 * cfg.s_head_dim), ("heads", None, None), scale=0.02),
        f"{p}b_gates": ParamDef((4 * d,), ("ffn",), init="zeros"),
        f"{p}norm_w": ParamDef((d,), ("embed",), init="ones"),
        f"{p}w_ff_up": ParamDef((d, cfg.d_ff_s), ("embed", "ffn")),
        f"{p}w_ff_down": ParamDef((cfg.d_ff_s, d), ("ffn", "embed")),
    }


def slstm_state_init(cfg: XLSTMConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, jnp.full((batch, d), -1e30, jnp.float32), z)  # c, n, m, h


def slstm_forward(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    cfg: XLSTMConfig,
    state=None,
    prefix: str = "",
):
    """sLSTM with head-wise recurrent gate mixing + FF. (b,s,d)->(b,s,d)."""
    p = prefix
    b, s, d = x.shape
    h, sdh = cfg.n_heads, cfg.s_head_dim
    pre = x @ params[f"{p}w_gates"] + params[f"{p}b_gates"]  # (b,s,4d)
    pre = pre.astype(jnp.float32)
    if state is None:
        state = slstm_state_init(cfg, b)

    r_w = params[f"{p}r_gates"].astype(jnp.float32)  # (h, sdh, 4*sdh)

    def step(carry, inp):
        pre_t, ok = inp
        c, n, m, h_prev = carry  # (b,d) each
        rec = jnp.einsum("bhk,hkj->bhj", h_prev.reshape(b, h, sdh), r_w)
        # rec: (b, h, 4*sdh) → interleave back to (b, 4d) gate layout per head
        rec = rec.reshape(b, h, 4, sdh).transpose(0, 2, 1, 3).reshape(b, 4 * d)
        g = pre_t + rec
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        carry = tuple(
            jnp.where(ok, new, old)
            for new, old in zip((c_new, n_new, m_new, h_new), carry)
        )
        return carry, h_new

    @jax.checkpoint
    def chunk_step(carry, inp):
        return jax.lax.scan(step, carry, inp)

    chunk = cfg.chunk
    pad = (-s) % chunk
    nc = (s + pad) // chunk
    pre_t = jnp.moveaxis(jnp.pad(pre, ((0, 0), (0, pad), (0, 0))), 1, 0)
    pre_c = pre_t.reshape(nc, chunk, b, 4 * d)
    valid = (jnp.arange(s + pad) < s).reshape(nc, chunk)
    state, ys = jax.lax.scan(chunk_step, state, (pre_c, valid))
    y = jnp.moveaxis(ys.reshape(s + pad, b, d), 0, 1)[:, :s].astype(x.dtype)

    y = rms_norm(y, params[f"{p}norm_w"])
    ff = jax.nn.gelu(y @ params[f"{p}w_ff_up"]) @ params[f"{p}w_ff_down"]
    return ff, state


def slstm_decode_step(x, params, cfg, state, prefix: str = ""):
    out, state = slstm_forward(x, params, cfg, state=state, prefix=prefix)
    return out, state
