"""Gated MLP (SwiGLU / GeLU) with optional neuron-sparse execution.

The sparse path implements the paper's masked matmul semantics
(App. B.2: ỹ = Σ M_i a_i W_i): a row mask over a matrix's *input* dimension
zeroes the corresponding activations. On flash/TPU hardware the mask is
realized as chunked reads (serving/sparse_exec.py + kernels/); here the dense
masked form is the mathematical reference the kernels are tested against.

Masks per the paper's Appendix A convention:
  * ``hidden_mask``: over d_model — shared by gate and up (they share input).
  * ``ffn_mask``: over d_ff — the down projection's own input.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..sharding import shard_act
from .common import ParamDef, swish


def mlp_param_defs(d_model: int, d_ff: int, prefix: str = "") -> Dict[str, ParamDef]:
    p = prefix
    return {
        f"{p}w_gate": ParamDef((d_model, d_ff), ("embed", "ffn")),
        f"{p}w_up": ParamDef((d_model, d_ff), ("embed", "ffn")),
        f"{p}w_down": ParamDef((d_ff, d_model), ("ffn", "embed")),
    }


def swiglu_mlp(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    prefix: str = "",
    hidden_mask: Optional[jnp.ndarray] = None,
    ffn_mask: Optional[jnp.ndarray] = None,
    activation: str = "silu",
) -> jnp.ndarray:
    p = prefix
    if hidden_mask is not None:
        x = x * hidden_mask.astype(x.dtype)
    gate = x @ params[f"{p}w_gate"]
    up = x @ params[f"{p}w_up"]
    act = swish(gate) if activation == "silu" else jax.nn.gelu(gate)
    h = act * up
    h = shard_act(h, ("batch", None, "ffn"))
    if ffn_mask is not None:
        h = h * ffn_mask.astype(h.dtype)
    return h @ params[f"{p}w_down"]


def gelu_mlp_param_defs(d_model: int, d_ff: int, prefix: str = "") -> Dict[str, ParamDef]:
    """Non-gated 2-matrix MLP (whisper/starcoder-style c_fc/c_proj)."""
    p = prefix
    return {
        f"{p}w_fc": ParamDef((d_model, d_ff), ("embed", "ffn")),
        f"{p}b_fc": ParamDef((d_ff,), ("ffn",), init="zeros"),
        f"{p}w_proj": ParamDef((d_ff, d_model), ("ffn", "embed")),
        f"{p}b_proj": ParamDef((d_model,), ("embed",), init="zeros"),
    }


def gelu_mlp(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    prefix: str = "",
    hidden_mask: Optional[jnp.ndarray] = None,
    ffn_mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    p = prefix
    if hidden_mask is not None:
        x = x * hidden_mask.astype(x.dtype)
    h = jax.nn.gelu(x @ params[f"{p}w_fc"] + params[f"{p}b_fc"])
    h = shard_act(h, ("batch", None, "ffn"))
    if ffn_mask is not None:
        h = h * ffn_mask.astype(h.dtype)
    return h @ params[f"{p}w_proj"] + params[f"{p}b_proj"]
