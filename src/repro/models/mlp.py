"""Gated MLP (SwiGLU / GeLU) with optional neuron-sparse execution.

The sparse path implements the paper's masked matmul semantics
(App. B.2: ỹ = Σ M_i a_i W_i): a row mask over a matrix's *input* dimension
zeroes the corresponding activations. On flash/TPU hardware the mask is
realized as chunked reads (serving/sparse_exec.py + kernels/); here the dense
masked form is the mathematical reference the kernels are tested against.

Masks per the paper's Appendix A convention:
  * ``hidden_mask``: over d_model — shared by gate and up (they share input).
  * ``ffn_mask``: over d_ff — the down projection's own input.

The PLANNED decode path (chunk-plan carry in ``transformer.block_decode``)
routes through ``swiglu_mlp_planned`` / ``gelu_mlp_planned`` instead: the
same masked semantics realized by the decode execution backend
(``kernels/backend.ExecutionBackend``) — either the kernel schedule twin in
pure jnp (``reference``) or the fused/DMA Pallas gather kernels consuming
the plan's chunk tables directly (``kernel``); the two are bitwise
identical by construction.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.quantize import (
    DECODE_COPY_SUFFIX,
    QUANT_SUFFIX_CHECKSUM,
    QUANT_SUFFIX_PAYLOAD,
    QUANT_SUFFIX_SCALE,
)
from ..sharding import shard_act
from .common import ParamDef, swish


def _stored(params, name: str, quantized: bool):
    """One matrix in the planned path's storage form: (int8 payload,
    per-block scales) at wbits=8, (fp weight, None) otherwise."""
    if quantized:
        return params[name + QUANT_SUFFIX_PAYLOAD], params[name + QUANT_SUFFIX_SCALE]
    if name + DECODE_COPY_SUFFIX in params:
        # sharded serving at wbits=16: stream the model-axis-sharded decode
        # copy; the replicated fp original stays for prefill/frame append
        return params[name + DECODE_COPY_SUFFIX], None
    return params[name], None


def _stored_checksum(params, name: str):
    """The matrix's per-block integrity-checksum leaf (engine-emitted when
    corruption injection is on), or None — a static presence check, so the
    checksum DMA lane compiles in only for integrity-enabled engines."""
    return params.get(name + QUANT_SUFFIX_CHECKSUM)


def mlp_param_defs(d_model: int, d_ff: int, prefix: str = "") -> Dict[str, ParamDef]:
    p = prefix
    return {
        f"{p}w_gate": ParamDef((d_model, d_ff), ("embed", "ffn")),
        f"{p}w_up": ParamDef((d_model, d_ff), ("embed", "ffn")),
        f"{p}w_down": ParamDef((d_ff, d_model), ("ffn", "embed")),
    }


def swiglu_mlp(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    prefix: str = "",
    hidden_mask: Optional[jnp.ndarray] = None,
    ffn_mask: Optional[jnp.ndarray] = None,
    activation: str = "silu",
) -> jnp.ndarray:
    p = prefix
    if hidden_mask is not None:
        x = x * hidden_mask.astype(x.dtype)
    gate = x @ params[f"{p}w_gate"]
    up = x @ params[f"{p}w_up"]
    act = swish(gate) if activation == "silu" else jax.nn.gelu(gate)
    h = act * up
    h = shard_act(h, ("batch", None, "ffn"))
    if ffn_mask is not None:
        h = h * ffn_mask.astype(h.dtype)
    return h @ params[f"{p}w_down"]


def swiglu_mlp_planned(
    x: jnp.ndarray,  # (b, s, d) — decode: s == 1
    params: Dict[str, jnp.ndarray],
    backend,  # kernels.backend.ExecutionBackend
    hidden_mask: jnp.ndarray,  # (d,) exact hidden_mlp-site mask
    ffn_mask: jnp.ndarray,  # (d_ff,) exact ffn-site mask
    starts: jnp.ndarray,  # (2, K) kernel plan lanes (hidden_mlp, ffn)
    sizes: jnp.ndarray,  # (2, K)
    prefix: str = "",
    quantized: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The planned-decode sparse SwiGLU: one execution-backend dispatch for
    gate/up/down off the decode plan's chunk-table lanes. Returns
    (y (b, s, d) in x.dtype, h (b·s, d_ff) f32 — the UNMASKED SwiGLU
    intermediate whose |·| the caller records as the next refresh's
    ffn-site importance). ``quantized`` streams the int8 payload + scale
    leaves (wbits=8 storage, kernels/quantize.py) instead of the fp
    weights."""
    p = prefix
    b, s, d = x.shape
    wg, sg = _stored(params, f"{p}w_gate", quantized)
    wu, su = _stored(params, f"{p}w_up", quantized)
    wd, sd = _stored(params, f"{p}w_down", quantized)
    scales = (sg, su, sd) if quantized else None
    cks = tuple(_stored_checksum(params, f"{p}{nm}")
                for nm in ("w_gate", "w_up", "w_down"))
    y, h = backend.swiglu_mlp(
        wg, wu, wd,
        x.reshape(b * s, d), hidden_mask, ffn_mask, starts, sizes, scales,
        cks if all(c is not None for c in cks) else None,
    )
    return y.astype(x.dtype).reshape(b, s, -1), h


def gelu_mlp_planned(
    x: jnp.ndarray,  # (b, s, d)
    params: Dict[str, jnp.ndarray],
    backend,  # kernels.backend.ExecutionBackend
    hidden_mask: jnp.ndarray,  # (d,)
    ffn_mask: jnp.ndarray,  # (d_ff,)
    hidden_table: Tuple[jnp.ndarray, jnp.ndarray],  # (starts, sizes) (K,)
    ffn_table: Tuple[jnp.ndarray, jnp.ndarray],
    prefix: str = "",
    quantized: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Planned-decode sparse non-gated MLP (whisper/starcoder c_fc/c_proj):
    two single-site backend projections with the gelu in f32 between them
    (identical on both backends, so parity rests on ``project`` alone).
    Returns (y (b, s, d) in x.dtype, mid (b·s, d_ff) f32 pre-ffn-mask).
    ``quantized`` streams the int8 payload + scale leaves (wbits=8)."""
    p = prefix
    b, s, d = x.shape
    w_fc, s_fc = _stored(params, f"{p}w_fc", quantized)
    w_proj, s_proj = _stored(params, f"{p}w_proj", quantized)
    mid = backend.project(
        w_fc, x.reshape(b * s, d), hidden_mask, *hidden_table, s_fc,
        _stored_checksum(params, f"{p}w_fc"),
    ) + params[f"{p}b_fc"].astype(jnp.float32)
    mid = jax.nn.gelu(mid)
    y = backend.project(
        w_proj, mid, ffn_mask, *ffn_table, s_proj,
        _stored_checksum(params, f"{p}w_proj"),
    ) + params[f"{p}b_proj"].astype(jnp.float32)
    return y.astype(x.dtype).reshape(b, s, -1), mid


def gelu_mlp_param_defs(d_model: int, d_ff: int, prefix: str = "") -> Dict[str, ParamDef]:
    """Non-gated 2-matrix MLP (whisper/starcoder-style c_fc/c_proj)."""
    p = prefix
    return {
        f"{p}w_fc": ParamDef((d_model, d_ff), ("embed", "ffn")),
        f"{p}b_fc": ParamDef((d_ff,), ("ffn",), init="zeros"),
        f"{p}w_proj": ParamDef((d_ff, d_model), ("ffn", "embed")),
        f"{p}b_proj": ParamDef((d_model,), ("embed",), init="zeros"),
    }


def gelu_mlp(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    prefix: str = "",
    hidden_mask: Optional[jnp.ndarray] = None,
    ffn_mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    p = prefix
    if hidden_mask is not None:
        x = x * hidden_mask.astype(x.dtype)
    h = jax.nn.gelu(x @ params[f"{p}w_fc"] + params[f"{p}b_fc"])
    h = shard_act(h, ("batch", None, "ffn"))
    if ffn_mask is not None:
        h = h * ffn_mask.astype(h.dtype)
    return h @ params[f"{p}w_proj"] + params[f"{p}b_proj"]
