"""Unified model builder: ``build_model(config)`` → a Model for any of the
six assigned families (dense / moe / vlm / audio / ssm / hybrid).

Interface (all pure functions over param pytrees, pjit-ready):

    model.init(key)                         -> params
    model.param_axes()                      -> logical-axes pytree (matches params)
    model.forward(params, batch)            -> (hidden, moe_aux)       # full seq
    model.logits(params, hidden)            -> (b, s, vocab)
    model.prefill(params, batch, max_seq)   -> (last_logits, cache)
    model.decode_step(params, token, cache, sparse_ctx=None)
                                            -> (logits, cache, io_latency)

Batch dict: {"tokens": (b, s_tok) int32, "frontend": (b, n, d_frontend)?}.
VLM/early-fusion archs prepend projected frontend embeddings to the token
embeddings; whisper routes "frontend" through its encoder. ``text_offset``
tells the trainer where token-aligned hidden states start.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard_act
from .attention import (
    CacheSpec,
    init_kv_cache,
    init_paged_kv_cache,
    multi_head_attention,
)
from .common import ParamDef, init_params, sinusoidal_positions, stack_layer_defs
from .mlp import gelu_mlp
from .ssm import (
    Mamba2Config,
    mamba2_decode_step,
    mamba2_forward,
    mamba2_param_defs,
    mamba2_state_init,
)
from .transformer import (
    apply_norm,
    block_decode,
    block_forward,
    block_param_defs,
    stack_decode,
    stack_forward,
    stack_prefill,
)
from .xlstm import (
    XLSTMConfig,
    mlstm_forward,
    mlstm_param_defs,
    mlstm_state_init,
    slstm_forward,
    slstm_param_defs,
    slstm_state_init,
)

COMPUTE_DTYPE = jnp.bfloat16

# Sliding windows engage only for ultra-long decode (long_500k); 32k shapes
# exercise the full cache (DESIGN.md §4).
WINDOW_ENGAGE_THRESHOLD = 65_536


def effective_window(cfg: ModelConfig, seq_len: int) -> Optional[int]:
    if cfg.sliding_window and seq_len > WINDOW_ENGAGE_THRESHOLD:
        return cfg.sliding_window
    return None


def _embed_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    defs = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "final_norm_w": ParamDef((cfg.d_model,), ("embed",), init="ones"),
    }
    if cfg.norm == "layernorm":
        defs["final_norm_b"] = ParamDef((cfg.d_model,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), scale=0.02)
    if cfg.d_frontend and not cfg.is_encdec:
        defs["projector"] = ParamDef((cfg.d_frontend, cfg.d_model), (None, "embed"))
    return defs


def _final_norm(x, params, cfg):
    from .common import layer_norm, rms_norm

    if cfg.norm == "layernorm":
        return layer_norm(x, params["final_norm_w"], params["final_norm_b"])
    return rms_norm(x, params["final_norm_w"])


class Model:
    """Family-dispatching functional model wrapper."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.family = cfg.arch_type
        if self.family in ("dense", "moe", "vlm"):
            self._impl = _DecoderLM(cfg)
        elif self.family == "hybrid":
            self._impl = _Zamba(cfg)
        elif self.family == "ssm":
            self._impl = _XLSTM(cfg)
        elif self.family == "audio":
            self._impl = _Whisper(cfg)
        else:
            raise ValueError(f"unknown arch_type {cfg.arch_type}")

    # delegate
    def init(self, key):
        return self._impl.init(key)

    def param_axes(self):
        return self._impl.param_axes()

    def forward(self, params, batch, remat: Optional[bool] = None):
        return self._impl.forward(params, batch, remat=self.cfg.remat if remat is None else remat)

    def logits(self, params, hidden):
        head = params["embed"].T if self.cfg.tie_embeddings else params["head"]
        out = hidden @ head.astype(hidden.dtype)
        return shard_act(out, ("batch", None, "vocab"))

    @property
    def text_offset(self) -> int:
        return self._impl.text_offset

    def prefill(self, params, batch, max_seq: int):
        return self._impl.prefill(params, batch, max_seq)

    def init_cache(self, batch_size: int, max_seq: int):
        return self._impl.init_cache(batch_size, max_seq)

    @property
    def supports_paged_kv(self) -> bool:
        return hasattr(self._impl, "init_paged_cache")

    def init_paged_cache(self, batch_size: int, max_seq: int, page_tokens: int, n_pages: int):
        """Paged twin of ``init_cache`` (decoder families only): per-layer
        page pools + per-slot page table (see attention.init_paged_kv_cache)."""
        if not self.supports_paged_kv:
            raise NotImplementedError(f"paged KV not supported for {self.family}")
        return self._impl.init_paged_cache(batch_size, max_seq, page_tokens, n_pages)

    def paged_cache_axes(self):
        if not self.supports_paged_kv:
            raise NotImplementedError(f"paged KV not supported for {self.family}")
        return self._impl.paged_cache_axes()

    def decode_step(self, params, token, cache, sparse_ctx=None):
        return self._impl.decode_step(params, token, cache, sparse_ctx)

    def decode_step_planned(
        self, params, token, cache, sparse_ctx=None, plan=None, refresh=None
    ):
        """decode_step threading chunk-plan reuse state through the layer
        stack (dense/moe/vlm). Returns (logits, cache, io, plan) with ``io``
        a PER-LAYER (n_layers,) I/O-estimate vector — the serve engine feeds
        it to the overlapped prefetch timeline. Families without
        sparsification sites run a plain decode_step, spread its scalar io
        uniformly over layers, and pass ``plan`` through unchanged."""
        if hasattr(self._impl, "decode_step_planned"):
            return self._impl.decode_step_planned(
                params, token, cache, sparse_ctx, plan, refresh
            )
        logits, cache, io = self._impl.decode_step(params, token, cache, sparse_ctx)
        n_layers = self.cfg.n_layers
        io_vec = jnp.broadcast_to(io / n_layers, (n_layers,)).astype(jnp.float32)
        return logits, cache, io_vec, plan

    def append_frame(self, params, frame_embeds, cache, sparse_ctx=None):
        """VLM frame-append stage (paper §2.1): project one frame's patch
        embeddings and extend every layer's KV cache. dense/moe/vlm only."""
        if not hasattr(self._impl, "append_embeds"):
            raise NotImplementedError(f"append_frame not supported for {self.family}")
        return self._impl.append_embeds(params, frame_embeds, cache, sparse_ctx)

    def cache_axes(self):
        """Logical-axes pytree matching ``init_cache`` output structure."""
        return self._impl.cache_axes()


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# dense / moe / vlm decoder LM
# ---------------------------------------------------------------------------


class _DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.block_defs = block_param_defs(cfg)
        self.has_frontend = bool(cfg.d_frontend)
        self.text_offset = cfg.frontend_tokens if self.has_frontend else 0

    def _defs(self):
        return {
            **_embed_defs(self.cfg),
            "layers": stack_layer_defs(self.block_defs, self.cfg.n_layers),
        }

    def init(self, key):
        defs = self._defs()
        top = {k: v for k, v in defs.items() if k != "layers"}
        k1, k2 = jax.random.split(key)
        params, _ = init_params(top, k1, COMPUTE_DTYPE)
        layers, _ = init_params(defs["layers"], k2, COMPUTE_DTYPE)
        params["layers"] = layers
        return params

    def param_axes(self):
        defs = self._defs()
        axes = {k: v.axes for k, v in defs.items() if k != "layers"}
        axes["layers"] = {k: v.axes for k, v in defs["layers"].items()}
        return axes

    def _embed_input(self, params, batch):
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
        if self.has_frontend:
            front = batch["frontend"].astype(COMPUTE_DTYPE)
            vis = front @ params["projector"].astype(COMPUTE_DTYPE)
            x = jnp.concatenate([vis, x], axis=1)  # early fusion: [vision|text]
        return shard_act(x, ("batch", "act_seq", "act_embed"))

    def forward(self, params, batch, remat: bool = True):
        cfg = self.cfg
        x = self._embed_input(params, batch)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        window = effective_window(cfg, s)
        x, aux = stack_forward(params["layers"], x, cfg, positions, window, remat)
        return _final_norm(x, params, cfg), aux

    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        spec = CacheSpec(
            batch=batch_size,
            max_seq=max_seq,
            n_kv_heads=cfg.n_cache_kv_heads,
            head_dim=cfg.resolved_head_dim,
            window=effective_window(cfg, max_seq),
        )
        return init_kv_cache(spec, cfg.n_layers, COMPUTE_DTYPE)

    def cache_axes(self):
        kv = ("layer", "batch", "cache_seq", "cache_kv_heads", "head_dim")
        return {"k": kv, "v": kv, "length": ()}

    def init_paged_cache(self, batch_size: int, max_seq: int, page_tokens: int, n_pages: int):
        cfg = self.cfg
        if max_seq % page_tokens != 0:
            raise ValueError(
                f"max_seq ({max_seq}) must be divisible by page_tokens ({page_tokens})"
            )
        if effective_window(cfg, max_seq):
            raise ValueError("paged KV does not compose with sliding windows")
        return init_paged_kv_cache(
            n_pages,
            page_tokens,
            batch_size,
            max_seq // page_tokens,
            cfg.n_cache_kv_heads,
            cfg.resolved_head_dim,
            cfg.n_layers,
            COMPUTE_DTYPE,
        )

    def paged_cache_axes(self):
        # pools shard over their page axis the way dense caches shard over
        # batch (sharding/serve.py treats kv_page like batch → "data")
        kv = ("layer", "kv_page", "page_tokens", "cache_kv_heads", "head_dim")
        return {"k": kv, "v": kv, "page_table": ("batch", None), "length": ("batch",)}

    def prefill(self, params, batch, max_seq: int):
        cfg = self.cfg
        x = self._embed_input(params, batch)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        window = effective_window(cfg, max_seq)
        phys = min(max_seq, window) if window else max_seq
        x, _aux, cache = stack_prefill(
            params["layers"], x, cfg, positions, window, phys
        )
        x = _final_norm(x, params, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        last = x[:, -1] @ head.astype(x.dtype)
        return last, cache

    def append_embeds(self, params, frame_embeds, cache, sparse_ctx=None):
        """frame_embeds: (b, n, d_frontend) → projector → n-token cache append.
        Returns (hidden_last, cache, io_latency). Linear caches only."""
        from .transformer import stack_append

        cfg = self.cfg
        if "projector" in params:
            x = frame_embeds.astype(COMPUTE_DTYPE) @ params["projector"].astype(COMPUTE_DTYPE)
        else:
            x = frame_embeds.astype(COMPUTE_DTYPE)
        x, cache, io = stack_append(params["layers"], x, cache, cfg, sparse_ctx)
        return _final_norm(x, params, cfg), cache, io

    def decode_step(self, params, token, cache, sparse_ctx=None):
        logits, cache, io, _ = self.decode_step_planned(params, token, cache, sparse_ctx)
        return logits, cache, jnp.sum(io)

    def decode_step_planned(
        self, params, token, cache, sparse_ctx=None, plan=None, refresh=None
    ):
        """decode_step + chunk-plan state: ``plan`` is the per-(layer, site)
        decode-plan carry (see SparseExecution.init_plan), ``refresh`` a
        scalar bool selecting recompute-vs-reuse. Returns (logits, cache,
        io (n_layers,) per-layer estimate vector, plan)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], token, axis=0).astype(COMPUTE_DTYPE)  # (b,1,d)
        if "page_table" in cache:
            # paged layout: cache["k"].shape[2] is page_tokens, not the
            # physical length — the shape-based window sniff below would
            # misfire. Paged KV never composes with sliding windows.
            window = None
        else:
            # window semantics are baked into the cache's physical length
            phys = cache["k"].shape[2]
            window = cfg.sliding_window if (cfg.sliding_window and phys == cfg.sliding_window) else None
        x, cache, io, plan = stack_decode(
            params["layers"], x, cache, cfg, window, sparse_ctx,
            plan=plan, refresh=refresh,
        )
        x = _final_norm(x, params, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = (x[:, 0] @ head.astype(x.dtype)).astype(jnp.float32)
        logits = shard_act(logits, ("batch", "vocab"))
        return logits, cache, io, plan


# ---------------------------------------------------------------------------
# zamba2 hybrid: scanned mamba2 groups + one shared attention/MLP block
# ---------------------------------------------------------------------------


class _Zamba:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mcfg = Mamba2Config(
            d_model=cfg.d_model,
            d_state=cfg.ssm_state,
            d_conv=cfg.ssm_conv,
            expand=cfg.ssm_expand,
            head_dim=cfg.ssm_head_dim,
        )
        k = cfg.attn_every
        self.group_size = k
        self.n_groups = cfg.n_layers // k  # groups followed by shared attn
        self.n_tail = cfg.n_layers - self.n_groups * k
        self.text_offset = 0
        # shared transformer block operates on d_model with MHA + SwiGLU
        self.shared_defs = block_param_defs(
            dataclasses.replace(cfg, n_experts=0, arch_type="dense")
        )
        self.mamba_defs = mamba2_param_defs(self.mcfg)
        self.mamba_norm = {"mnorm_w": ParamDef((cfg.d_model,), ("embed",), init="ones")}

    def _defs(self):
        layer_defs = {**self.mamba_defs, **self.mamba_norm}
        grouped = stack_layer_defs(stack_layer_defs(layer_defs, self.group_size), self.n_groups)
        defs = {
            **_embed_defs(self.cfg),
            "mamba_groups": grouped,
            "shared": self.shared_defs,
        }
        if self.n_tail:
            defs["mamba_tail"] = stack_layer_defs(layer_defs, self.n_tail)
        return defs

    def init(self, key):
        defs = self._defs()
        keys = jax.random.split(key, len(defs))
        params = {}
        for (name, d), k in zip(sorted(defs.items()), keys):
            if isinstance(d, dict):
                params[name], _ = init_params(d, k, COMPUTE_DTYPE)
            else:
                params[name] = d.make(k, COMPUTE_DTYPE)
        return params

    def param_axes(self):
        defs = self._defs()
        return {
            name: ({k: v.axes for k, v in d.items()} if isinstance(d, dict) else d.axes)
            for name, d in defs.items()
        }

    def _mamba_layer(self, layer_params, x):
        from .common import rms_norm

        h = rms_norm(x, layer_params["mnorm_w"])
        return x + mamba2_forward(h, layer_params, self.mcfg)

    def _shared_attn(self, params, x, positions, window):
        out, _, _ = block_forward(params["shared"], x, self.cfg, positions, window)
        return out

    def forward(self, params, batch, remat: bool = True):
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(COMPUTE_DTYPE)
        x = shard_act(x, ("batch", "act_seq", "act_embed"))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        window = effective_window(cfg, s)

        def group_body(h, group_params):
            def inner(h2, lp):
                return (
                    jax.checkpoint(self._mamba_layer)(lp, h2) if remat else self._mamba_layer(lp, h2)
                ), None

            h, _ = jax.lax.scan(inner, h, group_params)
            h = self._shared_attn(params, h, positions, window)
            return h, None

        x, _ = jax.lax.scan(group_body, x, params["mamba_groups"])
        if self.n_tail:
            def inner(h2, lp):
                return self._mamba_layer(lp, h2), None

            x, _ = jax.lax.scan(inner, x, params["mamba_tail"])
        return _final_norm(x, params, cfg), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_seq: int):
        cfg, m = self.cfg, self.mcfg
        window = effective_window(cfg, max_seq)
        phys = min(max_seq, window) if window else max_seq

        def stacked_state(n):
            st = mamba2_state_init(m, batch_size, COMPUTE_DTYPE)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), st
            )

        cache = {
            "mamba_groups": stacked_state(self.n_groups * self.group_size),
            "attn_k": jnp.zeros(
                (self.n_groups, batch_size, phys, cfg.n_kv_heads, cfg.resolved_head_dim),
                COMPUTE_DTYPE,
            ),
            "attn_v": jnp.zeros(
                (self.n_groups, batch_size, phys, cfg.n_kv_heads, cfg.resolved_head_dim),
                COMPUTE_DTYPE,
            ),
            "length": jnp.zeros((), jnp.int32),
        }
        if self.n_tail:
            cache["mamba_tail"] = stacked_state(self.n_tail)
        return cache

    def cache_axes(self):
        mstate = {
            "conv": ("layer", "batch", None, "conv_dim"),
            "ssm": ("layer", "batch", "ssm_heads", None, None),
        }
        kv = ("layer", "batch", "cache_seq", "cache_kv_heads", "head_dim")
        axes = {
            "mamba_groups": mstate,
            "attn_k": kv,
            "attn_v": kv,
            "length": (),
        }
        if self.n_tail:
            axes["mamba_tail"] = dict(mstate)
        return axes

    def prefill(self, params, batch, max_seq: int):
        """Chunked-SSD prefill: runs the full sequence through every Mamba2
        layer collecting final states, and fills each shared-attn
        application's KV cache."""
        from .transformer import block_prefill

        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(COMPUTE_DTYPE)
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        window = effective_window(cfg, max_seq)
        phys = min(max_seq, window) if window else max_seq

        def mamba_with_state(lp, h):
            from .common import rms_norm

            hn = rms_norm(h, lp["mnorm_w"])
            out, st = mamba2_forward(hn, lp, self.mcfg, return_state=True)
            return h + out, st

        def group_body(h, gp):
            def inner(h2, lp):
                h3, st = mamba_with_state(lp, h2)
                return h3, st

            h, states = jax.lax.scan(inner, h, gp)
            h2, _aux, k, v = block_prefill(
                params["shared"], h, cfg, positions, window, phys
            )
            return h2, (states, k, v)

        x, (gstates, ks, vs) = jax.lax.scan(group_body, x, params["mamba_groups"])
        cache = {
            "mamba_groups": jax.tree.map(
                lambda a: a.reshape((self.n_groups * self.group_size,) + a.shape[2:]),
                gstates,
            ),
            "attn_k": ks,
            "attn_v": vs,
            "length": jnp.int32(s),
        }
        if self.n_tail:
            def inner(h2, lp):
                h3, st = mamba_with_state(lp, h2)
                return h3, st

            x, tail_states = jax.lax.scan(inner, x, params["mamba_tail"])
            cache["mamba_tail"] = tail_states
        x = _final_norm(x, params, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        return x[:, -1] @ head.astype(x.dtype), cache

    def decode_step(self, params, token, cache, sparse_ctx=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], token, axis=0).astype(COMPUTE_DTYPE)
        length = cache["length"]
        window = cfg.sliding_window if cache["attn_k"].shape[2] == cfg.sliding_window else None
        gs, ng = self.group_size, self.n_groups

        group_states = cache["mamba_groups"]
        # reshape stacked (ng*gs, ...) -> (ng, gs, ...)
        group_states = jax.tree.map(
            lambda s: s.reshape((ng, gs) + s.shape[1:]), group_states
        )

        def group_body(carry, layer):
            h = carry
            gp, gstate, lk, lv = layer

            def inner(h2, sl):
                lp, st = sl
                from .common import rms_norm

                hn = rms_norm(h2, lp["mnorm_w"])
                out, st2 = mamba2_decode_step(hn, st, lp, self.mcfg)
                return h2 + out, st2

            h, gstate2 = jax.lax.scan(inner, h, (gp, gstate))
            h2, lk2, lv2, _, _ = block_decode(
                params["shared"], h, lk, lv, length, cfg, window
            )
            return h2, (gstate2, lk2, lv2)

        x, (gstates, ks, vs) = jax.lax.scan(
            group_body,
            x,
            (params["mamba_groups"], group_states, cache["attn_k"], cache["attn_v"]),
        )
        new_cache = dict(cache)
        new_cache["mamba_groups"] = jax.tree.map(
            lambda s: s.reshape((ng * gs,) + s.shape[2:]), gstates
        )
        new_cache["attn_k"], new_cache["attn_v"] = ks, vs
        if self.n_tail:
            def inner(h2, sl):
                lp, st = sl
                from .common import rms_norm

                hn = rms_norm(h2, lp["mnorm_w"])
                out, st2 = mamba2_decode_step(hn, st, lp, self.mcfg)
                return h2 + out, st2

            x, tail_states = jax.lax.scan(
                inner, x, (params["mamba_tail"], cache["mamba_tail"])
            )
            new_cache["mamba_tail"] = tail_states
        new_cache["length"] = length + 1
        x = _final_norm(x, params, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = (x[:, 0] @ head.astype(x.dtype)).astype(jnp.float32)
        return logits, new_cache, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# xLSTM (python loop over 12 heterogeneous blocks)
# ---------------------------------------------------------------------------


class _XLSTM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.xcfg = XLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads)
        self.text_offset = 0

    def _block_kind(self, i: int) -> str:
        return "slstm" if i in self.cfg.slstm_layers else "mlstm"

    def _defs(self):
        defs = {**_embed_defs(self.cfg)}
        for i in range(self.cfg.n_layers):
            kind = self._block_kind(i)
            bdefs = (
                slstm_param_defs(self.xcfg) if kind == "slstm" else mlstm_param_defs(self.xcfg)
            )
            bdefs = {**bdefs, "bnorm_w": ParamDef((self.cfg.d_model,), ("embed",), init="ones")}
            defs[f"block_{i}"] = bdefs
        return defs

    def init(self, key):
        defs = self._defs()
        keys = jax.random.split(key, len(defs))
        params = {}
        for (name, d), k in zip(sorted(defs.items()), keys):
            if isinstance(d, dict):
                params[name], _ = init_params(d, k, COMPUTE_DTYPE)
            else:
                params[name] = d.make(k, COMPUTE_DTYPE)
        return params

    def param_axes(self):
        defs = self._defs()
        return {
            name: ({k: v.axes for k, v in d.items()} if isinstance(d, dict) else d.axes)
            for name, d in defs.items()
        }

    def _run(self, params, x, states=None):
        from .common import rms_norm

        new_states = {}
        for i in range(self.cfg.n_layers):
            bp = params[f"block_{i}"]
            kind = self._block_kind(i)
            h = rms_norm(x, bp["bnorm_w"])
            st = states[f"block_{i}"] if states is not None else None
            if kind == "slstm":
                out, st2 = slstm_forward(h, bp, self.xcfg, state=st)
            else:
                out, st2 = mlstm_forward(h, bp, self.xcfg, state=st)
            x = x + out
            new_states[f"block_{i}"] = st2
        return x, new_states

    def forward(self, params, batch, remat: bool = True):
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(COMPUTE_DTYPE)
        x = shard_act(x, ("batch", "act_seq", "act_embed"))
        x, _ = self._run(params, x)
        return _final_norm(x, params, self.cfg), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_seq: int):
        states = {}
        for i in range(self.cfg.n_layers):
            if self._block_kind(i) == "slstm":
                states[f"block_{i}"] = slstm_state_init(self.xcfg, batch_size)
            else:
                states[f"block_{i}"] = mlstm_state_init(self.xcfg, batch_size)
        states["length"] = jnp.zeros((), jnp.int32)
        return states

    def cache_axes(self):
        axes = {}
        for i in range(self.cfg.n_layers):
            if self._block_kind(i) == "slstm":
                axes[f"block_{i}"] = (
                    ("batch", None),
                    ("batch", None),
                    ("batch", None),
                    ("batch", None),
                )
            else:
                axes[f"block_{i}"] = (
                    ("batch", "heads", None, None),
                    ("batch", "heads", None),
                    ("batch", "heads"),
                )
        axes["length"] = ()
        return axes

    def prefill(self, params, batch, max_seq: int):
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(COMPUTE_DTYPE)
        x, states = self._run(params, x, states=self.init_cache(x.shape[0], max_seq))
        states["length"] = jnp.int32(x.shape[1])
        x = _final_norm(x, params, self.cfg)
        head = params["embed"].T if self.cfg.tie_embeddings else params["head"]
        return x[:, -1] @ head.astype(x.dtype), states

    def decode_step(self, params, token, cache, sparse_ctx=None):
        x = jnp.take(params["embed"], token, axis=0).astype(COMPUTE_DTYPE)
        states = {k: v for k, v in cache.items() if k != "length"}
        x, new_states = self._run(params, x, states=states)
        new_states["length"] = cache["length"] + 1
        x = _final_norm(x, params, self.cfg)
        head = params["embed"].T if self.cfg.tie_embeddings else params["head"]
        logits = (x[:, 0] @ head.astype(x.dtype)).astype(jnp.float32)
        return logits, new_states, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# whisper-style encoder-decoder (audio)
# ---------------------------------------------------------------------------


class _Whisper:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.text_offset = 0
        dense_cfg = dataclasses.replace(cfg, arch_type="dense")
        self.dec_defs = {
            **block_param_defs(dense_cfg),
            # cross-attention sublayer (x_wk/x_wv consumed building enc_kv)
            **{
                f"x_{k}": v
                for k, v in block_param_defs(dense_cfg).items()
                if k in ("wq", "wk", "wv", "wo")
            },
            "ln_x_w": ParamDef((cfg.d_model,), ("embed",), init="ones"),
            "ln_x_b": ParamDef((cfg.d_model,), ("embed",), init="zeros"),
        }
        self.enc_defs = block_param_defs(dense_cfg)

    def _defs(self):
        cfg = self.cfg
        return {
            **_embed_defs(cfg),
            "pos_embed_dec": ParamDef((4096, cfg.d_model), (None, "embed"), scale=0.01),
            "frontend_proj": ParamDef((cfg.d_frontend, cfg.d_model), (None, "embed")),
            "enc_final_w": ParamDef((cfg.d_model,), ("embed",), init="ones"),
            "enc_final_b": ParamDef((cfg.d_model,), ("embed",), init="zeros"),
            "encoder": stack_layer_defs(self.enc_defs, cfg.encoder_layers),
            "decoder": stack_layer_defs(self.dec_defs, cfg.n_layers),
        }

    def init(self, key):
        defs = self._defs()
        keys = jax.random.split(key, len(defs))
        params = {}
        for (name, d), k in zip(sorted(defs.items()), keys):
            if isinstance(d, dict):
                params[name], _ = init_params(d, k, COMPUTE_DTYPE)
            else:
                params[name] = d.make(k, COMPUTE_DTYPE)
        return params

    def param_axes(self):
        defs = self._defs()
        return {
            name: ({k: v.axes for k, v in d.items()} if isinstance(d, dict) else d.axes)
            for name, d in defs.items()
        }

    def _dec_pos(self, params, s: int):
        """Decoder absolute positions; indexed modulo the table size — the
        assigned 32k/500k decoder contexts exceed Whisper's trained 448
        positions, so the geometry is exercised with wrapped embeddings
        (documented in DESIGN.md §4)."""
        table = params["pos_embed_dec"]
        idx = jnp.arange(s) % table.shape[0]
        return jnp.take(table, idx, axis=0)[None].astype(COMPUTE_DTYPE)

    def _encode(self, params, frontend):
        cfg = self.cfg
        x = frontend.astype(COMPUTE_DTYPE) @ params["frontend_proj"].astype(COMPUTE_DTYPE)
        pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(COMPUTE_DTYPE)
        x = x + pos[None]
        x = shard_act(x, ("batch", "act_seq", "act_embed"))

        def body(h, lp):
            h2 = apply_norm(h, lp, cfg, "ln1")
            attn = multi_head_attention(
                h2, lp, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
                rope_theta=None, causal=False,
            )
            h = h + attn
            h2 = apply_norm(h, lp, cfg, "ln2")
            h = h + gelu_mlp(h2, lp)
            return h, None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        from .common import layer_norm

        return layer_norm(x, params["enc_final_w"], params["enc_final_b"])

    def _decoder_block(self, lp, x, enc_kv, positions, window, cache=None, length=None):
        """One decoder block: self-attn (+cache), cross-attn, MLP."""
        cfg = self.cfg
        if cache is None:
            h = apply_norm(x, lp, cfg, "ln1")
            attn = multi_head_attention(
                h, lp, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
                positions=positions, rope_theta=None, causal=True, window=window,
            )
            x = x + attn
            new_cache = None
        else:
            lk, lv, = cache
            h = apply_norm(x, lp, cfg, "ln1")
            from .attention import cache_layer_update, decode_attention, project_kv_for_decode

            nk, nv = project_kv_for_decode(
                h, lp, cfg.n_kv_heads, cfg.resolved_head_dim, length, None
            )
            lk, lv = cache_layer_update(lk, lv, nk, nv, length, window)
            attn = decode_attention(
                h, lp, lk, lv, length + 1, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, None, window,
            )
            x = x + attn
            new_cache = (lk, lv)

        from .common import layer_norm

        h = layer_norm(x, lp["ln_x_w"], lp["ln_x_b"])
        ek, ev = enc_kv
        cross = multi_head_attention(
            h, lp, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
            rope_theta=None, causal=False, kv_override=(ek, ev), prefix="x_",
        )
        # note: x_wk/x_wv are consumed when building enc_kv, not here
        x = x + cross
        h = apply_norm(x, lp, cfg, "ln2")
        x = x + gelu_mlp(h, lp)
        return x, new_cache

    def _enc_kv(self, lp, enc):
        cfg = self.cfg
        b, sk, _ = enc.shape
        ek = (enc @ lp["x_wk"]).reshape(b, sk, cfg.n_kv_heads, cfg.resolved_head_dim)
        ev = (enc @ lp["x_wv"]).reshape(b, sk, cfg.n_kv_heads, cfg.resolved_head_dim)
        return ek, ev

    def forward(self, params, batch, remat: bool = True):
        cfg = self.cfg
        enc = self._encode(params, batch["frontend"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
        x = x + self._dec_pos(params, s)
        x = shard_act(x, ("batch", "act_seq", "act_embed"))
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        window = effective_window(cfg, s)

        def body(h, lp):
            enc_kv = self._enc_kv(lp, enc)
            h2, _ = self._decoder_block(lp, h, enc_kv, positions, window)
            return h2, None

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, params["decoder"])
        return _final_norm(x, params, cfg), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        window = effective_window(cfg, max_seq)
        phys = min(max_seq, window) if window else max_seq
        shape = (cfg.n_layers, batch_size, phys, cfg.n_kv_heads, cfg.resolved_head_dim)
        enc_shape = (
            cfg.n_layers,
            batch_size,
            cfg.frontend_tokens,
            cfg.n_kv_heads,
            cfg.resolved_head_dim,
        )
        return {
            "k": jnp.zeros(shape, COMPUTE_DTYPE),
            "v": jnp.zeros(shape, COMPUTE_DTYPE),
            "enc_k": jnp.zeros(enc_shape, COMPUTE_DTYPE),
            "enc_v": jnp.zeros(enc_shape, COMPUTE_DTYPE),
            "length": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self):
        kv = ("layer", "batch", "cache_seq", "cache_kv_heads", "head_dim")
        return {"k": kv, "v": kv, "enc_k": kv, "enc_v": kv, "length": ()}

    def prefill(self, params, batch, max_seq: int):
        """Encode audio + prefill decoder with prompt tokens."""
        cfg = self.cfg
        enc = self._encode(params, batch["frontend"])
        cache = self.init_cache(batch["tokens"].shape[0], max_seq)

        def kv_body(_, lp):
            return None, self._enc_kv(lp, enc)

        _, (enc_k, enc_v) = jax.lax.scan(kv_body, None, params["decoder"])
        cache["enc_k"], cache["enc_v"] = enc_k, enc_v

        tokens = batch["tokens"]
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
        x = x + self._dec_pos(params, s)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        window = effective_window(cfg, max_seq)
        phys = cache["k"].shape[2]

        def body(carry, layer):
            h = carry
            lp, ek, ev = layer
            # self-attn prefill (reuse decoder block without cache) + fill cache
            hb = apply_norm(h, lp, cfg, "ln1")
            k = (hb @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.resolved_head_dim)
            v = (hb @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.resolved_head_dim)
            if phys < s:
                k, v = k[:, -phys:], v[:, -phys:]
                pad = 0
            else:
                pad = phys - s
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            h2, _ = self._decoder_block(lp, h, (ek, ev), positions, window)
            return h2, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["decoder"], enc_k, enc_v))
        cache["k"], cache["v"] = ks, vs
        cache["length"] = jnp.int32(s)
        x = _final_norm(x, params, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        return x[:, -1] @ head.astype(x.dtype), cache

    def decode_step(self, params, token, cache, sparse_ctx=None):
        cfg = self.cfg
        length = cache["length"]
        x = jnp.take(params["embed"], token, axis=0).astype(COMPUTE_DTYPE)
        pos_emb = jax.lax.dynamic_slice(
            params["pos_embed_dec"], (length % params["pos_embed_dec"].shape[0], 0), (1, cfg.d_model)
        )
        x = x + pos_emb[None].astype(COMPUTE_DTYPE)
        phys = cache["k"].shape[2]
        window = cfg.sliding_window if (cfg.sliding_window and phys == cfg.sliding_window) else None

        def body(carry, layer):
            h, _io = carry
            lp, lk, lv, ek, ev = layer
            h2, (lk2, lv2) = self._decoder_block(
                lp, h, (ek, ev), None, window, cache=(lk, lv), length=length
            )
            return (h2, _io), (lk2, lv2)

        (x, _), (ks, vs) = jax.lax.scan(
            body,
            (x, jnp.float32(0.0)),
            (params["decoder"], cache["k"], cache["v"], cache["enc_k"], cache["enc_v"]),
        )
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = ks, vs
        new_cache["length"] = length + 1
        x = _final_norm(x, params, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = (x[:, 0] @ head.astype(x.dtype)).astype(jnp.float32)
        return logits, new_cache, jnp.float32(0.0)
