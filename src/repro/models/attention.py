"""GQA attention with RoPE, sliding windows, blockwise (flash-style) softmax,
and KV caches (linear + rotating-window).

Used by every attention-bearing architecture (dense, vlm, moe, zamba2 shared
block, whisper). The blockwise path keeps peak activation memory bounded for
32k-token prefill on the production mesh (online softmax over kv blocks,
scanned q blocks) — functionally identical to naive attention (tested).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard_act
from .common import ParamDef, apply_rope

NEG_INF = -1e30


def attention_param_defs(
    d_model: int, n_heads: int, n_kv_heads: int, head_dim: int, prefix: str = ""
) -> Dict[str, ParamDef]:
    p = prefix
    return {
        f"{p}wq": ParamDef((d_model, n_heads * head_dim), ("embed", "heads")),
        f"{p}wk": ParamDef((d_model, n_kv_heads * head_dim), ("embed", "kv_heads")),
        f"{p}wv": ParamDef((d_model, n_kv_heads * head_dim), ("embed", "kv_heads")),
        f"{p}wo": ParamDef((n_heads * head_dim, d_model), ("heads", "embed")),
    }


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(b, s, kv, hd) -> (b, s, kv * n_rep, hd) by head repetition."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd))
    return x.reshape(b, s, kv * n_rep, hd)


def _direct_attention(
    q: jnp.ndarray,  # (b, sq, h, hd)
    k: jnp.ndarray,  # (b, sk, h, hd)
    v: jnp.ndarray,
    mask: Optional[jnp.ndarray],  # (sq, sk) or (b, sq, sk) bool
) -> jnp.ndarray:
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        scores = jnp.where(m[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _blockwise_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_offset,
    causal: bool,
    window: Optional[int],
    block_q: int = 512,
    block_kv: int = 1024,
) -> jnp.ndarray:
    """Flash-style online-softmax attention, O(block_q*block_kv) score memory.

    q: (b, sq, h, hd); k/v: (b, sk, h, hd). Causal offset: query i has
    absolute position i + q_offset; key j has absolute position j.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd**-0.5
    # pad to block multiples
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_kv

    q_blocks = qp.reshape(b, nq, block_q, h, hd).transpose(1, 0, 3, 2, 4)  # (nq,b,h,bq,hd)
    k_blocks = kp.reshape(b, nk, block_kv, h, hd).transpose(1, 0, 3, 2, 4)
    v_blocks = vp.reshape(b, nk, block_kv, h, hd).transpose(1, 0, 3, 2, 4)

    def q_block_body(qi, qb):
        qb32 = qb.astype(jnp.float32) * scale  # (b,h,bq,hd)
        q_pos = qi * block_q + jnp.arange(block_q) + q_offset  # (bq,)

        def kv_body(carry, inputs):
            m_prev, l_prev, acc = carry
            ki, kb, vb = inputs
            k_pos = ki * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb32, kb.astype(jnp.float32))
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            mask &= (k_pos < sk)[None, :]  # kv padding
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_prev * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        acc0 = jnp.zeros((b, h, block_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, acc0), (jnp.arange(nk), k_blocks, v_blocks)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (b,h,bq,hd)

    outs = jax.lax.map(lambda args: q_block_body(*args), (jnp.arange(nq), q_blocks))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * block_q, h, hd)
    return out[:, :sq].astype(q.dtype)


def _want_seq_shard_map(n_heads: int, q_shape) -> bool:
    from ..sharding import current_rules

    rules = current_rules()
    if rules is None or not getattr(rules, "seq_shard_attention", False):
        return False
    tp = rules.axis_size("model")
    dp = rules.axis_size(rules.rules.get("batch"))
    b, s = q_shape[0], q_shape[1]
    return (
        tp > 1
        and n_heads % tp != 0
        and s > 2048
        and s % tp == 0
        and b % dp == 0
    )


def _seq_sharded_attention(q, k, v, window):
    """Causal attention with the q-sequence explicitly sharded over 'model'
    (shard_map); kv replicated across the model axis (one all-gather)."""
    import jax.experimental.shard_map as shmap
    from jax.sharding import PartitionSpec as P

    from ..sharding import current_rules

    rules = current_rules()
    mesh = rules.mesh
    dp_axis = rules.rules.get("batch")
    tp = rules.axis_size("model")
    s = q.shape[1]
    s_local = s // tp

    q_spec = P(dp_axis, "model", None, None)
    kv_spec = P(dp_axis, None, None, None)

    def local(qb, kb, vb):
        import jax as _jax

        shard = _jax.lax.axis_index("model")
        q_offset = shard * s_local  # absolute position of this shard's row 0
        return _blockwise_attention(qb, kb, vb, q_offset, True, window)

    return shmap.shard_map(
        local,
        mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec),
        out_specs=q_spec,
        check_rep=False,
    )(q, k, v)


def multi_head_attention(
    x: jnp.ndarray,  # (b, s, d)
    params: Dict[str, jnp.ndarray],
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions: Optional[jnp.ndarray] = None,  # (b, s) absolute positions
    rope_theta: Optional[float] = 10000.0,
    causal: bool = True,
    window: Optional[int] = None,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn
    blockwise_threshold: int = 2048,
    prefix: str = "",
    project_out: bool = True,
) -> jnp.ndarray:
    """Full attention sublayer (projections + SDPA). Returns (b, s, d), or the
    pre-o-projection (b, s, h*hd) when project_out=False (sparse exec masks
    the o-projection's input rows per paper App. A)."""
    b, s, d = x.shape
    p = prefix
    q = (x @ params[f"{p}wq"]).reshape(b, s, n_heads, head_dim)
    if kv_override is None:
        k = (x @ params[f"{p}wk"]).reshape(b, s, n_kv_heads, head_dim)
        v = (x @ params[f"{p}wv"]).reshape(b, s, n_kv_heads, head_dim)
        if rope_theta is not None:
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
    else:
        k, v = kv_override  # pre-projected encoder states (b, sk, kv, hd)
    # seq ("act_seq") is a FALLBACK target: it picks up the model axis only
    # when the head count doesn't divide it (e.g. starcoder2's 24/36 heads on
    # a 16-way mesh) — otherwise heads claim it first (§Perf iteration C).
    q = shard_act(q, ("batch", "act_seq", "heads", "head_dim"))
    k = shard_act(k, ("batch", None, "kv_heads", "head_dim"))
    v = shard_act(v, ("batch", None, "kv_heads", "head_dim"))

    n_rep = n_heads // k.shape[2]
    k, v = repeat_kv(k, n_rep), repeat_kv(v, n_rep)

    sk = k.shape[1]
    if causal and kv_override is None and _want_seq_shard_map(n_heads, q.shape):
        # §Perf iteration C: head count doesn't divide the model axis —
        # instead of letting GSPMD replicate the whole attention per device,
        # explicitly shard the q-sequence over 'model' with shard_map; each
        # shard runs blockwise attention for its s/tp rows against full kv.
        out = _seq_sharded_attention(q, k, v, window)
    elif max(s, sk) > blockwise_threshold:
        q_offset = jnp.int32(sk - s) if causal else jnp.int32(0)
        out = _blockwise_attention(q, k, v, q_offset, causal, window)
    else:
        mask = None
        if causal:
            qi = jnp.arange(s)[:, None] + (sk - s)
            kj = jnp.arange(sk)[None, :]
            mask = kj <= qi
            if window is not None:
                mask &= kj > qi - window
        out = _direct_attention(q, k, v, mask)
    out = out.reshape(b, s, n_heads * head_dim)
    if not project_out:
        return out
    return out @ params[f"{p}wo"]


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static geometry of one layer's KV cache.

    ``window`` caps physical length (rotating writes) — this is what makes
    dense architectures runnable at the 524k-token shape (DESIGN.md §4).
    """

    batch: int
    max_seq: int
    n_kv_heads: int
    head_dim: int
    window: Optional[int] = None

    @property
    def physical_len(self) -> int:
        return min(self.max_seq, self.window) if self.window else self.max_seq


def init_kv_cache(spec: CacheSpec, n_layers: int, dtype) -> Dict[str, jnp.ndarray]:
    shape = (n_layers, spec.batch, spec.physical_len, spec.n_kv_heads, spec.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # number of tokens ever written (logical length)
        "length": jnp.zeros((), jnp.int32),
    }


def init_paged_kv_cache(
    n_pages: int,
    page_tokens: int,
    batch: int,
    max_pages: int,
    n_kv_heads: int,
    head_dim: int,
    n_layers: int,
    dtype,
) -> Dict[str, jnp.ndarray]:
    """Paged twin of ``init_kv_cache``: per-layer page POOLS plus a shared
    per-slot page table. ``max_pages * page_tokens`` equals the logical
    ``max_seq`` — the gathered view has exactly the dense cache's physical
    shape, which is what keeps paged decode byte-identical to dense.

    Pools are zero-initialised so unreferenced pages hold finite values:
    masked attention positions then contribute exact 0.0 probability times
    finite garbage — bitwise zero, same as the dense path's zero slots.
    Table rows start at ``GARBAGE_PAGE`` (page 0, core/paged_kv.py): free
    slots scatter there harmlessly and no live table ever reads it."""
    pool = (n_layers, n_pages, page_tokens, n_kv_heads, head_dim)
    return {
        "k": jnp.zeros(pool, dtype),
        "v": jnp.zeros(pool, dtype),
        "page_table": jnp.zeros((batch, max_pages), jnp.int32),
        # per-slot logical lengths (continuous batching: independent rows)
        "length": jnp.zeros((batch,), jnp.int32),
    }


def gather_paged_kv(pool_layer: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Materialise one layer's dense-view cache through the page table.

    ``pool_layer`` (n_pages, page_tokens, kv, hd) gathered by ``table``
    (b, max_pages) → (b, max_pages·page_tokens, kv, hd): positionally
    identical to the dense (b, P, kv, hd) layer cache, so the downstream
    ``decode_attention`` reduction tree — and therefore every bit of its
    output — is unchanged. Positions past a slot's logical length read
    whatever page the table maps (garbage page for unmapped tail entries);
    the validity mask zeroes their probabilities exactly."""
    b, max_pages = table.shape
    _, page_tokens, n_kv, head_dim = pool_layer.shape
    gathered = jnp.take(pool_layer, table, axis=0)  # (b, mp, pt, kv, hd)
    return gathered.reshape(b, max_pages * page_tokens, n_kv, head_dim)


def scatter_paged_kv(
    pool_layer: jnp.ndarray,  # (n_pages, page_tokens, kv, hd)
    dense_layer: jnp.ndarray,  # (b, P, kv, hd) gathered view AFTER update
    table: jnp.ndarray,  # (b, max_pages) int32
    length: jnp.ndarray,  # (b,) per-slot position the new entry was written at
) -> jnp.ndarray:
    """Write each slot's newly-decoded cache entry back into its page.

    The decode write position is ``min(length, P-1)`` — the same clamp as
    ``cache_layer_update`` — and always lands in a slot-private page (the
    partial prompt tail or a decode-grown page; full shared prefix pages
    are immutable by the pool's sharing discipline, which for exactly this
    reason keeps the final page of a ``max_seq``-length prompt private and
    unregistered: the clamp targets position ``max_seq - 1`` inside it),
    so cross-slot scatter collisions only occur on the garbage page, which
    nothing reads."""
    b, phys = dense_layer.shape[:2]
    page_tokens = pool_layer.shape[1]
    rows = jnp.arange(b)
    pos = jnp.minimum(length, phys - 1)
    page = table[rows, pos // page_tokens]
    return pool_layer.at[page, pos % page_tokens].set(dense_layer[rows, pos])


def cache_layer_update(
    layer_k: jnp.ndarray,  # (b, P, kv, hd) one layer's cache
    layer_v: jnp.ndarray,
    new_k: jnp.ndarray,  # (b, 1, kv, hd) decode step
    new_v: jnp.ndarray,
    length: jnp.ndarray,  # tokens already in cache: scalar, or (b,) per-slot
    window: Optional[int],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    phys = layer_k.shape[1]
    slot = length % phys if window else jnp.minimum(length, phys - 1)
    if length.ndim == 0:
        k = jax.lax.dynamic_update_slice(layer_k, new_k, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(layer_v, new_v, (0, slot, 0, 0))
    else:
        # continuous batching: each batch row is an independent request with
        # its own write position
        rows = jnp.arange(layer_k.shape[0])
        k = layer_k.at[rows, slot].set(new_k[:, 0])
        v = layer_v.at[rows, slot].set(new_v[:, 0])
    return k, v


def decode_attention(
    x: jnp.ndarray,  # (b, 1, d)
    params: Dict[str, jnp.ndarray],
    layer_k: jnp.ndarray,  # (b, P, kv, hd) cache AFTER update
    layer_v: jnp.ndarray,
    length: jnp.ndarray,  # logical length INCLUDING current token; () or (b,)
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float],
    window: Optional[int],
    prefix: str = "",
    project_out: bool = True,
    q: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Single-token attention against a (possibly rotating) cache.

    ``length`` may be a scalar (whole batch at the same position) or a (b,)
    vector (continuous batching: one independent request per batch row).

    ``q``: optional precomputed q projection (b, 1, n_heads*head_dim) —
    the planned decode path computes it through the execution backend
    (kernel chunk gather / reference twin) instead of the dense matmul
    here; RoPE still applies below either way."""
    b, one, d = x.shape
    p = prefix
    phys = layer_k.shape[1]
    if q is None:
        q = x @ params[f"{p}wq"]
    q = q.reshape(b, 1, n_heads, head_dim)
    if rope_theta is not None:
        pos = jnp.broadcast_to(jnp.reshape(length - 1, (-1, 1)), (b, 1))
        q = apply_rope(q, pos, rope_theta)
    q = shard_act(q, ("batch", None, "heads", "head_dim"))

    n_rep = n_heads // n_kv_heads
    k = repeat_kv(layer_k, n_rep)  # (b, P, h, hd)
    v = repeat_kv(layer_v, n_rep)
    scale = head_dim**-0.5
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    # valid slots: < length (linear) — rotation makes all slots valid once full
    slot_idx = jnp.arange(phys)[None, :]  # (1, P)
    len_col = jnp.reshape(length, (-1, 1))  # (1, 1) or (b, 1)
    valid = slot_idx < len_col
    if window:
        # rotating cache: slots hold the last min(length, phys) tokens
        valid = slot_idx < jnp.minimum(len_col, phys)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(b, 1, n_heads * head_dim)
    if not project_out:
        return out
    return out @ params[f"{p}wo"]


def append_attention(
    x: jnp.ndarray,  # (b, n, d) new tokens (VLM frame append: n = tokens/frame)
    params: Dict[str, jnp.ndarray],
    layer_k: jnp.ndarray,  # (b, P, kv, hd) LINEAR cache (no window rotation)
    layer_v: jnp.ndarray,
    length: jnp.ndarray,  # tokens in cache BEFORE this call
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float],
    kv_replicate: int = 1,
    prefix: str = "",
    project_out: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Multi-token cache-extending attention (the paper's frame-append stage).

    Returns (out, new_k_cache, new_v_cache). Linear caches only.
    """
    b, n, d = x.shape
    p = prefix
    phys = layer_k.shape[1]
    positions = length[None, None] + jnp.arange(n)[None, :]  # (1, n) bcast
    positions = jnp.broadcast_to(positions.reshape(1, n), (b, n))
    q = (x @ params[f"{p}wq"]).reshape(b, n, n_heads, head_dim)
    k = (x @ params[f"{p}wk"]).reshape(b, n, n_kv_heads, head_dim)
    v = (x @ params[f"{p}wv"]).reshape(b, n, n_kv_heads, head_dim)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if kv_replicate > 1:
        k, v = repeat_kv(k, kv_replicate), repeat_kv(v, kv_replicate)
    slots = length + jnp.arange(n)
    layer_k = layer_k.at[:, slots].set(k)
    layer_v = layer_v.at[:, slots].set(v)

    n_rep = n_heads // (n_kv_heads * kv_replicate)
    kk = repeat_kv(layer_k, n_rep)
    vv = repeat_kv(layer_v, n_rep)
    scale = head_dim**-0.5
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    slot_idx = jnp.arange(phys)[None, :]  # key position = slot (linear cache)
    q_pos = (length + jnp.arange(n))[:, None]
    valid = slot_idx <= q_pos  # causal within the append + all history
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(b, n, n_heads * head_dim)
    if project_out:
        out = out @ params[f"{p}wo"]
    return out, layer_k, layer_v


def project_kv_for_decode(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    n_kv_heads: int,
    head_dim: int,
    length: jnp.ndarray,
    rope_theta: Optional[float],
    prefix: str = "",
    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``kv``: optional precomputed (k, v) projections (each (b, 1,
    n_kv_heads*head_dim)) from the planned decode path's execution-backend
    dispatch; RoPE on k still applies below either way."""
    b = x.shape[0]
    p = prefix
    if kv is None:
        k = x @ params[f"{p}wk"]
        v = x @ params[f"{p}wv"]
    else:
        k, v = kv
    k = k.reshape(b, 1, n_kv_heads, head_dim)
    v = v.reshape(b, 1, n_kv_heads, head_dim)
    if rope_theta is not None:
        pos = jnp.broadcast_to(jnp.reshape(length, (-1, 1)), (b, 1))
        k = apply_rope(k, pos, rope_theta)
    return k, v
