"""Shared model building blocks: params-as-pytrees, norms, init helpers.

Params are plain nested dicts of jnp arrays. Every leaf has a parallel
"logical axes" annotation (same tree structure, tuples of logical axis names)
produced by the same spec tables that drive initialization, so sharding rules
can never drift from parameter shapes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
Axes = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter's shape, logical axes, and init scale."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: Optional[float] = None  # override stddev for "normal"

    def make(self, key: jax.Array, dtype) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "small_normal":
            std = self.scale if self.scale is not None else 0.02
            return (jax.random.normal(key, self.shape) * std).astype(dtype)
        # fan-in scaled normal (truncation unnecessary for repro purposes)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape) * std).astype(dtype)


def init_params(
    defs: Dict[str, ParamDef], key: jax.Array, dtype
) -> Tuple[Params, Axes]:
    """Instantiate a flat table of ParamDefs → (params, logical axes)."""
    keys = jax.random.split(key, max(len(defs), 1))
    params: Params = {}
    axes: Axes = {}
    for (name, d), k in zip(sorted(defs.items()), keys):
        params[name] = d.make(k, dtype)
        axes[name] = d.axes
    return params, axes


def stack_layer_defs(defs: Dict[str, ParamDef], n_layers: int) -> Dict[str, ParamDef]:
    """Prepend a scan 'layer' dim to every ParamDef (scan-over-layers)."""
    return {
        name: ParamDef(
            shape=(n_layers,) + d.shape,
            axes=("layer",) + d.axes,
            init=d.init,
            scale=d.scale,
        )
        for name, d in defs.items()
    }


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(
    x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def swish(x: jnp.ndarray) -> jnp.ndarray:
    return x * jax.nn.sigmoid(x)


def sinusoidal_positions(n_pos: int, dim: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings (n_pos, dim)."""
    pos = np.arange(n_pos)[:, None]
    idx = np.arange(dim // 2)[None, :]
    angles = pos / np.power(10000.0, 2 * idx / dim)
    emb = np.concatenate([np.sin(angles), np.cos(angles)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
) -> jnp.ndarray:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_mask(q_len: int, kv_len: int, q_offset) -> jnp.ndarray:
    """(q_len, kv_len) bool mask; q token i attends kv j iff j <= i + offset."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    return kj <= qi
