from .model import Model, build_model, effective_window, COMPUTE_DTYPE
